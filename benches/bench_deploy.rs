//! Deploy-path microbenchmarks: bit-packing, weight decode, the kernel
//! layer (blocked GEMM vs the naive oracle), and the packed inference
//! engine (the new serve hot path) with its per-op compute split.
//!
//!     cargo bench --bench bench_deploy
//!     cargo bench --bench bench_deploy -- --smoke   # CI: tiny iteration
//!                                                   # counts, asserts the
//!                                                   # cross-path goldens
//!                                                   # (mlp AND the lenet5
//!                                                   # conv path)
//!
//! Hand-rolled harness (no criterion in the offline vendor set), same
//! reporting as bench_hot_paths: warmup, then timed repetitions with
//! mean / min / p50. No artifacts needed — the engine is pure host code.

use std::sync::Arc;
use std::time::Instant;

use cgmq::bench_harness::{
    pool_bench_engine, router_bench, synthetic_deploy_state, RouterBenchSpec,
    SyntheticDeployState, DEPLOY_LEVELS,
};
use cgmq::deploy::reference::fake_quant_logits;
use cgmq::deploy::{BatchConfig, DecodeMode, Engine, PackedModel, PoolConfig, RequestBatcher};
use cgmq::model::{lenet5, mlp};

fn bench(name: &str, iters: usize, mut f: impl FnMut()) {
    for _ in 0..iters.div_ceil(10).max(1) {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    let mean: f64 = times.iter().sum::<f64>() / times.len() as f64;
    println!(
        "{name:<44} {:>10.3} ms/iter (min {:>8.3}, p50 {:>8.3}, n={})",
        1e3 * mean,
        1e3 * times[0],
        1e3 * times[times.len() / 2],
        iters
    );
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--test");
    let scale = if smoke { 1 } else { 10 };
    println!("== cgmq deploy microbenchmarks{} ==\n", if smoke { " (smoke)" } else { "" });

    let arch = mlp();
    let SyntheticDeployState { params, betas_w, betas_a, gates } =
        synthetic_deploy_state(&arch, &DEPLOY_LEVELS, 7);

    // --- packing / decode ---
    bench("deploy: PackedModel::from_state (mlp)", 2 * scale, || {
        std::hint::black_box(
            PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap(),
        );
    });
    let model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
    bench("deploy: encode .cgmqm (mlp)", 5 * scale, || {
        std::hint::black_box(model.encode().unwrap());
    });
    bench("deploy: decode_weights fc1 (100k codes)", 5 * scale, || {
        std::hint::black_box(model.decode_weights(0).unwrap());
    });

    // --- the engine hot path ---
    let data = cgmq::data::Dataset::synth(3, 64);
    let in_len = arch.input_len();
    let one = &data.images[..in_len];
    let streaming = Engine::new(model.clone()).unwrap().with_mode(DecodeMode::Streaming);
    bench("deploy: Engine::infer b=1 (mlp, streaming)", 5 * scale, || {
        std::hint::black_box(streaming.infer(one).unwrap());
    });
    let cached = Engine::new(model.clone()).unwrap();
    bench("deploy: Engine::infer_batch b=64 (unpack)", 5 * scale, || {
        std::hint::black_box(cached.infer_batch(&data.images, 64).unwrap());
    });
    bench("deploy: reference fake-quant fwd b=64", 2 * scale, || {
        let logits =
            fake_quant_logits(&arch, &params, &betas_w, &betas_a, &gates, &data.images, 64);
        std::hint::black_box(logits.unwrap());
    });

    // --- the kernel layer: blocked GEMM vs the naive oracle. The timing
    // gap is the blocking win; the bit-equality assert is the accumulation
    // -order contract (one accumulator per output, k ascending, never
    // split) that keeps every cross-path golden alive. fc2-of-lenet5
    // shape: 50 x 500 weights against a 64-wide panel.
    {
        use cgmq::deploy::kernels::{gemm, gemm_naive};
        let (m, k, n) = (50, 500, 64);
        let mut st = 0x243F_6A88_85A3_08D3u64;
        let mut next = move || {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            (st.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 40) as f32 / 16_777_216.0 - 0.5
        };
        let a: Vec<f32> = (0..m * k).map(|_| next()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| next()).collect();
        let mut c_blocked = vec![0.0f32; m * n];
        let mut c_naive = vec![0.0f32; m * n];
        bench("kernels: gemm blocked 50x500x64", 20 * scale, || {
            gemm(&a, &b, &mut c_blocked, m, k, n);
            std::hint::black_box(&c_blocked);
        });
        bench("kernels: gemm naive   50x500x64", 20 * scale, || {
            gemm_naive(&a, &b, &mut c_naive, m, k, n);
            std::hint::black_box(&c_naive);
        });
        assert!(
            c_blocked.iter().zip(&c_naive).all(|(x, y)| x.to_bits() == y.to_bits()),
            "blocked GEMM drifted from the naive oracle"
        );
        println!("kernels: blocked gemm == naive oracle (bit-for-bit) ✓");
    }

    // --- per-op compute split of the warm engine (the baseline integer
    // SWAR kernels have to beat; decode ~0 after preload) ---
    cached.preload().unwrap();
    let (_, prof) = cached.profile_batch(&data.images, 64).unwrap();
    println!(
        "deploy: per-op split b=64 (mlp)              matmul {:>5.1}% | im2col {:>5.1}% | \
         elem {:>5.1}% | decode {:>5.1}%",
        prof.share_pct(prof.matmul),
        prof.share_pct(prof.im2col),
        prof.share_pct(prof.elementwise),
        prof.share_pct(prof.decode)
    );

    // --- the batched serve path ---
    let mut batcher = RequestBatcher::new(
        Engine::new(model.clone()).unwrap(),
        BatchConfig { max_batch: 16, max_delay: std::time::Duration::from_micros(200) },
    )
    .unwrap();
    bench("deploy: RequestBatcher 64 reqs, b=16", 2 * scale, || {
        let mut done = 0;
        for i in 0..64 {
            let now = Instant::now();
            done += batcher
                .submit_at(data.images[i * in_len..(i + 1) * in_len].to_vec(), now)
                .unwrap()
                .len();
        }
        done += batcher.flush_at(Instant::now()).unwrap().len();
        assert_eq!(done, 64);
    });

    // --- the sharded worker pool: 1 vs 4 workers over one shared engine ---
    let pool_requests = if smoke { 96 } else { 512 };
    let shared = Arc::new(Engine::new(model.clone()).unwrap());
    let bcfg = BatchConfig { max_batch: 16, max_delay: std::time::Duration::from_micros(200) };
    let rps_of = |workers: usize| {
        let j = pool_bench_engine(&shared, pool_requests, workers, bcfg, 11).unwrap();
        let rps = j.get("throughput_rps").unwrap().as_f64().unwrap();
        let p99 = j.get("p99_ms").unwrap().as_f64().unwrap();
        println!(
            "deploy: WorkerPool {pool_requests} reqs, workers={workers:<2}   \
             {rps:>10.1} req/s (p99 {p99:.3} ms)"
        );
        rps
    };
    let pool1 = rps_of(1);
    let pool4 = rps_of(4);
    println!("deploy: pool speedup 4 vs 1 workers          {:>10.2}x", pool4 / pool1);

    // --- the multi-model router: two variants behind one front, bounded
    // queues (tiny cap so the shed path executes), hot swap mid-traffic ---
    let s_b = synthetic_deploy_state(&arch, &DEPLOY_LEVELS, 8);
    let model_b =
        PackedModel::from_state(&arch, &s_b.params, &s_b.betas_w, &s_b.betas_a, &s_b.gates)
            .unwrap();
    let specs = vec![
        RouterBenchSpec {
            key: "mlp-a".into(),
            engine: Arc::new(Engine::new(model.clone()).unwrap()),
            // Hot-swap "mlp-a" to a fresh engine at the halfway mark:
            // exercises spawn-new -> swap -> drain-old under load.
            swap_to: Some(Arc::new(Engine::new(model.clone()).unwrap())),
        },
        RouterBenchSpec {
            key: "mlp-b".into(),
            engine: Arc::new(Engine::new(model_b).unwrap()),
            swap_to: None,
        },
    ];
    let route = router_bench(
        &specs,
        pool_requests,
        PoolConfig { workers: 2, batch: bcfg, queue_cap: 4 },
        11,
    )
    .unwrap();
    println!(
        "deploy: Router {pool_requests} reqs, 2 models, cap=4  {:>10.1} req/s \
         (shed {} of {}, {} swaps)",
        route.get("throughput_rps").unwrap().as_f64().unwrap(),
        route.get("shed").unwrap().as_f64().unwrap(),
        route.get("submitted").unwrap().as_f64().unwrap(),
        route.get("swaps").unwrap().as_f64().unwrap(),
    );

    // --- smoke-mode correctness anchor: engine == fake-quant reference ---
    let engine_logits = cached.infer_batch(&data.images, 64).unwrap();
    let ref_logits =
        fake_quant_logits(&arch, &params, &betas_w, &betas_a, &gates, &data.images, 64).unwrap();
    assert_eq!(engine_logits.len(), ref_logits.len());
    assert!(
        engine_logits.iter().zip(&ref_logits).all(|(a, b)| a.to_bits() == b.to_bits()),
        "packed engine drifted from the fake-quant reference"
    );
    println!("\ncross-path golden: engine logits == fake-quant reference (bit-for-bit) ✓");

    // --- the conv path (lenet5): runs in smoke too (tiny batch) so the
    // im2col + GEMM lowering is timed and golden-anchored in CI ---
    {
        let arch = lenet5();
        let s = synthetic_deploy_state(&arch, &DEPLOY_LEVELS, 7);
        let model =
            PackedModel::from_state(&arch, &s.params, &s.betas_w, &s.betas_a, &s.gates).unwrap();
        let engine = Engine::new(model).unwrap();
        engine.preload().unwrap();
        let nb = if smoke { 2 } else { 8 };
        let data = cgmq::data::Dataset::synth(5, nb);
        bench(&format!("deploy: Engine::infer_batch b={nb} (lenet5)"), 2 * scale, || {
            std::hint::black_box(engine.infer_batch(&data.images, nb).unwrap());
        });
        let (logits, prof) = engine.profile_batch(&data.images, nb).unwrap();
        let want = fake_quant_logits(
            &arch, &s.params, &s.betas_w, &s.betas_a, &s.gates, &data.images, nb,
        )
        .unwrap();
        assert!(
            logits.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()),
            "lenet5 conv engine drifted from the fake-quant reference"
        );
        println!(
            "deploy: per-op split b={nb} (lenet5)           matmul {:>5.1}% | im2col {:>5.1}% | \
             elem {:>5.1}% | decode {:>5.1}%",
            prof.share_pct(prof.matmul),
            prof.share_pct(prof.im2col),
            prof.share_pct(prof.elementwise),
            prof.share_pct(prof.decode)
        );
        println!("cross-path golden: lenet5 conv engine == reference (bit-for-bit) ✓");
    }

    // --- SWAR integer kernels vs the forced-f32 baseline. Same packed
    // model, same plan geometry — only the kernel differs
    // (`KernelSelector { force_f32 }` pins the baseline) — so the ratio
    // is the integer-native win. Each width is golden-anchored against
    // the fake-quant reference and plan-introspected, so the sweep
    // doubles as the CI width-sweep smoke (`make kernel-smoke`).
    {
        use cgmq::bench_harness::uniform_deploy_state;
        use cgmq::deploy::{Kernel, KernelSelector};

        println!("\n== SWAR integer kernels on packed code words ==\n");
        let time_mean = |iters: usize, f: &mut dyn FnMut()| -> f64 {
            for _ in 0..iters.div_ceil(10).max(1) {
                f();
            }
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            t0.elapsed().as_secs_f64() / iters as f64
        };
        let mut mlp4_speedup = None;
        for (arch, nb) in [(mlp(), 64usize), (lenet5(), if smoke { 2 } else { 8 })] {
            let data = cgmq::data::Dataset::synth(29, nb);
            for bits in [2u32, 4, 8] {
                let s = uniform_deploy_state(&arch, bits, 19);
                let model =
                    PackedModel::from_state(&arch, &s.params, &s.betas_w, &s.betas_a, &s.gates)
                        .unwrap();
                let swar = Engine::new(model.clone()).unwrap();
                let f32e = Engine::new_with_selector(
                    model,
                    KernelSelector { force_f32: true },
                )
                .unwrap();
                swar.preload().unwrap();
                f32e.preload().unwrap();
                // Plan introspection: the sweep must actually exercise the
                // width's SWAR kernel (and the baseline must not).
                let expect = match bits {
                    2 => Kernel::Swar2,
                    4 => Kernel::Swar4,
                    _ => Kernel::Swar8,
                };
                for (op, fop) in swar.plan().ops.iter().zip(&f32e.plan().ops) {
                    assert_eq!(op.kernel, expect, "{} {bits}-bit layer {}", arch.name, op.layer);
                    assert_eq!(fop.kernel, Kernel::F32Gemm, "baseline must stay f32");
                }
                // Golden anchor: both paths vs the fake-quant reference —
                // the SWAR path bit-for-bit (the reference mirrors the
                // default selection), the f32 baseline by prediction only
                // (different summation algebra).
                let want = fake_quant_logits(
                    &arch, &s.params, &s.betas_w, &s.betas_a, &s.gates, &data.images, nb,
                )
                .unwrap();
                let got = swar.infer_batch(&data.images, nb).unwrap();
                assert!(
                    got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{} {bits}-bit SWAR engine drifted from the reference",
                    arch.name
                );
                let iters = (5 * scale).max(3);
                let t_swar =
                    time_mean(iters, &mut || {
                        std::hint::black_box(swar.infer_batch(&data.images, nb).unwrap());
                    });
                let t_f32 =
                    time_mean(iters, &mut || {
                        std::hint::black_box(f32e.infer_batch(&data.images, nb).unwrap());
                    });
                let speedup = t_f32 / t_swar;
                println!(
                    "swar: {:<7} {bits}-bit b={nb:<3} Swar{bits} {:>9.3} ms | F32Gemm {:>9.3} ms \
                     | speedup {speedup:>5.2}x",
                    arch.name,
                    1e3 * t_swar,
                    1e3 * t_f32,
                );
                if arch.name == "mlp" && bits == 4 {
                    mlp4_speedup = Some(speedup);
                }
            }
            println!("swar: {} width sweep golden vs reference (bit-for-bit) ✓", arch.name);
        }
        let headline = mlp4_speedup.expect("the sweep always times uniform 4-bit mlp");
        // The acceptance line: integer-native 4-bit beats decoded f32 by
        // >= 1.5x on the uniform mlp. Asserted only in the full run —
        // smoke iteration counts are too small for a stable ratio there
        // (the smoke run still prints it).
        if !smoke {
            assert!(
                headline >= 1.5,
                "uniform 4-bit mlp SWAR speedup {headline:.2}x fell below the 1.5x floor"
            );
        }
        println!("swar: headline uniform 4-bit mlp speedup      {headline:>5.2}x");
    }
}
