//! End-to-end table benchmarks: regenerate the paper's Tables 1-3 (plus the
//! A2 penalty comparison) at CI scale on the MLP arch and time each row.
//!
//!     cargo bench --bench bench_tables              # tables 1-3 + A2
//!     CGMQ_BENCH_ARCH=lenet5 cargo bench --bench bench_tables
//!
//! These are the `benches/` counterparts of the `cgmq table1|2|3|a2` CLI
//! commands (same harness code, smaller defaults so `cargo bench` finishes
//! on one core). The paper-shape assertions at the bottom make this a
//! regression gate, not just a timer: the tightest bound must be satisfied
//! with near-floor RBOP, and every row must respect its bound.

use std::time::Instant;

use cgmq::bench_harness;
use cgmq::config::Config;
use cgmq::gates::Granularity;

fn base_cfg() -> Config {
    Config {
        arch: std::env::var("CGMQ_BENCH_ARCH").unwrap_or_else(|_| "mlp".into()),
        train_size: 2_000,
        test_size: 512,
        pretrain_epochs: 3,
        range_epochs: 1,
        cgmq_epochs: 10,
        gate_lr_scale: 10.0, // schedule-compensated gate lr (Config docs)
        out_dir: "runs/bench_tables".into(),
        ..Config::default()
    }
}

fn main() -> anyhow::Result<()> {
    if !cgmq::runtime::default_artifact_dir().join("manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let cfg = base_cfg();
    println!(
        "== table benches: arch={} train={} cgmq_epochs={} ==\n",
        cfg.arch, cfg.train_size, cfg.cgmq_epochs
    );

    let t0 = Instant::now();
    let table1 = bench_harness::table1(&cfg)?;
    let t1_secs = t0.elapsed().as_secs_f64();
    println!("{table1}");
    println!("[table1 regenerated in {t1_secs:.1}s]\n");

    let t0 = Instant::now();
    let table2 = bench_harness::table_sweep(&cfg, Granularity::Layer)?;
    let t2_secs = t0.elapsed().as_secs_f64();
    println!("{table2}");
    println!("[table2 regenerated in {t2_secs:.1}s]\n");

    let t0 = Instant::now();
    let table3 = bench_harness::table_sweep(&cfg, Granularity::Individual)?;
    let t3_secs = t0.elapsed().as_secs_f64();
    println!("{table3}");
    println!("[table3 regenerated in {t3_secs:.1}s]\n");

    let t0 = Instant::now();
    let a2 = bench_harness::penalty_comparison(&cfg, &[0.01, 0.1, 1.0])?;
    println!("{a2}");
    println!("[A2 regenerated in {:.1}s]\n", t0.elapsed().as_secs_f64());

    // Paper-shape regression checks from the emitted JSON.
    let dir = std::path::Path::new(&cfg.out_dir);
    for table in ["table1.json", "table2.json", "table3.json"] {
        let j = cgmq::util::json::parse_file(&dir.join(table))?;
        for row in j.as_arr()? {
            if row.opt("bound_rbop_percent").is_some() {
                let bound = row.get("bound_rbop_percent")?.as_f64()?;
                let rbop = row.get("rbop_percent")?.as_f64()?;
                if row.get("satisfied")?.as_bool()? {
                    assert!(
                        rbop <= bound + 1e-9,
                        "{table}: {} claims satisfaction but violates bound ({rbop} > {bound})",
                        row.get("run_id")?.as_str()?
                    );
                } else {
                    // honest-unsatisfied row: only legal within 50% of the
                    // bound (the CI-schedule asymptote), never a blowup.
                    println!(
                        "  note: {} ended unsatisfied at {rbop:.3}% (bound {bound}%) — CI horizon",
                        row.get("run_id")?.as_str()?
                    );
                    assert!(rbop <= bound * 1.5 + 1e-9);
                }
            }
        }
    }
    println!("all rows satisfy their bounds — paper-shape check OK");
    Ok(())
}
