//! Hot-path microbenchmarks (L3 + artifact execution).
//!
//!     cargo bench --bench bench_hot_paths
//!
//! Hand-rolled harness (no criterion in the offline vendor set): warmup,
//! then timed repetitions with mean / min / p50 reported. These cover the
//! per-step costs of the CGMQ loop in the order they occur:
//! gate materialization -> literal marshalling + XLA step -> dir
//! computation -> gate GD -> BOP accounting (epoch end).

use std::time::Instant;

use cgmq::cost::model_bops;
use cgmq::data::{Batcher, Dataset};
use cgmq::direction::{dir_tensor_w, DirConfig, DirKind, Sat};
use cgmq::gates::{GateSet, Granularity};
use cgmq::model::{lenet5, mlp};
use cgmq::quant::gated_quantize_tensor;
use cgmq::runtime::{Arg, ArtifactSet};
use cgmq::tensor::{Tensor, TensorI32};
use cgmq::util::rng::SplitMix64;

fn bench(name: &str, iters: usize, mut f: impl FnMut()) {
    // warmup
    for _ in 0..iters.div_ceil(10).max(1) {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    let mean: f64 = times.iter().sum::<f64>() / times.len() as f64;
    println!(
        "{name:<44} {:>10.3} ms/iter (min {:>8.3}, p50 {:>8.3}, n={})",
        1e3 * mean,
        1e3 * times[0],
        1e3 * times[times.len() / 2],
        iters
    );
}

fn main() {
    println!("== cgmq hot-path microbenchmarks ==\n");
    let arch = lenet5();
    let mut rng = SplitMix64::new(0);

    // --- host-side quantizer mirror (export/BOP path) ---
    let w = Tensor::he_normal(&[800, 500], 800, &mut rng);
    let g = {
        let data: Vec<f32> = (0..800 * 500).map(|_| rng.uniform(0.5, 5.5) as f32).collect();
        Tensor::new(vec![800, 500], data).unwrap()
    };
    bench("quant::gated_quantize_tensor (400k elems)", 20, || {
        std::hint::black_box(gated_quantize_tensor(&w, &g, 1.0, true));
    });

    // --- gate materialization (every step, both granularities) ---
    let gates_layer = GateSet::new(&arch, Granularity::Layer);
    let gates_indiv = GateSet::new(&arch, Granularity::Individual);
    bench("gates::materialize_all (lenet5, layer)", 50, || {
        std::hint::black_box(gates_layer.materialize_all_w(&arch));
        std::hint::black_box(gates_layer.materialize_all_a(&arch));
    });
    bench("gates::materialize_all (lenet5, indiv)", 50, || {
        std::hint::black_box(gates_indiv.materialize_all_w(&arch));
        std::hint::black_box(gates_indiv.materialize_all_a(&arch));
    });

    // --- dir computation (every step) ---
    let cfg = DirConfig::new(DirKind::Dir3);
    let grad = Tensor::he_normal(&[800, 500], 800, &mut rng);
    let store = Tensor::full(&[800, 500], 3.0);
    bench("direction::dir_tensor_w (400k, indiv)", 50, || {
        std::hint::black_box(
            dir_tensor_w(&cfg, Granularity::Individual, Sat::Unsatisfied, &grad, &w, &store)
                .unwrap(),
        );
    });

    // --- BOP accounting (every epoch end) ---
    let gw = gates_indiv.materialize_all_w(&arch);
    let ga = gates_indiv.materialize_all_a(&arch);
    bench("cost::model_bops (lenet5, indiv)", 50, || {
        std::hint::black_box(model_bops(&arch, &gw, &ga).unwrap());
    });

    // --- data pipeline ---
    let data = Dataset::synth(0, 2_048);
    let mut batcher = Batcher::new(2_048, 128, 7);
    bench("data::Batcher::epoch (2048 samples, b=128)", 30, || {
        std::hint::black_box(batcher.epoch(&data));
    });
    bench("data::synth::render_digit", 200, || {
        std::hint::black_box(cgmq::data::synth::render_digit(1, 5));
    });

    // --- artifact execution (the XLA step itself) ---
    let dir = cgmq::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("\n(artifacts missing — skipping XLA execution benches; run `make artifacts`)");
        return;
    }
    let mut set = ArtifactSet::open(&dir).unwrap();
    for arch in [mlp(), lenet5()] {
        set.load(&format!("{}_qat_step", arch.name)).unwrap();
        set.load(&format!("{}_eval", arch.name)).unwrap();
        let params = arch.init_params(1);
        let n = arch.train_batch;
        let data = Dataset::synth(3, n);
        let mut x_shape = vec![n];
        x_shape.extend_from_slice(&arch.input_shape);
        let x = Tensor::new(x_shape, data.images.clone()).unwrap();
        let y = TensorI32::new(vec![n], data.labels.clone()).unwrap();
        let bw = Tensor::full(&[arch.layers.len()], 1.0);
        let ba = Tensor::full(&[arch.n_quant_act()], 6.0);
        let gates = GateSet::new(&arch, Granularity::Individual);
        let gw = gates.materialize_all_w(&arch);
        let ga = gates.materialize_all_a(&arch);
        let exe = set.get(&format!("{}_qat_step", arch.name)).unwrap();
        bench(&format!("runtime: {}_qat_step (b=128)", arch.name), 12, || {
            let mut args: Vec<Arg> = params.iter().map(Arg::F32).collect();
            args.push(Arg::F32(&bw));
            args.push(Arg::F32(&ba));
            args.extend(gw.iter().map(Arg::F32));
            args.extend(ga.iter().map(Arg::F32));
            args.push(Arg::F32(&x));
            args.push(Arg::I32(&y));
            std::hint::black_box(exe.run(&args).unwrap());
        });
    }
}
