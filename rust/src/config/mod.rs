//! Config system: typed run configuration loaded from TOML files.
//!
//! Every experiment is a `Config`; the `configs/` directory ships the
//! CI-scale default, the paper-scale schedule and the table sweeps.
//! Unknown keys are rejected (typos fail loudly), all values are validated
//! (learning rates positive, bound feasible for the arch, etc.).

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::direction::DirKind;
use crate::gates::Granularity;
use crate::util::toml::{Doc, Value};

/// Where training data comes from.
#[derive(Debug, Clone, PartialEq)]
pub enum DataSource {
    /// Procedural SynthMNIST (DESIGN.md §2 substitution).
    Synth,
    /// Real MNIST IDX files from a directory.
    Mnist(String),
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    // [run]
    pub arch: String,
    pub seed: u64,
    pub out_dir: String,
    pub artifacts_dir: String,

    // [data]
    pub data: DataSource,
    pub train_size: usize,
    pub test_size: usize,

    // [schedule] — paper §4.2: 250 float + 1 calibrate + 20 range + 250 CGMQ
    pub pretrain_epochs: usize,
    pub range_epochs: usize,
    pub cgmq_epochs: usize,

    // [optim] — paper §4.2
    pub lr_weights: f32,
    pub lr_gates: f32,
    /// Multiplier applied to the paper's gate lr by the bench harness.
    /// The paper's schedule is 250 epochs x 469 batches (~117k gate steps);
    /// CI-scale schedules have ~100x fewer steps, so the gate descent is
    /// compensated by scaling the lr — the guarantee (dir sign correctness)
    /// is lr-independent, only the horizon changes. Paper-scale configs
    /// keep this at 1.0.
    pub gate_lr_scale: f32,
    /// Momentum of the running-mean range calibration (paper §2.4: 0.1).
    pub calib_momentum: f32,

    // [quant]
    pub granularity: Granularity,
    pub direction: DirKind,
    pub gate_init: f32,
    pub dir_clip_min: f32,
    pub dir_clip_max: f32,

    // [constraint]
    pub bound_rbop_percent: f64,
}

impl Default for Config {
    /// CI-scale defaults: small SynthMNIST, short schedule, paper optimizer
    /// settings. The paper-scale schedule lives in configs/paper_scale.toml.
    fn default() -> Self {
        Self {
            arch: "lenet5".into(),
            seed: 42,
            out_dir: "runs/default".into(),
            artifacts_dir: "artifacts".into(),
            data: DataSource::Synth,
            train_size: 8_000,
            test_size: 2_000,
            pretrain_epochs: 12,
            range_epochs: 2,
            cgmq_epochs: 20,
            lr_weights: 1e-3,
            lr_gates: 1e-2,
            gate_lr_scale: 1.0,
            calib_momentum: 0.1,
            granularity: Granularity::Layer,
            direction: DirKind::Dir1,
            gate_init: crate::GATE_INIT,
            dir_clip_min: 1e-6,
            dir_clip_max: 1e3,
            bound_rbop_percent: 0.40,
        }
    }
}

impl Config {
    /// Load from a TOML file, starting from defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let doc = crate::util::toml::parse_file(path)?;
        Self::from_doc(&doc).with_context(|| format!("in config {}", path.display()))
    }

    pub fn from_doc(doc: &Doc) -> Result<Self> {
        let mut cfg = Config::default();
        let mut known: BTreeSet<&str> = BTreeSet::new();
        let mut take = |key: &'static str| -> Option<&Value> {
            known.insert(key);
            doc.get(key)
        };

        if let Some(v) = take("run.arch") {
            cfg.arch = v.as_str()?.to_string();
        }
        if let Some(v) = take("run.seed") {
            cfg.seed = v.as_i64()? as u64;
        }
        if let Some(v) = take("run.out_dir") {
            cfg.out_dir = v.as_str()?.to_string();
        }
        if let Some(v) = take("run.artifacts") {
            cfg.artifacts_dir = v.as_str()?.to_string();
        }
        let mnist_dir = take("data.mnist_dir").map(|v| v.as_str().map(str::to_string)).transpose()?;
        if let Some(v) = take("data.source") {
            cfg.data = match v.as_str()? {
                "synth" => DataSource::Synth,
                "mnist" => DataSource::Mnist(
                    mnist_dir.clone().context("data.source = \"mnist\" needs data.mnist_dir")?,
                ),
                other => bail!("unknown data.source '{other}'"),
            };
        }
        if let Some(v) = take("data.train_size") {
            cfg.train_size = v.as_i64()? as usize;
        }
        if let Some(v) = take("data.test_size") {
            cfg.test_size = v.as_i64()? as usize;
        }
        if let Some(v) = take("schedule.pretrain_epochs") {
            cfg.pretrain_epochs = v.as_i64()? as usize;
        }
        if let Some(v) = take("schedule.range_epochs") {
            cfg.range_epochs = v.as_i64()? as usize;
        }
        if let Some(v) = take("schedule.cgmq_epochs") {
            cfg.cgmq_epochs = v.as_i64()? as usize;
        }
        if let Some(v) = take("optim.lr_weights") {
            cfg.lr_weights = v.as_f64()? as f32;
        }
        if let Some(v) = take("optim.lr_gates") {
            cfg.lr_gates = v.as_f64()? as f32;
        }
        if let Some(v) = take("optim.calib_momentum") {
            cfg.calib_momentum = v.as_f64()? as f32;
        }
        if let Some(v) = take("optim.gate_lr_scale") {
            cfg.gate_lr_scale = v.as_f64()? as f32;
        }
        if let Some(v) = take("quant.granularity") {
            cfg.granularity = Granularity::parse(v.as_str()?)?;
        }
        if let Some(v) = take("quant.direction") {
            cfg.direction = DirKind::parse(v.as_str()?)?;
        }
        if let Some(v) = take("quant.gate_init") {
            cfg.gate_init = v.as_f64()? as f32;
        }
        if let Some(v) = take("quant.dir_clip_min") {
            cfg.dir_clip_min = v.as_f64()? as f32;
        }
        if let Some(v) = take("quant.dir_clip_max") {
            cfg.dir_clip_max = v.as_f64()? as f32;
        }
        if let Some(v) = take("constraint.bound_rbop_percent") {
            cfg.bound_rbop_percent = v.as_f64()?;
        }

        // reject unknown keys (typos)
        for key in doc.keys() {
            if !known.contains(key.as_str()) {
                bail!("unknown config key '{key}'");
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        crate::model::arch_by_name(&self.arch)?;
        if self.train_size == 0 || self.test_size == 0 {
            bail!("train_size/test_size must be positive");
        }
        if self.lr_weights <= 0.0 || self.lr_gates <= 0.0 {
            bail!("learning rates must be positive");
        }
        if self.gate_lr_scale <= 0.0 {
            bail!("gate_lr_scale must be positive");
        }
        if !(0.0..1.0).contains(&self.calib_momentum) {
            bail!("calib_momentum must be in [0, 1)");
        }
        if self.dir_clip_min <= 0.0 || self.dir_clip_max <= self.dir_clip_min {
            bail!("dir clip bounds must satisfy 0 < min < max");
        }
        if self.bound_rbop_percent <= 0.0 || self.bound_rbop_percent > 100.0 {
            bail!("bound_rbop_percent must be in (0, 100]");
        }
        let arch = crate::model::arch_by_name(&self.arch)?;
        let c = crate::cost::CostConstraint::new(self.bound_rbop_percent);
        if !c.is_feasible(&arch) {
            bail!(
                "bound {}% is below the no-pruning floor {:.4}% for {}",
                self.bound_rbop_percent,
                crate::cost::rbop_percent(&arch, crate::cost::floor_bops(&arch)),
                self.arch
            );
        }
        Ok(())
    }

    /// The paper's learning-rate convention: dir3 uses 0.001, dir1/dir2 0.01
    /// (Section 4.2). Applied when the config doesn't override lr_gates.
    pub fn paper_gate_lr(direction: DirKind) -> f32 {
        match direction {
            DirKind::Dir3 => 1e-3,
            _ => 1e-2,
        }
    }

    /// Short human id for logs/outputs: "lenet5-dir1-layer-b0.40".
    pub fn run_id(&self) -> String {
        format!(
            "{}-{}-{}-b{:.2}",
            self.arch,
            self.direction.label(),
            self.granularity.label(),
            self.bound_rbop_percent
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let doc = crate::util::toml::parse(
            r#"
[run]
arch = "mlp"
seed = 7
[data]
source = "synth"
train_size = 1000
test_size = 200
[schedule]
pretrain_epochs = 2
cgmq_epochs = 5
[optim]
lr_gates = 0.001
[quant]
granularity = "individual"
direction = "dir3"
[constraint]
bound_rbop_percent = 1.4
"#,
        )
        .unwrap();
        let cfg = Config::from_doc(&doc).unwrap();
        assert_eq!(cfg.arch, "mlp");
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.granularity, Granularity::Individual);
        assert_eq!(cfg.direction, DirKind::Dir3);
        assert_eq!(cfg.bound_rbop_percent, 1.4);
        assert_eq!(cfg.run_id(), "mlp-dir3-indiv-b1.40");
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = crate::util::toml::parse("[run]\narch = \"mlp\"\ntypo_key = 1\n").unwrap();
        let err = Config::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("typo_key"), "{err}");
    }

    #[test]
    fn infeasible_bound_rejected() {
        let doc = crate::util::toml::parse("[constraint]\nbound_rbop_percent = 0.1\n").unwrap();
        let err = Config::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("floor"), "{err}");
    }

    #[test]
    fn mnist_source_needs_dir() {
        let doc = crate::util::toml::parse("[data]\nsource = \"mnist\"\n").unwrap();
        assert!(Config::from_doc(&doc).is_err());
        let doc2 = crate::util::toml::parse(
            "[data]\nsource = \"mnist\"\nmnist_dir = \"/data/mnist\"\n",
        )
        .unwrap();
        let cfg = Config::from_doc(&doc2).unwrap();
        assert_eq!(cfg.data, DataSource::Mnist("/data/mnist".into()));
    }

    #[test]
    fn bad_values_rejected() {
        for text in [
            "[optim]\nlr_weights = 0.0\n",
            "[data]\ntrain_size = 0\n",
            "[quant]\ndirection = \"dir9\"\n",
            "[quant]\ngranularity = \"channel\"\n",
            "[constraint]\nbound_rbop_percent = 150.0\n",
        ] {
            let doc = crate::util::toml::parse(text).unwrap();
            assert!(Config::from_doc(&doc).is_err(), "{text}");
        }
    }

    #[test]
    fn paper_gate_lr_convention() {
        assert_eq!(Config::paper_gate_lr(DirKind::Dir1), 0.01);
        assert_eq!(Config::paper_gate_lr(DirKind::Dir2), 0.01);
        assert_eq!(Config::paper_gate_lr(DirKind::Dir3), 0.001);
    }
}
