//! The rule families of `cgmq analyze`.
//!
//! Every rule is deny-by-default: a hit is a [`Finding`] unless the line
//! carries an `analyze-allow: <rule-id> <reason>` annotation (same line or
//! the comment run directly above). The catalog:
//!
//! * `panic-hygiene` — no `.unwrap()` / `.expect(` / `panic!` /
//!   `unreachable!` / `todo!` / `unimplemented!` / `dbg!` in the serving
//!   hot-path files under `src/deploy/` — the engine, the compiled
//!   `plan.rs`, every kernel under `kernels/`, the batcher/pool/router
//!   and the network front; everything except the load-time `format.rs`
//!   and the test-oracle `reference.rs`. A connection worker, batcher
//!   loop, plan build or GEMM inner loop that can panic turns one bad
//!   request into a dead thread.
//! * `atomic-ordering` — every `Ordering::` use, crate-wide, must carry an
//!   `// ordering:` justification on the same line or directly above. The
//!   choice of memory ordering is exactly the kind of invariant that looks
//!   arbitrary to the next editor unless the reasoning is pinned to the
//!   site.
//! * `atomic-seqcst` — `Ordering::SeqCst` inside the named hot functions
//!   of `src/deploy/` is flagged: on the per-request path the full fence
//!   is either load-bearing (then it deserves an explicit allow with the
//!   protocol written down) or an accidental default.
//! * `lock-scope` — a lock-guard binding whose (linearly approximated)
//!   scope also contains a blocking call or a second lock acquisition.
//!   These are the deadlock / latency-collapse shapes the `Server` pump
//!   and connection workers must never grow.
//! * `counter-choke` — `fetch_add`/`fetch_sub` on the named stats counters
//!   (`depth`, `outstanding`, `served`) outside their choke-point
//!   functions. The `submitted == accepted + shed` accounting survives
//!   only while every mutation goes through the single admission/delivery
//!   sites.
//! * `taxonomy-sync` — the non-200 status codes `deploy/net/http.rs` can
//!   emit must match the machine-checked taxonomy table in README.md
//!   (between the `analyze:taxonomy` markers).
//! * `metrics-name-sync` — the `cgmq_*` metric names
//!   `deploy/telemetry.rs` (and its `telemetry/window.rs` submodule)
//!   emits on `/metrics` must match the machine-checked table in
//!   README.md (between the `analyze:metrics` markers); both drift
//!   directions are findings, same contract as `taxonomy-sync`.
//! * `bad-allow` — an `analyze-allow:` annotation naming an unknown rule
//!   or missing a reason (typo guard: a misspelled allow must not silently
//!   disable nothing).

use super::scan::{allowed, has_marker, parse_allows, ScannedFile, SourceLine};
use super::Finding;

/// Rule ids, as they appear in findings and allow annotations.
pub const RULE_PANIC: &str = "panic-hygiene";
pub const RULE_ORDERING: &str = "atomic-ordering";
pub const RULE_SEQCST: &str = "atomic-seqcst";
pub const RULE_LOCK: &str = "lock-scope";
pub const RULE_COUNTER: &str = "counter-choke";
pub const RULE_TAXONOMY: &str = "taxonomy-sync";
pub const RULE_METRICS: &str = "metrics-name-sync";
pub const RULE_BAD_ALLOW: &str = "bad-allow";

/// Every known rule id (what `bad-allow` validates against).
pub const ALL_RULES: [&str; 8] = [
    RULE_PANIC,
    RULE_ORDERING,
    RULE_SEQCST,
    RULE_LOCK,
    RULE_COUNTER,
    RULE_TAXONOMY,
    RULE_METRICS,
    RULE_BAD_ALLOW,
];

/// Tokens the panic rule refuses in hot-path files.
const PANIC_TOKENS: [&str; 7] = [
    ".unwrap()",
    ".expect(",
    "panic!(",
    "unreachable!(",
    "todo!(",
    "unimplemented!(",
    "dbg!(",
];

/// Functions on the per-request path, where a `SeqCst` fence needs an
/// explicit justification-by-allow rather than being the default.
const HOT_FNS: [&str; 11] = [
    "admit",
    "worker_loop",
    "connection_loop",
    "accept_loop",
    "sweep",
    "pump_loop",
    "await_completion",
    "submit",
    "run_flush",
    "poll_at",
    "submit_at",
];

/// Calls that block the current thread. A live lock guard over any of
/// these is the latency/deadlock shape the rule exists for. Condvar
/// `wait`/`wait_timeout` are deliberately absent: they release the guard.
const BLOCKING_TOKENS: [&str; 7] = [
    ".recv()",
    ".recv(",
    ".recv_timeout(",
    ".accept(",
    "read_to_end(",
    "read_exact(",
    "::sleep(",
];

/// The stats counters and the only functions allowed to mutate them.
/// The telemetry counters (`cells` through `req_seq`) are the spine of
/// the `/metrics` accounting — same single-mutation-site contract as the
/// routing counters above them. `hits` is the windowed ring's slot
/// counter (`telemetry/window.rs`): the lazy-rotation protocol is only
/// sound while every mutation goes through `record`.
const COUNTER_CHOKES: [(&str, &[&str]); 10] = [
    ("depth", &["admit", "worker_loop"]),
    ("outstanding", &["submit", "await_completion"]),
    ("served", &["await_completion"]),
    ("cells", &["record"]),
    ("recorded", &["record"]),
    ("sum_us", &["record"]),
    ("slots", &["observe"]),
    ("hits", &["record"]),
    ("connections", &["count_connection"]),
    ("req_seq", &["next_request_id"]),
];

fn in_deploy(path: &str) -> bool {
    path.contains("src/deploy/")
}

fn panic_scope(path: &str) -> bool {
    in_deploy(path) && !path.ends_with("format.rs") && !path.ends_with("reference.rs")
}

/// Run every per-file rule over one scanned file.
pub fn check_file(file: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    out.extend(bad_allows(file));
    out.extend(panic_hygiene(file));
    out.extend(atomic_ordering(file));
    out.extend(atomic_seqcst(file));
    out.extend(lock_scope(file));
    out.extend(counter_choke(file));
    out
}

fn finding(
    file: &ScannedFile,
    line: &SourceLine,
    rule: &'static str,
    message: String,
    hint: &str,
) -> Finding {
    Finding {
        rule,
        file: file.path.clone(),
        line: line.number,
        message,
        hint: hint.to_string(),
    }
}

fn bad_allows(file: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for line in &file.lines {
        for (rule, reason) in parse_allows(&line.comment) {
            if !ALL_RULES.contains(&rule.as_str()) {
                out.push(finding(
                    file,
                    line,
                    RULE_BAD_ALLOW,
                    format!("analyze-allow names unknown rule '{rule}'"),
                    "valid rules: panic-hygiene, atomic-ordering, atomic-seqcst, \
                     lock-scope, counter-choke, taxonomy-sync, metrics-name-sync",
                ));
            } else if reason.is_empty() {
                out.push(finding(
                    file,
                    line,
                    RULE_BAD_ALLOW,
                    format!("analyze-allow for '{rule}' has no reason"),
                    "write `// analyze-allow: <rule> <why this site is exempt>`",
                ));
            }
        }
    }
    out
}

fn panic_hygiene(file: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    if !panic_scope(&file.path) {
        return out;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for token in PANIC_TOKENS {
            if line.code.contains(token) && !allowed(&file.lines, idx, RULE_PANIC) {
                out.push(finding(
                    file,
                    line,
                    RULE_PANIC,
                    format!("'{token}' in a deploy hot path"),
                    "return a typed error (bail!/ok_or_else) so one bad request \
                     cannot kill a serving thread, or allowlist with a reason",
                ));
            }
        }
    }
    out
}

fn atomic_ordering(file: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test || !line.code.contains("Ordering::") {
            continue;
        }
        if has_marker(&file.lines, idx, "ordering:")
            || allowed(&file.lines, idx, RULE_ORDERING)
        {
            continue;
        }
        out.push(finding(
            file,
            line,
            RULE_ORDERING,
            "atomic access without an `// ordering:` justification".to_string(),
            "state why this memory ordering is correct on the same line or \
             the comment directly above (e.g. `// ordering: relaxed — pure \
             counter, no synchronization edge`)",
        ));
    }
    out
}

fn atomic_seqcst(file: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    if !in_deploy(&file.path) {
        return out;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test || !line.code.contains("Ordering::SeqCst") {
            continue;
        }
        let hot = line.fn_name.as_deref().map(|f| HOT_FNS.contains(&f)).unwrap_or(false);
        if !hot || allowed(&file.lines, idx, RULE_SEQCST) {
            continue;
        }
        let f = line.fn_name.as_deref().unwrap_or("?");
        out.push(finding(
            file,
            line,
            RULE_SEQCST,
            format!("SeqCst on the hot path (fn {f})"),
            "use Relaxed/Acquire/Release with an `// ordering:` argument, or \
             allowlist with the protocol that needs the full fence",
        ));
    }
    out
}

/// A guard the lock rule is tracking.
struct Guard {
    name: String,
    depth: usize,
    line: usize,
}

fn lock_scope(file: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    if !in_deploy(&file.path) {
        return out;
    }
    let mut guards: Vec<Guard> = Vec::new();
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        // `drop(name)` ends a guard's scope on the spot.
        guards.retain(|g| !line.code.contains(&format!("drop({})", g.name)));
        let has_lock = line.code.contains("lock(") || line.code.contains(".lock()");
        if let Some(oldest) = guards.first() {
            if has_lock && !allowed(&file.lines, idx, RULE_LOCK) {
                out.push(finding(
                    file,
                    line,
                    RULE_LOCK,
                    format!(
                        "second lock acquisition while guard '{}' (line {}) is live",
                        oldest.name, oldest.line
                    ),
                    "nested locks deadlock the moment another path takes them \
                     in the other order; drop the first guard, or allowlist \
                     with the documented acquisition order",
                ));
            }
            for token in BLOCKING_TOKENS {
                if line.code.contains(token) && !allowed(&file.lines, idx, RULE_LOCK) {
                    out.push(finding(
                        file,
                        line,
                        RULE_LOCK,
                        format!(
                            "blocking call '{token}' while guard '{}' (line {}) is live",
                            oldest.name, oldest.line
                        ),
                        "blocking under a lock stalls every other thread on \
                         that mutex; move the call outside the guard's scope",
                    ));
                    break;
                }
            }
        }
        if let Some(name) = lock_binding(&line.code) {
            guards.push(Guard { name, depth: line.depth_after, line: line.number });
        }
        // Block exit closes every guard declared deeper than where we are.
        guards.retain(|g| g.depth <= line.depth_after);
    }
    out
}

/// `let [mut] <name> = ...lock(...)...;` on one line. A linear
/// approximation: the guard is assumed live until `drop(<name>)` or the
/// end of its block, whichever the scan sees first.
fn lock_binding(code: &str) -> Option<String> {
    let t = code.trim_start();
    let rest = t.strip_prefix("let ")?;
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let name: String =
        rest.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
    if name.is_empty() {
        return None;
    }
    let rhs = code.split_once('=').map(|(_, r)| r)?;
    if rhs.contains("lock(") || rhs.contains(".lock()") {
        Some(name)
    } else {
        None
    }
}

fn counter_choke(file: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    if !in_deploy(&file.path) {
        return out;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for op in [".fetch_add(", ".fetch_sub("] {
            let Some(pos) = line.code.find(op) else { continue };
            let receiver = receiver_before(&line.code, pos);
            for (counter, allowed_fns) in COUNTER_CHOKES {
                if !receiver.contains(counter) {
                    continue;
                }
                let ok = line
                    .fn_name
                    .as_deref()
                    .map(|f| allowed_fns.contains(&f))
                    .unwrap_or(false);
                if ok || allowed(&file.lines, idx, RULE_COUNTER) {
                    continue;
                }
                out.push(finding(
                    file,
                    line,
                    RULE_COUNTER,
                    format!(
                        "direct {} on counter '{counter}' outside {:?} (in fn {})",
                        op.trim_matches(|c| c == '.' || c == '('),
                        allowed_fns,
                        line.fn_name.as_deref().unwrap_or("?"),
                    ),
                    "stats counters are only coherent because every mutation \
                     goes through the admission/delivery choke points; route \
                     this update through them instead of a new call site",
                ));
            }
        }
    }
    out
}

/// The dotted receiver expression ending right before byte `pos`.
fn receiver_before(code: &str, pos: usize) -> &str {
    let bytes = code.as_bytes();
    let mut start = pos;
    while start > 0 {
        let c = bytes[start - 1] as char;
        if c.is_alphanumeric() || matches!(c, '_' | '.' | '[' | ']') {
            start -= 1;
        } else {
            break;
        }
    }
    &code[start..pos]
}

// ---------------------------------------------------------------------------
// taxonomy-sync
// ---------------------------------------------------------------------------

/// Begin/end markers of the machine-checked README taxonomy table.
pub const TAXONOMY_BEGIN: &str = "<!-- analyze:taxonomy:begin -->";
pub const TAXONOMY_END: &str = "<!-- analyze:taxonomy:end -->";

/// Compare the non-200 status codes `http.rs` can emit (the `Status::code`
/// match arms) against the codes the README taxonomy table documents
/// (`**NNN**` between the markers). Either direction of drift is a
/// finding.
pub fn check_taxonomy(
    http_path: &str,
    http_src: &str,
    readme_path: &str,
    readme_src: &str,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut emitted: Vec<(u16, usize)> = Vec::new();
    for (idx, line) in http_src.lines().enumerate() {
        if !line.contains("Status::") || !line.contains("=>") {
            continue;
        }
        if let Some(code) = trailing_code(line) {
            if code != 200 && !emitted.iter().any(|(c, _)| *c == code) {
                emitted.push((code, idx + 1));
            }
        }
    }
    let begin = readme_src.find(TAXONOMY_BEGIN);
    let end = readme_src.find(TAXONOMY_END);
    let (Some(begin), Some(end)) = (begin, end) else {
        out.push(Finding {
            rule: RULE_TAXONOMY,
            file: readme_path.to_string(),
            line: 1,
            message: format!(
                "README has no '{TAXONOMY_BEGIN}' ... '{TAXONOMY_END}' block"
            ),
            hint: "wrap the status-code taxonomy table in the analyze markers \
                   so it stays machine-checked against http.rs"
                .to_string(),
        });
        return out;
    };
    let marker_line = readme_src[..begin].lines().count() + 1;
    let mut documented: Vec<u16> = Vec::new();
    let table = &readme_src[begin..end];
    let bytes = table.as_bytes();
    let mut i = 0;
    while let Some(pos) = table[i..].find("**") {
        let at = i + pos + 2;
        let digits: String = table[at..].chars().take_while(|c| c.is_ascii_digit()).collect();
        if digits.len() == 3 && table[at + 3..].starts_with("**") {
            if let Ok(code) = digits.parse::<u16>() {
                if !documented.contains(&code) {
                    documented.push(code);
                }
            }
        }
        i = at.min(bytes.len());
    }
    for (code, line) in &emitted {
        if !documented.contains(code) {
            out.push(Finding {
                rule: RULE_TAXONOMY,
                file: http_path.to_string(),
                line: *line,
                message: format!("status {code} is emitted but absent from the README taxonomy"),
                hint: format!("add a **{code}** row to the table between the analyze markers"),
            });
        }
    }
    for code in &documented {
        if !emitted.iter().any(|(c, _)| c == code) {
            out.push(Finding {
                rule: RULE_TAXONOMY,
                file: readme_path.to_string(),
                line: marker_line,
                message: format!("README documents status {code} but http.rs never emits it"),
                hint: "remove the stale row (or wire the status into Status::code)".to_string(),
            });
        }
    }
    out
}

/// The integer right after `=> ` on a `Status::X => NNN,` match-arm line.
fn trailing_code(line: &str) -> Option<u16> {
    let after = line.split("=>").nth(1)?.trim();
    let digits: String = after.chars().take_while(|c| c.is_ascii_digit()).collect();
    if digits.len() == 3 {
        digits.parse().ok()
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// metrics-name-sync
// ---------------------------------------------------------------------------

/// Begin/end markers of the machine-checked README metric-name table.
pub const METRICS_BEGIN: &str = "<!-- analyze:metrics:begin -->";
pub const METRICS_END: &str = "<!-- analyze:metrics:end -->";

/// Maximal `cgmq_[a-z0-9_]+` runs in `text`, first-occurrence line
/// numbers attached, deduplicated. With `strip_comments`, everything from
/// the first `//` of a line on is ignored — on the source side the metric
/// names live in string literals, and prose mentioning a retired name
/// must not keep it alive.
fn metric_names(text: &str, strip_comments: bool) -> Vec<(String, usize)> {
    let mut out: Vec<(String, usize)> = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = if strip_comments {
            raw.split("//").next().unwrap_or(raw)
        } else {
            raw
        };
        let mut i = 0;
        while let Some(pos) = line[i..].find("cgmq_") {
            let at = i + pos;
            let name: String = line[at..]
                .chars()
                .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '_')
                .collect();
            i = at + name.len().max(1);
            if name.len() > "cgmq_".len() && !out.iter().any(|(n, _)| *n == name) {
                out.push((name, idx + 1));
            }
        }
    }
    out
}

/// Compare the `cgmq_*` metric names `telemetry.rs` defines (each name is
/// a single string literal by construction; `_bucket`/`_sum`/`_count`
/// suffixes are appended via format interpolation and never appear as
/// literals) against the names the README table documents between the
/// `analyze:metrics` markers. Either direction of drift is a finding.
pub fn check_metrics(
    telemetry_path: &str,
    telemetry_src: &str,
    readme_path: &str,
    readme_src: &str,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let emitted = metric_names(telemetry_src, true);
    let begin = readme_src.find(METRICS_BEGIN);
    let end = readme_src.find(METRICS_END);
    let (Some(begin), Some(end)) = (begin, end) else {
        out.push(Finding {
            rule: RULE_METRICS,
            file: readme_path.to_string(),
            line: 1,
            message: format!("README has no '{METRICS_BEGIN}' ... '{METRICS_END}' block"),
            hint: "wrap the metric-name table in the analyze markers so it \
                   stays machine-checked against telemetry.rs"
                .to_string(),
        });
        return out;
    };
    let marker_line = readme_src[..begin].lines().count() + 1;
    let documented = metric_names(&readme_src[begin..end], false);
    for (name, line) in &emitted {
        if !documented.iter().any(|(n, _)| n == name) {
            out.push(Finding {
                rule: RULE_METRICS,
                file: telemetry_path.to_string(),
                line: *line,
                message: format!(
                    "metric '{name}' is emitted but absent from the README table"
                ),
                hint: format!(
                    "add a `{name}` row to the table between the analyze markers"
                ),
            });
        }
    }
    for (name, _) in &documented {
        if !emitted.iter().any(|(n, _)| n == name) {
            out.push(Finding {
                rule: RULE_METRICS,
                file: readme_path.to_string(),
                line: marker_line,
                message: format!(
                    "README documents metric '{name}' but telemetry.rs never defines it"
                ),
                hint: "remove the stale row (or define the metric name in telemetry.rs)"
                    .to_string(),
            });
        }
    }
    out
}
