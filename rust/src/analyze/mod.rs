//! `cgmq-analyze`: a std-only invariant lint pass over this crate's own
//! source.
//!
//! The serving spine rests on hand-maintained concurrency invariants —
//! `submitted == accepted + shed` through single choke points, the atomic
//! orderings on in-flight depth counters, the one-mutex submission front,
//! the documented HTTP status taxonomy. Nothing in the type system checks
//! any of that, so this module does: [`analyze_crate`] token-scans
//! `rust/src` and enforces the rule catalog in [`rules`] deny-by-default,
//! with `analyze-allow: <rule> <reason>` comments as the only escape
//! hatch (and `bad-allow` vetting the escapes themselves).
//!
//! The scanner ([`scan`]) is deliberately not a Rust parser: it
//! understands strings, comments, braces, `#[cfg(test)]` blocks and `fn`
//! names — enough to lint this crate reliably, with the fixture tests in
//! `tests/analyze.rs` pinning exactly which shapes it gets right.
//!
//! Run it as `cgmq analyze [--root <repo>] [--json]`; `make analyze`
//! wires it into `make ci`, and the GitHub workflow runs it on every
//! push. The dynamic-analysis complements (ThreadSanitizer, Miri) live in
//! the workflow's nightly jobs, not here.

pub mod rules;
pub mod scan;

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One rule violation: where, what, and how to fix it.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule id (one of [`rules::ALL_RULES`]).
    pub rule: &'static str,
    /// Repo-relative path with `/` separators.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// What is wrong at that line.
    pub message: String,
    /// How to fix it (or how to allowlist it honestly).
    pub hint: String,
}

impl Finding {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rule", Json::str(self.rule)),
            ("file", Json::str(self.file.as_str())),
            ("line", Json::num(self.line as f64)),
            ("message", Json::str(self.message.as_str())),
            ("hint", Json::str(self.hint.as_str())),
        ])
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let Self { rule, file, line, message, hint } = self;
        write!(f, "{file}:{line} [{rule}] {message}\n    fix: {hint}")
    }
}

/// The outcome of an analysis run.
#[derive(Debug, Default)]
pub struct Report {
    /// Findings in (file, line) order.
    pub findings: Vec<Finding>,
    /// How many files were scanned.
    pub files_scanned: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("files_scanned", Json::num(self.files_scanned as f64)),
            ("findings", Json::Arr(self.findings.iter().map(Finding::to_json).collect())),
            ("count", Json::num(self.findings.len() as f64)),
            ("clean", Json::Bool(self.clean())),
        ])
    }

    /// Human-readable rendering (one block per finding + a tally line).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&f.to_string());
            out.push('\n');
        }
        if self.clean() {
            out.push_str(&format!("analyze: clean ({} files scanned)\n", self.files_scanned));
        } else {
            out.push_str(&format!(
                "analyze: {} finding(s) across {} files scanned\n",
                self.findings.len(),
                self.files_scanned
            ));
        }
        out
    }
}

/// Scan one source string under a virtual path and return its findings.
/// This is the entry point the fixture tests drive; [`analyze_crate`] is
/// the same thing over the real tree.
pub fn analyze_source(path: &str, src: &str) -> Vec<Finding> {
    rules::check_file(&scan::scan(path, src))
}

/// Analyze the crate rooted at `root` (the directory holding `Cargo.toml`,
/// `rust/src` and `README.md`): every `.rs` file under `rust/src`, plus
/// the README/taxonomy cross-check.
pub fn analyze_crate(root: &Path) -> Result<Report> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)
        .with_context(|| format!("walking {}", src_root.display()))?;
    files.sort();
    let mut report = Report::default();
    for path in &files {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = rel_path(root, path);
        report.findings.extend(analyze_source(&rel, &src));
        report.files_scanned += 1;
    }
    // The taxonomy cross-check reads two specific files; their absence is
    // itself a finding (a deleted README table must not pass silently).
    let http_path = root.join("rust/src/deploy/net/http.rs");
    let readme_path = root.join("README.md");
    match (std::fs::read_to_string(&http_path), std::fs::read_to_string(&readme_path)) {
        (Ok(http_src), Ok(readme_src)) => {
            report.findings.extend(rules::check_taxonomy(
                &rel_path(root, &http_path),
                &http_src,
                &rel_path(root, &readme_path),
                &readme_src,
            ));
        }
        _ => report.findings.push(Finding {
            rule: rules::RULE_TAXONOMY,
            file: "README.md".to_string(),
            line: 1,
            message: "cannot read http.rs + README.md for the taxonomy cross-check".to_string(),
            hint: "run from the repo root or pass --root <repo>".to_string(),
        }),
    }
    // Same contract for the metric names: the telemetry module and the
    // README table must agree, and a missing file is itself a finding.
    // The windowed metric names live in telemetry/window.rs, so both
    // sources are concatenated into one virtual file for the check —
    // calling check_metrics per file would flag each one for the metric
    // names only the other defines.
    let telemetry_path = root.join("rust/src/deploy/telemetry.rs");
    let window_path = root.join("rust/src/deploy/telemetry/window.rs");
    match (
        std::fs::read_to_string(&telemetry_path),
        std::fs::read_to_string(&window_path),
        std::fs::read_to_string(&readme_path),
    ) {
        (Ok(telemetry_src), Ok(window_src), Ok(readme_src)) => {
            let combined = format!("{telemetry_src}\n{window_src}");
            report.findings.extend(rules::check_metrics(
                &rel_path(root, &telemetry_path),
                &combined,
                &rel_path(root, &readme_path),
                &readme_src,
            ));
        }
        _ => report.findings.push(Finding {
            rule: rules::RULE_METRICS,
            file: "README.md".to_string(),
            line: 1,
            message: "cannot read telemetry.rs + telemetry/window.rs + README.md for the \
                      metrics cross-check"
                .to_string(),
            hint: "run from the repo root or pass --root <repo>".to_string(),
        }),
    }
    report.findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(report)
}

fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/")
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
