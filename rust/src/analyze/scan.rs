//! Lexical line scanner for the analyzer.
//!
//! The rules in [`super::rules`] are token scans, so all they need from a
//! source file is, per line: the code with string-literal *contents*
//! blanked (so `"panic!("` in a message can never trip the panic rule),
//! the comment text (where `ordering:` justifications and
//! `analyze-allow:` annotations live), the brace depth, whether the line
//! sits inside a `#[cfg(test)]` block, and the name of the enclosing
//! function. This is deliberately not a Rust parser — it is a few hundred
//! lines that understand strings, comments and braces well enough to lint
//! this crate, and the fixture tests in `tests/analyze.rs` pin exactly
//! which shapes it gets right.

/// One source line, split into its analyzable parts.
#[derive(Debug, Clone)]
pub struct SourceLine {
    /// 1-based line number.
    pub number: usize,
    /// Code with string/char-literal contents replaced by spaces and
    /// comments removed.
    pub code: String,
    /// Comment text on this line (line comments and block-comment
    /// content), without the `//` / `/*` markers.
    pub comment: String,
    /// Brace depth before the first character of this line.
    pub depth_before: usize,
    /// Brace depth after the last character of this line.
    pub depth_after: usize,
    /// Inside a `#[cfg(test)]`-gated block (or the attribute line itself).
    pub in_test: bool,
    /// Name of the innermost enclosing `fn`, if any.
    pub fn_name: Option<String>,
}

/// A scanned file: the virtual path rules use for scoping, plus its lines.
#[derive(Debug)]
pub struct ScannedFile {
    /// Path with `/` separators, as given by the caller (relative to the
    /// repo root for real scans, a virtual path for fixture tests).
    pub path: String,
    pub lines: Vec<SourceLine>,
}

/// Lexer state that survives line breaks.
enum Mode {
    Code,
    /// Inside a string literal; `raw_hashes` is `Some(n)` for `r#"`-style
    /// raw strings (closed by `"` + n `#`s), `None` for normal strings.
    Str { raw_hashes: Option<usize> },
    /// Inside a (possibly nested) block comment; the value is the depth.
    BlockComment(usize),
}

/// Split `src` into per-line code and comment parts (first pass), then
/// annotate depth / test scope / enclosing fn (second pass).
pub fn scan(path: &str, src: &str) -> ScannedFile {
    let mut lines = split_lines(src);
    annotate(&mut lines);
    ScannedFile { path: path.replace('\\', "/"), lines }
}

fn split_lines(src: &str) -> Vec<SourceLine> {
    let mut lines = Vec::new();
    let mut mode = Mode::Code;
    for (idx, raw) in src.lines().enumerate() {
        let mut code = String::with_capacity(raw.len());
        let mut comment = String::new();
        let chars: Vec<char> = raw.chars().collect();
        let mut i = 0;
        while i < chars.len() {
            match mode {
                Mode::Code => {
                    let c = chars[i];
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        // Line comment: the rest of the line is comment.
                        comment.push_str(&chars[i + 2..].iter().collect::<String>());
                        i = chars.len();
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        mode = Mode::BlockComment(1);
                        i += 2;
                    } else if c == '"' {
                        code.push('"');
                        mode = Mode::Str { raw_hashes: None };
                        i += 1;
                    } else if c == 'r' && is_raw_string_start(&chars, i) {
                        let hashes = count_hashes(&chars, i + 1);
                        code.push('"');
                        mode = Mode::Str { raw_hashes: Some(hashes) };
                        i += 1 + hashes + 1; // r, hashes, opening quote
                    } else if c == '\'' {
                        i = skip_char_or_lifetime(&chars, i, &mut code);
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
                Mode::Str { raw_hashes } => match raw_hashes {
                    None => {
                        let c = chars[i];
                        if c == '\\' {
                            code.push(' ');
                            i += 2; // the escape and its target
                        } else if c == '"' {
                            code.push('"');
                            mode = Mode::Code;
                            i += 1;
                        } else {
                            code.push(' ');
                            i += 1;
                        }
                    }
                    Some(n) => {
                        if chars[i] == '"' && count_hashes(&chars, i + 1) >= n {
                            code.push('"');
                            mode = Mode::Code;
                            i += 1 + n;
                        } else {
                            code.push(' ');
                            i += 1;
                        }
                    }
                },
                Mode::BlockComment(depth) => {
                    if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        mode = if depth == 1 {
                            Mode::Code
                        } else {
                            Mode::BlockComment(depth - 1)
                        };
                        i += 2;
                    } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        mode = Mode::BlockComment(depth + 1);
                        i += 2;
                    } else {
                        comment.push(chars[i]);
                        i += 1;
                    }
                }
            }
        }
        lines.push(SourceLine {
            number: idx + 1,
            code,
            comment,
            depth_before: 0,
            depth_after: 0,
            in_test: false,
            fn_name: None,
        });
    }
    lines
}

/// `r"`, `r#"`, `r##"`, ... at `chars[i]` (the `r`). A plain identifier
/// containing `r` does not match because the caller only probes at an `r`
/// and we require the quote right after the hashes.
fn is_raw_string_start(chars: &[char], i: usize) -> bool {
    // Reject the middle of an identifier: `for`, `ptr`, `&str` names...
    if i > 0 {
        let prev = chars[i - 1];
        if prev.is_alphanumeric() || prev == '_' {
            return false;
        }
    }
    let hashes = count_hashes(chars, i + 1);
    chars.get(i + 1 + hashes) == Some(&'"')
}

fn count_hashes(chars: &[char], from: usize) -> usize {
    chars[from.min(chars.len())..].iter().take_while(|&&c| c == '#').count()
}

/// Skip a `'x'` / `'\n'` char literal (blanking its content) or a `'a`
/// lifetime (kept as-is, it contains no braces/quotes). Returns the next
/// index to process.
fn skip_char_or_lifetime(chars: &[char], i: usize, code: &mut String) -> usize {
    if chars.get(i + 1) == Some(&'\\') {
        // Escaped char literal: the char after the backslash is consumed
        // unconditionally (it may itself be a quote, as in '\''), then
        // everything up to the closing quote.
        code.push('\'');
        code.push(' ');
        let mut j = i + 3;
        while j < chars.len() && chars[j] != '\'' {
            code.push(' ');
            j += 1;
        }
        code.push('\'');
        j + 1
    } else if chars.get(i + 2) == Some(&'\'') {
        // Plain one-char literal, '{' included.
        code.push('\'');
        code.push(' ');
        code.push('\'');
        i + 3
    } else {
        // A lifetime (or a stray quote): keep the tick, move on.
        code.push('\'');
        i + 1
    }
}

/// Second pass: brace depth, `#[cfg(test)]` scope, enclosing fn.
fn annotate(lines: &mut [SourceLine]) {
    let mut depth = 0usize;
    // (name, depth the fn body's brace opened at)
    let mut fn_stack: Vec<(String, usize)> = Vec::new();
    let mut pending_fn: Option<String> = None;
    let mut pending_test = false;
    let mut test_depth: Option<usize> = None;
    for line in lines.iter_mut() {
        line.depth_before = depth;
        if line.code.contains("#[cfg(test)]") {
            pending_test = true;
        }
        line.in_test = test_depth.is_some() || pending_test;
        if let Some(name) = find_fn_name(&line.code) {
            pending_fn = Some(name);
        }
        for c in line.code.chars() {
            match c {
                '{' => {
                    if pending_test {
                        test_depth = Some(depth);
                        pending_test = false;
                    }
                    if let Some(name) = pending_fn.take() {
                        fn_stack.push((name, depth));
                    }
                    depth += 1;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    if fn_stack.last().map(|(_, d)| *d == depth).unwrap_or(false) {
                        fn_stack.pop();
                    }
                    if test_depth == Some(depth) {
                        test_depth = None;
                    }
                }
                _ => {}
            }
        }
        line.depth_after = depth;
        line.fn_name = fn_stack.last().map(|(n, _)| n.clone());
    }
}

/// The identifier after a word-boundary `fn ` on this line, if any.
fn find_fn_name(code: &str) -> Option<String> {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find("fn ") {
        let at = from + pos;
        let boundary = at == 0 || {
            let prev = bytes[at - 1];
            !prev.is_ascii_alphanumeric() && prev != b'_'
        };
        if boundary {
            let name: String = code[at + 3..]
                .trim_start()
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
        from = at + 3;
    }
    None
}

/// `analyze-allow: <rule-id> <reason>` annotations in a comment. Returns
/// `(rule, reason)` pairs; a missing reason comes back empty (the
/// `bad-allow` check rejects it).
pub fn parse_allows(comment: &str) -> Vec<(String, String)> {
    const MARKER: &str = "analyze-allow:";
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = comment[from..].find(MARKER) {
        let rest = comment[from + pos + MARKER.len()..].trim_start();
        let rule: String =
            rest.chars().take_while(|c| c.is_alphanumeric() || *c == '-').collect();
        // No rule name at all (e.g. prose quoting the marker syntax) is not
        // an annotation; `bad-allow` only vets real attempts.
        if !rule.is_empty() {
            let reason = rest[rule.len()..].trim().to_string();
            out.push((rule, reason));
        }
        from += pos + MARKER.len();
    }
    out
}

/// Is `rule` allowlisted for line index `idx` — by a same-line annotation
/// or one in the contiguous run of comment-only lines directly above?
pub fn allowed(lines: &[SourceLine], idx: usize, rule: &str) -> bool {
    comment_run(lines, idx).any(|c| parse_allows(c).iter().any(|(r, _)| r == rule))
}

/// Does line `idx` carry `marker` in its own comment or in the contiguous
/// comment-only run directly above? (The `// ordering:` justification
/// lookup.)
pub fn has_marker(lines: &[SourceLine], idx: usize, marker: &str) -> bool {
    comment_run(lines, idx).any(|c| c.contains(marker))
}

/// The line's own comment plus the comment-only lines immediately above.
fn comment_run<'a>(
    lines: &'a [SourceLine],
    idx: usize,
) -> impl Iterator<Item = &'a str> + 'a {
    let mut start = idx;
    while start > 0 {
        let above = &lines[start - 1];
        if above.code.trim().is_empty() && !above.comment.trim().is_empty() {
            start -= 1;
        } else {
            break;
        }
    }
    lines[start..=idx].iter().map(|l| l.comment.as_str())
}
