//! Minimal host tensor used by the L3 coordinator.
//!
//! The heavy math (fwd/bwd) runs inside the AOT-compiled XLA artifacts; the
//! coordinator only needs dense f32 host tensors for parameters, gates,
//! gradients and the elementwise dir/optimizer updates, plus i32 label
//! batches. Row-major (C) layout, matching XLA literal layout for the
//! shapes we exchange.

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    /// He-normal init (std = sqrt(2 / fan_in)) from the deterministic RNG.
    pub fn he_normal(shape: &[usize], fan_in: usize, rng: &mut crate::util::rng::SplitMix64) -> Self {
        let std = (2.0 / fan_in as f64).sqrt();
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| (rng.gauss() * std) as f32).collect();
        Self { shape: shape.to_vec(), data }
    }

    // -------------------------------------------------------------- access
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn item(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("item() on tensor with {} elements", self.data.len());
        }
        Ok(self.data[0])
    }

    pub fn reshaped(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {} elements to {:?}", self.data.len(), shape);
        }
        self.shape = shape;
        Ok(self)
    }

    // ---------------------------------------------------------- elementwise
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Self { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// self[i] = f(self[i], other[i]) — shapes must match.
    pub fn zip_inplace(&mut self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<()> {
        if self.shape != other.shape {
            bail!("shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        for (a, &b) in self.data.iter_mut().zip(other.data.iter()) {
            *a = f(*a, b);
        }
        Ok(())
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Self> {
        if self.shape != other.shape {
            bail!("shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        let data = self.data.iter().zip(other.data.iter()).map(|(&a, &b)| f(a, b)).collect();
        Ok(Self { shape: self.shape.clone(), data })
    }

    // -------------------------------------------------------------- reduce
    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.sum() / self.data.len() as f64
        }
    }

    pub fn sq_l2(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// argmax over the last axis for a 2-D tensor (logits -> predictions).
    pub fn argmax_rows(&self) -> Result<Vec<usize>> {
        if self.shape.len() != 2 {
            bail!("argmax_rows wants 2-D, got {:?}", self.shape);
        }
        let (n, c) = (self.shape[0], self.shape[1]);
        let mut out = Vec::with_capacity(n);
        for r in 0..n {
            let row = &self.data[r * c..(r + 1) * c];
            let mut best = 0;
            for j in 1..c {
                if row[j] > row[best] {
                    best = j;
                }
            }
            out.push(best);
        }
        Ok(out)
    }
}

/// Dense i32 tensor (labels).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Self { shape, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.len(), 6);
        let r = t.clone().reshaped(vec![3, 2]).unwrap();
        assert_eq!(r.shape(), &[3, 2]);
        assert!(t.clone().reshaped(vec![4]).is_err());
        assert!(Tensor::new(vec![2, 2], vec![0.0]).is_err());
    }

    #[test]
    fn elementwise_and_reduce() {
        let a = Tensor::new(vec![4], vec![1., -2., 3., -4.]).unwrap();
        let b = a.map(f32::abs);
        assert_eq!(b.data(), &[1., 2., 3., 4.]);
        assert_eq!(a.sum(), -2.0);
        assert_eq!(a.abs_max(), 4.0);
        let c = a.zip(&b, |x, y| x + y).unwrap();
        assert_eq!(c.data(), &[2., 0., 6., 0.]);
        assert!(a.zip(&Tensor::zeros(&[3]), |x, _| x).is_err());
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0]).unwrap();
        assert_eq!(t.argmax_rows().unwrap(), vec![1, 0]);
    }

    #[test]
    fn he_init_moments() {
        let mut rng = crate::util::rng::SplitMix64::new(1);
        let t = Tensor::he_normal(&[1000, 50], 50, &mut rng);
        let mean = t.mean();
        let var = t.sq_l2() / t.len() as f64 - mean * mean;
        assert!(mean.abs() < 0.01);
        assert!((var - 2.0 / 50.0).abs() < 0.01);
    }

    #[test]
    fn scalar_item() {
        assert_eq!(Tensor::scalar(2.5).item().unwrap(), 2.5);
        assert!(Tensor::zeros(&[2]).item().is_err());
    }
}
