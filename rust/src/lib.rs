//! # CGMQ — Constraint Guided Model Quantization
//!
//! Production-grade reproduction of *"Constraint Guided Model Quantization
//! of Neural Networks"* (Van Baelen & Karsmakers, 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (Pallas, build time) — the gated residual-decomposition
//!   fake quantizer (paper Eq. 1/3) as a Pallas kernel.
//! * **Layer 2** (JAX, build time) — LeNet-5/MLP forward+backward with fake
//!   quantization, lowered once to HLO-text artifacts (`make artifacts`).
//! * **Layer 3** (this crate, run time) — the paper's contribution: the
//!   constraint-guided training coordinator. It owns the epoch loop, the
//!   end-of-epoch BOP constraint check (Sat/Unsat state machine), the gate
//!   store and its `dir`-driven update (paper Section 2.2-2.3), optimizers,
//!   the data pipeline, checkpoints, metrics, baselines and the benchmark
//!   harness that regenerates the paper's tables.
//!
//! Python never runs on the training path: the Rust binary loads the HLO
//! artifacts through PJRT (the `xla` crate) and drives everything itself.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

pub mod baselines;
pub mod bench_harness;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod data;
pub mod direction;
pub mod gates;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Bit-widths of the residual decomposition (paper: B = {2,4,8,16,32}).
pub const BIT_LEVELS: [u32; 5] = [2, 4, 8, 16, 32];

/// Gate floor — pruning is future work in the paper, so gates are clamped
/// to 0.5 (bit-width 2) as soon as they drop below it (Section 2.1).
pub const GATE_FLOOR: f32 = 0.5;

/// Default gate initial value: T(5.5) = 32 bit (paper Section 4.2).
pub const GATE_INIT: f32 = 5.5;
