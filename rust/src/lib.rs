//! # CGMQ — Constraint Guided Model Quantization
//!
//! Production-grade reproduction of *"Constraint Guided Model Quantization
//! of Neural Networks"* (Van Baelen & Karsmakers, 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (Pallas, build time) — the gated residual-decomposition
//!   fake quantizer (paper Eq. 1/3) as a Pallas kernel.
//! * **Layer 2** (JAX, build time) — LeNet-5/MLP forward+backward with fake
//!   quantization, lowered once to HLO-text artifacts (`make artifacts`).
//! * **Layer 3** (this crate, run time) — the paper's contribution: the
//!   constraint-guided training pipeline, exposed through the staged
//!   [`session`] API.
//!
//! ## The staged session API
//!
//! Training is a [`session::Session`]: a [`session::TrainCtx`] (model,
//! gates, optimizers, data, compiled artifacts) driven through an ordered
//! list of [`session::Stage`]s, with [`session::Observer`]s subscribed to
//! the event bus (epoch ends, constraint checks, best-model snapshots).
//! The paper's four phases are the stock stages
//! [`session::Pretrain`] → [`session::Calibrate`] →
//! [`session::RangeLearn`] → [`session::CgmqLoop`]:
//!
//! ```no_run
//! use cgmq::config::Config;
//! use cgmq::session::SessionBuilder;
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut session = SessionBuilder::new(Config::default())
//!     .paper_pipeline()
//!     .build()?;
//! session.run()?;
//! let result = session.result()?; // best bound-satisfying model
//! # Ok(())
//! # }
//! ```
//!
//! Baselines and ablations are just other stage sequences over the same
//! context — uniform fixed-bit QAT is
//! `[Pretrain, Calibrate, PinGates(b), Finetune]`, resuming from a float
//! checkpoint swaps `Pretrain` for `LoadCheckpoint`, and the myQASR
//! heuristic ships as a custom stage in [`baselines::myqasr`].
//!
//! ## Deployment
//!
//! `session` users: the snapshot a finished run delivers does not stop at
//! a memory report — [`deploy`] packs it into a bit-packed `.cgmqm`
//! artifact ([`deploy::PackedModel`]) and runs it with
//! [`deploy::Engine`], whose logits match the fake-quant eval path
//! bit-for-bit; [`deploy::RequestBatcher`] batches single-sample `infer`
//! requests, [`deploy::WorkerPool`] serves one shared `Arc<Engine>` from
//! N sharded worker threads with bounded admission (`try_submit` sheds
//! once the per-shard in-flight cap is hit), [`deploy::Router`] runs
//! several models/versions side by side with per-model stats and
//! zero-downtime hot swap, and [`deploy::net::Server`] exposes the router
//! over a std-only HTTP/1.1 front — overload answered `429 Retry-After`,
//! graceful drain on shutdown (`cgmq export --format packed`, `cgmq
//! infer`, `cgmq serve-bench --workers N`, `cgmq route-bench --models
//! ...`, `cgmq serve` + `cgmq load-bench`).
//!
//! Training-side visibility goes through [`session::Observer`]s on the
//! event bus; the deploy-side equivalent is [`deploy::telemetry`]: every
//! server carries per-request stage traces (`X-Request-Id`), log₂
//! latency histograms and per-model × per-status counters, exposed as
//! Prometheus text at `GET /metrics` and as enriched `GET /stats` JSON.
//!
//! ### Migrating from `Trainer`
//!
//! The old monolithic `coordinator::Trainer` remains as a thin shim that
//! delegates every phase method to the corresponding stage. Replace
//! `Trainer::new(cfg)?` + `run_full()` with
//! `SessionBuilder::new(cfg).paper_pipeline().build()?` + `run()` +
//! `result()`; state the trainer exposed as fields (`params`, `gates`,
//! `log`, `rbop_trace`, ...) lives on `session.ctx`.
//!
//! Python never runs on the training path: the Rust binary loads the HLO
//! artifacts through PJRT (the `xla` crate, behind the `pjrt` feature) and
//! drives everything itself.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

pub mod analyze;
pub mod baselines;
pub mod bench_harness;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod data;
pub mod deploy;
pub mod direction;
pub mod gates;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod quant;
pub mod runtime;
pub mod session;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Bit-widths of the residual decomposition (paper: B = {2,4,8,16,32}).
pub const BIT_LEVELS: [u32; 5] = [2, 4, 8, 16, 32];

/// Gate floor — pruning is future work in the paper, so gates are clamped
/// to 0.5 (bit-width 2) as soon as they drop below it (Section 2.1).
pub const GATE_FLOOR: f32 = 0.5;

/// Default gate initial value: T(5.5) = 32 bit (paper Section 4.2).
pub const GATE_INIT: f32 = 5.5;
