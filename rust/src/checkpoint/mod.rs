//! Checkpointing: binary tensor blobs + JSON metadata.
//!
//! Format (little-endian, version-tagged):
//!
//! ```text
//! magic  "CGMQCKPT"            8 bytes
//! version u32                  currently 1
//! n_tensors u32
//! per tensor:
//!   name_len u32, name utf-8
//!   rank u32, dims u64 x rank
//!   data f32 x prod(dims)
//! ```
//!
//! A sidecar `<file>.meta.json` records the arch, phase and config id so a
//! checkpoint can't silently be loaded into the wrong model.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"CGMQCKPT";

/// Checkpoint format version written after the magic. Bump on any layout
/// change; `load` refuses other versions up front so a layout drift fails
/// with a clear error instead of garbage tensor deserialization.
pub const FORMAT_VERSION: u32 = 1;

/// Named tensor collection + metadata.
#[derive(Debug, Clone, Default)]
pub struct Checkpoint {
    pub tensors: BTreeMap<String, Tensor>,
    pub meta: BTreeMap<String, String>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.tensors.insert(name.into(), t);
    }

    pub fn insert_all(&mut self, prefix: &str, ts: &[Tensor]) {
        for (i, t) in ts.iter().enumerate() {
            self.insert(format!("{prefix}.{i}"), t.clone());
        }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).with_context(|| format!("checkpoint missing tensor '{name}'"))
    }

    /// Collect `prefix.0, prefix.1, ...` back into a vector.
    pub fn get_all(&self, prefix: &str) -> Result<Vec<Tensor>> {
        let mut out = Vec::new();
        loop {
            match self.tensors.get(&format!("{prefix}.{}", out.len())) {
                Some(t) => out.push(t.clone()),
                None => break,
            }
        }
        if out.is_empty() {
            bail!("checkpoint has no tensors under prefix '{prefix}'");
        }
        Ok(out)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&FORMAT_VERSION.to_le_bytes())?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
            for &d in t.shape() {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            for &v in t.data() {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        f.flush()?;
        // metadata sidecar
        let meta = Json::Obj(
            self.meta.iter().map(|(k, v)| (k.clone(), Json::str(v.clone()))).collect(),
        );
        std::fs::write(meta_path(path), meta.to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: not a CGMQ checkpoint", path.display());
        }
        let version = read_u32(&mut f)?;
        if version != FORMAT_VERSION {
            bail!(
                "{}: checkpoint format version {version}, but this build reads version \
                 {FORMAT_VERSION} — re-export the checkpoint with a matching cgmq build",
                path.display()
            );
        }
        let n = read_u32(&mut f)? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name_len = read_u32(&mut f)? as usize;
            if name_len > 4096 {
                bail!("corrupt checkpoint: name length {name_len}");
            }
            let mut name_bytes = vec![0u8; name_len];
            f.read_exact(&mut name_bytes)?;
            let name = String::from_utf8(name_bytes).context("non-utf8 tensor name")?;
            let rank = read_u32(&mut f)? as usize;
            if rank > 16 {
                bail!("corrupt checkpoint: rank {rank}");
            }
            let mut dims = Vec::with_capacity(rank);
            for _ in 0..rank {
                let mut b = [0u8; 8];
                f.read_exact(&mut b)?;
                dims.push(u64::from_le_bytes(b) as usize);
            }
            let count: usize = dims.iter().try_fold(1usize, |a, &d| a.checked_mul(d))
                .filter(|&c| c <= (1usize << 31))
                .with_context(|| format!("corrupt checkpoint: tensor dims {dims:?}"))?;
            let mut data = vec![0f32; count];
            let mut buf = vec![0u8; count * 4];
            f.read_exact(&mut buf).context("truncated tensor payload")?;
            for (i, chunk) in buf.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            tensors.insert(name, Tensor::new(dims, data)?);
        }
        // optional metadata sidecar
        let mut meta = BTreeMap::new();
        let mp = meta_path(path);
        if mp.exists() {
            if let Ok(j) = crate::util::json::parse_file(&mp) {
                if let Ok(obj) = j.as_obj() {
                    for (k, v) in obj {
                        if let Ok(s) = v.as_str() {
                            meta.insert(k.clone(), s.to_string());
                        }
                    }
                }
            }
        }
        Ok(Self { tensors, meta })
    }
}

fn meta_path(path: &Path) -> std::path::PathBuf {
    let mut p = path.as_os_str().to_owned();
    p.push(".meta.json");
    std::path::PathBuf::from(p)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("cgmq_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut c = Checkpoint::new();
        c.insert("w", Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap());
        c.insert("scalar", Tensor::scalar(7.5));
        c.meta.insert("arch".into(), "mlp".into());
        let p = tmp("roundtrip.ckpt");
        c.save(&p).unwrap();
        let l = Checkpoint::load(&p).unwrap();
        assert_eq!(l.tensors.len(), 2);
        assert_eq!(l.get("w").unwrap(), c.get("w").unwrap());
        assert_eq!(l.get("scalar").unwrap().item().unwrap(), 7.5);
        assert_eq!(l.meta.get("arch").unwrap(), "mlp");
    }

    #[test]
    fn vector_prefix_roundtrip() {
        let mut c = Checkpoint::new();
        let ts = vec![Tensor::zeros(&[2]), Tensor::full(&[3], 1.0)];
        c.insert_all("params", &ts);
        let p = tmp("prefix.ckpt");
        c.save(&p).unwrap();
        let l = Checkpoint::load(&p).unwrap();
        let back = l.get_all("params").unwrap();
        assert_eq!(back, ts);
        assert!(l.get_all("nope").is_err());
    }

    #[test]
    fn version_mismatch_rejected_with_clear_error() {
        // Write a valid checkpoint, then patch the version field (bytes
        // 8..12, little-endian, right after the magic) to a future version.
        let mut c = Checkpoint::new();
        c.insert("w", Tensor::scalar(1.0));
        let p = tmp("version.ckpt");
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&p, bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");
        assert!(err.contains(&format!("version {FORMAT_VERSION}")), "{err}");
    }

    #[test]
    fn absurd_tensor_dims_rejected() {
        // Header claims a tensor with an overflowing element count; the
        // loader must fail cleanly instead of attempting the allocation.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes()); // one tensor
        bytes.extend_from_slice(&1u32.to_le_bytes()); // name "w"
        bytes.push(b'w');
        bytes.extend_from_slice(&2u32.to_le_bytes()); // rank 2
        bytes.extend_from_slice(&(u64::MAX / 2).to_le_bytes());
        bytes.extend_from_slice(&4u64.to_le_bytes());
        let p = tmp("absurd.ckpt");
        std::fs::write(&p, bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("corrupt checkpoint"), "{err}");
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.ckpt");
        std::fs::write(&p, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn missing_tensor_error_names_it() {
        let c = Checkpoint::new();
        let err = c.get("gates.w.0").unwrap_err().to_string();
        assert!(err.contains("gates.w.0"));
    }
}
