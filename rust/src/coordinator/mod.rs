//! Compatibility shim over the staged [`session`](crate::session) API.
//!
//! The CGMQ training loop used to live here as a monolithic `Trainer` that
//! hard-coded the paper's four phases. The phases are now first-class
//! [`Stage`](crate::session::Stage) values
//! ([`Pretrain`](crate::session::Pretrain), [`Calibrate`](crate::session::Calibrate),
//! [`RangeLearn`](crate::session::RangeLearn), [`CgmqLoop`](crate::session::CgmqLoop))
//! run over a shared [`TrainCtx`](crate::session::TrainCtx), assembled with
//! [`SessionBuilder`](crate::session::SessionBuilder) — use that API for
//! new code (see the crate docs for a migration note).
//!
//! `Trainer` remains as a thin delegate so existing drivers keep
//! compiling: it derefs to `TrainCtx` (all state fields and primitive
//! operations come from there) and each old phase method just runs the
//! corresponding stage. No phase logic lives here.

use std::path::Path;

use anyhow::Result;

use crate::config::Config;
use crate::session::stage::Stage;
use crate::session::{Calibrate, CgmqLoop, LoadCheckpoint, Pretrain, RangeLearn, TrainCtx};

// Re-exports for pre-session call sites.
pub use crate::session::{CgmqPolicy, GatePolicy, PolicyInputs, RunResult, Snapshot};

/// Deprecated facade over [`TrainCtx`] + the paper's stages.
///
/// Prefer [`SessionBuilder`](crate::session::SessionBuilder):
///
/// ```text
/// // old                                    // new
/// let mut t = Trainer::new(cfg)?;           let mut s = SessionBuilder::new(cfg)
/// t.run_full()?;                                .paper_pipeline().build()?;
///                                           s.run()?; let r = s.result()?;
/// ```
pub struct Trainer {
    pub ctx: TrainCtx,
}

impl std::ops::Deref for Trainer {
    type Target = TrainCtx;

    fn deref(&self) -> &TrainCtx {
        &self.ctx
    }
}

impl std::ops::DerefMut for Trainer {
    fn deref_mut(&mut self) -> &mut TrainCtx {
        &mut self.ctx
    }
}

impl Trainer {
    /// Build a trainer: load artifacts, verify the manifest, init state.
    pub fn new(cfg: Config) -> Result<Self> {
        Ok(Self { ctx: TrainCtx::new(cfg)? })
    }

    /// Phase 1 — delegates to the [`Pretrain`] stage.
    pub fn pretrain(&mut self, epochs: usize) -> Result<()> {
        Pretrain::epochs(epochs).run(&mut self.ctx).map(|_| ())
    }

    /// Phase 2 — delegates to the [`Calibrate`] stage.
    pub fn calibrate(&mut self) -> Result<()> {
        Calibrate.run(&mut self.ctx).map(|_| ())
    }

    /// Phase 3 — delegates to the [`RangeLearn`] stage.
    pub fn learn_ranges(&mut self, epochs: usize) -> Result<()> {
        RangeLearn::epochs(epochs).run(&mut self.ctx).map(|_| ())
    }

    /// Phase 4 — delegates to the [`CgmqLoop`] stage.
    pub fn cgmq(&mut self, epochs: usize) -> Result<()> {
        CgmqLoop::epochs(epochs).run(&mut self.ctx).map(|_| ())
    }

    /// Full pipeline: pretrain -> calibrate -> range learning -> CGMQ.
    pub fn run_full(&mut self) -> Result<RunResult> {
        Pretrain::default().run(&mut self.ctx)?;
        Calibrate.run(&mut self.ctx)?;
        RangeLearn::default().run(&mut self.ctx)?;
        CgmqLoop::default().run(&mut self.ctx)?;
        self.ctx.result()
    }

    /// Resume from a pretrained float checkpoint (skips phase 1).
    pub fn run_from_pretrained(&mut self, ckpt: &Path) -> Result<RunResult> {
        LoadCheckpoint::new(ckpt).run(&mut self.ctx)?;
        Calibrate.run(&mut self.ctx)?;
        RangeLearn::default().run(&mut self.ctx)?;
        CgmqLoop::default().run(&mut self.ctx)?;
        self.ctx.result()
    }
}
