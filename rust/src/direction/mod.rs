//! The `dir` rules — the heart of CGMQ (paper Sections 2.2-2.3).
//!
//! The gate staircase T(g) has zero gradient, so gates are updated with a
//! constructed *direction* used in place of a gradient by plain gradient
//! descent: `g <- g - eta_g * dir`. The two required properties:
//!
//! 1. constraint **Unsat** -> dir strictly positive (gates shrink,
//!    bit-widths fall, cost falls);
//! 2. constraint **Sat**   -> dir <= 0 (gates may grow back selectively).
//!
//! Which statistic modulates the magnitude is the dir variant:
//!
//! * `dir1`: Unsat 1/|grad|;            Sat -|g|
//! * `dir2`: Unsat 1/(|grad| + |w|);    Sat -(|g| + |w|)
//! * `dir3`: Unsat 1/(|grad| + |w|);    Sat -(|grad| + |w|)   (1st-order Taylor)
//!
//! with the batch-mean absolute loss gradient for |grad|, and for
//! activations |w| replaced by the batch-mean absolute activation value.
//! The statistics arrive straight from the `qat_step` artifact outputs.
//!
//! The paper notes the directions should be bounded ([K1,K2] / [K3,K4]);
//! we clip the Unsat reciprocal into [clip_min, clip_max] (the reciprocal
//! of a vanishing gradient is otherwise unbounded).

use anyhow::{bail, Result};

use crate::gates::Granularity;
use crate::tensor::Tensor;

/// Which dir variant (paper Section 2.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DirKind {
    Dir1,
    Dir2,
    Dir3,
}

impl DirKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "dir1" => Ok(DirKind::Dir1),
            "dir2" => Ok(DirKind::Dir2),
            "dir3" => Ok(DirKind::Dir3),
            other => bail!("unknown direction '{other}' (dir1 | dir2 | dir3)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            DirKind::Dir1 => "dir1",
            DirKind::Dir2 => "dir2",
            DirKind::Dir3 => "dir3",
        }
    }
}

/// Constraint state decided at the end of the previous epoch (Section 2.5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sat {
    Satisfied,
    Unsatisfied,
}

/// Direction computation config.
#[derive(Debug, Clone, Copy)]
pub struct DirConfig {
    pub kind: DirKind,
    /// Clip bounds for the Unsat reciprocal (paper's [K1, K2]).
    pub clip_min: f32,
    pub clip_max: f32,
    /// Denominator floor (avoids division by exactly zero).
    pub eps: f32,
}

impl DirConfig {
    pub fn new(kind: DirKind) -> Self {
        Self { kind, clip_min: 1e-6, clip_max: 1e3, eps: 1e-12 }
    }
}

#[inline]
fn unsat_clip(v: f32, cfg: &DirConfig) -> f32 {
    v.max(cfg.clip_min).min(cfg.clip_max)
}

/// Elementwise dir for one *weight* gate element.
///
/// `grad` = batch-mean loss gradient for the weight, `w` = weight value,
/// `g` = current gate value.
#[inline]
pub fn dir_w(cfg: &DirConfig, sat: Sat, grad: f32, w: f32, g: f32) -> f32 {
    let ag = grad.abs();
    let aw = w.abs();
    match (cfg.kind, sat) {
        (DirKind::Dir1, Sat::Unsatisfied) => unsat_clip(1.0 / (ag + cfg.eps), cfg),
        (DirKind::Dir1, Sat::Satisfied) => -g.abs(),
        (DirKind::Dir2, Sat::Unsatisfied) => unsat_clip(1.0 / (ag + aw + cfg.eps), cfg),
        (DirKind::Dir2, Sat::Satisfied) => -(g.abs() + aw),
        (DirKind::Dir3, Sat::Unsatisfied) => unsat_clip(1.0 / (ag + aw + cfg.eps), cfg),
        (DirKind::Dir3, Sat::Satisfied) => -(ag + aw),
    }
}

/// Elementwise dir for one *activation* gate element.
///
/// `grad` = batch-mean loss gradient w.r.t. the activation (probe output of
/// the qat_step artifact), `act` = batch-mean activation value, `g` = gate.
#[inline]
pub fn dir_a(cfg: &DirConfig, sat: Sat, grad: f32, act: f32, g: f32) -> f32 {
    let ag = grad.abs();
    let aa = act.abs();
    match (cfg.kind, sat) {
        (DirKind::Dir1, Sat::Unsatisfied) => unsat_clip(1.0 / (ag + cfg.eps), cfg),
        (DirKind::Dir1, Sat::Satisfied) => -g.abs(),
        (DirKind::Dir2, Sat::Unsatisfied) => unsat_clip(1.0 / (ag + aa + cfg.eps), cfg),
        (DirKind::Dir2, Sat::Satisfied) => -(g.abs() + aa),
        (DirKind::Dir3, Sat::Unsatisfied) => unsat_clip(1.0 / (ag + aa + cfg.eps), cfg),
        (DirKind::Dir3, Sat::Satisfied) => -(ag + aa),
    }
}

/// Direction tensor for a weight-gate store.
///
/// For `Individual` granularity this is elementwise over the weight tensor;
/// for `Layer` granularity the per-weight statistics are mean-aggregated
/// over the layer first (the paper leaves the aggregation unspecified; the
/// mean keeps the magnitude scale identical to the individual case).
pub fn dir_tensor_w(
    cfg: &DirConfig,
    gran: Granularity,
    sat: Sat,
    grad: &Tensor,
    w: &Tensor,
    gate_store: &Tensor,
) -> Result<Tensor> {
    match gran {
        Granularity::Individual => {
            if grad.shape() != w.shape() || gate_store.shape() != w.shape() {
                bail!(
                    "dir_w shape mismatch: grad {:?} w {:?} gate {:?}",
                    grad.shape(),
                    w.shape(),
                    gate_store.shape()
                );
            }
            let data = grad
                .data()
                .iter()
                .zip(w.data())
                .zip(gate_store.data())
                .map(|((&gr, &wv), &gv)| dir_w(cfg, sat, gr, wv, gv))
                .collect();
            Tensor::new(w.shape().to_vec(), data)
        }
        Granularity::Layer => {
            let mean_abs = |t: &Tensor| (t.map(f32::abs).mean()) as f32;
            let d = dir_w(cfg, sat, mean_abs(grad), mean_abs(w), gate_store.data()[0]);
            Ok(Tensor::scalar(d))
        }
    }
}

/// Direction tensor for an activation-gate store (same aggregation rules).
pub fn dir_tensor_a(
    cfg: &DirConfig,
    gran: Granularity,
    sat: Sat,
    act_grad: &Tensor,
    act_mean: &Tensor,
    gate_store: &Tensor,
) -> Result<Tensor> {
    match gran {
        Granularity::Individual => {
            if act_grad.shape() != act_mean.shape() || gate_store.shape() != act_grad.shape() {
                bail!(
                    "dir_a shape mismatch: grad {:?} act {:?} gate {:?}",
                    act_grad.shape(),
                    act_mean.shape(),
                    gate_store.shape()
                );
            }
            let data = act_grad
                .data()
                .iter()
                .zip(act_mean.data())
                .zip(gate_store.data())
                .map(|((&gr, &av), &gv)| dir_a(cfg, sat, gr, av, gv))
                .collect();
            Tensor::new(act_grad.shape().to_vec(), data)
        }
        Granularity::Layer => {
            let mean_abs = |t: &Tensor| (t.map(f32::abs).mean()) as f32;
            let d = dir_a(cfg, sat, mean_abs(act_grad), mean_abs(act_mean), gate_store.data()[0]);
            Ok(Tensor::scalar(d))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(kind: DirKind) -> DirConfig {
        DirConfig::new(kind)
    }

    /// Paper property (i): Unsat -> dir strictly positive, for all variants.
    #[test]
    fn unsat_is_strictly_positive() {
        let mut rng = crate::util::rng::SplitMix64::new(0);
        for kind in [DirKind::Dir1, DirKind::Dir2, DirKind::Dir3] {
            let c = cfg(kind);
            for _ in 0..2000 {
                let grad = rng.uniform(-5.0, 5.0) as f32;
                let w = rng.uniform(-5.0, 5.0) as f32;
                let g = rng.uniform(0.5, 5.5) as f32;
                assert!(dir_w(&c, Sat::Unsatisfied, grad, w, g) > 0.0);
                assert!(dir_a(&c, Sat::Unsatisfied, grad, w, g) > 0.0);
            }
        }
    }

    /// Paper property (ii): Sat -> dir <= 0, for all variants.
    #[test]
    fn sat_is_nonpositive() {
        let mut rng = crate::util::rng::SplitMix64::new(1);
        for kind in [DirKind::Dir1, DirKind::Dir2, DirKind::Dir3] {
            let c = cfg(kind);
            for _ in 0..2000 {
                let grad = rng.uniform(-5.0, 5.0) as f32;
                let w = rng.uniform(-5.0, 5.0) as f32;
                let g = rng.uniform(0.5, 5.5) as f32;
                assert!(dir_w(&c, Sat::Satisfied, grad, w, g) <= 0.0);
                assert!(dir_a(&c, Sat::Satisfied, grad, w, g) <= 0.0);
            }
        }
    }

    /// dir1 Unsat: small |grad| -> big positive step (bit-width drops fast).
    #[test]
    fn dir1_prefers_shrinking_small_gradients() {
        let c = cfg(DirKind::Dir1);
        let small = dir_w(&c, Sat::Unsatisfied, 1e-4, 0.0, 1.0);
        let large = dir_w(&c, Sat::Unsatisfied, 10.0, 0.0, 1.0);
        assert!(small > large);
    }

    /// dir2 Sat: large weights grow their gates back faster.
    #[test]
    fn dir2_sat_prefers_large_weights() {
        let c = cfg(DirKind::Dir2);
        let big_w = dir_w(&c, Sat::Satisfied, 0.0, 3.0, 1.0);
        let small_w = dir_w(&c, Sat::Satisfied, 0.0, 0.01, 1.0);
        assert!(big_w < small_w); // more negative = faster growth
    }

    /// dir3 uses the Taylor magnitude |grad| + |w| in both phases.
    #[test]
    fn dir3_sat_depends_on_grad() {
        let c = cfg(DirKind::Dir3);
        let a = dir_w(&c, Sat::Satisfied, 2.0, 1.0, 1.0);
        let b = dir_w(&c, Sat::Satisfied, 0.0, 1.0, 1.0);
        assert!(a < b);
        // dir1's Sat by contrast ignores grad
        let c1 = cfg(DirKind::Dir1);
        assert_eq!(
            dir_w(&c1, Sat::Satisfied, 2.0, 1.0, 1.0),
            dir_w(&c1, Sat::Satisfied, 0.0, 1.0, 1.0)
        );
    }

    /// Unsat reciprocal is clipped into [K1, K2] (bounded, paper Section 2.3).
    #[test]
    fn unsat_clipped() {
        for kind in [DirKind::Dir1, DirKind::Dir2, DirKind::Dir3] {
            let c = cfg(kind);
            assert_eq!(dir_w(&c, Sat::Unsatisfied, 0.0, 0.0, 1.0), c.clip_max);
            assert_eq!(dir_w(&c, Sat::Unsatisfied, 1e12, 0.0, 1.0), c.clip_min);
        }
    }

    #[test]
    fn layer_granularity_aggregates_mean() {
        let c = cfg(DirKind::Dir1);
        let grad = Tensor::new(vec![4], vec![1.0, -1.0, 3.0, -3.0]).unwrap();
        let w = Tensor::zeros(&[4]);
        let store = Tensor::scalar(1.0);
        let d =
            dir_tensor_w(&c, Granularity::Layer, Sat::Unsatisfied, &grad, &w, &store).unwrap();
        // mean |grad| = 2 -> dir = 1/2
        assert_eq!(d.len(), 1);
        assert!((d.data()[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn individual_granularity_elementwise() {
        let c = cfg(DirKind::Dir1);
        let grad = Tensor::new(vec![2], vec![0.5, 2.0]).unwrap();
        let w = Tensor::zeros(&[2]);
        let store = Tensor::new(vec![2], vec![1.0, 1.0]).unwrap();
        let d = dir_tensor_w(&c, Granularity::Individual, Sat::Unsatisfied, &grad, &w, &store)
            .unwrap();
        assert!((d.data()[0] - 2.0).abs() < 1e-6);
        assert!((d.data()[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let c = cfg(DirKind::Dir1);
        let grad = Tensor::zeros(&[3]);
        let w = Tensor::zeros(&[4]);
        let store = Tensor::zeros(&[4]);
        assert!(dir_tensor_w(&c, Granularity::Individual, Sat::Satisfied, &grad, &w, &store)
            .is_err());
    }
}
