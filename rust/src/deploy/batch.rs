//! Request batching for the serve path.
//!
//! Single-sample `infer` requests are aggregated into batched
//! [`Engine::infer_batch`] invocations so the engine's per-layer weight
//! unpacking (and the cache-friendly batched matmuls) amortize across
//! requests. Two flush triggers, the standard micro-batching pair:
//!
//! * **size** — the queue reached `max_batch` pending requests;
//! * **deadline** — the *oldest* pending request has waited `max_delay`.
//!
//! A third flush kind, **drain**, is the explicit end-of-stream
//! [`flush_at`](RequestBatcher::flush_at) call. Exactly one of the three
//! counters is bumped per flush event, so the stats hold the invariant
//! `flushes == size_flushes + deadline_flushes + drain_flushes`; the
//! engine invocations a flush fans out into (a drain spanning several
//! `max_batch` chunks makes more than one) are counted separately as
//! `engine_calls`, the denominator of the amortization factor.
//!
//! The batcher is deterministic and clock-injected: `submit_at` / `poll_at`
//! take the caller's `Instant`, so tests drive time explicitly and the
//! serve loop passes `Instant::now()`. Completions preserve submission
//! order (FIFO, like `data::Batcher::sequential`), and every completion
//! reports its queue delay, chunk batch-wait, engine compute time, and
//! the batch size it rode in — the raw material for `serve-bench`'s
//! latency percentiles and the telemetry spine's stage histograms.
//! [`BatcherStats`] additionally accrues enqueue-to-flush wait (sum +
//! max, per flush reason), the arrival-rate signal adaptive batching
//! will tune against. The per-completion queue-delay / batch-wait /
//! compute split is what the windowed signal plane
//! ([`telemetry::window`](super::telemetry::window)) consumes live: each
//! served request lands those durations in both the cumulative and the
//! trailing-window stage histograms.
//!
//! The batcher holds its engine behind an [`Arc`], so several batchers —
//! the per-shard queues of [`super::pool::WorkerPool`] — can share one
//! engine and its decoded-weight cache. Each batcher also owns a
//! [`Scratch`] plus input/logits buffers that persist across flushes, so
//! a warm flush invokes the engine through
//! [`Engine::infer_batch_into`](super::Engine::infer_batch_into) with
//! zero heap allocations inside the engine.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::engine::Engine;
use super::kernels::argmax;
use super::plan::Scratch;

/// Flush policy of a [`RequestBatcher`].
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Flush as soon as this many requests are pending (>= 1).
    pub max_batch: usize,
    /// Flush once the oldest pending request has waited this long.
    pub max_delay: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self { max_batch: 32, max_delay: Duration::from_millis(2) }
    }
}

/// One finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Submission-order id (monotone from 0).
    pub id: u64,
    pub logits: Vec<f32>,
    /// Argmax class of `logits`.
    pub predicted: usize,
    /// Time spent queued before its batch was flushed.
    pub queue_delay: Duration,
    /// Flush start → this request's engine invocation starting. Zero for
    /// the first `max_batch` chunk; later chunks of a large drain wait
    /// behind the earlier chunks' engine calls.
    pub batch_wait: Duration,
    /// Wall-clock duration of the engine invocation this request rode in.
    pub compute: Duration,
    /// Size of the engine invocation this request rode in.
    pub batch_size: usize,
}

/// Cumulative batcher statistics.
///
/// Invariant: `flushes == size_flushes + deadline_flushes + drain_flushes`
/// — every flush event has exactly one trigger. `engine_calls >= flushes`:
/// one flush event drains the whole queue in `max_batch`-sized engine
/// invocations, so a drain of 70 pending requests at `max_batch = 32` is
/// one flush (one `drain_flushes`) but three engine calls.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatcherStats {
    pub submitted: u64,
    pub completed: u64,
    /// Flush events (any trigger).
    pub flushes: u64,
    /// Flushes triggered by the queue reaching `max_batch`.
    pub size_flushes: u64,
    /// Flushes triggered by the oldest request reaching `max_delay`.
    pub deadline_flushes: u64,
    /// Explicit end-of-stream drains that found pending requests.
    pub drain_flushes: u64,
    /// `Engine::infer_batch` invocations across all flushes.
    pub engine_calls: u64,
    /// Summed enqueue-to-flush waits (µs) of requests released by
    /// size-triggered flushes — with the matching flush counter this is
    /// the observed arrival-rate signal adaptive batching tunes against.
    pub size_wait_us: u64,
    /// Largest single enqueue-to-flush wait (µs) in any size flush.
    pub size_wait_max_us: u64,
    /// Summed enqueue-to-flush waits (µs) released by deadline flushes.
    pub deadline_wait_us: u64,
    /// Largest single enqueue-to-flush wait (µs) in any deadline flush.
    pub deadline_wait_max_us: u64,
    /// Summed enqueue-to-flush waits (µs) released by drain flushes.
    pub drain_wait_us: u64,
    /// Largest single enqueue-to-flush wait (µs) in any drain flush.
    pub drain_wait_max_us: u64,
}

impl BatcherStats {
    /// Mean samples per engine invocation (the amortization factor).
    pub fn mean_batch(&self) -> f64 {
        if self.engine_calls == 0 {
            0.0
        } else {
            self.completed as f64 / self.engine_calls as f64
        }
    }

    /// Total enqueue-to-flush wait (µs) across every flush reason.
    pub fn queue_wait_us(&self) -> u64 {
        self.size_wait_us + self.deadline_wait_us + self.drain_wait_us
    }

    /// Largest single enqueue-to-flush wait (µs) across every reason.
    pub fn queue_wait_max_us(&self) -> u64 {
        self.size_wait_max_us
            .max(self.deadline_wait_max_us)
            .max(self.drain_wait_max_us)
    }

    /// Per-reason wait invariant: no flushes of a reason means no wait
    /// accrued under it, and a max never exceeds its sum.
    fn wait_consistent(flushes: u64, sum_us: u64, max_us: u64) -> bool {
        (flushes > 0 || (sum_us == 0 && max_us == 0)) && max_us <= sum_us
    }

    /// The counter invariant; asserted by tests, cheap enough to check in
    /// debug servers.
    pub fn consistent(&self) -> bool {
        self.flushes == self.size_flushes + self.deadline_flushes + self.drain_flushes
            && self.engine_calls >= self.flushes
            && self.completed <= self.submitted
            && Self::wait_consistent(self.size_flushes, self.size_wait_us, self.size_wait_max_us)
            && Self::wait_consistent(
                self.deadline_flushes,
                self.deadline_wait_us,
                self.deadline_wait_max_us,
            )
            && Self::wait_consistent(
                self.drain_flushes,
                self.drain_wait_us,
                self.drain_wait_max_us,
            )
    }

    /// Fold another shard's counters into this one (pool-wide totals).
    /// Sums of consistent stats stay consistent — the invariant is linear
    /// in every counter — so merging never masks a shard-level violation
    /// that was not already there.
    pub fn merge(&mut self, other: &BatcherStats) {
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.flushes += other.flushes;
        self.size_flushes += other.size_flushes;
        self.deadline_flushes += other.deadline_flushes;
        self.drain_flushes += other.drain_flushes;
        self.engine_calls += other.engine_calls;
        // Wait sums add; maxes take the max. `a_max <= a_sum` on both
        // sides gives `max(a_max, b_max) <= a_sum + b_sum`, so merged
        // stats stay `consistent()`.
        self.size_wait_us += other.size_wait_us;
        self.size_wait_max_us = self.size_wait_max_us.max(other.size_wait_max_us);
        self.deadline_wait_us += other.deadline_wait_us;
        self.deadline_wait_max_us = self.deadline_wait_max_us.max(other.deadline_wait_max_us);
        self.drain_wait_us += other.drain_wait_us;
        self.drain_wait_max_us = self.drain_wait_max_us.max(other.drain_wait_max_us);
    }

    /// Fold a whole set of shard stats (a pool's, or every drained pool of
    /// a router entry) into one total.
    pub fn merge_all<'a>(stats: impl IntoIterator<Item = &'a BatcherStats>) -> BatcherStats {
        let mut out = BatcherStats::default();
        for s in stats {
            out.merge(s);
        }
        out
    }
}

struct Pending {
    id: u64,
    x: Vec<f32>,
    enqueued: Instant,
}

/// Which trigger fired a flush — routes the queue-wait accrual to the
/// matching per-reason counters.
#[derive(Debug, Clone, Copy)]
enum FlushKind {
    Size,
    Deadline,
    Drain,
}

/// Aggregates single-sample requests into batched engine invocations.
pub struct RequestBatcher {
    engine: Arc<Engine>,
    cfg: BatchConfig,
    queue: VecDeque<Pending>,
    next_id: u64,
    stats: BatcherStats,
    /// Engine working memory, reused across flushes (grown to the plan's
    /// maxima on the first full batch, never shrunk).
    scratch: Scratch,
    /// Gathered batch input, reused across flushes.
    xbuf: Vec<f32>,
    /// Engine output buffer, reused across flushes.
    logits_buf: Vec<f32>,
}

impl RequestBatcher {
    /// Wrap an engine (owned or already-shared `Arc` — the serve pool
    /// passes one `Arc<Engine>` to every shard's batcher).
    pub fn new(engine: impl Into<Arc<Engine>>, cfg: BatchConfig) -> Result<Self> {
        if cfg.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        Ok(Self {
            engine: engine.into(),
            cfg,
            queue: VecDeque::new(),
            next_id: 0,
            stats: BatcherStats::default(),
            scratch: Scratch::new(),
            xbuf: Vec::new(),
            logits_buf: Vec::new(),
        })
    }

    /// Enqueue one request at time `now`; returns the completions of any
    /// size-triggered flush (empty while the batch is still filling).
    pub fn submit_at(&mut self, x: Vec<f32>, now: Instant) -> Result<Vec<Completion>> {
        if x.len() != self.engine.input_len() {
            bail!("request has {} values, model wants {}", x.len(), self.engine.input_len());
        }
        let id = self.next_id;
        self.next_id += 1;
        self.stats.submitted += 1;
        self.queue.push_back(Pending { id, x, enqueued: now });
        if self.queue.len() >= self.cfg.max_batch {
            self.stats.flushes += 1;
            self.stats.size_flushes += 1;
            return self.run_flush(now, FlushKind::Size);
        }
        Ok(Vec::new())
    }

    /// Deadline check at time `now`: flushes if the oldest pending request
    /// has waited `max_delay` or longer.
    pub fn poll_at(&mut self, now: Instant) -> Result<Vec<Completion>> {
        match self.queue.front() {
            Some(p) if now.duration_since(p.enqueued) >= self.cfg.max_delay => {
                self.stats.flushes += 1;
                self.stats.deadline_flushes += 1;
                self.run_flush(now, FlushKind::Deadline)
            }
            _ => Ok(Vec::new()),
        }
    }

    /// Flush every pending request now (in `max_batch`-sized engine calls),
    /// regardless of triggers — end-of-stream drain. A no-op on an empty
    /// queue (no flush event is counted).
    pub fn flush_at(&mut self, now: Instant) -> Result<Vec<Completion>> {
        if self.queue.is_empty() {
            return Ok(Vec::new());
        }
        self.stats.flushes += 1;
        self.stats.drain_flushes += 1;
        self.run_flush(now, FlushKind::Drain)
    }

    /// One flush event: drain the whole queue in `max_batch`-sized engine
    /// invocations. Trigger counters are the caller's job; this counts
    /// `engine_calls`, `completed`, and the per-reason queue-wait accrual.
    ///
    /// Queue delays use the injected `now` (deterministic under test
    /// clocks); the `batch_wait`/`compute` spans time real engine work, so
    /// they read the wall clock directly.
    fn run_flush(&mut self, now: Instant, kind: FlushKind) -> Result<Vec<Completion>> {
        let flush_started = Instant::now();
        let mut wait_sum_us = 0u64;
        let mut wait_max_us = 0u64;
        let mut out = Vec::with_capacity(self.queue.len());
        while !self.queue.is_empty() {
            let take = self.queue.len().min(self.cfg.max_batch);
            let batch: Vec<Pending> = self.queue.drain(..take).collect();
            self.xbuf.clear();
            for p in &batch {
                self.xbuf.extend_from_slice(&p.x);
            }
            let call_started = Instant::now();
            let batch_wait = call_started.duration_since(flush_started);
            self.engine.infer_batch_into(
                &self.xbuf,
                take,
                &mut self.scratch,
                &mut self.logits_buf,
            )?;
            let compute = call_started.elapsed();
            let c = self.engine.num_classes();
            self.stats.engine_calls += 1;
            self.stats.completed += take as u64;
            for (k, p) in batch.into_iter().enumerate() {
                let row = self.logits_buf[k * c..(k + 1) * c].to_vec();
                let queue_delay = now.duration_since(p.enqueued);
                let us = queue_delay.as_micros() as u64;
                wait_sum_us += us;
                wait_max_us = wait_max_us.max(us);
                out.push(Completion {
                    id: p.id,
                    predicted: argmax(&row),
                    logits: row,
                    queue_delay,
                    batch_wait,
                    compute,
                    batch_size: take,
                });
            }
        }
        match kind {
            FlushKind::Size => {
                self.stats.size_wait_us += wait_sum_us;
                self.stats.size_wait_max_us = self.stats.size_wait_max_us.max(wait_max_us);
            }
            FlushKind::Deadline => {
                self.stats.deadline_wait_us += wait_sum_us;
                self.stats.deadline_wait_max_us =
                    self.stats.deadline_wait_max_us.max(wait_max_us);
            }
            FlushKind::Drain => {
                self.stats.drain_wait_us += wait_sum_us;
                self.stats.drain_wait_max_us = self.stats.drain_wait_max_us.max(wait_max_us);
            }
        }
        Ok(out)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Enqueue time of the oldest pending request — what a serve loop
    /// sleeps against to wake exactly at the deadline flush.
    pub fn oldest_enqueued(&self) -> Option<Instant> {
        self.queue.front().map(|p| p.enqueued)
    }

    pub fn stats(&self) -> BatcherStats {
        self.stats
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Dissolve into the wrapped (possibly shared) engine — pending
    /// requests are dropped; call [`flush_at`](Self::flush_at) first to
    /// drain.
    pub fn into_engine(self) -> Arc<Engine> {
        self.engine
    }
}
