//! Request batching for the serve path.
//!
//! Single-sample `infer` requests are aggregated into batched
//! [`Engine::infer_batch`] invocations so the engine's per-layer weight
//! unpacking (and the cache-friendly batched matmuls) amortize across
//! requests. Two flush triggers, the standard micro-batching pair:
//!
//! * **size** — the queue reached `max_batch` pending requests;
//! * **deadline** — the *oldest* pending request has waited `max_delay`.
//!
//! A third flush kind, **drain**, is the explicit end-of-stream
//! [`flush_at`](RequestBatcher::flush_at) call. Exactly one of the three
//! counters is bumped per flush event, so the stats hold the invariant
//! `flushes == size_flushes + deadline_flushes + drain_flushes`; the
//! engine invocations a flush fans out into (a drain spanning several
//! `max_batch` chunks makes more than one) are counted separately as
//! `engine_calls`, the denominator of the amortization factor.
//!
//! The batcher is deterministic and clock-injected: `submit_at` / `poll_at`
//! take the caller's `Instant`, so tests drive time explicitly and the
//! serve loop passes `Instant::now()`. Completions preserve submission
//! order (FIFO, like `data::Batcher::sequential`), and every completion
//! reports its queue delay and the batch size it rode in — the raw
//! material for `serve-bench`'s latency percentiles.
//!
//! The batcher holds its engine behind an [`Arc`], so several batchers —
//! the per-shard queues of [`super::pool::WorkerPool`] — can share one
//! engine and its decoded-weight cache.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::engine::{argmax, Engine};

/// Flush policy of a [`RequestBatcher`].
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Flush as soon as this many requests are pending (>= 1).
    pub max_batch: usize,
    /// Flush once the oldest pending request has waited this long.
    pub max_delay: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self { max_batch: 32, max_delay: Duration::from_millis(2) }
    }
}

/// One finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Submission-order id (monotone from 0).
    pub id: u64,
    pub logits: Vec<f32>,
    /// Argmax class of `logits`.
    pub predicted: usize,
    /// Time spent queued before its batch was flushed.
    pub queue_delay: Duration,
    /// Size of the engine invocation this request rode in.
    pub batch_size: usize,
}

/// Cumulative batcher statistics.
///
/// Invariant: `flushes == size_flushes + deadline_flushes + drain_flushes`
/// — every flush event has exactly one trigger. `engine_calls >= flushes`:
/// one flush event drains the whole queue in `max_batch`-sized engine
/// invocations, so a drain of 70 pending requests at `max_batch = 32` is
/// one flush (one `drain_flushes`) but three engine calls.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatcherStats {
    pub submitted: u64,
    pub completed: u64,
    /// Flush events (any trigger).
    pub flushes: u64,
    /// Flushes triggered by the queue reaching `max_batch`.
    pub size_flushes: u64,
    /// Flushes triggered by the oldest request reaching `max_delay`.
    pub deadline_flushes: u64,
    /// Explicit end-of-stream drains that found pending requests.
    pub drain_flushes: u64,
    /// `Engine::infer_batch` invocations across all flushes.
    pub engine_calls: u64,
}

impl BatcherStats {
    /// Mean samples per engine invocation (the amortization factor).
    pub fn mean_batch(&self) -> f64 {
        if self.engine_calls == 0 {
            0.0
        } else {
            self.completed as f64 / self.engine_calls as f64
        }
    }

    /// The counter invariant; asserted by tests, cheap enough to check in
    /// debug servers.
    pub fn consistent(&self) -> bool {
        self.flushes == self.size_flushes + self.deadline_flushes + self.drain_flushes
            && self.engine_calls >= self.flushes
            && self.completed <= self.submitted
    }

    /// Fold another shard's counters into this one (pool-wide totals).
    /// Sums of consistent stats stay consistent — the invariant is linear
    /// in every counter — so merging never masks a shard-level violation
    /// that was not already there.
    pub fn merge(&mut self, other: &BatcherStats) {
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.flushes += other.flushes;
        self.size_flushes += other.size_flushes;
        self.deadline_flushes += other.deadline_flushes;
        self.drain_flushes += other.drain_flushes;
        self.engine_calls += other.engine_calls;
    }

    /// Fold a whole set of shard stats (a pool's, or every drained pool of
    /// a router entry) into one total.
    pub fn merge_all<'a>(stats: impl IntoIterator<Item = &'a BatcherStats>) -> BatcherStats {
        let mut out = BatcherStats::default();
        for s in stats {
            out.merge(s);
        }
        out
    }
}

struct Pending {
    id: u64,
    x: Vec<f32>,
    enqueued: Instant,
}

/// Aggregates single-sample requests into batched engine invocations.
pub struct RequestBatcher {
    engine: Arc<Engine>,
    cfg: BatchConfig,
    queue: VecDeque<Pending>,
    next_id: u64,
    stats: BatcherStats,
}

impl RequestBatcher {
    /// Wrap an engine (owned or already-shared `Arc` — the serve pool
    /// passes one `Arc<Engine>` to every shard's batcher).
    pub fn new(engine: impl Into<Arc<Engine>>, cfg: BatchConfig) -> Result<Self> {
        if cfg.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        Ok(Self {
            engine: engine.into(),
            cfg,
            queue: VecDeque::new(),
            next_id: 0,
            stats: BatcherStats::default(),
        })
    }

    /// Enqueue one request at time `now`; returns the completions of any
    /// size-triggered flush (empty while the batch is still filling).
    pub fn submit_at(&mut self, x: Vec<f32>, now: Instant) -> Result<Vec<Completion>> {
        if x.len() != self.engine.input_len() {
            bail!("request has {} values, model wants {}", x.len(), self.engine.input_len());
        }
        let id = self.next_id;
        self.next_id += 1;
        self.stats.submitted += 1;
        self.queue.push_back(Pending { id, x, enqueued: now });
        if self.queue.len() >= self.cfg.max_batch {
            self.stats.flushes += 1;
            self.stats.size_flushes += 1;
            return self.run_flush(now);
        }
        Ok(Vec::new())
    }

    /// Deadline check at time `now`: flushes if the oldest pending request
    /// has waited `max_delay` or longer.
    pub fn poll_at(&mut self, now: Instant) -> Result<Vec<Completion>> {
        match self.queue.front() {
            Some(p) if now.duration_since(p.enqueued) >= self.cfg.max_delay => {
                self.stats.flushes += 1;
                self.stats.deadline_flushes += 1;
                self.run_flush(now)
            }
            _ => Ok(Vec::new()),
        }
    }

    /// Flush every pending request now (in `max_batch`-sized engine calls),
    /// regardless of triggers — end-of-stream drain. A no-op on an empty
    /// queue (no flush event is counted).
    pub fn flush_at(&mut self, now: Instant) -> Result<Vec<Completion>> {
        if self.queue.is_empty() {
            return Ok(Vec::new());
        }
        self.stats.flushes += 1;
        self.stats.drain_flushes += 1;
        self.run_flush(now)
    }

    /// One flush event: drain the whole queue in `max_batch`-sized engine
    /// invocations. Trigger counters are the caller's job; this counts
    /// only `engine_calls` and `completed`.
    fn run_flush(&mut self, now: Instant) -> Result<Vec<Completion>> {
        let mut out = Vec::with_capacity(self.queue.len());
        while !self.queue.is_empty() {
            let take = self.queue.len().min(self.cfg.max_batch);
            let batch: Vec<Pending> = self.queue.drain(..take).collect();
            let in_len = self.engine.input_len();
            let mut xs = Vec::with_capacity(take * in_len);
            for p in &batch {
                xs.extend_from_slice(&p.x);
            }
            let logits = self.engine.infer_batch(&xs, take)?;
            let c = self.engine.num_classes();
            self.stats.engine_calls += 1;
            self.stats.completed += take as u64;
            for (k, p) in batch.into_iter().enumerate() {
                let row = logits[k * c..(k + 1) * c].to_vec();
                out.push(Completion {
                    id: p.id,
                    predicted: argmax(&row),
                    logits: row,
                    queue_delay: now.duration_since(p.enqueued),
                    batch_size: take,
                });
            }
        }
        Ok(out)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Enqueue time of the oldest pending request — what a serve loop
    /// sleeps against to wake exactly at the deadline flush.
    pub fn oldest_enqueued(&self) -> Option<Instant> {
        self.queue.front().map(|p| p.enqueued)
    }

    pub fn stats(&self) -> BatcherStats {
        self.stats
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Dissolve into the wrapped (possibly shared) engine — pending
    /// requests are dropped; call [`flush_at`](Self::flush_at) first to
    /// drain.
    pub fn into_engine(self) -> Arc<Engine> {
        self.engine
    }
}
