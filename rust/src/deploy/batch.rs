//! Request batching for the serve path.
//!
//! Single-sample `infer` requests are aggregated into batched
//! [`Engine::infer_batch`] invocations so the engine's per-layer weight
//! unpacking (and the cache-friendly batched matmuls) amortize across
//! requests. Two flush triggers, the standard micro-batching pair:
//!
//! * **size** — the queue reached `max_batch` pending requests;
//! * **deadline** — the *oldest* pending request has waited `max_delay`.
//!
//! The batcher is deterministic and clock-injected: `submit_at` / `poll_at`
//! take the caller's `Instant`, so tests drive time explicitly and the
//! serve loop passes `Instant::now()`. Completions preserve submission
//! order (FIFO, like `data::Batcher::sequential`), and every completion
//! reports its queue delay and the batch size it rode in — the raw
//! material for `serve-bench`'s latency percentiles.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::engine::{argmax, Engine};

/// Flush policy of a [`RequestBatcher`].
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Flush as soon as this many requests are pending (>= 1).
    pub max_batch: usize,
    /// Flush once the oldest pending request has waited this long.
    pub max_delay: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self { max_batch: 32, max_delay: Duration::from_millis(2) }
    }
}

/// One finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Submission-order id (monotone from 0).
    pub id: u64,
    pub logits: Vec<f32>,
    /// Argmax class of `logits`.
    pub predicted: usize,
    /// Time spent queued before its batch was flushed.
    pub queue_delay: Duration,
    /// Size of the engine invocation this request rode in.
    pub batch_size: usize,
}

/// Cumulative batcher statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatcherStats {
    pub submitted: u64,
    pub completed: u64,
    pub flushes: u64,
    pub size_flushes: u64,
    pub deadline_flushes: u64,
}

impl BatcherStats {
    /// Mean samples per engine invocation (the amortization factor).
    pub fn mean_batch(&self) -> f64 {
        if self.flushes == 0 {
            0.0
        } else {
            self.completed as f64 / self.flushes as f64
        }
    }
}

struct Pending {
    id: u64,
    x: Vec<f32>,
    enqueued: Instant,
}

/// Aggregates single-sample requests into batched engine invocations.
pub struct RequestBatcher {
    engine: Engine,
    cfg: BatchConfig,
    queue: VecDeque<Pending>,
    next_id: u64,
    stats: BatcherStats,
}

impl RequestBatcher {
    pub fn new(engine: Engine, cfg: BatchConfig) -> Result<Self> {
        if cfg.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        Ok(Self { engine, cfg, queue: VecDeque::new(), next_id: 0, stats: BatcherStats::default() })
    }

    /// Enqueue one request at time `now`; returns the completions of any
    /// size-triggered flush (empty while the batch is still filling).
    pub fn submit_at(&mut self, x: Vec<f32>, now: Instant) -> Result<Vec<Completion>> {
        if x.len() != self.engine.input_len() {
            bail!("request has {} values, model wants {}", x.len(), self.engine.input_len());
        }
        let id = self.next_id;
        self.next_id += 1;
        self.stats.submitted += 1;
        self.queue.push_back(Pending { id, x, enqueued: now });
        if self.queue.len() >= self.cfg.max_batch {
            self.stats.size_flushes += 1;
            return self.flush_at(now);
        }
        Ok(Vec::new())
    }

    /// Deadline check at time `now`: flushes if the oldest pending request
    /// has waited `max_delay` or longer.
    pub fn poll_at(&mut self, now: Instant) -> Result<Vec<Completion>> {
        match self.queue.front() {
            Some(p) if now.duration_since(p.enqueued) >= self.cfg.max_delay => {
                self.stats.deadline_flushes += 1;
                self.flush_at(now)
            }
            _ => Ok(Vec::new()),
        }
    }

    /// Flush every pending request now (in `max_batch`-sized engine calls),
    /// regardless of triggers — end-of-stream drain.
    pub fn flush_at(&mut self, now: Instant) -> Result<Vec<Completion>> {
        let mut out = Vec::with_capacity(self.queue.len());
        while !self.queue.is_empty() {
            let take = self.queue.len().min(self.cfg.max_batch);
            let batch: Vec<Pending> = self.queue.drain(..take).collect();
            let in_len = self.engine.input_len();
            let mut xs = Vec::with_capacity(take * in_len);
            for p in &batch {
                xs.extend_from_slice(&p.x);
            }
            let logits = self.engine.infer_batch(&xs, take)?;
            let c = self.engine.num_classes();
            self.stats.flushes += 1;
            self.stats.completed += take as u64;
            for (k, p) in batch.into_iter().enumerate() {
                let row = logits[k * c..(k + 1) * c].to_vec();
                out.push(Completion {
                    id: p.id,
                    predicted: argmax(&row),
                    logits: row,
                    queue_delay: now.duration_since(p.enqueued),
                    batch_size: take,
                });
            }
        }
        Ok(out)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn stats(&self) -> BatcherStats {
        self.stats
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Dissolve into the wrapped engine (pending requests are dropped —
    /// call [`flush_at`](Self::flush_at) first to drain).
    pub fn into_engine(self) -> Engine {
        self.engine
    }
}
