//! Deploy-side telemetry: stage-latency histograms, per-request traces,
//! and per-model × per-status counters behind the `/metrics` and `/stats`
//! exposition routes.
//!
//! Design constraints, in order:
//!
//! - **std-only, allocation-free on the hot path.** [`Histogram::record`]
//!   and [`StatusCounters::observe`] are a handful of relaxed atomic adds
//!   — no locks, no heap. Allocation happens only when a completed
//!   request's [`Trace`] is assembled and pushed onto the bounded ring,
//!   i.e. once per *reply*, never per atomic sample.
//! - **Deterministic in tests.** All wall-clock reads go through the
//!   [`Clock`] trait: [`RealClock`] in production, [`ManualClock`] in
//!   tests so span math is exact.
//! - **Analyzer-clean.** Every atomic mutation lives in a designated
//!   choke function (`record`, `observe`, `count_connection`,
//!   `next_request_id`) enforced by `cgmq analyze`'s counter-choke rule,
//!   every `Ordering::` carries an `// ordering:` justification, and the
//!   metric names emitted here are kept in sync with the README table by
//!   the `metrics-name-sync` rule.
//!
//! The histogram is log₂-bucketed over microseconds: bucket 0 holds
//! `0..=1µs`, bucket `b` holds `(2^(b-1), 2^b]` µs. Powers of two land
//! exactly on their bucket's upper bound, which is what the property
//! tests pin down. Quantile queries return `(lo, hi)` *bounds* using the
//! same nearest-rank convention as the exact
//! [`percentiles_ms`](crate::bench_harness::percentiles_ms) oracle
//! (0-based index `ceil((count - 1) * q)` of the sorted samples), so the
//! exact percentile provably lies inside the returned bracket.
//!
//! Beside every cumulative series, [`window`] keeps the same signal over
//! a trailing sliding window (lazily rotated epoch-bucket rings — see the
//! submodule docs): per-model arrival rates, in-window responses by
//! status, windowed stage/whole-request latency distributions, and the
//! top-logit confidence-margin distribution of 200 replies. Those are
//! the live signals `GET /livez`, `cgmq watch`, and ROADMAP's adaptive
//! batching / cascade routing policies read.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub mod window;

pub use window::{
    ModelWindow, WindowSnapshot, WindowedCounter, WindowedHistogram, DEFAULT_WINDOW_EPOCH,
    WINDOW_SLOTS,
};

use super::router::RouteStats;

// ---------------------------------------------------------------------------
// Clock
// ---------------------------------------------------------------------------

/// Monotonic time source for trace timestamps and span marks.
///
/// Production uses [`RealClock`]; tests use [`ManualClock`] and advance it
/// explicitly, making every span in a [`Trace`] a deterministic number.
pub trait Clock: Send + Sync {
    /// Monotonic time elapsed since the clock's epoch.
    fn now(&self) -> Duration;
}

/// [`Clock`] backed by [`Instant`]; epoch is the moment of construction.
#[derive(Debug)]
pub struct RealClock {
    epoch: Instant,
}

impl Default for RealClock {
    fn default() -> Self {
        RealClock { epoch: Instant::now() }
    }
}

impl Clock for RealClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }
}

/// Test [`Clock`]: starts at zero, moves only when told to.
#[derive(Debug, Default)]
pub struct ManualClock {
    now_us: AtomicU64,
}

impl ManualClock {
    /// Advance the clock by `d` (truncated to whole microseconds).
    pub fn advance(&self, d: Duration) {
        // ordering: relaxed — test-only clock; tests sequence advance()
        // and now() on the same thread or across a join, never racing.
        self.now_us.fetch_add(d.as_micros() as u64, Ordering::Relaxed);
    }
}

impl Clock for ManualClock {
    fn now(&self) -> Duration {
        // ordering: relaxed — see advance(); reads are test-sequenced.
        Duration::from_micros(self.now_us.load(Ordering::Relaxed))
    }
}

// ---------------------------------------------------------------------------
// Stages
// ---------------------------------------------------------------------------

/// Number of [`Stage`]s — the length of every per-stage array.
pub const STAGES: usize = 7;

/// The deploy pipeline stages a request passes through, in order.
///
/// | stage | measures |
/// |---|---|
/// | `Accept` | first request-line byte → request fully parsed off the wire |
/// | `Parse` | JSON body decode + input validation |
/// | `Admit` | router admission (`try_submit`), including the shed decision |
/// | `QueueWait` | enqueue → flush start inside the shard batcher |
/// | `BatchWait` | flush start → this request's engine call starts |
/// | `Compute` | the engine forward pass for the batch chunk |
/// | `Reply` | completion handed back → HTTP response serialized |
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Accept,
    Parse,
    Admit,
    QueueWait,
    BatchWait,
    Compute,
    Reply,
}

impl Stage {
    /// Every stage, in pipeline order (also the array index order).
    pub const ALL: [Stage; STAGES] = [
        Stage::Accept,
        Stage::Parse,
        Stage::Admit,
        Stage::QueueWait,
        Stage::BatchWait,
        Stage::Compute,
        Stage::Reply,
    ];

    /// Stable label used in `/metrics` and `/stats`.
    pub fn as_str(self) -> &'static str {
        match self {
            Stage::Accept => "accept",
            Stage::Parse => "parse",
            Stage::Admit => "admit",
            Stage::QueueWait => "queue_wait",
            Stage::BatchWait => "batch_wait",
            Stage::Compute => "compute",
            Stage::Reply => "reply",
        }
    }
}

// ---------------------------------------------------------------------------
// Histogram
// ---------------------------------------------------------------------------

/// Number of log₂ buckets. The top bucket's nominal upper bound is
/// `2^39 µs` ≈ 6.4 days; anything slower clamps into it.
pub const BUCKETS: usize = 40;

/// Upper bound of bucket `b` in microseconds (`1` for bucket 0, else
/// `2^b`). Bucket `b` covers `(2^(b-1), 2^b]` µs.
pub fn bucket_upper_us(b: usize) -> u64 {
    1u64 << b.min(BUCKETS - 1)
}

fn bucket_index(us: u64) -> usize {
    if us <= 1 {
        0
    } else {
        // Smallest b with 2^b >= us, i.e. the bucket whose upper bound
        // is the first power of two at or above the sample.
        let b = 64 - (us - 1).leading_zeros() as usize;
        b.min(BUCKETS - 1)
    }
}

/// Fixed-bucket log₂ latency histogram over relaxed atomics.
///
/// Concurrent [`record`](Histogram::record) calls never block; a
/// [`snapshot`](Histogram::snapshot) taken mid-record may be torn by a
/// few in-flight samples (`count` vs the bucket sum), which is fine for
/// display and exact once the recorders are quiescent (post-drain).
pub struct Histogram {
    cells: [AtomicU64; BUCKETS],
    recorded: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            cells: std::array::from_fn(|_| AtomicU64::new(0)),
            recorded: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// Record one sample. Sole mutation point of the histogram counters
    /// (`cgmq analyze` counter-choke enforced).
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        let b = bucket_index(us);
        // ordering: relaxed — independent monotonic counters; nothing is
        // published under them, readers only snapshot for display.
        self.cells[b].fetch_add(1, Ordering::Relaxed);
        // ordering: relaxed — same monotonic-counter contract as cells.
        self.recorded.fetch_add(1, Ordering::Relaxed);
        // ordering: relaxed — same monotonic-counter contract as cells.
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        // ordering: relaxed — lossy running max, display only.
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Copy the current counters out (display read; see type docs for
    /// the mid-record tearing caveat).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut counts = [0u64; BUCKETS];
        for (i, c) in self.cells.iter().enumerate() {
            // ordering: relaxed — display read of a monotonic counter.
            counts[i] = c.load(Ordering::Relaxed);
        }
        HistogramSnapshot {
            counts,
            // ordering: relaxed — display read of a monotonic counter.
            count: self.recorded.load(Ordering::Relaxed),
            // ordering: relaxed — display read of a monotonic counter.
            sum_us: self.sum_us.load(Ordering::Relaxed),
            // ordering: relaxed — display read of a monotonic counter.
            max_us: self.max_us.load(Ordering::Relaxed),
        }
    }
}

/// Plain-value copy of a [`Histogram`], mergeable and queryable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Per-bucket sample counts (NOT cumulative; see [`bucket_upper_us`]).
    pub counts: [u64; BUCKETS],
    /// Total samples recorded.
    pub count: u64,
    /// Sum of all samples in microseconds.
    pub sum_us: u64,
    /// Largest sample in microseconds.
    pub max_us: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        HistogramSnapshot { counts: [0; BUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl HistogramSnapshot {
    /// Fold `other` into `self`. Associative and commutative: merging is
    /// bucket-wise addition plus a max, so shard histograms can be
    /// combined in any grouping.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Mean sample in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Bounded `q`-quantile estimate: `Some((lo_us, hi_us))` such that
    /// the exact nearest-rank percentile — the convention of the exact
    /// oracle [`percentiles_ms`](crate::bench_harness::percentiles_ms),
    /// 0-based index `ceil((count - 1) * q)` of the sorted samples —
    /// satisfies `lo <= p <= hi`. `None` when empty.
    pub fn quantile_bounds(&self, q: f64) -> Option<(u64, u64)> {
        if self.count == 0 {
            return None;
        }
        let idx = ((self.count - 1) as f64 * q.clamp(0.0, 1.0)).ceil() as u64;
        let rank = idx.min(self.count - 1) + 1; // 1-based rank in sorted order
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                let lo = if b == 0 { 0 } else { bucket_upper_us(b - 1) };
                // The rank bucket holds >= 1 sample, all <= max_us, so
                // capping by the global max only ever tightens the bound.
                let hi = bucket_upper_us(b).min(self.max_us);
                return Some((lo, hi.max(lo)));
            }
        }
        // Torn snapshot (count ahead of the cells): fall back to the
        // loosest correct bracket instead of panicking in deploy code.
        Some((0, self.max_us))
    }
}

// ---------------------------------------------------------------------------
// Status counters
// ---------------------------------------------------------------------------

/// The closed set of status codes the HTTP front can emit — mirrors
/// `net::http::Status` (the analyzer's taxonomy-sync rule keeps that enum
/// and the README table aligned; this array indexes the counters).
pub const STATUS_CODES: [u16; 11] =
    [200, 400, 404, 405, 408, 411, 413, 429, 500, 503, 504];

/// One relaxed counter per taxonomy status code.
pub struct StatusCounters {
    slots: [AtomicU64; STATUS_CODES.len()],
}

impl Default for StatusCounters {
    fn default() -> Self {
        StatusCounters { slots: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl StatusCounters {
    /// Count one response with `code`. Sole mutation point of the status
    /// slots (counter-choke enforced); codes outside the taxonomy are
    /// ignored (unreachable while `Status` stays closed).
    pub fn observe(&self, code: u16) {
        if let Some(i) = STATUS_CODES.iter().position(|&c| c == code) {
            // ordering: relaxed — monotonic display counter; no data is
            // published under it.
            self.slots[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Copy the counters out, index-aligned with [`STATUS_CODES`].
    pub fn snapshot(&self) -> [u64; STATUS_CODES.len()] {
        let mut out = [0u64; STATUS_CODES.len()];
        for (i, s) in self.slots.iter().enumerate() {
            // ordering: relaxed — display read of a monotonic counter.
            out[i] = s.load(Ordering::Relaxed);
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Traces
// ---------------------------------------------------------------------------

/// Per-request span recorder. Created when the request is picked up,
/// fed marks/durations as the request moves through the pipeline, and
/// finished into a [`Trace`].
///
/// [`mark`](SpanRecorder::mark) charges the time since the previous mark
/// (or start) to a stage via the injected [`Clock`];
/// [`set`](SpanRecorder::set) stores a duration measured elsewhere
/// (batcher queue delay, engine compute) without touching the clock.
pub struct SpanRecorder {
    clock: Arc<dyn Clock>,
    started: Duration,
    last: Duration,
    spans: [u64; STAGES],
    touched: [bool; STAGES],
}

impl SpanRecorder {
    /// Start recording now (per the injected clock).
    pub fn start(clock: Arc<dyn Clock>) -> Self {
        let t0 = clock.now();
        SpanRecorder {
            clock,
            started: t0,
            last: t0,
            spans: [0; STAGES],
            touched: [false; STAGES],
        }
    }

    /// Charge the time since the previous mark (or start) to `stage`.
    pub fn mark(&mut self, stage: Stage) {
        let t = self.clock.now();
        let d = t.saturating_sub(self.last);
        self.last = t;
        self.spans[stage as usize] += d.as_micros() as u64;
        self.touched[stage as usize] = true;
    }

    /// Store an externally measured duration for `stage` (additive, so
    /// repeated sets accumulate like marks do).
    pub fn set(&mut self, stage: Stage, d: Duration) {
        self.spans[stage as usize] += d.as_micros() as u64;
        self.touched[stage as usize] = true;
    }

    /// Freeze into a [`Trace`].
    pub fn finish(self, request_id: u64, key: &str, status: u16) -> Trace {
        Trace {
            request_id,
            key: key.to_string(),
            status,
            started_us: self.started.as_micros() as u64,
            spans: self.spans,
            touched: self.touched,
        }
    }
}

/// One completed request's stage timings, joinable to the client-side
/// latency via the `X-Request-Id` response header.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Trace {
    /// Server-assigned id, echoed to the client as `X-Request-Id`.
    pub request_id: u64,
    /// Model key the request targeted.
    pub key: String,
    /// Final HTTP status.
    pub status: u16,
    /// Microseconds since the telemetry clock's epoch at request start.
    pub started_us: u64,
    /// Per-stage microseconds, indexed by `Stage as usize`.
    pub spans: [u64; STAGES],
    /// Which stages actually ran (a shed request never reaches compute;
    /// untouched stages are excluded from the stage histograms).
    pub touched: [bool; STAGES],
}

impl Trace {
    /// Sum of all recorded spans in microseconds.
    pub fn total_us(&self) -> u64 {
        self.spans.iter().sum()
    }
}

// ---------------------------------------------------------------------------
// Per-model and server-wide aggregation
// ---------------------------------------------------------------------------

/// Per-model counters: responses by status + one histogram per stage,
/// plus the model's windowed signal plane ([`ModelWindow`]).
pub struct ModelTelemetry {
    by_status: StatusCounters,
    stages: [Histogram; STAGES],
    window: ModelWindow,
}

impl Default for ModelTelemetry {
    fn default() -> Self {
        ModelTelemetry {
            by_status: StatusCounters::default(),
            stages: std::array::from_fn(|_| Histogram::default()),
            window: ModelWindow::new(DEFAULT_WINDOW_EPOCH),
        }
    }
}

impl ModelTelemetry {
    /// Copy this model's counters out; `now` anchors the window reads.
    pub fn snapshot(&self, now: Duration) -> ModelSnapshot {
        ModelSnapshot {
            by_status: self.by_status.snapshot(),
            stages: std::array::from_fn(|i| self.stages[i].snapshot()),
            window: self.window.snapshot(now),
        }
    }
}

/// The server's telemetry spine: one instance per
/// [`Server`](crate::deploy::net::Server), shared by the listener, the
/// connection threads, and the request handler.
///
/// The model set is fixed at construction (the router's keys), so the
/// hot path never locks a map — per-model lookup is a read of an
/// immutable `BTreeMap`.
pub struct ServerTelemetry {
    clock: Arc<dyn Clock>,
    connections: AtomicU64,
    http_status: StatusCounters,
    /// Windowed twin of `http_status`: responses written inside the
    /// trailing window, index-aligned with [`STATUS_CODES`].
    http_window: [WindowedCounter; STATUS_CODES.len()],
    req_seq: AtomicU64,
    models: BTreeMap<String, ModelTelemetry>,
    ring: Mutex<VecDeque<Trace>>,
    ring_cap: usize,
}

impl ServerTelemetry {
    /// Build a telemetry spine for `keys`, keeping the last `ring_cap`
    /// completed traces.
    pub fn new(keys: &[String], clock: Arc<dyn Clock>, ring_cap: usize) -> Self {
        ServerTelemetry {
            clock,
            connections: AtomicU64::new(0),
            http_status: StatusCounters::default(),
            http_window: std::array::from_fn(|_| WindowedCounter::new(DEFAULT_WINDOW_EPOCH)),
            req_seq: AtomicU64::new(0),
            models: keys.iter().map(|k| (k.clone(), ModelTelemetry::default())).collect(),
            ring: Mutex::new(VecDeque::new()),
            ring_cap,
        }
    }

    /// The clock spans are measured against.
    pub fn clock(&self) -> Arc<dyn Clock> {
        Arc::clone(&self.clock)
    }

    /// Count one accepted TCP connection. Sole mutation point of the
    /// connection counter (counter-choke enforced).
    pub fn count_connection(&self) {
        // ordering: relaxed — monotonic display counter.
        self.connections.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one written HTTP response (any route, including read-error
    /// replies) — the server-wide responses-by-status series, cumulative
    /// and windowed.
    pub fn observe_http_status(&self, code: u16) {
        self.http_status.observe(code);
        if let Some(i) = STATUS_CODES.iter().position(|&c| c == code) {
            self.http_window[i].record(self.clock.now(), 1);
        }
    }

    /// Count one keyed infer request entering admission — the per-model
    /// windowed arrival-rate estimator. Unknown keys are dropped (they
    /// never reach admission).
    pub fn count_arrival(&self, key: &str) {
        if let Some(model) = self.models.get(key) {
            model.window.arrivals.record(self.clock.now(), 1);
        }
    }

    /// Record the top-logit confidence margin of a 200 reply into `key`'s
    /// windowed margin histogram (scaled by [`margin_milli`]).
    pub fn record_margin(&self, key: &str, margin: f32) {
        if let Some(model) = self.models.get(key) {
            model.window.margin.record(self.clock.now(), margin_milli(margin));
        }
    }

    /// Allocate a fresh request id (1-based, unique per server). Sole
    /// mutation point of the id sequence (counter-choke enforced).
    pub fn next_request_id(&self) -> u64 {
        // ordering: relaxed — unique-id allocator; ids only need to be
        // distinct, not ordered with any other data.
        self.req_seq.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Record a finished infer-route request: per-model status counter,
    /// stage histograms (touched stages only), and the trace ring.
    /// Unknown keys (404s) have no per-model slot and are dropped here;
    /// they are still counted by
    /// [`observe_http_status`](ServerTelemetry::observe_http_status).
    pub fn record(&self, rec: SpanRecorder, key: &str, request_id: u64, status: u16) {
        let Some(model) = self.models.get(key) else { return };
        model.by_status.observe(status);
        let now = self.clock.now();
        if let Some(i) = STATUS_CODES.iter().position(|&c| c == status) {
            model.window.by_status[i].record(now, 1);
        }
        let trace = rec.finish(request_id, key, status);
        for (i, h) in model.stages.iter().enumerate() {
            if trace.touched[i] {
                h.record(Duration::from_micros(trace.spans[i]));
                model.window.stages[i].record(now, trace.spans[i]);
            }
        }
        model.window.total.record(now, trace.total_us());
        self.push_trace(trace);
    }

    fn push_trace(&self, t: Trace) {
        if self.ring_cap == 0 {
            return;
        }
        let mut ring = super::net::lock(&self.ring);
        if ring.len() == self.ring_cap {
            ring.pop_front();
        }
        ring.push_back(t);
    }

    /// The last N completed traces, oldest first.
    pub fn recent_traces(&self) -> Vec<Trace> {
        super::net::lock(&self.ring).iter().cloned().collect()
    }

    /// Copy every counter out for exposition. One clock read anchors all
    /// window sections, so a snapshot is internally epoch-consistent.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let now = self.clock.now();
        TelemetrySnapshot {
            // ordering: relaxed — display read of a monotonic counter.
            connections: self.connections.load(Ordering::Relaxed),
            http_status: self.http_status.snapshot(),
            http_window: std::array::from_fn(|i| self.http_window[i].total(now)),
            models: self.models.iter().map(|(k, m)| (k.clone(), m.snapshot(now))).collect(),
        }
    }
}

/// Scale a top-logit margin (a logit difference, `>= 0` by construction)
/// to the milli-logit integers the windowed margin histogram buckets:
/// `round(margin * 1000)`, negatives clamped to 0.
pub fn margin_milli(margin: f32) -> u64 {
    (margin.max(0.0) as f64 * 1000.0).round() as u64
}

/// Plain-value copy of a [`ServerTelemetry`] at one instant.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TelemetrySnapshot {
    /// TCP connections accepted since start.
    pub connections: u64,
    /// Responses written by status, index-aligned with [`STATUS_CODES`].
    pub http_status: [u64; STATUS_CODES.len()],
    /// Responses written inside the trailing window, index-aligned with
    /// [`STATUS_CODES`].
    pub http_window: [u64; STATUS_CODES.len()],
    /// Per-model counters, keyed by model key.
    pub models: BTreeMap<String, ModelSnapshot>,
}

/// Plain-value copy of one model's [`ModelTelemetry`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSnapshot {
    /// Infer-route responses by status, index-aligned with
    /// [`STATUS_CODES`].
    pub by_status: [u64; STATUS_CODES.len()],
    /// One histogram per [`Stage`], indexed by `Stage as usize`.
    pub stages: [HistogramSnapshot; STAGES],
    /// The model's windowed signal plane at snapshot time.
    pub window: WindowSnapshot,
}

impl Default for ModelSnapshot {
    fn default() -> Self {
        ModelSnapshot {
            by_status: [0; STATUS_CODES.len()],
            stages: [HistogramSnapshot::default(); STAGES],
            window: WindowSnapshot::default(),
        }
    }
}

impl ModelSnapshot {
    /// Total infer-route responses across every status.
    pub fn total(&self) -> u64 {
        self.by_status.iter().sum()
    }

    /// Count for one status code (0 for codes outside the taxonomy).
    pub fn status_count(&self, code: u16) -> u64 {
        STATUS_CODES
            .iter()
            .position(|&c| c == code)
            .map_or(0, |i| self.by_status[i])
    }
}

// ---------------------------------------------------------------------------
// Prometheus text exposition
// ---------------------------------------------------------------------------
//
// Metric names are defined once here and mirrored by the marker-wrapped
// table in README "Observability"; `cgmq analyze`'s metrics-name-sync
// rule fails the build when either side drifts.

/// `counter` — TCP connections accepted by the listener.
pub const M_CONNECTIONS: &str = "cgmq_connections_total";
/// `counter` — HTTP responses written, by status (every route, including
/// parse-error replies).
pub const M_HTTP_RESPONSES: &str = "cgmq_http_responses_total";
/// `counter` — infer responses delivered to a waiting client (the
/// server's `served` drain invariant counter).
pub const M_SERVED: &str = "cgmq_served_total";
/// `counter` — infer-route requests by model and status.
pub const M_REQUESTS: &str = "cgmq_requests_total";
/// `counter` — requests submitted to a model's pool (accepted + shed).
pub const M_SUBMITTED: &str = "cgmq_submitted_total";
/// `counter` — requests admitted past the depth cap.
pub const M_ACCEPTED: &str = "cgmq_accepted_total";
/// `counter` — completions returned by a model's pool.
pub const M_COMPLETED: &str = "cgmq_completed_total";
/// `counter` — requests shed at admission (HTTP 429).
pub const M_SHED: &str = "cgmq_shed_total";
/// `counter` — zero-downtime model swaps.
pub const M_SWAPS: &str = "cgmq_swaps_total";
/// `counter` — batcher flushes (size + deadline + drain).
pub const M_FLUSHES: &str = "cgmq_batch_flushes_total";
/// `counter` — engine forward calls (>= flushes; chunked by max_batch).
pub const M_ENGINE_CALLS: &str = "cgmq_engine_calls_total";
/// `gauge` — engine layers whose weights are decoded into the unpack
/// cache.
pub const M_DECODED_LAYERS: &str = "cgmq_engine_decoded_layers";
/// `histogram` — per-stage request latency in seconds, labelled by model
/// and stage.
pub const M_STAGE_SECONDS: &str = "cgmq_stage_duration_seconds";
/// `gauge` — HTTP responses written inside the trailing window, by
/// status.
pub const M_HTTP_RESPONSES_WINDOW: &str = "cgmq_http_responses_window";
/// `gauge` — infer-route requests inside the trailing window, by model
/// and status.
pub const M_REQUESTS_WINDOW: &str = "cgmq_requests_window";
/// `gauge` — request arrivals per second over the trailing window, by
/// model.
pub const M_ARRIVAL_RATE_WINDOW: &str = "cgmq_arrival_rate_window";
/// `gauge` — queued requests per shard at scrape time, by model and
/// shard.
pub const M_QUEUE_DEPTH: &str = "cgmq_queue_depth";
/// `gauge` — accepted-but-not-completed requests at scrape time, by
/// model.
pub const M_IN_FLIGHT: &str = "cgmq_in_flight";
/// `histogram` — per-stage latency in seconds over the trailing window,
/// by model and stage.
pub const M_STAGE_WINDOW_SECONDS: &str = "cgmq_stage_window_seconds";
/// `histogram` — whole-request latency in seconds over the trailing
/// window, by model.
pub const M_REQUEST_WINDOW_SECONDS: &str = "cgmq_request_window_seconds";
/// `histogram` — top-logit confidence margin (logits) over the trailing
/// window, by model.
pub const M_MARGIN_WINDOW: &str = "cgmq_margin_window";

fn esc_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push('\n');
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

/// Emit one Prometheus histogram series set (`_bucket`/`_sum`/`_count`)
/// for `h` under `labels` (the label pairs without `le`). Bucket upper
/// bounds and the sum are divided by `scale` — `1e6` converts the log₂
/// microsecond buckets to seconds, `1e3` converts milli-logit margin
/// buckets to logits.
fn prom_histogram(out: &mut String, name: &str, labels: &str, h: &HistogramSnapshot, scale: f64) {
    use std::fmt::Write as _;
    let mut cum = 0u64;
    for (b, &c) in h.counts.iter().enumerate() {
        cum += c;
        let le = bucket_upper_us(b) as f64 / scale;
        let _ = writeln!(out, "{name}_bucket{{{labels},le=\"{le}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{{labels},le=\"+Inf\"}} {}", h.count);
    let _ = writeln!(out, "{name}_sum{{{labels}}} {}", h.sum_us as f64 / scale);
    let _ = writeln!(out, "{name}_count{{{labels}}} {}", h.count);
}

/// Render the Prometheus text exposition (`GET /metrics`).
///
/// Counter series are emitted for every taxonomy code and every model —
/// zeros included — so scrapers and the `load-bench` cross-check always
/// find a stable series set; the windowed `cgmq_*_window*` gauges and
/// histograms follow the same contract and decay back to zero once the
/// trailing window passes without traffic. Histogram buckets follow the
/// Prometheus convention: cumulative counts with `le` upper bounds in
/// *seconds* (the underlying buckets are log₂ microseconds), except the
/// margin histogram whose bounds are logits (milli-logit buckets).
/// `depths` carries per-model per-shard queue depths sampled at scrape
/// time from the pool's admission counters.
pub fn render_prometheus(
    snap: &TelemetrySnapshot,
    served: u64,
    routes: &BTreeMap<String, RouteStats>,
    decoded: &BTreeMap<String, u64>,
    depths: &BTreeMap<String, Vec<u64>>,
) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(4096);

    header(&mut out, M_CONNECTIONS, "counter", "TCP connections accepted");
    let _ = writeln!(out, "{M_CONNECTIONS} {}", snap.connections);

    header(&mut out, M_HTTP_RESPONSES, "counter", "HTTP responses written, by status");
    for (i, &code) in STATUS_CODES.iter().enumerate() {
        let _ = writeln!(out, "{M_HTTP_RESPONSES}{{status=\"{code}\"}} {}", snap.http_status[i]);
    }

    header(&mut out, M_SERVED, "counter", "infer responses delivered to a waiting client");
    let _ = writeln!(out, "{M_SERVED} {served}");

    header(&mut out, M_REQUESTS, "counter", "infer-route requests by model and status");
    for (key, m) in &snap.models {
        let k = esc_label(key);
        for (i, &code) in STATUS_CODES.iter().enumerate() {
            let _ = writeln!(
                out,
                "{M_REQUESTS}{{model=\"{k}\",status=\"{code}\"}} {}",
                m.by_status[i]
            );
        }
    }

    let route_counters: [(&str, &str, fn(&RouteStats) -> u64); 7] = [
        (M_SUBMITTED, "requests submitted to the model's pool", |r| r.submitted),
        (M_ACCEPTED, "requests admitted past the depth cap", |r| r.accepted),
        (M_COMPLETED, "completions returned by the model's pool", |r| r.completed),
        (M_SHED, "requests shed at admission (HTTP 429)", |r| r.shed),
        (M_SWAPS, "zero-downtime model swaps", |r| r.swaps),
        (M_FLUSHES, "batcher flushes across the model's shards", |r| r.batch.flushes),
        (M_ENGINE_CALLS, "engine forward calls across the model's shards", |r| {
            r.batch.engine_calls
        }),
    ];
    for (name, help, get) in route_counters {
        header(&mut out, name, "counter", help);
        for (key, r) in routes {
            let _ = writeln!(out, "{name}{{model=\"{}\"}} {}", esc_label(key), get(r));
        }
    }

    header(&mut out, M_DECODED_LAYERS, "gauge", "engine layers decoded into the unpack cache");
    for (key, n) in decoded {
        let _ = writeln!(out, "{M_DECODED_LAYERS}{{model=\"{}\"}} {n}", esc_label(key));
    }

    header(
        &mut out,
        M_STAGE_SECONDS,
        "histogram",
        "per-stage request latency in seconds, by model and stage",
    );
    for (key, m) in &snap.models {
        let k = esc_label(key);
        for stage in Stage::ALL {
            let labels = format!("model=\"{k}\",stage=\"{}\"", stage.as_str());
            prom_histogram(&mut out, M_STAGE_SECONDS, &labels, &m.stages[stage as usize], 1e6);
        }
    }

    // -- windowed signal plane (gauges: values decay with the window) --

    header(
        &mut out,
        M_HTTP_RESPONSES_WINDOW,
        "gauge",
        "HTTP responses written inside the trailing window, by status",
    );
    for (i, &code) in STATUS_CODES.iter().enumerate() {
        let _ = writeln!(
            out,
            "{M_HTTP_RESPONSES_WINDOW}{{status=\"{code}\"}} {}",
            snap.http_window[i]
        );
    }

    header(
        &mut out,
        M_REQUESTS_WINDOW,
        "gauge",
        "infer-route requests inside the trailing window, by model and status",
    );
    for (key, m) in &snap.models {
        let k = esc_label(key);
        for (i, &code) in STATUS_CODES.iter().enumerate() {
            let _ = writeln!(
                out,
                "{M_REQUESTS_WINDOW}{{model=\"{k}\",status=\"{code}\"}} {}",
                m.window.by_status[i]
            );
        }
    }

    header(
        &mut out,
        M_ARRIVAL_RATE_WINDOW,
        "gauge",
        "request arrivals per second over the trailing window, by model",
    );
    for (key, m) in &snap.models {
        let _ = writeln!(
            out,
            "{M_ARRIVAL_RATE_WINDOW}{{model=\"{}\"}} {}",
            esc_label(key),
            m.window.arrival_rate_per_sec()
        );
    }

    header(
        &mut out,
        M_QUEUE_DEPTH,
        "gauge",
        "queued requests per shard at scrape time, by model and shard",
    );
    for (key, shards) in depths {
        let k = esc_label(key);
        for (shard, d) in shards.iter().enumerate() {
            let _ = writeln!(out, "{M_QUEUE_DEPTH}{{model=\"{k}\",shard=\"{shard}\"}} {d}");
        }
    }

    header(
        &mut out,
        M_IN_FLIGHT,
        "gauge",
        "accepted-but-not-completed requests at scrape time, by model",
    );
    for (key, r) in routes {
        let _ = writeln!(
            out,
            "{M_IN_FLIGHT}{{model=\"{}\"}} {}",
            esc_label(key),
            r.accepted.saturating_sub(r.completed)
        );
    }

    header(
        &mut out,
        M_STAGE_WINDOW_SECONDS,
        "histogram",
        "per-stage latency in seconds over the trailing window, by model and stage",
    );
    for (key, m) in &snap.models {
        let k = esc_label(key);
        for stage in Stage::ALL {
            let labels = format!("model=\"{k}\",stage=\"{}\"", stage.as_str());
            let h = &m.window.stages[stage as usize];
            prom_histogram(&mut out, M_STAGE_WINDOW_SECONDS, &labels, h, 1e6);
        }
    }

    header(
        &mut out,
        M_REQUEST_WINDOW_SECONDS,
        "histogram",
        "whole-request latency in seconds over the trailing window, by model",
    );
    for (key, m) in &snap.models {
        let labels = format!("model=\"{}\"", esc_label(key));
        prom_histogram(&mut out, M_REQUEST_WINDOW_SECONDS, &labels, &m.window.total, 1e6);
    }

    header(
        &mut out,
        M_MARGIN_WINDOW,
        "histogram",
        "top-logit confidence margin over the trailing window, by model",
    );
    for (key, m) in &snap.models {
        let labels = format!("model=\"{}\"", esc_label(key));
        prom_histogram(&mut out, M_MARGIN_WINDOW, &labels, &m.window.margin, 1e3);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_places_powers_of_two_on_their_upper_bound() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        for b in 1..BUCKETS - 1 {
            let edge = 1u64 << b;
            assert_eq!(bucket_index(edge), b, "2^{b} must land in bucket {b}");
            assert_eq!(bucket_index(edge + 1), b + 1, "2^{b}+1 must spill over");
        }
        // Clamp: beyond the top bucket's range everything lands in it.
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn manual_clock_is_deterministic() {
        let c = ManualClock::default();
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_micros(250));
        c.advance(Duration::from_micros(750));
        assert_eq!(c.now(), Duration::from_micros(1000));
    }

    #[test]
    fn recorder_charges_inter_mark_time_to_stages() {
        let clock = Arc::new(ManualClock::default());
        let mut rec = SpanRecorder::start(clock.clone());
        clock.advance(Duration::from_micros(10));
        rec.mark(Stage::Parse);
        clock.advance(Duration::from_micros(5));
        rec.mark(Stage::Admit);
        rec.set(Stage::QueueWait, Duration::from_micros(40));
        let t = rec.finish(7, "m", 200);
        assert_eq!(t.spans[Stage::Parse as usize], 10);
        assert_eq!(t.spans[Stage::Admit as usize], 5);
        assert_eq!(t.spans[Stage::QueueWait as usize], 40);
        assert!(!t.touched[Stage::Compute as usize]);
        assert_eq!(t.total_us(), 55);
        assert_eq!(t.request_id, 7);
    }

    #[test]
    fn ring_keeps_only_the_last_n() {
        let tel = ServerTelemetry::new(
            &["m".to_string()],
            Arc::new(ManualClock::default()),
            3,
        );
        for i in 0..5u64 {
            let rec = SpanRecorder::start(tel.clock());
            tel.record(rec, "m", i + 1, 200);
        }
        let traces = tel.recent_traces();
        assert_eq!(traces.len(), 3);
        assert_eq!(traces[0].request_id, 3);
        assert_eq!(traces[2].request_id, 5);
    }

    #[test]
    fn unknown_key_is_dropped_not_counted() {
        let tel = ServerTelemetry::new(
            &["m".to_string()],
            Arc::new(ManualClock::default()),
            8,
        );
        let rec = SpanRecorder::start(tel.clock());
        tel.record(rec, "ghost", 1, 404);
        assert!(tel.recent_traces().is_empty());
        assert_eq!(tel.snapshot().models["m"].total(), 0);
    }
}
