//! Multi-model routing front: several packed models served side by side.
//!
//! One [`WorkerPool`] serves one `.cgmqm`; CGMQ's whole point is a
//! *family* of mixed-precision models, each pinned under a different
//! compute budget, so a deployment wants several variants live at once —
//! budget-tiered traffic, A/B comparison, staged rollouts. The [`Router`]
//! is that front:
//!
//! ```text
//!   try_submit("tight", x)            try_submit("loose", x)
//!            \                                 /
//!             Router — BTreeMap<key, ModelEntry>
//!            /                |                \
//!      WorkerPool "tight"  WorkerPool "loose"  ...   (one pool per key)
//!        shards + shed       shards + shed           (bounded queues)
//! ```
//!
//! * **Routing** — each named model key owns a private [`WorkerPool`]
//!   (its own shards, workers and admission cap from the shared
//!   [`PoolConfig`]); requests are routed by key, an unknown key is a
//!   clean error naming the loaded keys.
//! * **Backpressure** — submission goes through the pool's
//!   admission-controlled [`try_submit`](WorkerPool::try_submit): once a
//!   model's shards are all at `queue_cap` in-flight requests, the router
//!   returns [`Submission::Shed`] instead of queueing unboundedly, and
//!   counts the shed in that model's [`RouteStats`].
//! * **Hot swap** — [`swap_model`](Router::swap_model) loads the
//!   replacement *first* (spawn + preload, fail-fast interface check),
//!   atomically swaps the pool behind the key, then drains the old pool;
//!   its in-flight completions are carried over and delivered through the
//!   normal [`try_completions`](Router::try_completions) path, so no
//!   accepted request is ever lost across a swap.
//!
//! Request ids are **per key and monotone across swaps**: each entry
//! remaps its live pool's ids by the number of requests every previous
//! pool behind that key accepted, so `(key, id)` uniquely names a request
//! for the lifetime of the router. The accounting invariant — per key,
//! `submitted == accepted + shed` always, and `completed == accepted`
//! once drained — is pinned by `tests/router.rs`.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::batch::BatcherStats;
use super::engine::Engine;
use super::pool::{PoolCompletion, PoolConfig, PoolStats, Submission, WorkerPool};
use crate::util::json::Json;

/// Cumulative per-model routing statistics.
///
/// Invariants: `submitted == accepted + shed` (every routed request is
/// either admitted or shed, never both), `completed <= accepted` at all
/// times and `completed == accepted` after the entry is drained
/// (shutdown/remove). `batch` folds in the per-shard [`BatcherStats`] of
/// every pool drained so far behind this key (swapped-out pools
/// immediately, the live pool at shutdown).
#[derive(Debug, Clone, Copy, Default)]
pub struct RouteStats {
    /// `try_submit` calls that passed validation (accepted + shed).
    pub submitted: u64,
    /// Requests admitted into a pool behind this key.
    pub accepted: u64,
    /// Completions handed back to the caller.
    pub completed: u64,
    /// Requests refused because every shard was at `queue_cap`.
    pub shed: u64,
    /// Hot swaps performed on this key.
    pub swaps: u64,
    /// Merged shard batcher counters of every drained pool.
    pub batch: BatcherStats,
}

impl RouteStats {
    /// Shed fraction of all routed requests (0 when nothing was routed).
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            0.0
        } else {
            self.shed as f64 / self.submitted as f64
        }
    }

    /// The accounting invariant; `tests/router.rs` holds it under
    /// saturating load and across hot swaps.
    pub fn consistent(&self) -> bool {
        self.submitted == self.accepted + self.shed
            && self.completed <= self.accepted
            && self.batch.consistent()
    }

    /// Fold a pool's choke-point counters into this snapshot.
    fn add_pool(&mut self, p: PoolStats) {
        self.submitted += p.submitted;
        self.accepted += p.accepted;
        self.shed += p.shed;
    }

    /// The wire form the `/stats` endpoint and the bench reports share.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("submitted", Json::num(self.submitted as f64)),
            ("accepted", Json::num(self.accepted as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("shed_rate", Json::num(self.shed_rate())),
            ("swaps", Json::num(self.swaps as f64)),
            ("flushes", Json::num(self.batch.flushes as f64)),
            ("engine_calls", Json::num(self.batch.engine_calls as f64)),
            ("mean_batch", Json::num(self.batch.mean_batch())),
            // Queue-wait accounting (µs) from the drained shards' batcher
            // counters — the arrival-rate signal, per flush reason.
            (
                "queue_wait",
                Json::obj(vec![
                    ("total_us", Json::num(self.batch.queue_wait_us() as f64)),
                    ("max_us", Json::num(self.batch.queue_wait_max_us() as f64)),
                    ("size_us", Json::num(self.batch.size_wait_us as f64)),
                    ("deadline_us", Json::num(self.batch.deadline_wait_us as f64)),
                    ("drain_us", Json::num(self.batch.drain_wait_us as f64)),
                ]),
            ),
        ])
    }
}

/// Everything a drained model entry reports: the completions that were
/// still buffered, plus the final [`RouteStats`].
#[derive(Debug)]
pub struct ModelReport {
    pub completions: Vec<PoolCompletion>,
    pub stats: RouteStats,
}

struct ModelEntry {
    pool: WorkerPool,
    /// Requests accepted by every *previous* pool behind this key; the
    /// live pool's shard-local ids are offset by this so `(key, id)` stays
    /// unique across hot swaps.
    base: u64,
    /// Routing stats *excluding* the live pool's submission counters:
    /// `completed`/`swaps`/`batch` accrue here directly, while
    /// `submitted`/`accepted`/`shed` are folded in from each pool's
    /// [`PoolStats`] choke point when that pool is drained (swap/shutdown).
    /// [`stats_now`](Self::stats_now) adds the live pool's counters, so a
    /// reader always sees the authoritative totals — no per-call-site
    /// bookkeeping that an uncapped submission path could bypass.
    stats: RouteStats,
    /// Completions drained from a swapped-out pool, ids already remapped;
    /// delivered ahead of live completions by `try_completions`.
    carryover: Vec<PoolCompletion>,
}

impl ModelEntry {
    /// The authoritative stats snapshot: drained-pool totals plus the live
    /// pool's choke-point counters.
    fn stats_now(&self) -> RouteStats {
        let mut s = self.stats;
        s.add_pool(self.pool.stats());
        s
    }

    /// Shut the live pool down and fold everything into a final report.
    fn drain(mut self) -> Result<ModelReport> {
        let base = self.base;
        self.stats.add_pool(self.pool.stats());
        let (rest, shard_stats) = self.pool.shutdown()?;
        self.stats.batch.merge(&BatcherStats::merge_all(&shard_stats));
        let mut completions = std::mem::take(&mut self.carryover);
        completions.extend(rest.into_iter().map(|mut c| {
            c.id += base;
            c
        }));
        self.stats.completed += completions.len() as u64;
        Ok(ModelReport { completions, stats: self.stats })
    }
}

/// A routing front over several named [`WorkerPool`]s — one per loaded
/// `.cgmqm` model/version — with bounded per-shard queues and
/// zero-downtime hot swap. See the module docs for the architecture.
pub struct Router {
    cfg: PoolConfig,
    models: BTreeMap<String, ModelEntry>,
}

impl Router {
    /// A router whose pools all use `cfg` (worker count, batching policy
    /// and the per-shard `queue_cap` admission bound).
    pub fn new(cfg: PoolConfig) -> Self {
        Self { cfg, models: BTreeMap::new() }
    }

    /// Put `engine` behind `key` (spawns its pool, preloads the weight
    /// cache). Errors on an empty or already-loaded key — replacing a live
    /// model is [`swap_model`](Self::swap_model)'s job.
    pub fn add_model(&mut self, key: impl Into<String>, engine: Arc<Engine>) -> Result<()> {
        let key = key.into();
        if key.is_empty() {
            bail!("model key must be non-empty");
        }
        let cfg = self.cfg;
        match self.models.entry(key) {
            std::collections::btree_map::Entry::Occupied(e) => {
                bail!("model key '{}' is already loaded (use swap_model to replace it)", e.key())
            }
            std::collections::btree_map::Entry::Vacant(v) => {
                let pool = WorkerPool::new(engine, cfg)
                    .with_context(|| format!("spawning pool for model '{}'", v.key()))?;
                v.insert(ModelEntry {
                    pool,
                    base: 0,
                    stats: RouteStats::default(),
                    carryover: Vec::new(),
                });
                Ok(())
            }
        }
    }

    /// Load a `.cgmqm` file (checksum + arch verification) behind `key`.
    pub fn load_model(&mut self, key: impl Into<String>, path: &Path) -> Result<()> {
        self.add_model(key, Arc::new(Engine::load(path)?))
    }

    /// Loaded model keys, sorted.
    pub fn keys(&self) -> Vec<&str> {
        self.models.keys().map(String::as_str).collect()
    }

    /// The engine currently serving `key`.
    pub fn engine(&self, key: &str) -> Result<&Engine> {
        Ok(self.entry(key)?.pool.engine())
    }

    /// A snapshot of `key`'s routing statistics —
    /// `submitted`/`accepted`/`shed` come from the pools' own admission
    /// choke points (every drained pool plus the live one), so the totals
    /// are authoritative whichever submission path fed them. `batch`
    /// covers only the pools drained so far — the live pool's shard
    /// counters join at shutdown/remove.
    pub fn stats(&self, key: &str) -> Result<RouteStats> {
        Ok(self.entry(key)?.stats_now())
    }

    /// Stats snapshots of every loaded model in one call — what the
    /// `/stats` endpoint serves and the bench reports iterate, instead of
    /// stitching `keys()` + `stats(key)` per model.
    pub fn stats_all(&self) -> BTreeMap<String, RouteStats> {
        self.models.iter().map(|(k, e)| (k.clone(), e.stats_now())).collect()
    }

    /// Per-model decoded-weight-cache fill (layers decoded / preloaded) —
    /// the `cgmq_engine_decoded_layers` gauge on `/metrics`.
    pub fn decoded_layers_all(&self) -> BTreeMap<String, u64> {
        self.models
            .iter()
            .map(|(k, e)| (k.clone(), e.pool.engine().decoded_layers() as u64))
            .collect()
    }

    /// Per-model per-shard queue depths at this instant
    /// ([`WorkerPool::queue_depths`]) — the `cgmq_queue_depth` gauge on
    /// `/metrics` and the `queue_depth` section of `/stats`.
    pub fn queue_depths_all(&self) -> BTreeMap<String, Vec<u64>> {
        self.models
            .iter()
            .map(|(k, e)| (k.clone(), e.pool.queue_depths()))
            .collect()
    }

    /// Route one request to the model behind `key`. Returns the admission
    /// outcome: [`Submission::Accepted`] with the per-key request id its
    /// completion will carry, or [`Submission::Shed`] when every shard of
    /// that model's pool is at `queue_cap`. Unknown keys and wrong-length
    /// inputs are `Err` (and are not counted as submitted).
    pub fn try_submit(&mut self, key: &str, x: Vec<f32>) -> Result<Submission> {
        let entry = self.entry_mut(key)?;
        // Counting happens inside the pool's admission choke point; the
        // router only remaps the id into the per-key space.
        match entry.pool.try_submit(x)? {
            Submission::Accepted { id, shard } => {
                Ok(Submission::Accepted { id: entry.base + id, shard })
            }
            shed @ Submission::Shed { .. } => Ok(shed),
        }
    }

    /// The shed-policy `Retry-After` hint for `key`: seconds until the
    /// model's current in-flight backlog clears at its pool's observed
    /// drain rate ([`WorkerPool::retry_after_hint`]), clamped to
    /// `[1, 30]`. Read at shed time so the 429 response advertises the
    /// shedding pool's actual pace, not a constant.
    pub fn retry_after_hint(&self, key: &str) -> Result<u64> {
        Ok(self.entry(key)?.pool.retry_after_hint())
    }

    /// Completions of `key` that have arrived so far (non-blocking):
    /// carryover from a hot swap first, then the live pool's.
    pub fn try_completions(&mut self, key: &str) -> Result<Vec<PoolCompletion>> {
        let entry = self.entry_mut(key)?;
        let base = entry.base;
        let mut out = std::mem::take(&mut entry.carryover);
        out.extend(entry.pool.try_completions().into_iter().map(|mut c| {
            c.id += base;
            c
        }));
        entry.stats.completed += out.len() as u64;
        Ok(out)
    }

    /// Zero-downtime hot swap: spawn a pool for `engine` (preloading its
    /// weight cache) while the old pool is still serving, fail fast if the
    /// replacement does not serve the same request/response interface,
    /// atomically swap the pool behind `key`, then drain the old pool —
    /// its in-flight completions are carried over (ids remapped) and
    /// delivered through [`try_completions`](Self::try_completions), so no
    /// accepted request is lost. Returns the number of carried-over
    /// completions.
    ///
    /// The interface check is input length + class count: budget variants
    /// (even of different architectures) may stand behind one key as long
    /// as callers see the same request and logit shapes. Internal
    /// consistency of the replacement itself (checksum, arch fingerprint)
    /// was already enforced when it was loaded/constructed.
    pub fn swap_model(&mut self, key: &str, engine: Arc<Engine>) -> Result<usize> {
        let cfg = self.cfg;
        let entry = self.entry_mut(key)?;
        let old = entry.pool.engine();
        if engine.input_len() != old.input_len() || engine.num_classes() != old.num_classes() {
            bail!(
                "hot swap rejected for '{key}': replacement serves {} -> {} values, \
                 the live model serves {} -> {}",
                engine.input_len(),
                engine.num_classes(),
                old.input_len(),
                old.num_classes()
            );
        }
        // New pool up (workers spawned, cache preloaded) before the old
        // one stops taking traffic.
        let new_pool = WorkerPool::new(engine, cfg)
            .with_context(|| format!("spawning replacement pool for model '{key}'"))?;
        let old_pool = std::mem::replace(&mut entry.pool, new_pool);
        let old_base = entry.base;
        entry.base += old_pool.accepted();
        entry.stats.add_pool(old_pool.stats());
        let (rest, shard_stats) = old_pool.shutdown()?;
        entry.stats.batch.merge(&BatcherStats::merge_all(&shard_stats));
        let carried = rest.len();
        entry.carryover.extend(rest.into_iter().map(|mut c| {
            c.id += old_base;
            c
        }));
        entry.stats.swaps += 1;
        Ok(carried)
    }

    /// Take the model behind `key` out of service: drain its pool and
    /// return the buffered completions plus final stats.
    pub fn remove_model(&mut self, key: &str) -> Result<ModelReport> {
        match self.models.remove(key) {
            Some(entry) => entry.drain(),
            None => bail!("no model behind key '{key}' (loaded: {})", self.key_list()),
        }
    }

    /// Drain every model and return the per-key reports. After this, each
    /// key's `completed == accepted` — the no-request-lost guarantee.
    pub fn shutdown(self) -> Result<BTreeMap<String, ModelReport>> {
        let mut out = BTreeMap::new();
        for (key, entry) in self.models {
            let report = entry.drain().with_context(|| format!("draining model '{key}'"))?;
            out.insert(key, report);
        }
        Ok(out)
    }

    fn entry(&self, key: &str) -> Result<&ModelEntry> {
        match self.models.get(key) {
            Some(e) => Ok(e),
            None => bail!("no model behind key '{key}' (loaded: {})", self.key_list()),
        }
    }

    fn entry_mut(&mut self, key: &str) -> Result<&mut ModelEntry> {
        if !self.models.contains_key(key) {
            bail!("no model behind key '{key}' (loaded: {})", self.key_list());
        }
        match self.models.get_mut(key) {
            Some(e) => Ok(e),
            None => bail!("model behind key '{key}' vanished mid-lookup"),
        }
    }

    fn key_list(&self) -> String {
        if self.models.is_empty() {
            "none".to_string()
        } else {
            self.models.keys().cloned().collect::<Vec<_>>().join(", ")
        }
    }
}
