//! `.cgmqm` — the packed mixed-precision model format.
//!
//! A trained CGMQ snapshot (params + ranges + gates) is turned into a
//! self-contained, serde-free binary artifact that stores each layer's
//! *integer weight codes* bit-packed at their trained per-gate bit-widths
//! (0/2/4/8/16/32 from [`crate::quant::transform_t`]), together with the
//! per-layer ranges and the activation quantization recipe the inference
//! engine needs. Biases are not quantized by the model and ship as f32.
//!
//! Layout (little-endian; all bit streams LSB-first within each byte):
//!
//! ```text
//! magic      "CGMQMODL"            8 bytes
//! version    u32                   currently 1
//! checksum   u64                   FNV-1a 64 over every byte after this field
//! arch_name  u16 len + utf-8
//! granularity u8                   0 = layer, 1 = individual
//! input_bits u32
//! input_shape u8 rank + u32 dims
//! n_layers   u32
//! per layer:
//!   name          u16 len + utf-8
//!   kind          u8               0 = dense, 1 = conv
//!   w_shape       u8 rank + u32 dims
//!   beta_w        f32
//!   bias          u32 len + f32 x len
//!   pool          u8
//!   weight widths  width stream (see below), one width per weight
//!   code_bits     u64
//!   codes         ceil(code_bits / 8) bytes, bit-packed weight codes
//!   has_act       u8
//!   if has_act: beta_a f32, activation width stream (one per act unit)
//! ```
//!
//! A *width stream* is `flag u8` then either `u8` (flag 0: one uniform
//! width code for the whole tensor — the `Layer` granularity case) or
//! `u64 count` + packed 4-bit width codes (flag 1: per-element widths,
//! the `Individual` granularity case). Width codes index
//! [`WIDTH_TABLE`] = `[0, 2, 4, 8, 16, 32]`.
//!
//! Weight codes are stored per weight at that weight's bit-width: nothing
//! for pruned (0-bit) weights, the two's-complement grid index in `b` bits
//! for b in {2, 4, 8, 16}, and the raw bits of the *clipped* f32 value for
//! 32 (>= [`crate::quant::IDENTITY_BITS`] fake quantization degenerates to
//! clip, so there is no integer grid to index). Decoding multiplies the
//! grid index by [`crate::quant::step_size`] — exactly the arithmetic the
//! fake quantizer used, so `decode(pack(w)) == gated_quantize(w)`
//! bit-for-bit; the cross-path golden test in `tests/deploy_roundtrip.rs`
//! holds the whole forward pass to that standard.
//!
//! The loader mirrors the `runtime::ArtifactSet::verify_arch` idiom: the
//! recorded arch name is resolved through [`arch_by_name`] and every layer
//! record is verified against the compiled-in [`ArchSpec`] (names, kinds,
//! shapes, pooling, activation quantization), so a model packed against a
//! drifted architecture fails fast at load instead of feeding codes into
//! the wrong matmul.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::gates::{GateSet, Granularity};
use crate::model::{arch_by_name, ArchSpec, LayerKind};
use crate::quant::{clip, integer_code, transform_t, IDENTITY_BITS};
use crate::session::Snapshot;
use crate::tensor::Tensor;

pub const MAGIC: &[u8; 8] = b"CGMQMODL";

/// Packed-model format version. Bump on any layout change; `load` refuses
/// other versions up front (same contract as `checkpoint::FORMAT_VERSION`).
pub const FORMAT_VERSION: u32 = 1;

/// The bit-widths a width code can index (T(g) levels incl. pruning).
pub const WIDTH_TABLE: [u32; 6] = [0, 2, 4, 8, 16, 32];

/// Bits of storage one weight of width `w` occupies in the code stream.
#[inline]
pub fn storage_bits(width: u32) -> u64 {
    match width {
        0 => 0,
        w if w >= IDENTITY_BITS => 32,
        w => w as u64,
    }
}

fn width_code(width: u32) -> Result<u8> {
    WIDTH_TABLE
        .iter()
        .position(|&w| w == width)
        .map(|i| i as u8)
        .with_context(|| format!("bit-width {width} is not a T(g) level"))
}

fn width_from_code(code: u8) -> Result<u32> {
    WIDTH_TABLE
        .get(code as usize)
        .copied()
        .with_context(|| format!("width code {code} out of range"))
}

// ---------------------------------------------------------------------------
// Bit-level packing
// ---------------------------------------------------------------------------

/// LSB-first bit stream writer: bit i of the stream is
/// `byte[i / 8] >> (i % 8) & 1`.
#[derive(Debug, Default)]
pub struct BitWriter {
    bytes: Vec<u8>,
    /// Total bits written.
    bits: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the low `n_bits` of `value` (n_bits <= 64).
    pub fn push(&mut self, value: u64, n_bits: u32) {
        debug_assert!(n_bits <= 64);
        let mut remaining = n_bits;
        let mut v = value;
        while remaining > 0 {
            let used = (self.bits % 8) as u32;
            if used == 0 {
                self.bytes.push(0);
            }
            let room = 8 - used;
            let take = room.min(remaining);
            let mask = if take == 64 { u64::MAX } else { (1u64 << take) - 1 };
            let last = self.bytes.last_mut().expect("byte pushed above");
            *last |= ((v & mask) as u8) << used;
            v >>= take;
            self.bits += take as u64;
            remaining -= take;
        }
    }

    pub fn bit_len(&self) -> u64 {
        self.bits
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }
}

/// LSB-first bit stream reader over a byte slice.
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos: 0 }
    }

    /// Read the next `n_bits` (<= 64) as an unsigned value.
    pub fn read(&mut self, n_bits: u32) -> Result<u64> {
        debug_assert!(n_bits <= 64);
        if self.pos + n_bits as u64 > self.bytes.len() as u64 * 8 {
            bail!(
                "bit stream exhausted: want {} bits at position {}, have {}",
                n_bits,
                self.pos,
                self.bytes.len() as u64 * 8
            );
        }
        let mut out = 0u64;
        let mut got = 0u32;
        while got < n_bits {
            let byte = self.bytes[(self.pos / 8) as usize] as u64;
            let used = (self.pos % 8) as u32;
            let room = 8 - used;
            let take = room.min(n_bits - got);
            let mask = (1u64 << take) - 1;
            out |= ((byte >> used) & mask) << got;
            self.pos += take as u64;
            got += take;
        }
        Ok(out)
    }
}

/// Sign-extend an LSB-aligned `bits`-wide two's-complement value.
#[inline]
pub fn sign_extend(raw: u64, bits: u32) -> i64 {
    debug_assert!((1..=63).contains(&bits));
    let shift = 64 - bits;
    ((raw << shift) as i64) >> shift
}

// ---------------------------------------------------------------------------
// Width streams
// ---------------------------------------------------------------------------

/// Per-tensor bit-width assignment: one shared width (`Layer` granularity)
/// or one width per element (`Individual` granularity).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WidthStream {
    Uniform(u32),
    PerElement(Vec<u32>),
}

impl WidthStream {
    /// Width of element `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        match self {
            WidthStream::Uniform(w) => *w,
            WidthStream::PerElement(v) => v[i],
        }
    }

    /// Total storage bits of `n` elements packed at these widths.
    pub fn payload_bits(&self, n: usize) -> u64 {
        match self {
            WidthStream::Uniform(w) => storage_bits(*w) * n as u64,
            WidthStream::PerElement(v) => v.iter().map(|&w| storage_bits(w)).sum(),
        }
    }

    /// Build from a gate tensor: uniform for `Layer` granularity (single
    /// scalar gate), per-element otherwise.
    pub fn from_gates(granularity: Granularity, gates: &Tensor) -> Result<Self> {
        match granularity {
            Granularity::Layer => {
                if gates.len() != 1 {
                    bail!("layer granularity wants a scalar gate, got {} values", gates.len());
                }
                Ok(WidthStream::Uniform(transform_t(gates.data()[0])))
            }
            Granularity::Individual => {
                Ok(WidthStream::PerElement(gates.data().iter().map(|&g| transform_t(g)).collect()))
            }
        }
    }

    fn encode(&self, out: &mut Vec<u8>) -> Result<()> {
        match self {
            WidthStream::Uniform(w) => {
                out.push(0);
                out.push(width_code(*w)?);
            }
            WidthStream::PerElement(v) => {
                out.push(1);
                out.extend_from_slice(&(v.len() as u64).to_le_bytes());
                let mut bw = BitWriter::new();
                for &w in v {
                    bw.push(width_code(w)? as u64, 4);
                }
                out.extend_from_slice(&bw.into_bytes());
            }
        }
        Ok(())
    }

    fn decode(cur: &mut Cursor, expect_n: usize) -> Result<Self> {
        match cur.u8()? {
            0 => Ok(WidthStream::Uniform(width_from_code(cur.u8()?)?)),
            1 => {
                let n = cur.u64()? as usize;
                if n != expect_n {
                    bail!("width stream has {n} entries, tensor wants {expect_n}");
                }
                let packed = cur.bytes((n as u64 * 4).div_ceil(8) as usize)?;
                let mut br = BitReader::new(packed);
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(width_from_code(br.read(4)? as u8)?);
                }
                Ok(WidthStream::PerElement(v))
            }
            other => bail!("bad width stream flag {other}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Packed layers / model
// ---------------------------------------------------------------------------

/// One layer of a packed model: quantization recipe + bit-packed codes.
#[derive(Debug, Clone)]
pub struct PackedLayer {
    pub name: String,
    pub kind: LayerKind,
    pub w_shape: Vec<usize>,
    /// Weight range (alpha = -beta, signed grid).
    pub beta_w: f32,
    /// Per-weight bit-widths.
    pub w_bits: WidthStream,
    /// Bit-packed weight codes (see module docs for the per-width layout).
    pub codes: Vec<u8>,
    /// Exact bit length of `codes` (the tail byte may be partial).
    pub code_bits: u64,
    /// Unquantized bias, shipped as f32.
    pub bias: Vec<f32>,
    /// Square max-pool window/stride applied after the activation (0 = none).
    pub pool: usize,
    /// Activation fake-quantization recipe (`None` for the output layer).
    pub act: Option<PackedAct>,
}

/// Activation quantization recipe of one layer (unsigned grid on [0, beta]).
#[derive(Debug, Clone)]
pub struct PackedAct {
    pub beta_a: f32,
    /// Per-activation-unit bit-widths (feature dims, broadcast over batch).
    pub a_bits: WidthStream,
}

impl PackedLayer {
    pub fn w_len(&self) -> usize {
        self.w_shape.iter().product()
    }

    /// Bytes of the bit-packed weight code payload of this layer.
    pub fn payload_bytes(&self) -> u64 {
        self.code_bits.div_ceil(8)
    }

    /// Decode the packed codes back to the fake-quantized f32 weights.
    pub fn decode_weights(&self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.decode_weights_into(&mut out)?;
        Ok(out)
    }

    /// [`decode_weights`](Self::decode_weights) into a caller-owned
    /// buffer (cleared first). A buffer that has already seen this
    /// layer's length decodes with no allocation — the streaming
    /// engine's per-call path.
    pub fn decode_weights_into(&self, out: &mut Vec<f32>) -> Result<()> {
        let n = self.w_len();
        out.clear();
        out.reserve(n);
        let mut br = BitReader::new(&self.codes);
        for i in 0..n {
            let width = self.w_bits.get(i);
            let v = match width {
                0 => 0.0,
                w if w >= IDENTITY_BITS => f32::from_bits(br.read(32)? as u32),
                w => {
                    let raw = br.read(w)?;
                    crate::quant::decode_code(sign_extend(raw, w), w, self.beta_w, true)
                }
            };
            out.push(v);
        }
        Ok(())
    }

    /// Walk the packed integer code stream without leaving the code
    /// domain: `f(index, width, code)` for every weight element, with
    /// pruned (0-width) elements reported as code 0. This is the SWAR
    /// repack's entry point — the integer kernels consume the codes
    /// directly, so the stream must carry an integer grid; a layer with
    /// any >= [`IDENTITY_BITS`] width (raw f32 payload) is a typed
    /// error, and the [`KernelSelector`](super::plan::KernelSelector)
    /// never routes such a layer here.
    pub fn with_codes(&self, mut f: impl FnMut(usize, u32, i64)) -> Result<()> {
        let n = self.w_len();
        let mut br = BitReader::new(&self.codes);
        for i in 0..n {
            let width = self.w_bits.get(i);
            let code = match width {
                0 => 0,
                w if w >= IDENTITY_BITS => bail!(
                    "layer {}: {w}-bit elements carry raw f32 payloads, not integer codes",
                    self.name
                ),
                w => sign_extend(br.read(w)?, w),
            };
            f(i, width, code);
        }
        Ok(())
    }
}

/// A full packed model: what `.cgmqm` serializes.
#[derive(Debug, Clone)]
pub struct PackedModel {
    pub arch_name: String,
    pub granularity: Granularity,
    pub input_bits: u32,
    pub input_shape: Vec<usize>,
    pub layers: Vec<PackedLayer>,
}

impl PackedModel {
    /// Pack a trained state (the tensors a [`Snapshot`] carries).
    pub fn from_state(
        arch: &ArchSpec,
        params: &[Tensor],
        betas_w: &Tensor,
        betas_a: &Tensor,
        gates: &GateSet,
    ) -> Result<Self> {
        if params.len() != 2 * arch.layers.len() {
            bail!(
                "{} param tensors, arch '{}' wants {}",
                params.len(),
                arch.name,
                2 * arch.layers.len()
            );
        }
        if betas_w.len() != arch.layers.len() || betas_a.len() != arch.n_quant_act() {
            bail!(
                "range tensors ({}, {}) do not match arch '{}' ({} layers, {} quant acts)",
                betas_w.len(),
                betas_a.len(),
                arch.name,
                arch.layers.len(),
                arch.n_quant_act()
            );
        }
        let mut layers = Vec::with_capacity(arch.layers.len());
        let mut ai = 0;
        for (li, spec) in arch.layers.iter().enumerate() {
            let w = &params[2 * li];
            if w.shape() != spec.w_shape.as_slice() {
                bail!(
                    "layer {}: weight shape {:?} != arch {:?}",
                    spec.name,
                    w.shape(),
                    spec.w_shape
                );
            }
            let beta_w = betas_w.data()[li];
            let w_bits = match gates.granularity {
                Granularity::Layer => {
                    WidthStream::from_gates(gates.granularity, &gates.gates_w[li])?
                }
                Granularity::Individual => {
                    WidthStream::from_gates(gates.granularity, &gates.materialize_w(arch, li))?
                }
            };
            if let WidthStream::PerElement(v) = &w_bits {
                if v.len() != spec.w_len() {
                    bail!(
                        "layer {}: {} weight gates for {} weights (granularity mismatch?)",
                        spec.name,
                        v.len(),
                        spec.w_len()
                    );
                }
            }
            let mut bw = BitWriter::new();
            for (i, &x) in w.data().iter().enumerate() {
                match w_bits.get(i) {
                    0 => {}
                    width if width >= IDENTITY_BITS => {
                        bw.push(clip(x, -beta_w, beta_w).to_bits() as u64, 32);
                    }
                    width => {
                        let (n, _) = integer_code(x, width, beta_w, true);
                        bw.push(n as u64 & ((1u64 << width) - 1), width);
                    }
                }
            }
            let act = if spec.quant_act {
                let a_bits = match gates.granularity {
                    Granularity::Layer => {
                        WidthStream::from_gates(gates.granularity, &gates.gates_a[ai])?
                    }
                    Granularity::Individual => {
                        WidthStream::from_gates(gates.granularity, &gates.materialize_a(arch, ai))?
                    }
                };
                if let WidthStream::PerElement(v) = &a_bits {
                    if v.len() != spec.n_units() {
                        bail!(
                            "layer {}: {} activation gates for {} units (granularity mismatch?)",
                            spec.name,
                            v.len(),
                            spec.n_units()
                        );
                    }
                }
                let beta_a = betas_a.data()[ai];
                ai += 1;
                Some(PackedAct { beta_a, a_bits })
            } else {
                None
            };
            layers.push(PackedLayer {
                name: spec.name.to_string(),
                kind: spec.kind,
                w_shape: spec.w_shape.clone(),
                beta_w,
                code_bits: bw.bit_len(),
                codes: bw.into_bytes(),
                w_bits,
                bias: params[2 * li + 1].data().to_vec(),
                pool: spec.pool,
                act,
            });
        }
        Ok(Self {
            arch_name: arch.name.to_string(),
            granularity: gates.granularity,
            input_bits: arch.input_bits,
            input_shape: arch.input_shape.clone(),
            layers,
        })
    }

    /// Pack the delivered model of a finished run.
    pub fn from_snapshot(arch: &ArchSpec, snap: &Snapshot) -> Result<Self> {
        Self::from_state(arch, &snap.params, &snap.betas_w, &snap.betas_a, &snap.gates)
    }

    /// Per-sample input element count.
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Bytes of each layer's bit-packed weight code payload.
    pub fn layer_payload_bytes(&self) -> Vec<u64> {
        self.layers.iter().map(|l| l.payload_bytes()).collect()
    }

    /// Total bit-packed weight payload across layers.
    pub fn total_payload_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.payload_bytes()).sum()
    }

    /// Decode layer `li`'s weights to the fake-quantized f32 tensor data.
    pub fn decode_weights(&self, li: usize) -> Result<Vec<f32>> {
        self.layers
            .get(li)
            .with_context(|| format!("layer index {li} out of range"))?
            .decode_weights()
    }

    // ------------------------------------------------------------- encoding

    /// Serialize to the `.cgmqm` byte layout (including header + checksum).
    pub fn encode(&self) -> Result<Vec<u8>> {
        let mut body = Vec::new();
        write_str(&mut body, &self.arch_name)?;
        body.push(match self.granularity {
            Granularity::Layer => 0,
            Granularity::Individual => 1,
        });
        body.extend_from_slice(&self.input_bits.to_le_bytes());
        write_shape(&mut body, &self.input_shape)?;
        body.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        for l in &self.layers {
            write_str(&mut body, &l.name)?;
            body.push(match l.kind {
                LayerKind::Dense => 0,
                LayerKind::Conv => 1,
            });
            write_shape(&mut body, &l.w_shape)?;
            body.extend_from_slice(&l.beta_w.to_le_bytes());
            body.extend_from_slice(&(l.bias.len() as u32).to_le_bytes());
            for &b in &l.bias {
                body.extend_from_slice(&b.to_le_bytes());
            }
            body.push(l.pool as u8);
            l.w_bits.encode(&mut body)?;
            let expect = l.w_bits.payload_bits(l.w_len());
            if expect != l.code_bits || l.codes.len() as u64 != l.code_bits.div_ceil(8) {
                bail!(
                    "layer {}: code stream is {} bits / {} bytes, widths want {} bits",
                    l.name,
                    l.code_bits,
                    l.codes.len(),
                    expect
                );
            }
            body.extend_from_slice(&l.code_bits.to_le_bytes());
            body.extend_from_slice(&l.codes);
            match &l.act {
                None => body.push(0),
                Some(act) => {
                    body.push(1);
                    body.extend_from_slice(&act.beta_a.to_le_bytes());
                    act.a_bits.encode(&mut body)?;
                }
            }
        }
        let mut out = Vec::with_capacity(body.len() + 20);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&fnv1a64(&body).to_le_bytes());
        out.extend_from_slice(&body);
        Ok(out)
    }

    /// Total `.cgmqm` file size in bytes (header + all records). Performs
    /// a full serialization — when also writing the file, prefer the byte
    /// count [`save`](Self::save) returns.
    pub fn encoded_len(&self) -> Result<u64> {
        Ok(self.encode()?.len() as u64)
    }

    /// Write the `.cgmqm` file; returns the number of bytes written.
    pub fn save(&self, path: &Path) -> Result<u64> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let bytes = self.encode()?;
        std::fs::write(path, &bytes).with_context(|| format!("writing {}", path.display()))?;
        Ok(bytes.len() as u64)
    }

    /// Load and fully verify a packed model: magic, version, checksum, then
    /// the recorded arch against the compiled-in [`ArchSpec`] (fail-fast on
    /// drift). Returns the model together with its resolved arch.
    pub fn load(path: &Path) -> Result<(Self, ArchSpec)> {
        let bytes =
            std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
        let model = Self::decode(&bytes).with_context(|| format!("loading {}", path.display()))?;
        let arch = model.verify()?;
        Ok((model, arch))
    }

    /// Parse the byte layout (magic/version/checksum checks; no arch check).
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 20 || &bytes[..8] != MAGIC {
            bail!("not a .cgmqm packed model (bad magic)");
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != FORMAT_VERSION {
            bail!(
                "packed model format version {version}, but this build reads version \
                 {FORMAT_VERSION} — re-export with a matching cgmq build"
            );
        }
        let checksum = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
        let body = &bytes[20..];
        let actual = fnv1a64(body);
        if checksum != actual {
            bail!("checksum mismatch: header {checksum:#x}, payload {actual:#x} — file corrupt");
        }
        let mut cur = Cursor::new(body);
        let arch_name = cur.string()?;
        let granularity = match cur.u8()? {
            0 => Granularity::Layer,
            1 => Granularity::Individual,
            other => bail!("bad granularity byte {other}"),
        };
        let input_bits = cur.u32()?;
        let input_shape = cur.shape()?;
        let n_layers = cur.u32()? as usize;
        if n_layers > 256 {
            bail!("corrupt packed model: {n_layers} layers");
        }
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let name = cur.string()?;
            let kind = match cur.u8()? {
                0 => LayerKind::Dense,
                1 => LayerKind::Conv,
                other => bail!("layer {name}: bad kind byte {other}"),
            };
            let w_shape = cur.shape()?;
            let w_len: usize = w_shape.iter().product();
            let beta_w = cur.f32()?;
            let bias_len = cur.u32()? as usize;
            if bias_len > (1 << 24) {
                bail!("layer {name}: corrupt bias length {bias_len}");
            }
            let mut bias = Vec::with_capacity(bias_len);
            for _ in 0..bias_len {
                bias.push(cur.f32()?);
            }
            let pool = cur.u8()? as usize;
            let w_bits = WidthStream::decode(&mut cur, w_len)?;
            let code_bits = cur.u64()?;
            let expect = w_bits.payload_bits(w_len);
            if code_bits != expect {
                bail!("layer {name}: {code_bits} code bits recorded, widths want {expect}");
            }
            let codes = cur.bytes(code_bits.div_ceil(8) as usize)?.to_vec();
            let act = match cur.u8()? {
                0 => None,
                1 => {
                    let beta_a = cur.f32()?;
                    // Unit count is validated against the arch in verify();
                    // here only self-consistency of the stream is enforced.
                    let n_units = cur.peek_stream_len()?;
                    let a_bits = WidthStream::decode(&mut cur, n_units)?;
                    Some(PackedAct { beta_a, a_bits })
                }
                other => bail!("layer {name}: bad has_act byte {other}"),
            };
            layers.push(PackedLayer {
                name,
                kind,
                w_shape,
                beta_w,
                w_bits,
                codes,
                code_bits,
                bias,
                pool,
                act,
            });
        }
        cur.expect_end()?;
        Ok(Self { arch_name, granularity, input_bits, input_shape, layers })
    }

    /// Walk the recorded geometry (input shape through conv/dense/pool)
    /// and reject anything the engine's kernels would mishandle —
    /// foremost a max-pool window that does not divide the spatial dims:
    /// `kernels::maxpool` floor-divides, so a non-divisible window would
    /// *silently drop* edge rows/cols instead of pooling them.
    fn verify_geometry(&self) -> Result<()> {
        let mut dims = self.input_shape.clone();
        for l in &self.layers {
            match l.kind {
                LayerKind::Dense => {
                    if l.w_shape.len() != 2 {
                        bail!("layer {}: dense weight shape {:?} is not 2-D", l.name, l.w_shape);
                    }
                    dims = vec![l.w_shape[1]];
                }
                LayerKind::Conv => {
                    if l.w_shape.len() != 4 {
                        bail!("layer {}: conv weight shape {:?} is not OIHW", l.name, l.w_shape);
                    }
                    if dims.len() != 3 {
                        bail!("layer {}: conv wants CHW input, got {:?}", l.name, dims);
                    }
                    let (kh, kw) = (l.w_shape[2], l.w_shape[3]);
                    if dims[1] < kh || dims[2] < kw {
                        bail!(
                            "layer {}: input {:?} smaller than kernel {:?}",
                            l.name,
                            dims,
                            l.w_shape
                        );
                    }
                    dims = vec![l.w_shape[0], dims[1] - kh + 1, dims[2] - kw + 1];
                }
            }
            if l.pool > 1 {
                if dims.len() != 3 {
                    bail!("layer {}: max-pool on a non-spatial output {:?}", l.name, dims);
                }
                if dims[1] % l.pool != 0 || dims[2] % l.pool != 0 {
                    bail!(
                        "layer {}: {}x{} output is not divisible by max-pool window {} — \
                         pooling would silently drop edge rows/cols",
                        l.name,
                        dims[1],
                        dims[2],
                        l.pool
                    );
                }
                dims = vec![dims[0], dims[1] / l.pool, dims[2] / l.pool];
            }
        }
        Ok(())
    }

    /// Resolve the recorded arch and verify every layer record against it
    /// (the manifest-verification idiom): names, kinds, shapes, pooling and
    /// activation quantization must all match the compiled-in spec. Runs
    /// the geometry walk first, so impossible pooling is reported as such
    /// rather than as generic arch drift.
    pub fn verify(&self) -> Result<ArchSpec> {
        self.verify_geometry()?;
        let arch = arch_by_name(&self.arch_name)
            .with_context(|| format!("packed model records unknown arch '{}'", self.arch_name))?;
        if self.input_shape != arch.input_shape {
            bail!(
                "{}: input_shape {:?} != arch {:?}",
                self.arch_name,
                self.input_shape,
                arch.input_shape
            );
        }
        if self.input_bits != arch.input_bits {
            bail!("{}: input_bits {} != arch {}", self.arch_name, self.input_bits, arch.input_bits);
        }
        if self.layers.len() != arch.layers.len() {
            bail!("{}: {} layers != arch {}", self.arch_name, self.layers.len(), arch.layers.len());
        }
        for (l, spec) in self.layers.iter().zip(&arch.layers) {
            if l.name != spec.name {
                bail!("{}: layer name '{}' != arch '{}'", self.arch_name, l.name, spec.name);
            }
            if l.kind != spec.kind {
                bail!("{}: layer {} kind drifted", self.arch_name, l.name);
            }
            if l.w_shape != spec.w_shape {
                bail!(
                    "{}: layer {} w_shape {:?} != arch {:?}",
                    self.arch_name,
                    l.name,
                    l.w_shape,
                    spec.w_shape
                );
            }
            let b_len: usize = spec.b_shape.iter().product();
            if l.bias.len() != b_len {
                bail!(
                    "{}: layer {} bias length {} != arch {}",
                    self.arch_name,
                    l.name,
                    l.bias.len(),
                    b_len
                );
            }
            if l.pool != spec.pool {
                bail!("{}: layer {} pool drifted", self.arch_name, l.name);
            }
            if l.act.is_some() != spec.quant_act {
                bail!("{}: layer {} quant_act drifted", self.arch_name, l.name);
            }
            if let Some(act) = &l.act {
                if let WidthStream::PerElement(v) = &act.a_bits {
                    if v.len() != spec.n_units() {
                        bail!(
                            "{}: layer {} has {} act widths, arch wants {}",
                            self.arch_name,
                            l.name,
                            v.len(),
                            spec.n_units()
                        );
                    }
                }
            }
        }
        Ok(arch)
    }
}

/// FNV-1a 64-bit hash (the header checksum).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Byte-level helpers
// ---------------------------------------------------------------------------

fn write_str(out: &mut Vec<u8>, s: &str) -> Result<()> {
    if s.len() > u16::MAX as usize {
        bail!("string too long for format: {} bytes", s.len());
    }
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

fn write_shape(out: &mut Vec<u8>, shape: &[usize]) -> Result<()> {
    if shape.len() > u8::MAX as usize {
        bail!("shape rank too large: {}", shape.len());
    }
    out.push(shape.len() as u8);
    for &d in shape {
        if d > u32::MAX as usize {
            bail!("shape dim too large: {d}");
        }
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    Ok(())
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!("truncated packed model: want {} bytes at offset {}", n, self.pos);
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.bytes(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        String::from_utf8(self.bytes(n)?.to_vec()).context("non-utf8 string in packed model")
    }

    fn shape(&mut self) -> Result<Vec<usize>> {
        let rank = self.u8()? as usize;
        if rank > 16 {
            bail!("corrupt packed model: rank {rank}");
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(self.u32()? as usize);
        }
        dims.iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .filter(|&c| c <= (1usize << 31))
            .with_context(|| format!("corrupt packed model: dims {dims:?}"))?;
        Ok(dims)
    }

    /// For a per-element width stream whose count field is self-recorded:
    /// read the count *without* consuming it (the stream decoder validates
    /// it against the caller's expectation).
    fn peek_stream_len(&self) -> Result<usize> {
        if self.pos >= self.bytes.len() {
            bail!("truncated packed model at width stream");
        }
        match self.bytes[self.pos] {
            0 => Ok(0), // uniform: count unused by decode()
            _ => {
                if self.pos + 9 > self.bytes.len() {
                    bail!("truncated packed model at width stream count");
                }
                let raw: [u8; 8] = self.bytes[self.pos + 1..self.pos + 9].try_into().unwrap();
                let n = u64::from_le_bytes(raw);
                if n > (1 << 31) {
                    bail!("corrupt packed model: width stream count {n}");
                }
                Ok(n as usize)
            }
        }
    }

    fn expect_end(&self) -> Result<()> {
        if self.pos != self.bytes.len() {
            bail!("{} trailing bytes after packed model payload", self.bytes.len() - self.pos);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn bit_writer_reader_roundtrip_unaligned() {
        // Mixed widths with a non-byte-aligned tail.
        let mut w = BitWriter::new();
        let fields: [(u64, u32); 7] =
            [(0b10, 2), (0b1011, 4), (0xAB, 8), (0x7FFF, 16), (1, 2), (0, 2), (0b101, 3)];
        for &(v, b) in &fields {
            w.push(v, b);
        }
        assert_eq!(w.bit_len(), 37);
        let bytes = w.into_bytes();
        assert_eq!(bytes.len(), 5); // ceil(37 / 8)
        let mut r = BitReader::new(&bytes);
        for &(v, b) in &fields {
            assert_eq!(r.read(b).unwrap(), v);
        }
        assert!(r.read(8).is_err()); // exhausted
    }

    #[test]
    fn sign_extend_small_widths() {
        assert_eq!(sign_extend(0b11, 2), -1);
        assert_eq!(sign_extend(0b01, 2), 1);
        assert_eq!(sign_extend(0b1111, 4), -1);
        assert_eq!(sign_extend(0b1001, 4), -7);
        assert_eq!(sign_extend(0x8001, 16), -32767);
        assert_eq!(sign_extend(0x7FFF, 16), 32767);
    }

    #[test]
    fn width_stream_payload_accounting() {
        let u = WidthStream::Uniform(4);
        assert_eq!(u.payload_bits(10), 40);
        let p = WidthStream::PerElement(vec![0, 2, 4, 8, 16, 32]);
        assert_eq!(p.payload_bits(6), 2 + 4 + 8 + 16 + 32);
    }

    #[test]
    fn fnv_vector() {
        // FNV-1a 64 known vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn width_stream_encode_decode_odd_counts() {
        // Odd element counts leave a partial nibble byte at the tail.
        for n in [1usize, 3, 5, 13, 257] {
            let v: Vec<u32> = (0..n).map(|i| WIDTH_TABLE[i % 6]).collect();
            let ws = WidthStream::PerElement(v);
            let mut out = Vec::new();
            ws.encode(&mut out).unwrap();
            let mut cur = Cursor::new(&out);
            let back = WidthStream::decode(&mut cur, n).unwrap();
            assert_eq!(back, ws);
            cur.expect_end().unwrap();
            // Wrong expected count is rejected.
            let mut cur = Cursor::new(&out);
            assert!(WidthStream::decode(&mut cur, n + 1).is_err());
        }
    }

    #[test]
    fn random_codes_roundtrip_all_widths() {
        let mut rng = SplitMix64::new(21);
        for len in [1usize, 3, 7, 13, 64, 257] {
            let widths: Vec<u32> =
                (0..len).map(|_| WIDTH_TABLE[(rng.next_u64() % 6) as usize]).collect();
            let mut codes: Vec<i64> = Vec::with_capacity(len);
            let mut w = BitWriter::new();
            for &b in &widths {
                match b {
                    0 => codes.push(0),
                    32 => {
                        let v = rng.uniform(-2.0, 2.0) as f32;
                        codes.push(v.to_bits() as i64);
                        w.push(v.to_bits() as u64, 32);
                    }
                    b => {
                        let n_max = (1i64 << (b - 1)) - 1;
                        let n = (rng.next_u64() % (2 * n_max as u64 + 1)) as i64 - n_max;
                        codes.push(n);
                        w.push(n as u64 & ((1u64 << b) - 1), b);
                    }
                }
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for (i, &b) in widths.iter().enumerate() {
                match b {
                    0 => {}
                    32 => assert_eq!(r.read(32).unwrap(), codes[i] as u64),
                    b => assert_eq!(sign_extend(r.read(b).unwrap(), b), codes[i], "i={i} b={b}"),
                }
            }
        }
    }
}
