//! The [`Server`]: the multi-model [`Router`] behind a thread-safe HTTP
//! front.
//!
//! `Router::try_submit` takes `&mut self`, so the N connection workers
//! cannot call it directly — the server fronts the router with one mutex,
//! which is also what keeps the accounting exact across threads: every
//! submission serializes through the pool's admission choke point, so
//! `submitted == accepted + shed` holds under any interleaving and
//! `/stats` can never tear a snapshot mid-update.
//!
//! Completions flow the other way through a single **pump** thread: it
//! drains [`Router::try_completions`] for every key and hands each
//! completion to the connection worker waiting on `(key, id)` via a shared
//! map + condvar. Connection workers never hold the router lock while
//! waiting, so submission stays live while responses are in flight.
//!
//! **Graceful drain** ([`Server::finish`], also what `POST
//! /admin/shutdown` triggers via [`Server::run`]): stop accepting, join
//! every connection worker (each finishes its in-flight request — the
//! pump keeps running until nothing is outstanding), then shut the router
//! down and report per-model stats. [`ServerReport::verify_drained`]
//! checks the no-request-lost guarantee: per key, `completed == accepted`.
//!
//! **Observability**: every infer request gets a server-unique id (echoed
//! back as `X-Request-Id`) and a per-stage [`SpanRecorder`] trace;
//! counters and stage histograms aggregate in the shared
//! [`ServerTelemetry`] and are exposed as Prometheus text on `GET
//! /metrics` and as JSON on `GET /stats`. Both surfaces (and the final
//! [`ServerReport`]) read the same counters, so they agree bit-exactly
//! whenever the server is quiescent — which is what `cgmq load-bench`
//! cross-checks against its client-side tallies. On top of the
//! cumulative plane sits the *windowed* signal plane
//! ([`telemetry::window`](crate::deploy::telemetry::window)): trailing-
//! window arrival rates, per-status/stage windows, queue-depth and
//! in-flight gauges, and the top-logit margin histogram — surfaced as
//! `cgmq_*_window*` series on `/metrics`, a `window` section per model
//! on `/stats`, and the `GET /livez` readiness probe, which reports
//! degraded (503) when the windowed shed rate or whole-request p99
//! bound crosses the configured thresholds.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::deploy::engine::{top_logit_margin, Engine};
use crate::deploy::pool::{PoolCompletion, PoolConfig, Submission};
use crate::deploy::router::{ModelReport, Router};
use crate::deploy::telemetry::{
    self, HistogramSnapshot, RealClock, ServerTelemetry, SpanRecorder, Stage, TelemetrySnapshot,
    STAGES, STATUS_CODES,
};
use crate::util::json::{self, Json};

use super::http::{Request, Response, Status};
use super::listener::{ConnLimits, Handler, Listener};
use super::lock;

/// Server knobs on top of the pool policy.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Worker/batching/admission policy of every model's pool.
    pub pool: PoolConfig,
    /// Request bodies above this are refused with 413.
    pub max_body: usize,
    /// Per-connection read deadline (idle keep-alive reap / stalled-peer 408).
    pub read_timeout: Duration,
    /// How long a connection worker waits for its completion before
    /// answering 504 (generous: it only fires if a worker wedges).
    pub reply_timeout: Duration,
    /// Completed [`Trace`](crate::deploy::telemetry::Trace)s kept in the
    /// telemetry ring for inspection (0 disables trace retention).
    pub trace_ring: usize,
    /// `GET /livez` reports degraded (503) when the server-wide windowed
    /// shed rate (429s over responses, trailing window) reaches this
    /// fraction. `> 1.0` disables the check.
    pub livez_shed_rate: f64,
    /// `GET /livez` reports degraded (503) when any model's windowed
    /// whole-request p99 upper bound (µs) exceeds this. `0` disables the
    /// check.
    pub livez_p99_us: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            pool: PoolConfig::default(),
            max_body: 1 << 20,
            read_timeout: Duration::from_secs(5),
            reply_timeout: Duration::from_secs(30),
            trace_ring: 256,
            livez_shed_rate: 0.5,
            livez_p99_us: 0,
        }
    }
}

type CompKey = (String, u64);

/// The thread-safe front over the router shared by every connection
/// worker and the pump.
struct Front {
    /// `None` once the server has drained — late requests get 503.
    router: Mutex<Option<Router>>,
    /// Loaded model keys (fixed after bind; no HTTP route mutates the set).
    keys: Vec<String>,
    /// Completions delivered by the pump, keyed by `(model key, id)`.
    done: Mutex<HashMap<CompKey, PoolCompletion>>,
    /// Signals new entries in `done`.
    arrived: Condvar,
    /// Waiters that gave up (reply timeout); the pump discards their
    /// completions instead of letting them sit in `done` forever.
    abandoned: Mutex<HashSet<CompKey>>,
    /// Accepted requests whose waiter has not been answered yet.
    outstanding: AtomicU64,
    /// 200s served on the infer route.
    served: AtomicU64,
    /// Graceful shutdown requested (`/admin/shutdown` or `finish`).
    stop: AtomicBool,
    /// Tells the pump to exit once nothing is outstanding.
    pump_stop: AtomicBool,
    reply_timeout: Duration,
    /// `/livez` degraded threshold on the windowed shed rate.
    livez_shed_rate: f64,
    /// `/livez` degraded threshold on the windowed p99 bound (µs, 0 off).
    livez_p99_us: u64,
    /// Stage histograms, per-model/status counters, request ids, traces.
    telemetry: Arc<ServerTelemetry>,
}

/// Admission outcome as the HTTP layer sees it.
enum SubmitOutcome {
    Accepted { id: u64 },
    Shed { queue_cap: usize, retry_after: u64 },
    UnknownKey,
    BadInput(String),
    /// Draining, or a pool whose workers are gone — a server-side 503
    /// either way, never blamed on the client.
    Unavailable(String),
}

impl Front {
    fn submit(&self, key: &str, x: Vec<f32>) -> SubmitOutcome {
        if !self.keys.iter().any(|k| k == key) {
            return SubmitOutcome::UnknownKey;
        }
        let mut guard = lock(&self.router);
        let Some(router) = guard.as_mut() else {
            return SubmitOutcome::Unavailable("server is draining".into());
        };
        // Validate the request shape up front, so any Err from the
        // submission path below is a server-side fault (dead worker), not
        // a client one.
        if let Ok(engine) = router.engine(key) {
            if engine.input_len() != x.len() {
                return SubmitOutcome::BadInput(format!(
                    "request has {} values, model wants {}",
                    x.len(),
                    engine.input_len()
                ));
            }
        }
        match router.try_submit(key, x) {
            Ok(Submission::Accepted { id, .. }) => {
                // ordering: relaxed — the increment happens under the router
                // mutex and only gates the pump's exit/backoff polling; the
                // completion data itself synchronizes through `done`.
                self.outstanding.fetch_add(1, Ordering::Relaxed);
                SubmitOutcome::Accepted { id }
            }
            Ok(Submission::Shed { queue_cap }) => {
                // Still under the router lock: read the shedding pool's
                // observed drain rate so the 429 advertises how long the
                // backlog actually needs, not a constant.
                let retry_after = router.retry_after_hint(key).unwrap_or(1);
                SubmitOutcome::Shed { queue_cap, retry_after }
            }
            Err(e) => SubmitOutcome::Unavailable(format!("{e:#}")),
        }
    }

    /// Block until the pump delivers `(key, id)` or the reply timeout
    /// passes (then the completion is marked abandoned so the pump can
    /// discard it on arrival).
    fn await_completion(&self, key: &str, id: u64) -> Option<PoolCompletion> {
        let k: CompKey = (key.to_string(), id);
        let deadline = Instant::now() + self.reply_timeout;
        let mut done = lock(&self.done);
        loop {
            if let Some(c) = done.remove(&k) {
                drop(done);
                // ordering: relaxed — decremented after the `done` mutex
                // already ordered the handoff; pump staleness only costs an
                // extra poll tick, never a lost completion.
                self.outstanding.fetch_sub(1, Ordering::Relaxed);
                // ordering: relaxed — display-only counter.
                self.served.fetch_add(1, Ordering::Relaxed);
                return Some(c);
            }
            let now = Instant::now();
            if now >= deadline {
                drop(done);
                // Same lock order as the pump (abandoned, then done), so a
                // completion that raced in during the gap is still found.
                let mut abandoned = lock(&self.abandoned);
                // analyze-allow: lock-scope intentional abandoned->done
                // nesting, same acquisition order as the pump's sweep
                let mut done = lock(&self.done);
                if let Some(c) = done.remove(&k) {
                    drop(done);
                    drop(abandoned);
                    // ordering: relaxed — see the fast path above.
                    self.outstanding.fetch_sub(1, Ordering::Relaxed);
                    // ordering: relaxed — display-only counter.
                    self.served.fetch_add(1, Ordering::Relaxed);
                    return Some(c);
                }
                abandoned.insert(k);
                drop(done);
                drop(abandoned);
                // ordering: relaxed — see the fast path above.
                self.outstanding.fetch_sub(1, Ordering::Relaxed);
                return None;
            }
            let (guard, _) = self
                .arrived
                .wait_timeout(done, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            done = guard;
        }
    }

    /// One pump sweep: drain every key's completions and wake the waiting
    /// workers. Returns how many completions were moved.
    fn sweep(&self) -> usize {
        let mut collected: Vec<(String, PoolCompletion)> = Vec::new();
        {
            let mut guard = lock(&self.router);
            if let Some(router) = guard.as_mut() {
                for key in &self.keys {
                    if let Ok(comps) = router.try_completions(key) {
                        collected.extend(comps.into_iter().map(|c| (key.clone(), c)));
                    }
                }
            }
        }
        if collected.is_empty() {
            return 0;
        }
        let n = collected.len();
        let mut abandoned = lock(&self.abandoned);
        // analyze-allow: lock-scope intentional abandoned->done nesting,
        // same acquisition order as await_completion's timeout path
        let mut done = lock(&self.done);
        for (key, c) in collected {
            let k = (key, c.id);
            if abandoned.remove(&k) {
                continue; // its waiter already answered 504
            }
            done.insert(k, c);
        }
        drop(done);
        drop(abandoned);
        self.arrived.notify_all();
        n
    }
}

fn pump_loop(front: Arc<Front>) {
    loop {
        if front.sweep() == 0 {
            // ordering: relaxed — a stale false only delays exit by one
            // poll tick; `finish` sets the flag after the listener joined.
            let stop = front.pump_stop.load(Ordering::Relaxed);
            // ordering: relaxed — once pump_stop is set every waiter has
            // returned (listener joined first), so the counter is
            // quiescent: a stale read of 0 implies a real 0. Before that,
            // staleness only mistunes the backoff below.
            let outstanding = front.outstanding.load(Ordering::Relaxed);
            if stop && outstanding == 0 {
                return;
            }
            // Poll fast only while requests are actually in flight; an
            // idle server backs off so the router mutex is not hammered
            // for nothing (the first request after an idle stretch pays
            // at most the long tick extra).
            std::thread::sleep(if outstanding == 0 {
                Duration::from_millis(2)
            } else {
                Duration::from_micros(200)
            });
        }
    }
}

/// Routes requests; all state lives in the shared [`Front`].
struct NetHandler {
    front: Arc<Front>,
}

impl NetHandler {
    fn healthz(&self) -> Response {
        Response::json(
            Status::Ok,
            &Json::obj(vec![
                ("status", Json::str("ok")),
                (
                    "models",
                    Json::Arr(self.front.keys.iter().map(|k| Json::str(k.as_str())).collect()),
                ),
                // ordering: relaxed — display-only snapshot for /healthz.
                ("outstanding", Json::num(self.front.outstanding.load(Ordering::Relaxed) as f64)),
            ]),
        )
    }

    fn stats(&self) -> Response {
        let guard = lock(&self.front.router);
        let Some(router) = guard.as_ref() else {
            return Response::error(Status::ServiceUnavailable, "server is draining");
        };
        let stats = router.stats_all();
        let decoded = router.decoded_layers_all();
        let depths = router.queue_depths_all();
        drop(guard);
        let snap = self.front.telemetry.snapshot();
        let models: BTreeMap<String, Json> = stats
            .into_iter()
            .map(|(k, s)| {
                let in_flight = s.accepted.saturating_sub(s.completed);
                let mut j = s.to_json();
                if let Json::Obj(m) = &mut j {
                    if let Some(ms) = snap.models.get(&k) {
                        m.insert("statuses".into(), statuses_json(&ms.by_status));
                        m.insert("stages".into(), stages_json(&ms.stages));
                        m.insert("window".into(), window_json(&ms.window));
                    }
                    if let Some(n) = decoded.get(&k) {
                        m.insert("decoded_layers".into(), Json::num(*n as f64));
                    }
                    if let Some(d) = depths.get(&k) {
                        m.insert(
                            "queue_depth".into(),
                            Json::Arr(d.iter().map(|&q| Json::num(q as f64)).collect()),
                        );
                    }
                    m.insert("in_flight".into(), Json::num(in_flight as f64));
                }
                (k, j)
            })
            .collect();
        Response::json(
            Status::Ok,
            &Json::obj(vec![
                // ordering: relaxed — display-only snapshot for /stats.
                ("served", Json::num(self.front.served.load(Ordering::Relaxed) as f64)),
                ("connections", Json::num(snap.connections as f64)),
                ("http_responses", statuses_json(&snap.http_status)),
                ("http_responses_window", statuses_json(&snap.http_window)),
                ("models", Json::Obj(models)),
            ]),
        )
    }

    /// `GET /metrics`: Prometheus text exposition. Reads the same router
    /// stats and telemetry counters `/stats` serializes, so the two
    /// surfaces agree bit-exactly at any quiescent point.
    fn metrics(&self) -> Response {
        let guard = lock(&self.front.router);
        let Some(router) = guard.as_ref() else {
            return Response::error(Status::ServiceUnavailable, "server is draining");
        };
        let routes = router.stats_all();
        let decoded = router.decoded_layers_all();
        let depths = router.queue_depths_all();
        drop(guard);
        let snap = self.front.telemetry.snapshot();
        // ordering: relaxed — display-only snapshot for /metrics.
        let served = self.front.served.load(Ordering::Relaxed);
        Response::text(
            Status::Ok,
            telemetry::render_prometheus(&snap, served, &routes, &decoded, &depths),
        )
    }

    /// `GET /livez`: the windowed readiness probe. Healthy (200) while
    /// the trailing-window shed rate stays under the configured fraction
    /// and every model's windowed whole-request p99 bound stays under the
    /// configured ceiling; degraded (503) otherwise, with the tripped
    /// thresholds listed in `reasons`. An idle window is healthy by
    /// definition — all windowed series decay to zero.
    fn livez(&self) -> Response {
        let snap = self.front.telemetry.snapshot();
        let mut responses = 0u64;
        let mut shed = 0u64;
        let mut worst_p99 = 0u64;
        let mut worst_p99_model = String::new();
        for (key, m) in &snap.models {
            responses += m.window.responses();
            shed += m.window.status_count(429);
            if let Some((_, hi)) = m.window.total.quantile_bounds(0.99) {
                if hi > worst_p99 {
                    worst_p99 = hi;
                    worst_p99_model = key.clone();
                }
            }
        }
        let shed_rate = if responses == 0 { 0.0 } else { shed as f64 / responses as f64 };
        let mut reasons: Vec<Json> = Vec::new();
        if responses > 0 && shed_rate >= self.front.livez_shed_rate {
            reasons.push(Json::str(format!(
                "windowed shed rate {shed_rate:.3} >= {:.3}",
                self.front.livez_shed_rate
            )));
        }
        if self.front.livez_p99_us > 0 && worst_p99 > self.front.livez_p99_us {
            reasons.push(Json::str(format!(
                "windowed p99 bound {worst_p99}us > {}us (model '{worst_p99_model}')",
                self.front.livez_p99_us
            )));
        }
        let degraded = !reasons.is_empty();
        let window_us = snap.models.values().next().map_or(0, |m| m.window.window_us);
        let body = Json::obj(vec![
            ("status", Json::str(if degraded { "degraded" } else { "live" })),
            ("window_us", Json::num(window_us as f64)),
            ("responses_window", Json::num(responses as f64)),
            ("shed_rate_window", Json::num(shed_rate)),
            ("p99_bound_us_window", Json::num(worst_p99 as f64)),
            ("reasons", Json::Arr(reasons)),
        ]);
        Response::json(if degraded { Status::ServiceUnavailable } else { Status::Ok }, &body)
    }

    /// The infer route's telemetry shell: allocates the request id, seeds
    /// the span recorder with the wire-level accept span, and records the
    /// finished trace whatever the outcome.
    fn infer(&self, key: &str, req: &Request) -> Response {
        let tel = &self.front.telemetry;
        let request_id = tel.next_request_id();
        let mut rec = SpanRecorder::start(tel.clock());
        if let (Some(first), Some(parsed)) = (req.first_byte, req.parsed) {
            rec.set(Stage::Accept, parsed.saturating_duration_since(first));
        }
        let mut resp = self.infer_inner(key, &req.body, &mut rec);
        resp.request_id = Some(request_id);
        tel.record(rec, key, request_id, resp.status.code());
        resp
    }

    fn infer_inner(&self, key: &str, body: &[u8], rec: &mut SpanRecorder) -> Response {
        let Ok(text) = std::str::from_utf8(body) else {
            return Response::error(Status::BadRequest, "body is not UTF-8");
        };
        let parsed = match json::parse(text) {
            Ok(v) => v,
            Err(e) => {
                return Response::error(Status::BadRequest, format!("body is not JSON: {e:#}"))
            }
        };
        let x = match parsed.get("x").and_then(Json::as_f32_vec) {
            Ok(x) => x,
            Err(_) => {
                return Response::error(
                    Status::BadRequest,
                    "body must be {\"x\": [<input floats>]}",
                )
            }
        };
        rec.mark(Stage::Parse);
        // Arrival = a keyed, parseable request reaching admission; counted
        // before the submit outcome so the rate estimator sees shed load.
        self.front.telemetry.count_arrival(key);
        let outcome = self.front.submit(key, x);
        rec.mark(Stage::Admit);
        match outcome {
            SubmitOutcome::Accepted { id } => match self.front.await_completion(key, id) {
                Some(c) => {
                    // Server-side stage durations measured by the batcher
                    // and the pool; the wall time this worker spent blocked
                    // in await_completion is covered by their sum.
                    rec.set(Stage::QueueWait, c.queue_delay);
                    rec.set(Stage::BatchWait, c.batch_wait);
                    rec.set(Stage::Compute, c.compute);
                    // The reply path is where the logits are in hand — feed
                    // the windowed confidence-margin histogram the cascade
                    // router reads.
                    self.front
                        .telemetry
                        .record_margin(key, top_logit_margin(&c.logits));
                    let resp = Response::json(
                        Status::Ok,
                        &Json::obj(vec![
                            ("key", Json::str(key)),
                            ("id", Json::num(id as f64)),
                            ("predicted", Json::num(c.predicted as f64)),
                            ("logits", Json::arr_f32(&c.logits)),
                            ("batch_size", Json::num(c.batch_size as f64)),
                        ]),
                    );
                    // Reply span: completion ready → response serialized
                    // (includes the pump handoff + JSON encoding above).
                    rec.set(Stage::Reply, c.completed_at.elapsed());
                    resp
                }
                None => Response::error(Status::GatewayTimeout, "completion did not arrive"),
            },
            SubmitOutcome::Shed { queue_cap, retry_after } => {
                let mut resp = Response::json(
                    Status::TooManyRequests,
                    &Json::obj(vec![
                        ("error", Json::str("shed")),
                        ("queue_cap", Json::num(queue_cap as f64)),
                        ("retry_after", Json::num(retry_after as f64)),
                    ]),
                );
                // Derived from the shedding pool's observed drain rate
                // (clamped to [1, 30]s); 1s before any drain is observed.
                resp.retry_after = Some(retry_after);
                resp
            }
            SubmitOutcome::UnknownKey => Response::error(
                Status::NotFound,
                format!("no model behind key '{key}' (loaded: {})", self.front.keys.join(", ")),
            ),
            SubmitOutcome::BadInput(msg) => Response::error(Status::BadRequest, msg),
            SubmitOutcome::Unavailable(msg) => Response::error(Status::ServiceUnavailable, msg),
        }
    }
}

impl Handler for NetHandler {
    fn handle(&self, req: Request) -> Response {
        let path = req.path().to_string();
        let segments: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
        match (req.method.as_str(), segments.as_slice()) {
            ("GET", ["healthz"]) => self.healthz(),
            ("GET", ["livez"]) => self.livez(),
            ("GET", ["stats"]) => self.stats(),
            ("GET", ["metrics"]) => self.metrics(),
            ("POST", ["v1", "models", key, "infer"]) => self.infer(key, &req),
            ("POST", ["admin", "shutdown"]) => {
                // ordering: seqcst — one-shot control-plane flag, off the
                // request fast path; the strongest order costs nothing here.
                self.front.stop.store(true, Ordering::SeqCst);
                Response::json(Status::Ok, &Json::obj(vec![("status", Json::str("draining"))]))
            }
            (_, ["healthz"]) | (_, ["livez"]) | (_, ["stats"]) | (_, ["metrics"]) => {
                Response::error(Status::MethodNotAllowed, "route is GET-only")
            }
            (_, ["v1", "models", _, "infer"]) | (_, ["admin", "shutdown"]) => {
                Response::error(Status::MethodNotAllowed, "route is POST-only")
            }
            _ => Response::error(
                Status::NotFound,
                format!(
                    "no route '{path}' (routes: POST /v1/models/{{key}}/infer, GET /healthz, \
                     GET /livez, GET /stats, GET /metrics, POST /admin/shutdown)"
                ),
            ),
        }
    }
}

/// `{"200": n, ...}` over the full status taxonomy, zeros included, so the
/// three exposition surfaces (`/stats`, `/metrics`, [`ServerReport`]) stay
/// shape-stable and bit-comparable.
fn statuses_json(counts: &[u64; STATUS_CODES.len()]) -> Json {
    let mut m = BTreeMap::new();
    for (i, code) in STATUS_CODES.iter().enumerate() {
        m.insert(code.to_string(), Json::num(counts[i] as f64));
    }
    Json::Obj(m)
}

/// Quantile upper bound as JSON, honouring the empty-histogram sentinel:
/// zero samples have no quantile, so this is `null` — never a misleading
/// numeric `(0, 0)` bracket. `cgmq watch` renders the `null` as `—`.
fn quantile_json(h: &HistogramSnapshot, q: f64) -> Json {
    h.quantile_bounds(q).map_or(Json::Null, |(_, hi)| Json::num(hi as f64))
}

/// Per-stage histogram summary: count/sum/max plus p50/p99 upper bounds
/// from the log₂ buckets (`null` when the stage has no samples).
fn stages_json(stages: &[HistogramSnapshot; STAGES]) -> Json {
    let mut m = BTreeMap::new();
    for stage in Stage::ALL {
        let h = &stages[stage as usize];
        m.insert(
            stage.as_str().to_string(),
            Json::obj(vec![
                ("count", Json::num(h.count as f64)),
                ("sum_us", Json::num(h.sum_us as f64)),
                ("max_us", Json::num(h.max_us as f64)),
                ("p50_us_le", quantile_json(h, 0.50)),
                ("p99_us_le", quantile_json(h, 0.99)),
            ]),
        );
    }
    Json::Obj(m)
}

/// One histogram's summary with unit-agnostic keys: the windowed
/// whole-request histogram holds microseconds, the margin histogram
/// milli-logits — callers know which. Quantile bounds follow the
/// empty-histogram sentinel ([`quantile_json`]).
fn histogram_json(h: &HistogramSnapshot) -> Json {
    Json::obj(vec![
        ("count", Json::num(h.count as f64)),
        ("sum", Json::num(h.sum_us as f64)),
        ("max", Json::num(h.max_us as f64)),
        ("p10_le", quantile_json(h, 0.10)),
        ("p50_le", quantile_json(h, 0.50)),
        ("p99_le", quantile_json(h, 0.99)),
    ])
}

/// One model's `window` section on `/stats` and in the [`ServerReport`]:
/// the trailing-window twin of the cumulative counters, plus the derived
/// arrival-rate and shed-rate estimates and the margin distribution.
fn window_json(w: &telemetry::WindowSnapshot) -> Json {
    Json::obj(vec![
        ("window_us", Json::num(w.window_us as f64)),
        ("arrivals", Json::num(w.arrivals as f64)),
        ("arrival_rate_per_sec", Json::num(w.arrival_rate_per_sec())),
        ("shed_rate", Json::num(w.shed_rate())),
        ("statuses", statuses_json(&w.by_status)),
        ("stages", stages_json(&w.stages)),
        ("total", histogram_json(&w.total)),
        ("margin", histogram_json(&w.margin)),
    ])
}

/// What a drained server reports: per-model router reports plus the served
/// request count and the final telemetry snapshot.
#[derive(Debug)]
pub struct ServerReport {
    pub models: BTreeMap<String, ModelReport>,
    /// 200s served on the infer route.
    pub served: u64,
    /// Telemetry captured after every worker joined — quiescent, so it is
    /// bit-comparable with the last `/metrics` or `/stats` scrape.
    pub telemetry: TelemetrySnapshot,
}

impl ServerReport {
    /// The no-request-lost guarantee: per key, the accounting invariant
    /// holds and every accepted request completed.
    pub fn verify_drained(&self) -> Result<()> {
        for (key, report) in &self.models {
            let s = report.stats;
            if !s.consistent() {
                bail!("model '{key}' stats violate the routing invariant: {s:?}");
            }
            if s.completed != s.accepted {
                bail!(
                    "model '{key}' lost requests: accepted {} but completed {}",
                    s.accepted,
                    s.completed
                );
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let models: BTreeMap<String, Json> = self
            .models
            .iter()
            .map(|(k, report)| {
                let mut j = report.stats.to_json();
                if let Json::Obj(m) = &mut j {
                    // Completions nobody waited for (0 in normal operation;
                    // every HTTP-accepted request has a waiting worker).
                    m.insert("uncollected".into(), Json::num(report.completions.len() as f64));
                    if let Some(ms) = self.telemetry.models.get(k) {
                        m.insert("statuses".into(), statuses_json(&ms.by_status));
                        m.insert("stages".into(), stages_json(&ms.stages));
                        m.insert("window".into(), window_json(&ms.window));
                    }
                }
                (k.clone(), j)
            })
            .collect();
        Json::obj(vec![
            ("served", Json::num(self.served as f64)),
            ("connections", Json::num(self.telemetry.connections as f64)),
            ("http_responses", statuses_json(&self.telemetry.http_status)),
            ("http_responses_window", statuses_json(&self.telemetry.http_window)),
            ("models", Json::Obj(models)),
        ])
    }
}

/// The HTTP serving front: listener + router front + completion pump.
pub struct Server {
    front: Arc<Front>,
    /// `Some` until [`finish`](Self::finish) takes it.
    listener: Option<Listener>,
    pump: Option<JoinHandle<()>>,
    /// Captured at bind time so [`local_addr`](Self::local_addr) stays
    /// infallible for the whole lifetime of the value.
    addr: SocketAddr,
}

impl Drop for Server {
    /// A server dropped without [`finish`](Self::finish) (early error
    /// path, test panic) must not leak its threads: flag everything to
    /// stop — the accept loop exits on its own, connection workers wind
    /// down with their requests, and the pump exits once nothing is
    /// outstanding. (No joins here; `finish` is the orderly path.)
    fn drop(&mut self) {
        // ordering: seqcst — cold teardown flags; strongest order, no cost.
        self.front.stop.store(true, Ordering::SeqCst);
        // ordering: seqcst — as above.
        self.front.pump_stop.store(true, Ordering::SeqCst);
        if let Some(listener) = &self.listener {
            listener.stop();
        }
    }
}

impl Server {
    /// Load `models` behind their keys and start serving on `addr`
    /// (`127.0.0.1:0` picks an ephemeral port — read it back with
    /// [`local_addr`](Self::local_addr)).
    pub fn bind(
        addr: &str,
        models: Vec<(String, Arc<Engine>)>,
        cfg: ServerConfig,
    ) -> Result<Self> {
        Self::bind_with_clock(addr, models, cfg, Arc::new(RealClock::default()))
    }

    /// [`bind`](Self::bind) with an injected telemetry clock — the seam
    /// tests use to drive the windowed series with `ManualClock` (advance
    /// past the window, watch every windowed series decay to zero while
    /// the cumulative counters keep the traffic).
    pub fn bind_with_clock(
        addr: &str,
        models: Vec<(String, Arc<Engine>)>,
        cfg: ServerConfig,
        clock: Arc<dyn telemetry::Clock>,
    ) -> Result<Self> {
        if models.is_empty() {
            bail!("server needs at least one model");
        }
        let mut router = Router::new(cfg.pool);
        let mut keys = Vec::with_capacity(models.len());
        for (key, engine) in models {
            router.add_model(key.clone(), engine)?;
            keys.push(key);
        }
        let telemetry = Arc::new(ServerTelemetry::new(&keys, clock, cfg.trace_ring));
        let front = Arc::new(Front {
            router: Mutex::new(Some(router)),
            keys,
            done: Mutex::new(HashMap::new()),
            arrived: Condvar::new(),
            abandoned: Mutex::new(HashSet::new()),
            outstanding: AtomicU64::new(0),
            served: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            pump_stop: AtomicBool::new(false),
            reply_timeout: cfg.reply_timeout,
            livez_shed_rate: cfg.livez_shed_rate,
            livez_p99_us: cfg.livez_p99_us,
            telemetry: Arc::clone(&telemetry),
        });
        let handler: Arc<dyn Handler> = Arc::new(NetHandler { front: Arc::clone(&front) });
        let limits = ConnLimits { max_body: cfg.max_body, read_timeout: cfg.read_timeout };
        let listener = Listener::bind(addr, handler, limits, telemetry)?;
        let pump = std::thread::Builder::new()
            .name("cgmq-http-pump".into())
            .spawn({
                let front = Arc::clone(&front);
                move || pump_loop(front)
            })
            .context("spawning completion pump");
        let pump = match pump {
            Ok(p) => p,
            Err(e) => {
                // Don't leak the accept loop holding the port.
                listener.stop();
                let _ = listener.join();
                return Err(e);
            }
        };
        let addr = listener.local_addr();
        Ok(Self { front, listener: Some(listener), pump: Some(pump), addr })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The live telemetry spine (counters, stage histograms, trace ring).
    pub fn telemetry(&self) -> Arc<ServerTelemetry> {
        Arc::clone(&self.front.telemetry)
    }

    /// Whether a graceful shutdown has been requested (`/admin/shutdown`
    /// or [`request_shutdown`](Self::request_shutdown)).
    pub fn shutdown_requested(&self) -> bool {
        // ordering: seqcst — cold 20ms control poll in `run`; no cost.
        self.front.stop.load(Ordering::SeqCst)
    }

    pub fn request_shutdown(&self) {
        // ordering: seqcst — one-shot control-plane flag; no cost.
        self.front.stop.store(true, Ordering::SeqCst);
    }

    /// Serve until a shutdown is requested, then drain gracefully.
    pub fn run(self) -> Result<ServerReport> {
        while !self.shutdown_requested() {
            std::thread::sleep(Duration::from_millis(20));
        }
        self.finish()
    }

    /// Graceful drain: stop accepting, finish every in-flight request,
    /// stop the pump, shut the router down. The report's per-key stats
    /// satisfy `completed == accepted` (checked by
    /// [`ServerReport::verify_drained`]) unless something was genuinely
    /// lost.
    pub fn finish(mut self) -> Result<ServerReport> {
        // ordering: seqcst — cold teardown flag; no cost.
        self.front.stop.store(true, Ordering::SeqCst);
        // 1. Close the front door and wait out every connection worker —
        //    each finishes its in-flight request (the pump is still
        //    delivering completions underneath them).
        let Some(listener) = self.listener.take() else {
            bail!("server listener already taken: finish ran twice");
        };
        let joined = listener.join();
        // 2. Tell the pump to drain and exit *before* propagating a join
        //    failure, so an accept-loop panic cannot leave it spinning.
        // ordering: seqcst — cold teardown flag; the pump reading it late
        // only costs one extra poll tick.
        self.front.pump_stop.store(true, Ordering::SeqCst);
        joined?;
        if let Some(pump) = self.pump.take() {
            pump.join().map_err(|_| anyhow!("completion pump panicked"))?;
        }
        // 3. Drain the router itself.
        let router = lock(&self.front.router).take().context("router already drained")?;
        let models = router.shutdown()?;
        Ok(ServerReport {
            models,
            // ordering: relaxed — every writer thread joined above, so
            // the counter is quiescent and this reads the final value.
            served: self.front.served.load(Ordering::Relaxed),
            // Quiescent for the same reason: every recorder joined.
            telemetry: self.front.telemetry.snapshot(),
        })
    }
}
