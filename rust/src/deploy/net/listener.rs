//! Accept loop + connection workers for the HTTP front.
//!
//! One non-blocking `TcpListener` accept loop (non-blocking so a stop
//! request is observed within milliseconds, not at the next connection),
//! one `std` thread per live connection. A connection worker runs a
//! keep-alive loop: parse request → hand to the [`Handler`] → write
//! response → repeat, under a per-connection read deadline. The hardening
//! contract — pinned by `tests/net_serve.rs` — is that *nothing a peer
//! sends can take a worker down*: parse errors answer with their taxonomy
//! status and close (after one framing error the byte stream is
//! untrustworthy), idle keep-alive timeouts close silently, and a handler
//! panic is caught and mapped to 500.
//!
//! Every response written here passes through one telemetry choke point
//! ([`ServerTelemetry::observe_http_status`]), which feeds both the
//! cumulative status counters and the trailing-window series behind
//! `GET /livez` and `cgmq watch` — the listener is where the windowed
//! signal plane sees every byte that leaves the process.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use super::super::telemetry::ServerTelemetry;
use super::http::{self, Request, Response, Status};
use super::lock;

/// What the server does with one parsed request.
pub trait Handler: Send + Sync + 'static {
    fn handle(&self, req: Request) -> Response;
}

/// Per-connection hardening limits.
#[derive(Debug, Clone, Copy)]
pub struct ConnLimits {
    /// Bodies declaring more than this many bytes are refused with 413.
    pub max_body: usize,
    /// Read deadline: an idle keep-alive connection is reaped after this,
    /// and a peer that stalls mid-request gets 408.
    pub read_timeout: Duration,
}

impl Default for ConnLimits {
    fn default() -> Self {
        Self { max_body: 1 << 20, read_timeout: Duration::from_secs(5) }
    }
}

/// The accept loop and its connection workers.
pub struct Listener {
    addr: SocketAddr,
    running: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Listener {
    /// Bind `addr` (`127.0.0.1:0` picks an ephemeral port) and start
    /// accepting; every request goes to `handler`. Accepted connections
    /// and every written response status are counted on `telemetry`.
    pub fn bind(
        addr: &str,
        handler: Arc<dyn Handler>,
        limits: ConnLimits,
        telemetry: Arc<ServerTelemetry>,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let running = Arc::new(AtomicBool::new(true));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = std::thread::Builder::new()
            .name("cgmq-http-accept".into())
            .spawn({
                let running = Arc::clone(&running);
                let conns = Arc::clone(&conns);
                move || accept_loop(listener, handler, limits, running, conns, telemetry)
            })
            .context("spawning accept loop")?;
        Ok(Self { addr, running, accept: Some(accept), conns })
    }

    /// The bound address (the actual port when an ephemeral one was asked).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask the accept loop and every worker to wind down (non-blocking;
    /// workers finish their current request first).
    pub fn stop(&self) {
        // ordering: seqcst — one-shot control-plane flag; no cost.
        self.running.store(false, Ordering::SeqCst);
    }

    /// Stop accepting and join the accept loop plus every connection
    /// worker. Workers blocked on an idle keep-alive connection exit at
    /// the latest after the read deadline.
    pub fn join(mut self) -> Result<()> {
        self.stop();
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| anyhow!("accept loop panicked"))?;
        }
        let conns = std::mem::take(&mut *lock(&self.conns));
        for c in conns {
            let _ = c.join();
        }
        Ok(())
    }
}

fn accept_loop(
    listener: TcpListener,
    handler: Arc<dyn Handler>,
    limits: ConnLimits,
    running: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    telemetry: Arc<ServerTelemetry>,
) {
    let mut next_conn = 0u64;
    // ordering: relaxed — a stale true costs at most one extra 2ms accept
    // tick before the loop observes the stop flag; no data rides on it.
    while running.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                next_conn += 1;
                telemetry.count_connection();
                let worker = std::thread::Builder::new()
                    .name(format!("cgmq-http-{next_conn}"))
                    .spawn({
                        let handler = Arc::clone(&handler);
                        let running = Arc::clone(&running);
                        let telemetry = Arc::clone(&telemetry);
                        move || connection_loop(stream, handler, limits, running, telemetry)
                    });
                if let Ok(handle) = worker {
                    let mut conns = lock(&conns);
                    conns.retain(|h| !h.is_finished());
                    conns.push(handle);
                }
                // Spawn failure: the stream drops, the peer sees a closed
                // connection and retries — better than taking down accept.
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
}

/// One connection: keep-alive request loop until close, error, deadline or
/// server stop.
fn connection_loop(
    stream: TcpStream,
    handler: Arc<dyn Handler>,
    limits: ConnLimits,
    running: Arc<AtomicBool>,
    telemetry: Arc<ServerTelemetry>,
) {
    stream.set_nodelay(true).ok();
    if stream.set_read_timeout(Some(limits.read_timeout)).is_err() {
        return;
    }
    let mut writer = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        match http::read_request(&mut reader, limits.max_body) {
            Ok(req) => {
                // A stopping server finishes this request but closes after
                // it instead of idling on the keep-alive read.
                // ordering: relaxed — worst case one extra keep-alive round
                // before the worker notices the stop; join still bounds the
                // wait by the read deadline.
                let keep = req.keep_alive() && running.load(Ordering::Relaxed);
                let resp = std::panic::catch_unwind(AssertUnwindSafe(|| handler.handle(req)))
                    .unwrap_or_else(|_| {
                        Response::error(Status::InternalError, "handler panicked")
                    });
                // Count at the single write point, so the responses-by-
                // status series covers every route *and* the panic->500
                // path.
                telemetry.observe_http_status(resp.status.code());
                if resp.write_to(&mut writer, keep).is_err() || !keep {
                    return;
                }
            }
            Err(e) => {
                // Taxonomy status if one applies (400/408/411/413), then
                // close — after a framing error the stream is unreadable.
                // Clean EOF / idle timeout / dead transport close silently.
                if let Some(status) = e.status() {
                    telemetry.observe_http_status(status.code());
                    let _ = Response::error(status, e.message()).write_to(&mut writer, false);
                }
                return;
            }
        }
    }
}
