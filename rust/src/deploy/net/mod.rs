//! Network serving front: a dependency-free HTTP/1.1 listener over the
//! [`Router`](super::Router).
//!
//! PRs 2–4 built the packed engine, the sharded worker pool and the
//! bounded-admission multi-model router — but the front door was still an
//! in-process function call. This module is the missing rung: a real
//! network listener feeding the shard queues, with the router's typed
//! overload signal mapped onto the HTTP status taxonomy a deployment
//! expects. Everything is `std` (TCP + threads), matching the rest of the
//! crate — no async runtime, no HTTP crate.
//!
//! ```text
//!   clients ── TCP ──▶ listener (accept loop, non-blocking)
//!                        │ one worker thread per connection
//!                        ▼ keep-alive loop, read deadline, body cap
//!                      http::read_request ── taxonomy ──▶ 400/404/405/411/413
//!                        │
//!                        ▼
//!                      Server handler ── Mutex<Router>::try_submit
//!                        │                  ├─ Accepted → wait on completion
//!                        │                  └─ Shed     → 429 + Retry-After
//!                        ▼
//!                      completion pump (one thread) — drains
//!                      Router::try_completions, wakes the waiting
//!                      connection workers by (key, id)
//! ```
//!
//! * [`http`] — the minimal HTTP/1.1 request parser / response writer and
//!   its hardened error taxonomy: malformed request → 400, unknown route
//!   or model key → 404, wrong method on a known route → 405, missing
//!   `Content-Length` on a body-bearing method → 411, body over the cap →
//!   413 (refused *before* reading), overload shed → 429 with a
//!   `Retry-After` hint. Parse errors close the connection (framing is
//!   unknown after one) but never panic the worker. Also carries the tiny
//!   [`HttpClient`] the load generator and tests drive the server with.
//! * [`listener`] — the accept loop (non-blocking `TcpListener`, so
//!   shutdown is observed promptly) and per-connection worker threads:
//!   keep-alive request loop, per-connection read deadline, handler
//!   panics caught and mapped to 500.
//! * [`server`] — the [`Server`]: the router behind a thread-safe front.
//!   `Router::try_submit` takes `&mut self`, so submissions from N
//!   connection threads serialize through one mutex — the single choke
//!   point that keeps the `submitted == accepted + shed` accounting exact
//!   across threads — while completions are pumped out by one background
//!   thread and handed to the waiting connection workers. Endpoints:
//!   `POST /v1/models/{key}/infer`, `GET /healthz`, `GET /livez` (the
//!   windowed readiness probe: 503 when the trailing-window shed rate or
//!   p99 bound crosses the configured thresholds), `GET /stats`
//!   (per-model [`RouteStats`](super::RouteStats) plus telemetry —
//!   cumulative and windowed — as JSON), `GET /metrics` (the same
//!   counters as Prometheus text — see [`telemetry`](super::telemetry)),
//!   `POST /admin/shutdown` (graceful drain: stop accepting, finish
//!   every accepted request, then shut the router down and verify
//!   nothing was lost). Every infer response carries an `X-Request-Id`
//!   header joinable to the server-side trace ring; `cgmq watch` polls
//!   `/stats` and renders the windowed signal plane as a live table.
//!
//! `cgmq serve` binds a server from `.cgmqm` files; `cgmq load-bench` is
//! the loopback load generator (open-loop client threads, 429-retry,
//! bit-identity verification against a locally loaded engine);
//! `tests/net_serve.rs` pins the HTTP path bit-for-bit to
//! [`Engine::infer_batch`](super::Engine::infer_batch).

pub mod http;
pub mod listener;
pub mod server;

pub use http::{HttpClient, Request, Response, Status};
pub use listener::{ConnLimits, Handler, Listener};
pub use server::{Server, ServerConfig, ServerReport};

use std::sync::{Mutex, MutexGuard};

/// Lock a mutex, recovering the guard if a panicking holder poisoned it
/// (the protected state is counters + queues that stay valid line-by-line;
/// refusing to serve after a poisoned lock would turn one bad request into
/// a full outage).
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}
