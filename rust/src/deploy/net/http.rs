//! Minimal HTTP/1.1 wire handling: request parser, response writer, and a
//! tiny client — `std` only.
//!
//! The parser is deliberately small and *hard to surprise*: every way a
//! request can be wrong maps to one documented status code, and none of
//! them can panic the connection worker. The taxonomy (also in the README):
//!
//! | condition                                   | status |
//! |---------------------------------------------|--------|
//! | malformed request line / headers / body     | 400    |
//! | unknown route or model key (server layer)   | 404    |
//! | wrong method on a known route (server layer)| 405    |
//! | read deadline hit mid-request               | 408    |
//! | body-bearing method without `Content-Length`| 411    |
//! | declared body larger than the cap           | 413 (refused before reading) |
//! | admission shed (server layer)               | 429 + `Retry-After` |
//!
//! The readiness probe `GET /livez` (server layer) reuses this taxonomy —
//! 200 when live, 503 when the trailing-window shed rate or p99 bound is
//! over threshold — rather than minting new codes.
//!
//! Unsupported-but-valid HTTP (chunked transfer encoding, non-1.x
//! versions) is a 400 with a message naming the gap. A connection that
//! goes quiet *between* requests (idle keep-alive) is closed silently; a
//! deadline hit *inside* a request is a 408 — the distinction is
//! [`ReadError::Timeout::started`].

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Cap on the request line + header section, total bytes.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;
/// Cap on the header count.
pub const MAX_HEADERS: usize = 64;

/// The status codes this server speaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Ok,
    BadRequest,
    NotFound,
    MethodNotAllowed,
    RequestTimeout,
    LengthRequired,
    PayloadTooLarge,
    TooManyRequests,
    InternalError,
    ServiceUnavailable,
    GatewayTimeout,
}

impl Status {
    pub fn code(self) -> u16 {
        match self {
            Status::Ok => 200,
            Status::BadRequest => 400,
            Status::NotFound => 404,
            Status::MethodNotAllowed => 405,
            Status::RequestTimeout => 408,
            Status::LengthRequired => 411,
            Status::PayloadTooLarge => 413,
            Status::TooManyRequests => 429,
            Status::InternalError => 500,
            Status::ServiceUnavailable => 503,
            Status::GatewayTimeout => 504,
        }
    }

    pub fn reason(self) -> &'static str {
        match self {
            Status::Ok => "OK",
            Status::BadRequest => "Bad Request",
            Status::NotFound => "Not Found",
            Status::MethodNotAllowed => "Method Not Allowed",
            Status::RequestTimeout => "Request Timeout",
            Status::LengthRequired => "Length Required",
            Status::PayloadTooLarge => "Payload Too Large",
            Status::TooManyRequests => "Too Many Requests",
            Status::InternalError => "Internal Server Error",
            Status::ServiceUnavailable => "Service Unavailable",
            Status::GatewayTimeout => "Gateway Timeout",
        }
    }
}

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// The raw request target (path + optional query).
    pub target: String,
    /// `HTTP/1.1` (keep-alive by default) vs `HTTP/1.0` (close by default).
    pub http11: bool,
    /// Header names lowercased, values trimmed.
    headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// When the request line had been read off the wire — the start stamp
    /// of the telemetry `accept` span. `None` only for requests built
    /// outside [`read_request`].
    pub first_byte: Option<Instant>,
    /// When the request (headers + body) was fully parsed — the end stamp
    /// of the `accept` span.
    pub parsed: Option<Instant>,
}

impl Request {
    /// First header value by (lowercase) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    /// The target without its query string.
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or(&self.target)
    }

    /// HTTP/1.1 persistence: keep alive unless `Connection: close` (or an
    /// HTTP/1.0 peer that did not ask for keep-alive).
    pub fn keep_alive(&self) -> bool {
        match self.header("connection").map(str::to_ascii_lowercase) {
            Some(v) if v == "close" => false,
            Some(v) if v == "keep-alive" => true,
            _ => self.http11,
        }
    }
}

/// Everything that can go wrong reading one request off the wire.
#[derive(Debug)]
pub enum ReadError {
    /// Clean EOF before any byte of a request — normal keep-alive close.
    Closed,
    /// Read deadline hit; `started` says whether any request bytes had
    /// arrived (idle keep-alive timeouts close silently, mid-request ones
    /// are a 408).
    Timeout { started: bool },
    /// Unparseable request line, headers or body framing.
    Malformed(String),
    /// Body-bearing method without a `Content-Length`.
    LengthRequired,
    /// Declared `Content-Length` above the configured cap.
    TooLarge { limit: usize },
    /// Transport error (peer reset, broken pipe, ...).
    Io(std::io::Error),
}

impl ReadError {
    /// The status code to answer with before closing; `None` means close
    /// without a response (clean EOF, idle timeout, dead transport).
    pub fn status(&self) -> Option<Status> {
        match self {
            ReadError::Closed | ReadError::Io(_) => None,
            ReadError::Timeout { started: false } => None,
            ReadError::Timeout { started: true } => Some(Status::RequestTimeout),
            ReadError::Malformed(_) => Some(Status::BadRequest),
            ReadError::LengthRequired => Some(Status::LengthRequired),
            ReadError::TooLarge { .. } => Some(Status::PayloadTooLarge),
        }
    }

    pub fn message(&self) -> String {
        match self {
            ReadError::Closed => "connection closed".into(),
            ReadError::Timeout { .. } => "read deadline hit".into(),
            ReadError::Malformed(m) => m.clone(),
            ReadError::LengthRequired => "body-bearing request without Content-Length".into(),
            ReadError::TooLarge { limit } => {
                format!("declared body exceeds the {limit}-byte cap")
            }
            ReadError::Io(e) => format!("transport error: {e}"),
        }
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Read one CRLF (or bare-LF) terminated line, charging its bytes against
/// `budget` — over-budget input errors out *without* buffering the rest,
/// so a newline-free flood cannot balloon memory. `Ok(None)` is EOF with
/// nothing read on *this* line.
fn read_line<R: BufRead>(
    r: &mut R,
    budget: &mut usize,
    started: &mut bool,
) -> Result<Option<String>, ReadError> {
    let mut buf = Vec::new();
    loop {
        let available = match r.fill_buf() {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => {
                return Err(ReadError::Timeout { started: *started || !buf.is_empty() })
            }
            Err(e) => return Err(ReadError::Io(e)),
        };
        if available.is_empty() {
            if buf.is_empty() {
                return Ok(None);
            }
            return Err(ReadError::Malformed("connection closed mid-line".into()));
        }
        *started = true;
        let nl = available.iter().position(|&b| b == b'\n');
        let take = nl.map_or(available.len(), |i| i + 1);
        if buf.len() + take > *budget {
            return Err(ReadError::Malformed(format!(
                "request head exceeds the {MAX_HEAD_BYTES}-byte cap"
            )));
        }
        buf.extend_from_slice(&available[..take]);
        r.consume(take);
        if nl.is_some() {
            *budget -= buf.len();
            buf.pop(); // the \n
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            return Ok(Some(String::from_utf8_lossy(&buf).into_owned()));
        }
    }
}

/// Parse one request: request line, headers, then exactly `Content-Length`
/// body bytes (checked against `max_body` *before* reading them).
pub fn read_request<R: BufRead>(r: &mut R, max_body: usize) -> Result<Request, ReadError> {
    let mut budget = MAX_HEAD_BYTES;
    let mut started = false;
    let line = match read_line(r, &mut budget, &mut started)? {
        None => return Err(ReadError::Closed),
        Some(l) => l,
    };
    // Stamp *after* the request line arrived, not at call time — between
    // keep-alive requests this function sits in read_line waiting, and
    // that idle time must not be charged to the accept span.
    let first_byte = Instant::now();
    let mut parts = line.split_whitespace();
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) => (m.to_string(), t.to_string(), v),
            _ => {
                return Err(ReadError::Malformed(format!(
                    "bad request line '{}'",
                    line.chars().take(80).collect::<String>()
                )))
            }
        };
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        v => return Err(ReadError::Malformed(format!("unsupported protocol '{v}'"))),
    };
    if !target.starts_with('/') {
        return Err(ReadError::Malformed(format!("bad request target '{target}'")));
    }

    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = match read_line(r, &mut budget, &mut started)? {
            None => return Err(ReadError::Malformed("connection closed mid-headers".into())),
            Some(l) => l,
        };
        if line.is_empty() {
            break;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(ReadError::Malformed(format!(
                "bad header line '{}'",
                line.chars().take(80).collect::<String>()
            )));
        };
        if headers.len() >= MAX_HEADERS {
            return Err(ReadError::Malformed(format!("more than {MAX_HEADERS} headers")));
        }
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    let mut req = Request {
        method,
        target,
        http11,
        headers,
        body: Vec::new(),
        first_byte: Some(first_byte),
        parsed: None,
    };
    if req.header("transfer-encoding").is_some() {
        return Err(ReadError::Malformed("chunked transfer encoding not supported".into()));
    }
    let body_len = match req.header("content-length") {
        Some(v) => match v.parse::<usize>() {
            Ok(n) => Some(n),
            Err(_) => return Err(ReadError::Malformed(format!("bad Content-Length '{v}'"))),
        },
        None => None,
    };
    match body_len {
        None if matches!(req.method.as_str(), "POST" | "PUT" | "PATCH") => {
            return Err(ReadError::LengthRequired)
        }
        None | Some(0) => {}
        Some(n) if n > max_body => return Err(ReadError::TooLarge { limit: max_body }),
        Some(n) => {
            let mut body = vec![0u8; n];
            if let Err(e) = r.read_exact(&mut body) {
                return Err(match e.kind() {
                    std::io::ErrorKind::UnexpectedEof => {
                        ReadError::Malformed("connection closed mid-body".into())
                    }
                    _ if is_timeout(&e) => ReadError::Timeout { started: true },
                    _ => ReadError::Io(e),
                });
            }
            req.body = body;
        }
    }
    req.parsed = Some(Instant::now());
    Ok(req)
}

/// One response — JSON by default, plain text for the Prometheus
/// `/metrics` exposition.
#[derive(Debug)]
pub struct Response {
    pub status: Status,
    pub body: String,
    /// `Retry-After` seconds hint (the 429 path sets it).
    pub retry_after: Option<u64>,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Server-assigned request id, echoed as `X-Request-Id` so a
    /// client-observed latency can be joined to its server-side trace.
    pub request_id: Option<u64>,
}

impl Response {
    pub fn json(status: Status, body: &Json) -> Self {
        Self {
            status,
            body: body.to_string(),
            retry_after: None,
            content_type: "application/json",
            request_id: None,
        }
    }

    /// A plain-text body (the Prometheus text exposition).
    pub fn text(status: Status, body: String) -> Self {
        Self {
            status,
            body,
            retry_after: None,
            content_type: "text/plain; version=0.0.4",
            request_id: None,
        }
    }

    /// An error body: `{"error": <reason>, "detail": <msg>}`.
    pub fn error(status: Status, msg: impl Into<String>) -> Self {
        let body = Json::obj(vec![
            ("error", Json::str(status.reason())),
            ("detail", Json::str(msg.into())),
        ]);
        Self::json(status, &body)
    }

    /// Serialize status line + headers + body; flushes the writer.
    pub fn write_to(&self, w: &mut impl Write, keep_alive: bool) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\n",
            self.status.code(),
            self.status.reason(),
            self.content_type,
            self.body.len()
        )?;
        if let Some(secs) = self.retry_after {
            write!(w, "retry-after: {secs}\r\n")?;
        }
        if let Some(id) = self.request_id {
            write!(w, "x-request-id: {id}\r\n")?;
        }
        write!(w, "connection: {}\r\n\r\n", if keep_alive { "keep-alive" } else { "close" })?;
        w.write_all(self.body.as_bytes())?;
        w.flush()
    }
}

// ---------------------------------------------------------------------------
// Client side — what `cgmq load-bench`, the example and the tests drive the
// server with. Deliberately the same parser discipline in the other
// direction.
// ---------------------------------------------------------------------------

/// Cap on response bodies the client will read.
pub const CLIENT_MAX_BODY: usize = 4 << 20;

/// Read one response: status line, headers, `Content-Length` body.
pub fn read_client_response<R: BufRead>(r: &mut R) -> Result<(u16, String), ReadError> {
    let (status, _, body) = read_client_response_with_headers(r)?;
    Ok((status, body))
}

/// Like [`read_client_response`], but also returns the response headers
/// as `(lowercased-name, trimmed-value)` pairs in wire order — what a
/// client needs to read policy headers such as `Retry-After` off a 429.
pub fn read_client_response_with_headers<R: BufRead>(
    r: &mut R,
) -> Result<(u16, Vec<(String, String)>, String), ReadError> {
    let mut budget = MAX_HEAD_BYTES;
    let mut started = false;
    let line = match read_line(r, &mut budget, &mut started)? {
        None => return Err(ReadError::Closed),
        Some(l) => l,
    };
    let mut parts = line.split_whitespace();
    let status = match (parts.next(), parts.next()) {
        (Some(v), Some(code)) if v.starts_with("HTTP/1.") => code
            .parse::<u16>()
            .map_err(|_| ReadError::Malformed(format!("bad status line '{line}'")))?,
        _ => return Err(ReadError::Malformed(format!("bad status line '{line}'"))),
    };
    let mut headers = Vec::new();
    let mut body_len = 0usize;
    loop {
        let line = match read_line(r, &mut budget, &mut started)? {
            None => return Err(ReadError::Malformed("connection closed mid-headers".into())),
            Some(l) => l,
        };
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            let (name, value) = (name.trim().to_ascii_lowercase(), value.trim().to_string());
            if name == "content-length" {
                body_len = value
                    .parse()
                    .map_err(|_| ReadError::Malformed(format!("bad Content-Length '{value}'")))?;
            }
            headers.push((name, value));
        }
    }
    if body_len > CLIENT_MAX_BODY {
        return Err(ReadError::TooLarge { limit: CLIENT_MAX_BODY });
    }
    let mut body = vec![0u8; body_len];
    r.read_exact(&mut body).map_err(|e| {
        if is_timeout(&e) {
            ReadError::Timeout { started: true }
        } else {
            ReadError::Io(e)
        }
    })?;
    String::from_utf8(body)
        .map(|b| (status, headers, b))
        .map_err(|_| ReadError::Malformed("response body is not UTF-8".into()))
}

/// Write one request (request line, `host`, and — with a body —
/// `content-type` + `content-length`) and flush.
fn send_request(
    stream: &mut TcpStream,
    method: &str,
    target: &str,
    body: Option<&str>,
) -> std::io::Result<()> {
    write!(stream, "{method} {target} HTTP/1.1\r\nhost: cgmq\r\n")?;
    match body {
        Some(b) => write!(
            stream,
            "content-type: application/json\r\ncontent-length: {}\r\n\r\n{b}",
            b.len()
        )?,
        None => write!(stream, "\r\n")?,
    }
    stream.flush()
}

/// A keep-alive HTTP/1.1 client over one `TcpStream`.
///
/// Reconnects and resends **only** when the request provably never
/// reached the application: a write failure, or a clean connection close
/// before any response byte (the idle keep-alive reap — the server always
/// writes a response before closing a connection it read a request from).
/// A failure *after* response bytes started, or a read timeout, is
/// surfaced instead of blind-retried: `POST /infer` is not idempotent,
/// and a resend would make the server count one request twice.
pub struct HttpClient {
    addr: String,
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl HttpClient {
    /// Connect, retrying until `timeout` (covers the race against a server
    /// that is still binding).
    pub fn connect(addr: &str, timeout: Duration) -> Result<Self> {
        let deadline = Instant::now() + timeout;
        loop {
            match TcpStream::connect(addr) {
                Ok(stream) => return Self::over(stream, addr),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e).with_context(|| format!("connecting to {addr}"));
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
    }

    fn over(stream: TcpStream, addr: &str) -> Result<Self> {
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self { addr: addr.to_string(), stream, reader })
    }

    /// One request/response roundtrip; `body` is a JSON string. Retries
    /// once, and only when the request provably went unprocessed (see the
    /// type docs).
    pub fn request(
        &mut self,
        method: &str,
        target: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        let (status, _, body) = self.request_with_headers(method, target, body)?;
        Ok((status, body))
    }

    /// [`request`](Self::request), but also returning the response
    /// headers (`(lowercased-name, value)` pairs) — how the load
    /// generator and the tests observe `Retry-After` on shed responses.
    pub fn request_with_headers(
        &mut self,
        method: &str,
        target: &str,
        body: Option<&str>,
    ) -> Result<(u16, Vec<(String, String)>, String)> {
        match self.roundtrip(method, target, body) {
            Ok(r) => Ok(r),
            Err((true, _)) => {
                let addr = self.addr.clone();
                *self = Self::connect(&addr, Duration::from_secs(2))?;
                self.roundtrip(method, target, body).map_err(|(_, e)| e)
            }
            Err((false, e)) => Err(e),
        }
    }

    /// The error side carries `retry_safe`: true only when the server
    /// cannot have processed the request.
    fn roundtrip(
        &mut self,
        method: &str,
        target: &str,
        body: Option<&str>,
    ) -> Result<(u16, Vec<(String, String)>, String), (bool, anyhow::Error)> {
        if let Err(e) = send_request(&mut self.stream, method, target, body) {
            return Err((true, anyhow::anyhow!("sending {method} {target}: {e}")));
        }
        match read_client_response_with_headers(&mut self.reader) {
            Ok(r) => Ok(r),
            // Clean close before any response byte: the keep-alive reap —
            // a request the server read is always answered before close.
            Err(ReadError::Closed) => Err((
                true,
                anyhow::anyhow!("connection closed before a response to {method} {target}"),
            )),
            Err(e) => Err((
                false,
                anyhow::anyhow!("reading response to {method} {target}: {}", e.message()),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Request, ReadError> {
        read_request(&mut Cursor::new(raw.as_bytes()), 1024)
    }

    #[test]
    fn parses_get_and_post() {
        let req = parse("GET /healthz HTTP/1.1\r\nhost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path(), "/healthz");
        assert!(req.keep_alive());
        assert!(req.body.is_empty());

        let req = parse(
            "POST /v1/models/m/infer HTTP/1.1\r\ncontent-length: 9\r\n\r\n{\"x\":[1]}",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"{\"x\":[1]}");
        assert_eq!(req.header("content-length"), Some("9"));

        // Query strings are split off by path().
        let req = parse("GET /stats?verbose=1 HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.path(), "/stats");
        assert_eq!(req.target, "/stats?verbose=1");
    }

    #[test]
    fn keep_alive_semantics() {
        let r = parse("GET / HTTP/1.1\r\nconnection: close\r\n\r\n").unwrap();
        assert!(!r.keep_alive());
        let r = parse("GET / HTTP/1.0\r\n\r\n").unwrap();
        assert!(!r.keep_alive(), "HTTP/1.0 defaults to close");
        let r = parse("GET / HTTP/1.0\r\nconnection: keep-alive\r\n\r\n").unwrap();
        assert!(r.keep_alive());
    }

    // The negative matrix: every way a request can be wrong maps to its
    // documented status code — and none of them panic.
    #[test]
    fn clean_eof_is_closed_not_an_error_status() {
        let e = parse("").unwrap_err();
        assert!(matches!(e, ReadError::Closed));
        assert_eq!(e.status(), None);
    }

    #[test]
    fn truncated_request_line_is_400() {
        for raw in ["GET /healthz", "GET /healthz HTTP/1.1", "POST", "GET /x HTTP/1.1\r\nhost"] {
            let e = parse(raw).unwrap_err();
            assert!(matches!(e, ReadError::Malformed(_)), "{raw:?}: {e:?}");
            assert_eq!(e.status(), Some(Status::BadRequest), "{raw:?}");
        }
    }

    #[test]
    fn garbage_request_lines_are_400() {
        for raw in [
            "garbage\r\n\r\n",
            "GET\r\n\r\n",
            "GET /x HTTP/2\r\n\r\n",
            "GET x HTTP/1.1\r\n\r\n",
            "GET /x HTTP/1.1 extra\r\n\r\n",
            "GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n",
            "POST /x HTTP/1.1\r\ncontent-length: nope\r\n\r\n",
            "POST /x HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n",
        ] {
            let e = parse(raw).unwrap_err();
            assert_eq!(e.status(), Some(Status::BadRequest), "{raw:?}: {e:?}");
        }
    }

    #[test]
    fn missing_content_length_on_post_is_411() {
        let e = parse("POST /v1/models/m/infer HTTP/1.1\r\nhost: x\r\n\r\n").unwrap_err();
        assert!(matches!(e, ReadError::LengthRequired));
        assert_eq!(e.status(), Some(Status::LengthRequired));
        // GET without a length is fine.
        assert!(parse("GET / HTTP/1.1\r\n\r\n").is_ok());
    }

    #[test]
    fn oversized_body_is_413_and_refused_before_reading() {
        // Declared length over the cap fails even though no body bytes
        // follow — the parser must not try to buffer it first.
        let e = parse("POST /x HTTP/1.1\r\ncontent-length: 99999\r\n\r\n").unwrap_err();
        assert!(matches!(e, ReadError::TooLarge { limit: 1024 }), "{e:?}");
        assert_eq!(e.status(), Some(Status::PayloadTooLarge));
    }

    #[test]
    fn premature_close_mid_body_is_400() {
        let e = parse("POST /x HTTP/1.1\r\ncontent-length: 10\r\n\r\nabc").unwrap_err();
        assert!(matches!(e, ReadError::Malformed(_)), "{e:?}");
        assert_eq!(e.status(), Some(Status::BadRequest));
    }

    #[test]
    fn oversized_head_is_400() {
        let raw = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_HEAD_BYTES));
        let e = parse(&raw).unwrap_err();
        assert_eq!(e.status(), Some(Status::BadRequest));
    }

    #[test]
    fn pipelined_requests_parse_sequentially_and_garbage_after_is_400() {
        let raw = "GET /healthz HTTP/1.1\r\n\r\nXYZ\r\n\r\n";
        let mut cur = Cursor::new(raw.as_bytes());
        let first = read_request(&mut cur, 1024).unwrap();
        assert_eq!(first.path(), "/healthz");
        let e = read_request(&mut cur, 1024).unwrap_err();
        assert_eq!(e.status(), Some(Status::BadRequest), "{e:?}");
    }

    #[test]
    fn response_wire_format_roundtrips_through_the_client_parser() {
        let mut resp = Response::json(Status::Ok, &Json::obj(vec![("a", Json::num(1.0))]));
        resp.request_id = Some(42);
        let mut wire = Vec::new();
        resp.write_to(&mut wire, true).unwrap();
        let text = String::from_utf8(wire.clone()).unwrap();
        assert!(text.contains("content-type: application/json\r\n"), "{text}");
        assert!(text.contains("x-request-id: 42\r\n"), "{text}");
        let (status, body) = read_client_response(&mut Cursor::new(&wire)).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{\"a\":1}");

        // The Prometheus route answers text/plain, no request id.
        let metrics = Response::text(Status::Ok, "cgmq_served_total 0\n".into());
        let mut wire = Vec::new();
        metrics.write_to(&mut wire, true).unwrap();
        let text = String::from_utf8(wire.clone()).unwrap();
        assert!(text.contains("content-type: text/plain; version=0.0.4\r\n"), "{text}");
        assert!(!text.contains("x-request-id:"), "{text}");

        // A drain-rate-derived Retry-After must survive the wire both as
        // the raw header line and through the header-returning client.
        let mut shed = Response::error(Status::TooManyRequests, "shed");
        shed.retry_after = Some(17);
        let mut wire = Vec::new();
        shed.write_to(&mut wire, false).unwrap();
        let text = String::from_utf8(wire.clone()).unwrap();
        assert!(text.contains("retry-after: 17\r\n"), "{text}");
        assert!(text.contains("connection: close\r\n"), "{text}");
        let (status, headers, body) =
            read_client_response_with_headers(&mut Cursor::new(&wire)).unwrap();
        assert_eq!(status, 429);
        assert!(body.contains("Too Many Requests"), "{body}");
        let retry = headers.iter().find(|(n, _)| n == "retry-after").map(|(_, v)| v.as_str());
        assert_eq!(retry, Some("17"));
        // The plain reader stays oblivious to headers, same payload.
        let (status, body) = read_client_response(&mut Cursor::new(&wire)).unwrap();
        assert_eq!(status, 429);
        assert!(body.contains("Too Many Requests"), "{body}");
    }
}
