//! Host fake-quant reference forward — the golden anchor of the deploy
//! subsystem.
//!
//! Mirrors the training-path eval graph (`python/compile/model.py::
//! forward_quantized`, the `<arch>_eval` artifact the `session::ctx` eval
//! path executes) on the host: 8-bit input quantization, per-layer gated
//! weight fake quantization (Eq. 3, signed on `[-beta_w, beta_w]`), dense /
//! conv / bias, ReLU, per-unit gated activation fake quantization (unsigned
//! on `[0, beta_a]`), max-pool after activation quantization, float logits
//! from the output layer.
//!
//! The packed [`Engine`](super::Engine) must agree with this function
//! *bit-for-bit* on every layer at every bit-width — that is the property
//! `tests/deploy_roundtrip.rs` pins. The two paths share the kernel layer
//! ([`super::kernels`]: the same blocked GEMM behind `dense` / `conv2d`,
//! the same `maxpool`) so the comparison isolates exactly what deployment
//! changes: fake-quantized f32 weights vs bit-packed integer codes decoded
//! through per-gate scales — never summation order.

use anyhow::{bail, Result};

use crate::gates::GateSet;
use crate::model::{ArchSpec, LayerKind};
use crate::quant::{gated_quantize, quantize};
use crate::tensor::Tensor;

use super::kernels::{conv2d, dense, maxpool, relu_inplace};

/// Fake-quant forward over `n` samples; returns flattened
/// `n x num_classes` logits. This is the eval-graph semantics computed on
/// the host from the raw (float) snapshot state.
pub fn fake_quant_logits(
    arch: &ArchSpec,
    params: &[Tensor],
    betas_w: &Tensor,
    betas_a: &Tensor,
    gates: &GateSet,
    xs: &[f32],
    n: usize,
) -> Result<Vec<f32>> {
    if params.len() != 2 * arch.layers.len() {
        bail!("{} param tensors, arch wants {}", params.len(), 2 * arch.layers.len());
    }
    if xs.len() != n * arch.input_len() {
        bail!("input has {} values, want {} x {}", xs.len(), n, arch.input_len());
    }
    let mut h: Vec<f32> = xs.iter().map(|&v| quantize(v, arch.input_bits, 1.0, true)).collect();
    let mut dims: Vec<usize> = arch.input_shape.clone();
    let n_layers = arch.layers.len();
    let mut ai = 0;
    for (li, spec) in arch.layers.iter().enumerate() {
        let beta_w = betas_w.data()[li];
        let gw = gates.materialize_w(arch, li);
        let w = &params[2 * li];
        let wq: Vec<f32> = w
            .data()
            .iter()
            .zip(gw.data())
            .map(|(&x, &g)| gated_quantize(x, g, beta_w, true))
            .collect();
        let bias = params[2 * li + 1].data();
        match spec.kind {
            LayerKind::Dense => {
                let (d_in, d_out) = (spec.w_shape[0], spec.w_shape[1]);
                h = dense(&h, &wq, bias, n, d_in, d_out);
                dims = vec![d_out];
            }
            LayerKind::Conv => {
                let (ci, hi, wi) = (dims[0], dims[1], dims[2]);
                let (o, kh, kw) = (spec.w_shape[0], spec.w_shape[2], spec.w_shape[3]);
                h = conv2d(&h, &wq, bias, n, ci, hi, wi, o, kh, kw);
                dims = vec![o, hi - kh + 1, wi - kw + 1];
            }
        }
        if li == n_layers - 1 {
            return Ok(h);
        }
        relu_inplace(&mut h);
        if spec.quant_act {
            let beta_a = betas_a.data()[ai];
            let ga = gates.materialize_a(arch, ai);
            let units = ga.len();
            for s in 0..n {
                let block = &mut h[s * units..(s + 1) * units];
                for (v, &g) in block.iter_mut().zip(ga.data()) {
                    *v = gated_quantize(*v, g, beta_a, false);
                }
            }
            ai += 1;
        }
        if spec.pool > 1 {
            let (c, hh, ww) = (dims[0], dims[1], dims[2]);
            h = maxpool(&h, n, c, hh, ww, spec.pool);
            dims = vec![c, hh / spec.pool, ww / spec.pool];
        }
    }
    unreachable!("loop returns at the output layer");
}
