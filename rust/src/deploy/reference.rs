//! Host fake-quant reference forward — the golden anchor of the deploy
//! subsystem.
//!
//! Mirrors the training-path eval graph (`python/compile/model.py::
//! forward_quantized`, the `<arch>_eval` artifact the `session::ctx` eval
//! path executes) on the host: 8-bit input quantization, per-layer gated
//! weight fake quantization (Eq. 3, signed on `[-beta_w, beta_w]`), dense /
//! conv / bias, ReLU, per-unit gated activation fake quantization (unsigned
//! on `[0, beta_a]`), max-pool after activation quantization, float logits
//! from the output layer.
//!
//! The packed [`Engine`](super::Engine) must agree with this function
//! *bit-for-bit* on every layer at every bit-width — that is the property
//! `tests/deploy_roundtrip.rs` pins. The reference mirrors the engine's
//! kernel selection exactly (the same [`swar::decide`] call the
//! [`KernelSelector`](super::plan::KernelSelector) makes, from the same
//! width/grid/depth inputs — gate-derived here, packed-stream-derived
//! there, identical by construction of `WidthStream::from_gates`):
//!
//! * f32-selected layers share the kernel layer ([`super::kernels`]: the
//!   same blocked GEMM behind `dense` / `conv2d`, the same `maxpool`), so
//!   the comparison isolates quantization fidelity, never summation
//!   order;
//! * SWAR-selected layers run an **independent naive `i64` oracle**:
//!   weight codes taken from the raw floats via `integer_code` (never
//!   touching the packed bit stream the engine repacks from), activation
//!   codes recovered from the reference's own on-grid f32s, a plain
//!   triple-loop integer dot, and the identical `(dot as f32) *
//!   combined_scale` epilogue. Integer sums are exact and
//!   order-independent, so the engine's offset-encoded SWAR lanes must
//!   equal this oracle bit-for-bit — that equality is what certifies the
//!   whole packed-lane machinery.

use anyhow::{bail, Result};

use crate::gates::GateSet;
use crate::model::{ArchSpec, LayerKind};
use crate::quant::{gated_quantize, integer_code, quantize, transform_t, IDENTITY_BITS};
use crate::tensor::Tensor;

use super::kernels::swar::{self, ActGrid};
use super::kernels::{add_bias_cols, add_bias_rows, conv2d, dense, maxpool, relu_inplace};

/// Fake-quant forward over `n` samples; returns flattened
/// `n x num_classes` logits. This is the eval-graph semantics computed on
/// the host from the raw (float) snapshot state.
pub fn fake_quant_logits(
    arch: &ArchSpec,
    params: &[Tensor],
    betas_w: &Tensor,
    betas_a: &Tensor,
    gates: &GateSet,
    xs: &[f32],
    n: usize,
) -> Result<Vec<f32>> {
    if params.len() != 2 * arch.layers.len() {
        bail!("{} param tensors, arch wants {}", params.len(), 2 * arch.layers.len());
    }
    if xs.len() != n * arch.input_len() {
        bail!("input has {} values, want {} x {}", xs.len(), n, arch.input_len());
    }
    let mut h: Vec<f32> = xs.iter().map(|&v| quantize(v, arch.input_bits, 1.0, true)).collect();
    let mut dims: Vec<usize> = arch.input_shape.clone();
    let n_layers = arch.layers.len();
    let mut ai = 0;
    // The activation grid feeding the next matmul — the same chain the
    // plan threads through `KernelSelector::select`.
    let mut grid = if arch.input_bits < IDENTITY_BITS {
        Some(ActGrid { bits: arch.input_bits, signed: true, beta: 1.0 })
    } else {
        None
    };
    for (li, spec) in arch.layers.iter().enumerate() {
        let beta_w = betas_w.data()[li];
        let gw = gates.materialize_w(arch, li);
        let w = &params[2 * li];
        let widths: Vec<u32> = gw.data().iter().map(|&g| transform_t(g)).collect();
        let w_uniform = swar::uniform_nonzero_width(widths.iter().copied());
        let k = match spec.kind {
            LayerKind::Dense => spec.w_shape[0],
            LayerKind::Conv => dims[0] * spec.w_shape[2] * spec.w_shape[3],
        };
        let bias = params[2 * li + 1].data();
        if let Some(prm) = swar::decide(w_uniform, beta_w, grid, k) {
            // Integer oracle: raw-float weight codes, recovered
            // activation codes, naive i64 dots, shared epilogue.
            let qw: Vec<i64> = w
                .data()
                .iter()
                .zip(&widths)
                .map(|(&x, &wi)| if *wi == 0 { 0 } else { integer_code(x, *wi, beta_w, true).0 })
                .collect();
            let qa: Vec<i64> = h.iter().map(|&v| swar::code_of(v, prm.inv_a_scale)).collect();
            match spec.kind {
                LayerKind::Dense => {
                    let (d_in, d_out) = (spec.w_shape[0], spec.w_shape[1]);
                    let mut out = vec![0.0f32; n * d_out];
                    for s in 0..n {
                        for j in 0..d_out {
                            let mut dot = 0i64;
                            for i in 0..d_in {
                                dot += qa[s * d_in + i] * qw[i * d_out + j];
                            }
                            out[s * d_out + j] = dot as f32 * prm.combined_scale;
                        }
                    }
                    add_bias_cols(&mut out, bias, n, d_out);
                    h = out;
                    dims = vec![d_out];
                }
                LayerKind::Conv => {
                    let (ci, hi, wi) = (dims[0], dims[1], dims[2]);
                    let (o, kh, kw) = (spec.w_shape[0], spec.w_shape[2], spec.w_shape[3]);
                    let (ho, wo) = (hi - kh + 1, wi - kw + 1);
                    let p = ho * wo;
                    let mut out = vec![0.0f32; n * o * p];
                    for s in 0..n {
                        let img = &qa[s * ci * hi * wi..(s + 1) * ci * hi * wi];
                        let planes = &mut out[s * o * p..(s + 1) * o * p];
                        for r in 0..o {
                            for oy in 0..ho {
                                for ox in 0..wo {
                                    let mut dot = 0i64;
                                    for ic in 0..ci {
                                        for ky in 0..kh {
                                            for kx in 0..kw {
                                                let a = img
                                                    [ic * hi * wi + (oy + ky) * wi + (ox + kx)];
                                                let wv = qw[r * ci * kh * kw
                                                    + ic * kh * kw
                                                    + ky * kw
                                                    + kx];
                                                dot += a * wv;
                                            }
                                        }
                                    }
                                    planes[r * p + oy * wo + ox] =
                                        dot as f32 * prm.combined_scale;
                                }
                            }
                        }
                        add_bias_rows(planes, bias, o, p);
                    }
                    h = out;
                    dims = vec![o, ho, wo];
                }
            }
        } else {
            let wq: Vec<f32> = w
                .data()
                .iter()
                .zip(gw.data())
                .map(|(&x, &g)| gated_quantize(x, g, beta_w, true))
                .collect();
            match spec.kind {
                LayerKind::Dense => {
                    let (d_in, d_out) = (spec.w_shape[0], spec.w_shape[1]);
                    h = dense(&h, &wq, bias, n, d_in, d_out);
                    dims = vec![d_out];
                }
                LayerKind::Conv => {
                    let (ci, hi, wi) = (dims[0], dims[1], dims[2]);
                    let (o, kh, kw) = (spec.w_shape[0], spec.w_shape[2], spec.w_shape[3]);
                    h = conv2d(&h, &wq, bias, n, ci, hi, wi, o, kh, kw);
                    dims = vec![o, hi - kh + 1, wi - kw + 1];
                }
            }
        }
        if li == n_layers - 1 {
            return Ok(h);
        }
        relu_inplace(&mut h);
        grid = None;
        if spec.quant_act {
            let beta_a = betas_a.data()[ai];
            let ga = gates.materialize_a(arch, ai);
            let units = ga.len();
            for s in 0..n {
                let block = &mut h[s * units..(s + 1) * units];
                for (v, &g) in block.iter_mut().zip(ga.data()) {
                    *v = gated_quantize(*v, g, beta_a, false);
                }
            }
            let wa = swar::uniform_nonzero_width(ga.data().iter().map(|&g| transform_t(g)));
            grid = wa.filter(|&w| w < IDENTITY_BITS).map(|w| ActGrid {
                bits: w,
                signed: false,
                beta: beta_a,
            });
            ai += 1;
        }
        if spec.pool > 1 {
            let (c, hh, ww) = (dims[0], dims[1], dims[2]);
            h = maxpool(&h, n, c, hh, ww, spec.pool);
            dims = vec![c, hh / spec.pool, ww / spec.pool];
        }
    }
    unreachable!("loop returns at the output layer");
}
