//! The packed-model inference engine: the deploy-path hot loop.
//!
//! Runs a [`PackedModel`] forward on the host — dense, conv (NCHW/OIHW,
//! valid padding, stride 1), ReLU, max-pool — decoding the bit-packed
//! integer weight codes back to their fake-quantized f32 values via the
//! per-gate scales, and fake-quantizing activations per unit exactly as the
//! training-path eval graph does (unsigned grid on `[0, beta_a]` after
//! ReLU, pooling *after* activation quantization, 8-bit input
//! quantization, float logits).
//!
//! Two decode modes:
//!
//! * [`DecodeMode::Streaming`] — decode every layer's weights on the fly,
//!   per call, into a scratch buffer that is dropped afterwards. Minimal
//!   resident memory (the packed codes stay packed); the decode cost is
//!   paid on every call. This is the honest single-request deployment
//!   baseline `serve-bench` measures.
//! * [`DecodeMode::UnpackOnce`] — decode each layer once, cache the dense
//!   f32 weights, and reuse them for every subsequent call. The batched
//!   serve path ([`super::batch::RequestBatcher`]) uses this mode so the
//!   unpack cost amortizes across aggregated requests.
//!
//! Both modes produce bit-identical logits (same kernels, same decoded
//! values), and both match the host fake-quant reference forward
//! ([`super::reference`]) bit-for-bit — the cross-path golden test in
//! `tests/deploy_roundtrip.rs` pins all three.
//!
//! The engine is **shared state**: inference takes `&self`, the decoded
//! weight cache lives in per-layer [`OnceLock`] slots, and the packed
//! model behind them is immutable, so one `Arc<Engine>` serves any number
//! of threads concurrently ([`super::pool::WorkerPool`]). The hot path is
//! lock-free — a filled slot costs one atomic load; a decode race on a
//! cold slot wastes at most one redundant decode (both threads compute
//! the same bytes, the first `set` wins).

use std::path::Path;
use std::sync::OnceLock;

use anyhow::{bail, Result};

use crate::model::{ArchSpec, LayerKind};
use crate::quant::quantize;

use super::format::{PackedAct, PackedModel};

/// Weight decode strategy of an [`Engine`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecodeMode {
    /// Decode per call; drop the dense weights afterwards.
    Streaming,
    /// Decode each layer once and cache the dense f32 weights.
    #[default]
    UnpackOnce,
}

/// Packed-model inference engine. Immutable after construction: `infer*`
/// take `&self`, so an `Arc<Engine>` is safely shared across threads.
pub struct Engine {
    model: PackedModel,
    arch: ArchSpec,
    mode: DecodeMode,
    /// Per-layer dense weight cache (`UnpackOnce` mode), filled lazily and
    /// at most once; `OnceLock::get` on the hot path is a single atomic
    /// load, no lock.
    cache: Vec<OnceLock<Vec<f32>>>,
}

impl Engine {
    /// Wrap an already-verified packed model (default `UnpackOnce` mode).
    pub fn new(model: PackedModel) -> Result<Self> {
        let arch = model.verify()?;
        let cache = (0..model.layers.len()).map(|_| OnceLock::new()).collect();
        Ok(Self { model, arch, mode: DecodeMode::default(), cache })
    }

    /// Load a `.cgmqm` file (checksum + arch verification included).
    pub fn load(path: &Path) -> Result<Self> {
        let (model, _) = PackedModel::load(path)?;
        Self::new(model)
    }

    /// Select the weight decode strategy (resets the cache).
    pub fn with_mode(mut self, mode: DecodeMode) -> Self {
        self.mode = mode;
        self.cache = (0..self.model.layers.len()).map(|_| OnceLock::new()).collect();
        self
    }

    /// Eagerly decode every layer into the cache (`UnpackOnce` mode), so a
    /// worker pool pays the unpack cost once up front instead of racing on
    /// the first requests. No-op in `Streaming` mode (the cache is unread).
    pub fn preload(&self) -> Result<()> {
        if self.mode == DecodeMode::UnpackOnce {
            for li in 0..self.model.layers.len() {
                self.cached_weights(li)?;
            }
        }
        Ok(())
    }

    /// How many layers currently sit decoded in the unpack cache — the
    /// `cgmq_engine_decoded_layers` telemetry gauge. Equal to the layer
    /// count after [`preload`](Self::preload); 0 in `Streaming` mode.
    pub fn decoded_layers(&self) -> usize {
        self.cache.iter().filter(|c| c.get().is_some()).count()
    }

    /// The decoded dense weights of layer `li`, filling the slot on first
    /// use. A lost `set` race means another thread stored the identical
    /// decode first; its value is returned.
    fn cached_weights(&self, li: usize) -> Result<&[f32]> {
        if let Some(w) = self.cache[li].get() {
            return Ok(w);
        }
        let w = self.model.decode_weights(li)?;
        let _ = self.cache[li].set(w);
        match self.cache[li].get() {
            Some(w) => Ok(w.as_slice()),
            None => bail!("layer {li}: weight cache slot empty right after set"),
        }
    }

    pub fn mode(&self) -> DecodeMode {
        self.mode
    }

    pub fn arch(&self) -> &ArchSpec {
        &self.arch
    }

    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    /// Per-sample input element count.
    pub fn input_len(&self) -> usize {
        self.model.input_len()
    }

    /// Logit count (output units of the last layer).
    pub fn num_classes(&self) -> usize {
        // analyze-allow: panic-hygiene infallible signature; a layerless
        // arch is rejected by PackedModel verification at load time
        self.arch.layers.last().expect("arch has layers").n_units()
    }

    /// Run one sample; returns its logits.
    pub fn infer(&self, x: &[f32]) -> Result<Vec<f32>> {
        self.infer_batch(x, 1)
    }

    /// Run `n` samples (row-major, `n * input_len` values); returns the
    /// flattened `n x num_classes` logits. Takes `&self`: safe to call
    /// from many threads over one shared engine.
    pub fn infer_batch(&self, xs: &[f32], n: usize) -> Result<Vec<f32>> {
        let in_len = self.model.input_len();
        if n == 0 {
            bail!("infer_batch needs at least one sample");
        }
        if xs.len() != n * in_len {
            bail!("input has {} values, {} samples x {} want {}", xs.len(), n, in_len, n * in_len);
        }
        // Fixed input quantization (mirror of quantizer.quantize_input).
        let input_bits = self.model.input_bits;
        let mut h: Vec<f32> = xs.iter().map(|&v| quantize(v, input_bits, 1.0, true)).collect();
        let mut dims: Vec<usize> = self.model.input_shape.clone();
        let n_layers = self.model.layers.len();
        for li in 0..n_layers {
            let scratch;
            let wq: &[f32] = match self.mode {
                DecodeMode::UnpackOnce => self.cached_weights(li)?,
                DecodeMode::Streaming => {
                    scratch = self.model.decode_weights(li)?;
                    &scratch
                }
            };
            let layer = &self.model.layers[li];
            match layer.kind {
                LayerKind::Dense => {
                    let d_in = layer.w_shape[0];
                    let d_out = layer.w_shape[1];
                    let flat: usize = dims.iter().product();
                    if flat != d_in {
                        bail!(
                            "layer {}: input {} features, weights want {}",
                            layer.name,
                            flat,
                            d_in
                        );
                    }
                    h = dense(&h, wq, &layer.bias, n, d_in, d_out);
                    dims = vec![d_out];
                }
                LayerKind::Conv => {
                    if dims.len() != 3 {
                        bail!("layer {}: conv wants CHW input, got {:?}", layer.name, dims);
                    }
                    let (ci, hi, wi) = (dims[0], dims[1], dims[2]);
                    let (o, wc, kh, kw) =
                        (layer.w_shape[0], layer.w_shape[1], layer.w_shape[2], layer.w_shape[3]);
                    if wc != ci || hi < kh || wi < kw {
                        bail!(
                            "layer {}: input {:?} incompatible with kernel {:?}",
                            layer.name,
                            dims,
                            layer.w_shape
                        );
                    }
                    h = conv2d_valid(&h, wq, &layer.bias, n, ci, hi, wi, o, kh, kw);
                    dims = vec![o, hi - kh + 1, wi - kw + 1];
                }
            }
            if li == n_layers - 1 {
                return Ok(h); // output layer: float logits, no activation FQ
            }
            relu_inplace(&mut h);
            if let Some(act) = &layer.act {
                quantize_activations(&mut h, act, n);
            }
            if layer.pool > 1 {
                let (c, hh, ww) = (dims[0], dims[1], dims[2]);
                h = maxpool(&h, n, c, hh, ww, layer.pool);
                dims = vec![c, hh / layer.pool, ww / layer.pool];
            }
        }
        // Only reachable when the model has zero layers, which load-time
        // verification rejects — but a serving thread must not panic on it.
        bail!("packed model has no layers");
    }

    /// Predicted class per sample (argmax over logits).
    pub fn predict_batch(&self, xs: &[f32], n: usize) -> Result<Vec<usize>> {
        let logits = self.infer_batch(xs, n)?;
        let c = self.num_classes();
        Ok((0..n).map(|s| argmax(&logits[s * c..(s + 1) * c])).collect())
    }
}

// Compile-time proof the engine is shareable across threads; the serve
// pool hands one `Arc<Engine>` to every worker.
const _: fn() = || {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Engine>();
};

/// Argmax index of a non-empty slice (first max wins, like
/// `Tensor::argmax_rows`).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for j in 1..row.len() {
        if row[j] > row[best] {
            best = j;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// Kernels (shared with the fake-quant reference path so the cross-path
// golden compares quantization fidelity, not summation order)
// ---------------------------------------------------------------------------

/// Per-unit activation fake quantization: ReLU output on the unsigned grid
/// `[0, beta_a]` at that unit's trained bit-width (0 = pruned unit).
pub(super) fn quantize_activations(h: &mut [f32], act: &PackedAct, n: usize) {
    let units = h.len() / n;
    for s in 0..n {
        let block = &mut h[s * units..(s + 1) * units];
        for (u, v) in block.iter_mut().enumerate() {
            *v = match act.a_bits.get(u) {
                0 => 0.0,
                bits => quantize(*v, bits, act.beta_a, false),
            };
        }
    }
}

/// `out[s] = h[s] @ w + bias` for row-major `h (n, d_in)`, `w (d_in, d_out)`.
pub(super) fn dense(
    h: &[f32],
    w: &[f32],
    bias: &[f32],
    n: usize,
    d_in: usize,
    d_out: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; n * d_out];
    for s in 0..n {
        let hrow = &h[s * d_in..(s + 1) * d_in];
        let orow = &mut out[s * d_out..(s + 1) * d_out];
        for (i, &hv) in hrow.iter().enumerate() {
            let wrow = &w[i * d_out..(i + 1) * d_out];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += hv * wv;
            }
        }
        for (o, &b) in orow.iter_mut().zip(bias) {
            *o += b;
        }
    }
    out
}

/// Valid-padding stride-1 conv, NCHW input, OIHW weights, then bias.
#[allow(clippy::too_many_arguments)]
pub(super) fn conv2d_valid(
    h: &[f32],
    w: &[f32],
    bias: &[f32],
    n: usize,
    ci: usize,
    hi: usize,
    wi: usize,
    o: usize,
    kh: usize,
    kw: usize,
) -> Vec<f32> {
    let ho = hi - kh + 1;
    let wo = wi - kw + 1;
    let mut out = vec![0.0f32; n * o * ho * wo];
    for s in 0..n {
        let img = &h[s * ci * hi * wi..(s + 1) * ci * hi * wi];
        for oc in 0..o {
            let kernel = &w[oc * ci * kh * kw..(oc + 1) * ci * kh * kw];
            let b = bias[oc];
            let plane = &mut out[(s * o + oc) * ho * wo..(s * o + oc + 1) * ho * wo];
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = 0.0f32;
                    for ic in 0..ci {
                        let ch = &img[ic * hi * wi..(ic + 1) * hi * wi];
                        let kc = &kernel[ic * kh * kw..(ic + 1) * kh * kw];
                        for ky in 0..kh {
                            let irow = &ch[(oy + ky) * wi + ox..(oy + ky) * wi + ox + kw];
                            let krow = &kc[ky * kw..(ky + 1) * kw];
                            for (iv, kv) in irow.iter().zip(krow) {
                                acc += iv * kv;
                            }
                        }
                    }
                    plane[oy * wo + ox] = acc + b;
                }
            }
        }
    }
    out
}

pub(super) fn relu_inplace(h: &mut [f32]) {
    for v in h.iter_mut() {
        *v = v.max(0.0);
    }
}

/// Non-overlapping `k x k` max pooling over NCHW, window == stride.
/// Assumes `k` divides both spatial dims — inputs where it doesn't are
/// rejected up front by `PackedModel::verify`'s geometry walk (the floor
/// division here would otherwise silently drop edge rows/cols).
pub(super) fn maxpool(h: &[f32], n: usize, c: usize, hh: usize, ww: usize, k: usize) -> Vec<f32> {
    let ho = hh / k;
    let wo = ww / k;
    let mut out = vec![f32::NEG_INFINITY; n * c * ho * wo];
    for sc in 0..n * c {
        let plane = &h[sc * hh * ww..(sc + 1) * hh * ww];
        let oplane = &mut out[sc * ho * wo..(sc + 1) * ho * wo];
        for oy in 0..ho {
            for ox in 0..wo {
                let mut m = f32::NEG_INFINITY;
                for ky in 0..k {
                    for kx in 0..k {
                        m = m.max(plane[(oy * k + ky) * ww + ox * k + kx]);
                    }
                }
                oplane[oy * wo + ox] = m;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_matches_hand_computation() {
        // h (1, 2) @ w (2, 3) + b
        let h = [1.0, 2.0];
        let w = [1.0, 0.0, -1.0, 0.5, 2.0, 1.0];
        let b = [10.0, 20.0, 30.0];
        let out = dense(&h, &w, &b, 1, 2, 3);
        assert_eq!(out, vec![1.0 + 1.0 + 10.0, 4.0 + 20.0, -1.0 + 2.0 + 30.0]);
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel with weight 1 is a passthrough plus bias.
        let h: Vec<f32> = (0..9).map(|v| v as f32).collect();
        let out = conv2d_valid(&h, &[1.0], &[0.5], 1, 1, 3, 3, 1, 1, 1);
        let expect: Vec<f32> = (0..9).map(|v| v as f32 + 0.5).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn conv_sums_window() {
        // 2x2 all-ones kernel over a 3x3 ramp.
        let h: Vec<f32> = (0..9).map(|v| v as f32).collect();
        let out = conv2d_valid(&h, &[1.0; 4], &[0.0], 1, 1, 3, 3, 1, 2, 2);
        let expect = [0. + 1. + 3. + 4., 1. + 2. + 4. + 5., 3. + 4. + 6. + 7., 4. + 5. + 7. + 8.];
        assert_eq!(out, expect);
    }

    #[test]
    fn maxpool_2x2() {
        let h =
            [1.0, 2.0, 3.0, 4.0, 8.0, 7.0, 6.0, 5.0, 0.0, -1.0, -2.0, -3.0, 4.0, 4.0, 4.0, 4.0];
        let out = maxpool(&h, 1, 1, 4, 4, 2);
        assert_eq!(out, [8.0, 6.0, 4.0, 4.0]);
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }
}
