//! The packed-model inference engine: a thin executor over the compiled
//! [`ExecPlan`] and the shared kernel layer.
//!
//! Construction verifies the packed model (checksum + arch drift, in
//! `PackedModel::verify`) and compiles the [`ExecPlan`]: every geometry
//! check resolved once, `Dense` and `Conv` lowered onto the unified
//! blocked GEMM ([`super::kernels`]), each op's kernel chosen by the
//! [`KernelSelector`](super::plan::KernelSelector) from its packed
//! bit-widths. The forward pass is then straight-line plan execution:
//! no shape `bail!`s, and — through the plan's precomputed [`Scratch`]
//! layout (two ping-pong activation buffers + one im2col buffer) — a
//! fixed handful of heap allocations per [`infer_batch`](Engine::infer_batch)
//! call, or **zero** for a warm [`infer_batch_into`](Engine::infer_batch_into).
//!
//! Two decode modes:
//!
//! * [`DecodeMode::Streaming`] — decode every layer's weights per call
//!   into the scratch decode buffer. Minimal resident memory (the packed
//!   codes stay packed); the decode cost is paid on every call. This is
//!   the honest single-request deployment baseline `serve-bench` measures.
//! * [`DecodeMode::UnpackOnce`] — decode each layer once, cache the dense
//!   f32 weights, and reuse them for every subsequent call. The batched
//!   serve path ([`super::batch::RequestBatcher`]) uses this mode so the
//!   unpack cost amortizes across aggregated requests.
//!
//! Both modes produce bit-identical logits (same kernels, same code
//! streams), and both match the host fake-quant reference forward
//! ([`super::reference`]) bit-for-bit: f32 ops route through the *same*
//! kernel layer with a fixed batch-size-independent accumulation order,
//! and SWAR ops ([`Kernel::Swar2`]/`Swar4`/`Swar8` — integer dot
//! products directly on the packed code words, cached as a packed-lane
//! repack beside the f32 cache) are exact integer arithmetic the
//! reference reproduces with an independent naive `i64` oracle. The
//! cross-path golden test in `tests/deploy_roundtrip.rs` therefore
//! compares quantization fidelity, never summation order.
//!
//! The engine is **shared state**: inference takes `&self`, the decoded
//! weight cache lives in per-layer [`OnceLock`] slots, and the packed
//! model and plan behind them are immutable, so one `Arc<Engine>` serves
//! any number of threads ([`super::pool::WorkerPool`]). The hot path is
//! lock-free — a filled slot costs one atomic load; a decode race on a
//! cold slot wastes at most one redundant decode (both threads compute
//! the same bytes, the first fill wins).

use std::mem;
use std::path::Path;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::model::ArchSpec;
use crate::quant::quantize;

use super::format::PackedModel;
use super::kernels::{
    add_bias_cols, add_bias_rows, argmax, encode_scalar_rows, gemm, im2col, maxpool_into,
    pack_conv_weights, pack_dense_weights, pack_lane_cols, quantize_activations, relu_inplace,
    swar_gemm,
};
use super::plan::{ExecPlan, Kernel, KernelSelector, Lowering, PlannedOp, Scratch};

/// Weight decode strategy of an [`Engine`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecodeMode {
    /// Decode per call into scratch; drop the dense weights afterwards.
    Streaming,
    /// Decode each layer once and cache the dense f32 weights.
    #[default]
    UnpackOnce,
}

/// Per-op-kind wall-clock breakdown of one profiled forward pass
/// ([`Engine::profile_batch`]) — the baseline the per-bit-width integer
/// kernels have to beat, reported by `bench_deploy` and `table-deploy`.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpProfile {
    /// Packed-weight handling: streaming decode/repack, or the
    /// unpack-cache fill/load (f32 decode and SWAR repack alike).
    pub decode: Duration,
    /// GEMM time including the bias epilogues (both lowerings).
    pub matmul: Duration,
    /// Conv column scatter.
    pub im2col: Duration,
    /// Input quantization, ReLU, activation fake-quant, max-pool.
    pub elementwise: Duration,
}

impl OpProfile {
    /// Sum of every accounted span.
    pub fn total(&self) -> Duration {
        self.decode + self.matmul + self.im2col + self.elementwise
    }

    /// `part` as a percentage of [`total`](Self::total) (0 when empty).
    pub fn share_pct(&self, part: Duration) -> f64 {
        let t = self.total().as_secs_f64();
        if t == 0.0 {
            0.0
        } else {
            100.0 * part.as_secs_f64() / t
        }
    }
}

/// One layer's cached SWAR repack — the packed-weight cache variant
/// that lives beside the decoded-f32 cache. A SWAR op never touches the
/// f32 weights; it consumes the integer codes in the layout its lowering
/// wants, plus the offset-correction sums.
enum SwarWeights {
    /// Dense lowering: weights are the lane side — the stripe panel plus
    /// per-output-feature lane sums.
    DensePanel { words: Vec<u64>, sums: Vec<i64> },
    /// Conv lowering: weights are the scalar side — offset `u16` codes in
    /// `o × ci·kh·kw` row-major plus per-output-channel row sums.
    ConvCodes { codes: Vec<u16>, sums: Vec<i64> },
}

/// Packed-model inference engine. Immutable after construction: `infer*`
/// take `&self`, so an `Arc<Engine>` is safely shared across threads.
pub struct Engine {
    model: PackedModel,
    arch: ArchSpec,
    plan: ExecPlan,
    mode: DecodeMode,
    /// Per-layer dense weight cache (`UnpackOnce` mode), filled lazily and
    /// at most once; `OnceLock::get` on the hot path is a single atomic
    /// load, no lock.
    cache: Vec<OnceLock<Vec<f32>>>,
    /// Per-layer packed-domain cache (`UnpackOnce` mode, SWAR ops): the
    /// lane panel / scalar codes repack, same fill discipline as `cache`.
    swar_cache: Vec<OnceLock<SwarWeights>>,
}

fn empty_caches(n: usize) -> (Vec<OnceLock<Vec<f32>>>, Vec<OnceLock<SwarWeights>>) {
    ((0..n).map(|_| OnceLock::new()).collect(), (0..n).map(|_| OnceLock::new()).collect())
}

impl Engine {
    /// Verify a packed model and compile its execution plan (default
    /// `UnpackOnce` mode).
    pub fn new(model: PackedModel) -> Result<Self> {
        Self::new_with_selector(model, KernelSelector::default())
    }

    /// [`new`](Self::new) with an explicit [`KernelSelector`] — how the
    /// bench harness builds the forced-`F32Gemm` baseline engine it
    /// measures SWAR speedups against.
    pub fn new_with_selector(model: PackedModel, selector: KernelSelector) -> Result<Self> {
        let arch = model.verify()?;
        let plan = ExecPlan::build_with(&model, selector)?;
        let (cache, swar_cache) = empty_caches(model.layers.len());
        Ok(Self { model, arch, plan, mode: DecodeMode::default(), cache, swar_cache })
    }

    /// Load a `.cgmqm` file (checksum + arch verification included).
    pub fn load(path: &Path) -> Result<Self> {
        let (model, _) = PackedModel::load(path)?;
        Self::new(model)
    }

    /// Select the weight decode strategy. Always resets the decoded-weight
    /// cache: a preloaded engine switched to `Streaming` (and back) must
    /// not keep stale decoded layers observable via
    /// [`decoded_layers`](Self::decoded_layers) — pinned by
    /// `tests/deploy_roundtrip.rs`.
    pub fn with_mode(mut self, mode: DecodeMode) -> Self {
        self.mode = mode;
        let (cache, swar_cache) = empty_caches(self.model.layers.len());
        self.cache = cache;
        self.swar_cache = swar_cache;
        self
    }

    /// Eagerly fill every layer's cache (`UnpackOnce` mode) — the f32
    /// decode for `F32Gemm`/`Pruned` ops, the packed-domain repack for
    /// SWAR ops — so a worker pool pays the unpack cost once up front
    /// instead of racing on the first requests. No-op in `Streaming`
    /// mode (both caches are unread).
    pub fn preload(&self) -> Result<()> {
        if self.mode == DecodeMode::UnpackOnce {
            for op in &self.plan.ops {
                match op.kernel {
                    Kernel::F32Gemm | Kernel::Pruned => {
                        self.cached_weights(op.layer)?;
                    }
                    Kernel::Swar2 | Kernel::Swar4 | Kernel::Swar8 => {
                        self.swar_cached(op)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// How many layers currently sit unpacked in a cache — f32 decode or
    /// SWAR repack — the `cgmq_engine_decoded_layers` telemetry gauge.
    /// Equal to the layer count after [`preload`](Self::preload); 0 in
    /// `Streaming` mode.
    pub fn decoded_layers(&self) -> usize {
        self.cache
            .iter()
            .zip(&self.swar_cache)
            .filter(|(f, s)| f.get().is_some() || s.get().is_some())
            .count()
    }

    /// The decoded dense weights of layer `li`, filling the slot on first
    /// use. The decode runs *before* `get_or_init` so its error stays a
    /// typed `Result`; a lost fill race means another thread stored the
    /// identical decode first, and its value is returned.
    fn cached_weights(&self, li: usize) -> Result<&[f32]> {
        if let Some(w) = self.cache[li].get() {
            return Ok(w);
        }
        let w = self.model.decode_weights(li)?;
        Ok(self.cache[li].get_or_init(|| w).as_slice())
    }

    /// The cached SWAR repack of `op`'s layer, same fill discipline as
    /// [`cached_weights`](Self::cached_weights).
    fn swar_cached(&self, op: &PlannedOp) -> Result<&SwarWeights> {
        let li = op.layer;
        if let Some(w) = self.swar_cache[li].get() {
            return Ok(w);
        }
        let w = self.build_swar_weights(op)?;
        Ok(self.swar_cache[li].get_or_init(|| w))
    }

    /// Repack one SWAR op's weights from the packed code stream into the
    /// layout its lowering consumes (no f32 round trip).
    fn build_swar_weights(&self, op: &PlannedOp) -> Result<SwarWeights> {
        let layer = &self.model.layers[op.layer];
        let prm = match &op.swar {
            Some(p) => p,
            None => bail!("layer {}: SWAR kernel without plan parameters", layer.name),
        };
        match op.lowering {
            Lowering::Dense { d_in, d_out } => {
                let (mut words, mut sums) = (Vec::new(), Vec::new());
                pack_dense_weights(layer, d_in, d_out, prm, &mut words, &mut sums)?;
                Ok(SwarWeights::DensePanel { words, sums })
            }
            Lowering::Conv { ci, o, kh, kw, .. } => {
                let (mut codes, mut sums) = (Vec::new(), Vec::new());
                pack_conv_weights(layer, o, ci * kh * kw, prm, &mut codes, &mut sums)?;
                Ok(SwarWeights::ConvCodes { codes, sums })
            }
        }
    }

    pub fn mode(&self) -> DecodeMode {
        self.mode
    }

    pub fn arch(&self) -> &ArchSpec {
        &self.arch
    }

    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    /// The compiled execution plan (geometry, lowerings, kernel choices).
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    /// Per-sample input element count.
    pub fn input_len(&self) -> usize {
        self.plan.input_len
    }

    /// Logit count — the last op's output units, read from the verified
    /// plan (a built plan always has a last op).
    pub fn num_classes(&self) -> usize {
        self.plan.num_classes
    }

    /// Run one sample; returns its logits.
    pub fn infer(&self, x: &[f32]) -> Result<Vec<f32>> {
        self.infer_batch(x, 1)
    }

    /// Run `n` samples (row-major, `n * input_len` values); returns the
    /// flattened `n x num_classes` logits. Takes `&self`: safe to call
    /// from many threads over one shared engine. Allocates one fresh
    /// [`Scratch`] + output — a fixed handful of allocations however deep
    /// the model; callers on the hot serve path keep their own scratch
    /// and use [`infer_batch_into`](Self::infer_batch_into) instead.
    pub fn infer_batch(&self, xs: &[f32], n: usize) -> Result<Vec<f32>> {
        let mut scratch = Scratch::new();
        let mut out = Vec::new();
        self.infer_batch_into(xs, n, &mut scratch, &mut out)?;
        Ok(out)
    }

    /// [`infer_batch`](Self::infer_batch) into caller-owned buffers:
    /// `out` receives the flattened `n x num_classes` logits. Once
    /// `scratch` and `out` have seen a batch of `n` samples, repeated
    /// calls at sizes `<= n` perform **zero** heap allocations — the
    /// batcher's per-flush path.
    pub fn infer_batch_into(
        &self,
        xs: &[f32],
        n: usize,
        scratch: &mut Scratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let mut prof = OpProfile::default();
        self.run_plan::<false>(xs, n, scratch, out, &mut prof)
    }

    /// One instrumented forward pass: the logits (bit-identical to
    /// [`infer_batch`](Self::infer_batch)) plus the per-op-kind timing
    /// breakdown. Timer reads sit inside the loop, so profile a warm
    /// engine and treat the shares, not the totals, as the signal.
    pub fn profile_batch(&self, xs: &[f32], n: usize) -> Result<(Vec<f32>, OpProfile)> {
        let mut scratch = Scratch::new();
        let mut out = Vec::new();
        let mut prof = OpProfile::default();
        self.run_plan::<true>(xs, n, &mut scratch, &mut out, &mut prof)?;
        Ok((out, prof))
    }

    /// Plan execution. `PROF` gates the `Instant` reads at compile time:
    /// the unprofiled hot path carries no timing code at all.
    fn run_plan<const PROF: bool>(
        &self,
        xs: &[f32],
        n: usize,
        scratch: &mut Scratch,
        out: &mut Vec<f32>,
        prof: &mut OpProfile,
    ) -> Result<()> {
        if n == 0 {
            bail!("infer_batch needs at least one sample");
        }
        let plan = &self.plan;
        let in_len = plan.input_len;
        if xs.len() != n * in_len {
            bail!("input has {} values, {} samples x {} want {}", xs.len(), n, in_len, n * in_len);
        }
        scratch.ensure(plan, n, self.mode == DecodeMode::Streaming);
        let Scratch { a, b, col, wdec, codes16, lanes, sums_s, sums_l } = scratch;
        let (mut cur, mut nxt) = (a, b);
        // Fixed input quantization (mirror of quantizer.quantize_input).
        let t = PROF.then(Instant::now);
        for (dst, &v) in cur.iter_mut().zip(xs) {
            *dst = quantize(v, plan.input_bits, 1.0, true);
        }
        if let Some(t) = t {
            prof.elementwise += t.elapsed();
        }
        let last = plan.ops.len() - 1;
        for (oi, op) in plan.ops.iter().enumerate() {
            let layer = &self.model.layers[op.layer];
            match op.kernel {
                Kernel::F32Gemm => {
                    let t = PROF.then(Instant::now);
                    let wq: &[f32] = match self.mode {
                        DecodeMode::UnpackOnce => self.cached_weights(op.layer)?,
                        DecodeMode::Streaming => {
                            layer.decode_weights_into(wdec)?;
                            wdec.as_slice()
                        }
                    };
                    if let Some(t) = t {
                        prof.decode += t.elapsed();
                    }
                    match op.lowering {
                        Lowering::Dense { d_in, d_out } => {
                            let t = PROF.then(Instant::now);
                            let c = &mut nxt[..n * d_out];
                            gemm(&cur[..n * d_in], wq, c, n, d_in, d_out);
                            add_bias_cols(c, &layer.bias, n, d_out);
                            if let Some(t) = t {
                                prof.matmul += t.elapsed();
                            }
                        }
                        Lowering::Conv { ci, hi, wi, o, kh, kw, ho, wo } => {
                            let kdim = ci * kh * kw;
                            let p = ho * wo;
                            let cols = &mut col[..kdim * p];
                            for s in 0..n {
                                let t = PROF.then(Instant::now);
                                let img = &cur[s * ci * hi * wi..(s + 1) * ci * hi * wi];
                                im2col(img, ci, hi, wi, kh, kw, cols);
                                if let Some(t) = t {
                                    prof.im2col += t.elapsed();
                                }
                                let t = PROF.then(Instant::now);
                                let planes = &mut nxt[s * o * p..(s + 1) * o * p];
                                gemm(wq, cols, planes, o, kdim, p);
                                add_bias_rows(planes, &layer.bias, o, p);
                                if let Some(t) = t {
                                    prof.matmul += t.elapsed();
                                }
                            }
                        }
                    }
                }
                // Fully pruned layer: every weight is 0.0, so the matmul
                // output is all `+0.0` (any finite activation times 0.0
                // sums to +0.0 under round-to-nearest) — zero-fill and
                // run only the bias epilogue, bit-identical to the f32
                // GEMM over the all-zero decode.
                Kernel::Pruned => {
                    let t = PROF.then(Instant::now);
                    match op.lowering {
                        Lowering::Dense { d_out, .. } => {
                            let c = &mut nxt[..n * d_out];
                            c.fill(0.0);
                            add_bias_cols(c, &layer.bias, n, d_out);
                        }
                        Lowering::Conv { o, ho, wo, .. } => {
                            let p = ho * wo;
                            let c = &mut nxt[..n * o * p];
                            c.fill(0.0);
                            for s in 0..n {
                                add_bias_rows(&mut c[s * o * p..(s + 1) * o * p], &layer.bias, o, p);
                            }
                        }
                    }
                    if let Some(t) = t {
                        prof.matmul += t.elapsed();
                    }
                }
                Kernel::Swar2 | Kernel::Swar4 | Kernel::Swar8 => {
                    let prm = match &op.swar {
                        Some(p) => p,
                        None => bail!("layer {}: SWAR kernel without plan parameters", layer.name),
                    };
                    match op.lowering {
                        Lowering::Dense { d_in, d_out } => {
                            // Lane side = weights: cached repack, or a
                            // per-call repack into scratch (streaming
                            // keeps nothing resident, same as the f32
                            // path's per-call decode).
                            let t = PROF.then(Instant::now);
                            let (wwords, wsums): (&[u64], &[i64]) = match self.mode {
                                DecodeMode::UnpackOnce => match self.swar_cached(op)? {
                                    SwarWeights::DensePanel { words, sums } => (words, sums),
                                    SwarWeights::ConvCodes { .. } => {
                                        bail!("layer {}: SWAR cache kind mismatch", layer.name)
                                    }
                                },
                                DecodeMode::Streaming => {
                                    pack_dense_weights(layer, d_in, d_out, prm, lanes, sums_l)?;
                                    (lanes.as_slice(), sums_l.as_slice())
                                }
                            };
                            if let Some(t) = t {
                                prof.decode += t.elapsed();
                            }
                            let t = PROF.then(Instant::now);
                            // Scalar side = the batch's activation codes,
                            // recovered exactly from the on-grid f32s.
                            encode_scalar_rows(&cur[..n * d_in], n, d_in, prm, codes16, sums_s);
                            let c = &mut nxt[..n * d_out];
                            swar_gemm(
                                codes16,
                                sums_s,
                                wwords,
                                wsums,
                                c,
                                n,
                                d_in,
                                d_out,
                                prm,
                                prm.a_off,
                                prm.w_off,
                                prm.combined_scale,
                            );
                            add_bias_cols(c, &layer.bias, n, d_out);
                            if let Some(t) = t {
                                prof.matmul += t.elapsed();
                            }
                        }
                        Lowering::Conv { ci, hi, wi, o, kh, kw, ho, wo } => {
                            let kdim = ci * kh * kw;
                            let p = ho * wo;
                            // Scalar side = weights: cached codes, or a
                            // per-call re-encode into scratch.
                            let t = PROF.then(Instant::now);
                            let (wcodes, wsums): (&[u16], &[i64]) = match self.mode {
                                DecodeMode::UnpackOnce => match self.swar_cached(op)? {
                                    SwarWeights::ConvCodes { codes, sums } => (codes, sums),
                                    SwarWeights::DensePanel { .. } => {
                                        bail!("layer {}: SWAR cache kind mismatch", layer.name)
                                    }
                                },
                                DecodeMode::Streaming => {
                                    pack_conv_weights(layer, o, kdim, prm, codes16, sums_s)?;
                                    (codes16.as_slice(), sums_s.as_slice())
                                }
                            };
                            if let Some(t) = t {
                                prof.decode += t.elapsed();
                            }
                            let cols = &mut col[..kdim * p];
                            for s in 0..n {
                                let t = PROF.then(Instant::now);
                                let img = &cur[s * ci * hi * wi..(s + 1) * ci * hi * wi];
                                im2col(img, ci, hi, wi, kh, kw, cols);
                                if let Some(t) = t {
                                    prof.im2col += t.elapsed();
                                }
                                // Lane side = the sample's column codes,
                                // packed fresh per sample (the pack is
                                // part of the matmul's cost).
                                let t = PROF.then(Instant::now);
                                pack_lane_cols(cols, kdim, p, prm, lanes, sums_l);
                                let planes = &mut nxt[s * o * p..(s + 1) * o * p];
                                swar_gemm(
                                    wcodes,
                                    wsums,
                                    lanes,
                                    sums_l,
                                    planes,
                                    o,
                                    kdim,
                                    p,
                                    prm,
                                    prm.w_off,
                                    prm.a_off,
                                    prm.combined_scale,
                                );
                                add_bias_rows(planes, &layer.bias, o, p);
                                if let Some(t) = t {
                                    prof.matmul += t.elapsed();
                                }
                            }
                        }
                    }
                }
            }
            mem::swap(&mut cur, &mut nxt);
            if oi == last {
                out.clear();
                out.extend_from_slice(&cur[..n * op.out_elems]);
                return Ok(()); // output layer: float logits, no activation FQ
            }
            let t = PROF.then(Instant::now);
            let h = &mut cur[..n * op.out_elems];
            relu_inplace(h);
            if let Some(act) = &layer.act {
                quantize_activations(h, act, n);
            }
            if let Some(pg) = op.pool {
                maxpool_into(
                    &cur[..n * pg.c * pg.h * pg.w],
                    &mut nxt[..n * op.final_elems],
                    n,
                    pg.c,
                    pg.h,
                    pg.w,
                    pg.k,
                );
                mem::swap(&mut cur, &mut nxt);
            }
            if let Some(t) = t {
                prof.elementwise += t.elapsed();
            }
        }
        // Only reachable with a zero-op plan, which `ExecPlan::build`
        // rejects — but a serving thread must not panic on it.
        bail!("exec plan has no ops")
    }

    /// Predicted class per sample (argmax over logits).
    pub fn predict_batch(&self, xs: &[f32], n: usize) -> Result<Vec<usize>> {
        let logits = self.infer_batch(xs, n)?;
        let c = self.num_classes();
        Ok((0..n).map(|s| argmax(&logits[s * c..(s + 1) * c])).collect())
    }
}

/// Top-logit confidence margin of one sample's logit row: the gap
/// between the largest and second-largest logit, clamped to `>= 0`
/// (NaNs lose every comparison and so never win a slot). Rows with
/// fewer than two classes have no runner-up and report `0.0` — the
/// "no confidence signal" floor a cascade router treats as escalate.
pub fn top_logit_margin(logits: &[f32]) -> f32 {
    if logits.len() < 2 {
        return 0.0;
    }
    let (mut top1, mut top2) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
    for &v in logits {
        if v > top1 {
            top2 = top1;
            top1 = v;
        } else if v > top2 {
            top2 = v;
        }
    }
    (top1 - top2).max(0.0)
}

// Compile-time proof the engine is shareable across threads; the serve
// pool hands one `Arc<Engine>` to every worker.
const _: fn() = || {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Engine>();
};

#[cfg(test)]
mod tests {
    use super::top_logit_margin;

    #[test]
    fn margin_is_gap_between_top_two() {
        assert_eq!(top_logit_margin(&[1.0, 4.0, 2.5]), 1.5);
        assert_eq!(top_logit_margin(&[3.0, 3.0]), 0.0);
        assert_eq!(top_logit_margin(&[7.0]), 0.0);
        assert_eq!(top_logit_margin(&[]), 0.0);
        assert_eq!(top_logit_margin(&[-1.0, -4.0]), 3.0);
    }

    #[test]
    fn margin_ignores_nans_when_finites_remain() {
        let m = top_logit_margin(&[f32::NAN, 2.0, 5.0]);
        assert_eq!(m, 3.0);
    }
}
