//! Deployment subsystem: packed mixed-precision artifacts + the inference
//! engine + the batched serve path.
//!
//! Training ([`crate::session`]) produces a [`Snapshot`](crate::session::Snapshot)
//! whose gates assign every weight and activation unit a bit-width; this
//! module is what turns that snapshot into something that *runs*:
//!
//! * [`format`] — the `.cgmqm` binary model format: per-layer integer
//!   weight codes bit-packed at their trained bit-widths, plus ranges,
//!   signs, biases and the arch fingerprint, behind a checksummed header
//!   and a loader that fails fast on architecture drift.
//! * [`plan`] — the compiled [`ExecPlan`]: every geometry check resolved
//!   once at engine construction, dense and conv lowered onto one unified
//!   matmul (conv via im2col), per-op kernel choice recorded by the
//!   [`KernelSelector`] from the packed bit-widths (the seam for SWAR
//!   integer kernels), and the [`Scratch`] layout precomputed so a warm
//!   forward pass allocates nothing.
//! * [`kernels`] — the shared kernel layer: register-blocked cache-tiled
//!   f32 GEMM with a fixed batch-size-independent accumulation order,
//!   im2col, and the element-wise ops. Both the engine and the reference
//!   forward run through these.
//! * [`Engine`] — plan execution + the decoded-weight cache: packed
//!   weights decoded through the per-gate scales, with a streaming mode
//!   (decode per call) and an unpack-once mode that caches dense weights
//!   for batched serving; [`Engine::profile_batch`] reports the per-op
//!   compute split ([`OpProfile`]).
//! * [`RequestBatcher`] — aggregates single-sample `infer` requests into
//!   batched engine invocations (size- and deadline-triggered flush) so
//!   the unpack cost and the batched matmuls amortize across requests.
//! * [`WorkerPool`] — multi-worker sharded serving: N std threads over
//!   one shared `Arc<Engine>` (inference takes `&self`; the decoded
//!   weight cache is `OnceLock`-filled, lock-free on the hot path), each
//!   worker batching its own shard with the same flush triggers. Admission
//!   is bounded: [`WorkerPool::try_submit`] sheds ([`Submission::Shed`])
//!   once every shard holds `queue_cap` in-flight requests.
//! * [`Router`] — the multi-model front: several named pools (one per
//!   loaded `.cgmqm` model/version), requests routed by key, per-model
//!   [`RouteStats`] (accepted/completed/shed), and zero-downtime hot swap
//!   that drains the old pool without losing a request.
//! * [`net`] — the network front: a dependency-free HTTP/1.1 server
//!   ([`Server`]) exposing the router over TCP — `POST
//!   /v1/models/{key}/infer`, `GET /healthz`, `GET /stats`, `GET /metrics`
//!   — mapping [`Submission::Shed`] to `429 Retry-After` and draining
//!   gracefully on shutdown so no accepted request is dropped.
//! * [`telemetry`] — the deploy-side observability spine: log₂
//!   stage-latency [`Histogram`]s over relaxed atomics, per-request
//!   [`Trace`]s via an injectable [`Clock`] (deterministic in tests), and
//!   per-model × per-status counters, rendered as Prometheus text on
//!   `GET /metrics` and JSON on `GET /stats`.
//! * [`reference`] — the host fake-quant forward mirroring the eval graph;
//!   the engine is held to bit-for-bit agreement with it (the cross-path
//!   golden test in `tests/deploy_roundtrip.rs`).
//!
//! ```no_run
//! use cgmq::deploy::{Engine, PackedModel, PoolConfig, WorkerPool};
//! # fn main() -> anyhow::Result<()> {
//! # let (arch, snapshot): (cgmq::model::ArchSpec, cgmq::session::Snapshot) = todo!();
//! // Pack the delivered model and serve it across all cores:
//! let packed = PackedModel::from_snapshot(&arch, &snapshot)?;
//! packed.save(std::path::Path::new("model.cgmqm"))?;
//! let mut pool = WorkerPool::load(std::path::Path::new("model.cgmqm"), PoolConfig::default())?;
//! let _id = pool.submit(vec![0.0; pool.engine().input_len()])?;
//! let (completions, _stats) = pool.shutdown()?;
//! # assert_eq!(completions.len(), 1);
//! # Ok(())
//! # }
//! ```

pub mod batch;
pub mod engine;
pub mod format;
pub mod kernels;
pub mod net;
pub mod plan;
pub mod pool;
pub mod reference;
pub mod router;
pub mod telemetry;

pub use batch::{BatchConfig, BatcherStats, Completion, RequestBatcher};
pub use engine::{top_logit_margin, DecodeMode, Engine, OpProfile};
pub use format::{PackedLayer, PackedModel, WidthStream};
pub use plan::{ExecPlan, Kernel, KernelSelector, Lowering, PlannedOp, PoolGeom, Scratch};
pub use net::{Server, ServerConfig, ServerReport};
pub use pool::{default_workers, PoolCompletion, PoolConfig, PoolStats, Submission, WorkerPool};
pub use router::{ModelReport, RouteStats, Router};
pub use telemetry::{
    Clock, Histogram, HistogramSnapshot, ManualClock, ModelSnapshot, ModelWindow, RealClock,
    ServerTelemetry, SpanRecorder, Stage, TelemetrySnapshot, Trace, WindowSnapshot,
    WindowedCounter, WindowedHistogram, DEFAULT_WINDOW_EPOCH, WINDOW_SLOTS,
};
