//! Deployment subsystem: packed mixed-precision artifacts + the inference
//! engine + the batched serve path.
//!
//! Training ([`crate::session`]) produces a [`Snapshot`](crate::session::Snapshot)
//! whose gates assign every weight and activation unit a bit-width; this
//! module is what turns that snapshot into something that *runs*:
//!
//! * [`format`] — the `.cgmqm` binary model format: per-layer integer
//!   weight codes bit-packed at their trained bit-widths, plus ranges,
//!   signs, biases and the arch fingerprint, behind a checksummed header
//!   and a loader that fails fast on architecture drift.
//! * [`Engine`] — the integer-domain forward pass (dense, conv, ReLU,
//!   max-pool) decoding packed weights through the per-gate scales, with a
//!   streaming mode (decode per call) and an unpack-once mode that caches
//!   dense weights for batched serving.
//! * [`RequestBatcher`] — aggregates single-sample `infer` requests into
//!   batched engine invocations (size- and deadline-triggered flush) so
//!   the unpack cost and the batched matmuls amortize across requests.
//! * [`reference`] — the host fake-quant forward mirroring the eval graph;
//!   the engine is held to bit-for-bit agreement with it (the cross-path
//!   golden test in `tests/deploy_roundtrip.rs`).
//!
//! ```no_run
//! use cgmq::deploy::{BatchConfig, Engine, PackedModel, RequestBatcher};
//! # fn main() -> anyhow::Result<()> {
//! # let (arch, snapshot): (cgmq::model::ArchSpec, cgmq::session::Snapshot) = todo!();
//! // Pack the delivered model and serve it:
//! let packed = PackedModel::from_snapshot(&arch, &snapshot)?;
//! packed.save(std::path::Path::new("model.cgmqm"))?;
//! let engine = Engine::load(std::path::Path::new("model.cgmqm"))?;
//! let _server = RequestBatcher::new(engine, BatchConfig::default())?;
//! # Ok(())
//! # }
//! ```

pub mod batch;
pub mod engine;
pub mod format;
pub mod reference;

pub use batch::{BatchConfig, BatcherStats, Completion, RequestBatcher};
pub use engine::{DecodeMode, Engine};
pub use format::{PackedLayer, PackedModel, WidthStream};
