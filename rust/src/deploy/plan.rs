//! The compiled execution plan: every geometry decision of the forward
//! pass, resolved once at [`Engine`](super::Engine) construction.
//!
//! [`ExecPlan::build`] walks the packed model's layers with the same
//! shape checks the old hot loop re-ran per call — conv wants CHW, the
//! kernel must fit, dense input features must match, pool windows must
//! divide — and bakes the answers into a flat op list, so
//! `infer_batch` executes straight-line with no `bail!` left on the
//! hot path. Both layer kinds lower onto one unified matmul:
//!
//! * `Dense` → a single `(n × d_in) · (d_in × d_out)` GEMM per batch;
//! * `Conv`  → an [`Im2col`](super::kernels::im2col) step per sample,
//!   then a `(o × ci·kh·kw) · (ci·kh·kw × ho·wo)` GEMM whose output is
//!   already the NCHW result plane.
//!
//! Each op records the [`Kernel`] the [`KernelSelector`] chose for its
//! packed bit-widths: fully pruned layers skip their matmul outright
//! ([`Kernel::Pruned`]), layers whose uniform 2/4/8-bit weights meet an
//! on-grid activation stream run integer-native SWAR
//! ([`Kernel::Swar2`]/[`Swar4`](Kernel::Swar4)/[`Swar8`](Kernel::Swar8),
//! parameters in [`PlannedOp::swar`]), and everything else decodes to
//! f32 for the blocked GEMM ([`Kernel::F32Gemm`]). The plan also
//! precomputes the [`Scratch`] layout: two ping-pong activation buffers
//! plus one im2col buffer (and, in streaming mode, one decode buffer),
//! plus the SWAR code/lane/sum buffers when any op needs them, each
//! sized to the plan-wide maximum, so a warm `infer_batch_into` call
//! performs **zero** heap allocations and `infer_batch` a fixed
//! handful.

use anyhow::{bail, Result};

use crate::model::LayerKind;
use crate::quant::IDENTITY_BITS;

use super::format::{PackedModel, WidthStream};
use super::kernels::swar::{self, ActGrid, SwarParams};

/// Kernel implementations a lowered matmul can dispatch to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Decode packed codes to f32, run the blocked f32 GEMM
    /// ([`super::kernels::gemm`]). The fallback for 16/32-bit and
    /// mixed-width layers, and — through the fake-quant reference —
    /// part of the bit-identity spec the integer kernels are held to.
    F32Gemm,
    /// Fully pruned layer (`max_width == 0`): every weight decodes to
    /// 0.0, so the matmul is skipped entirely — zero-fill the output
    /// and run only the bias epilogue. Bit-identical to the f32 GEMM
    /// over all-zero weights (every partial sum is `+0.0`).
    Pruned,
    /// Integer SWAR dot products on 2-bit code words
    /// ([`super::kernels::swar`]). The three SWAR variants share one
    /// parameterized kernel; they differ in the packed-lane geometry
    /// and flush cadence [`PlannedOp::swar`] records.
    Swar2,
    /// SWAR on 4-bit code words.
    Swar4,
    /// SWAR on 8-bit code words.
    Swar8,
}

/// Chooses the kernel for one lowered matmul — the dispatch seam for
/// bitwidth-specialized kernels. Keyed on the layer's packed widths and
/// the incoming activation grid: a uniform 2/4/8-bit layer fed by
/// on-grid activations (and inside the `i32` accumulator bound) runs
/// SWAR; a fully pruned layer skips its matmul; everything else —
/// 16/32-bit, mixed widths beyond one nonzero value, gridless
/// activations — falls back to [`Kernel::F32Gemm`].
#[derive(Debug, Clone, Copy, Default)]
pub struct KernelSelector {
    /// Pin every non-pruned op to [`Kernel::F32Gemm`] — the bench
    /// harness's baseline switch for measuring SWAR speedups on
    /// otherwise-identical plans.
    pub force_f32: bool,
}

impl KernelSelector {
    /// Select the kernel for a layer whose widest weight code is
    /// `max_width` bits (0 = fully pruned layer), with the context the
    /// SWAR decision needs: the uniform nonzero weight width (if any),
    /// the weight range bound, the incoming activation grid, and the
    /// reduction depth `k` of the lowered matmul.
    pub fn select(
        &self,
        max_width: u32,
        w_uniform: Option<u32>,
        beta_w: f32,
        incoming: Option<ActGrid>,
        k: usize,
    ) -> (Kernel, Option<SwarParams>) {
        if max_width == 0 {
            return (Kernel::Pruned, None);
        }
        if self.force_f32 {
            return (Kernel::F32Gemm, None);
        }
        match swar::decide(w_uniform, beta_w, incoming, k) {
            Some(prm) => {
                let kernel = match prm.w_bits {
                    2 => Kernel::Swar2,
                    4 => Kernel::Swar4,
                    _ => Kernel::Swar8,
                };
                (kernel, Some(prm))
            }
            None => (Kernel::F32Gemm, None),
        }
    }
}

/// How one layer's linear op lowers onto the unified matmul.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lowering {
    /// One batched GEMM: activations `(n × d_in)` · weights `(d_in × d_out)`.
    Dense { d_in: usize, d_out: usize },
    /// Per sample: im2col to `(ci·kh·kw) × (ho·wo)`, then weights
    /// `(o × ci·kh·kw)` · columns — output is the NCHW plane directly.
    Conv {
        ci: usize,
        hi: usize,
        wi: usize,
        o: usize,
        kh: usize,
        kw: usize,
        ho: usize,
        wo: usize,
    },
}

/// Geometry of a max-pool step baked into an op (`None` = no pool).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolGeom {
    /// Channels of the pooled NCHW tensor.
    pub c: usize,
    /// Input spatial dims (divisible by `k`, verified at build).
    pub h: usize,
    pub w: usize,
    /// Window == stride.
    pub k: usize,
}

/// One fully resolved step of the compiled forward: which packed layer,
/// how it lowers, which kernel runs it, and the element counts every
/// buffer slice is cut to.
#[derive(Debug, Clone)]
pub struct PlannedOp {
    /// Index into `PackedModel::layers`.
    pub layer: usize,
    pub lowering: Lowering,
    /// Kernel chosen by the [`KernelSelector`] for this op.
    pub kernel: Kernel,
    /// Widest packed weight code in the layer (the selector's key).
    pub max_width: u32,
    /// Integer-kernel parameters when `kernel` is a SWAR variant:
    /// offsets, lane geometry, flush cadence, and the fixed-point
    /// rescale — resolved once here so the engine and the fake-quant
    /// reference run from the same numbers.
    pub swar: Option<SwarParams>,
    /// Per-sample elements produced by the matmul (pre-pool).
    pub out_elems: usize,
    /// Max-pool step after activation quantization, if any.
    pub pool: Option<PoolGeom>,
    /// Per-sample elements this op hands to the next (post-pool).
    pub final_elems: usize,
}

/// The compiled forward: ops plus the scratch-sizing maxima. Built once
/// per engine; immutable and `Sync` afterwards.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    pub ops: Vec<PlannedOp>,
    /// Per-sample input element count.
    pub input_len: usize,
    /// Input quantization width (mirror of the trainer's input grid).
    pub input_bits: u32,
    /// Output units of the last op — the logit count. Reading it here
    /// (a verified plan always has a last op) is what lets the engine
    /// drop its `expect` on `arch.layers.last()`.
    pub num_classes: usize,
    /// Per-sample peak of any activation buffer the plan touches
    /// (input included) — each ping-pong buffer holds `n ×` this.
    pub act_elems: usize,
    /// Peak per-sample im2col footprint (`ci·kh·kw × ho·wo`, maxed over
    /// conv ops); 0 for an all-dense plan.
    pub col_elems: usize,
    /// Largest decoded weight tensor (streaming-mode decode buffer).
    pub max_w_len: usize,
    /// Scratch-sizing maxima for the SWAR buffers (all zero when no op
    /// selected an integer kernel).
    pub swar_sizing: SwarSizing,
}

/// Plan-wide maxima for the SWAR scratch buffers. Dense ops encode the
/// batch's activation codes per call (and, streaming, repack the weight
/// lane panel per call); conv ops pack the im2col columns per call
/// (and, streaming, re-encode the weight scalar codes per call).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SwarSizing {
    /// Per-sample scalar codes a dense SWAR op encodes (max `d_in`).
    pub dense_codes: usize,
    /// Flat weight code block a streaming conv SWAR op re-encodes
    /// (max `o · ci·kh·kw`).
    pub conv_codes: usize,
    /// Lane words a conv SWAR op packs per call.
    pub conv_lane_words: usize,
    /// Lane words a streaming dense SWAR op repacks per call.
    pub dense_lane_words: usize,
    /// Whether any dense op runs SWAR (its scalar sums are batch-sized).
    pub has_dense: bool,
    /// Scalar-sum slots a streaming conv SWAR op needs (max `o`).
    pub conv_rows: usize,
    /// Lane-sum slots a conv SWAR op needs per call (max `ho·wo`).
    pub conv_lane_cols: usize,
    /// Lane-sum slots a streaming dense SWAR op needs (max `d_out`).
    pub dense_lane_cols: usize,
}

impl ExecPlan {
    /// Resolve every layer's geometry and kernel choice up front. All
    /// the shape `bail!`s of the old per-call loop live here now; an
    /// engine holding a built plan runs its hot path check-free.
    pub fn build(model: &PackedModel) -> Result<Self> {
        Self::build_with(model, KernelSelector::default())
    }

    /// [`build`](Self::build) with an explicit [`KernelSelector`] —
    /// how the bench harness pins an `F32Gemm` baseline plan.
    pub fn build_with(model: &PackedModel, selector: KernelSelector) -> Result<Self> {
        if model.layers.is_empty() {
            bail!("packed model has no layers");
        }
        let input_len = model.input_len();
        let mut dims = model.input_shape.clone();
        let mut act_elems = input_len;
        let mut col_elems = 0usize;
        let mut max_w_len = 0usize;
        let mut sizing = SwarSizing::default();
        // The activation grid feeding the next matmul: the input grid
        // at op 0 (`quantize(v, input_bits, 1.0, true)`), then each
        // layer's uniform activation-quantization grid — `None` as soon
        // as a layer emits raw/mixed-width activations, which pins every
        // downstream op to f32.
        let mut grid = if model.input_bits < IDENTITY_BITS {
            Some(ActGrid { bits: model.input_bits, signed: true, beta: 1.0 })
        } else {
            None
        };
        let mut ops = Vec::with_capacity(model.layers.len());
        for (li, layer) in model.layers.iter().enumerate() {
            let flat: usize = dims.iter().product();
            let lowering = match layer.kind {
                LayerKind::Dense => {
                    if layer.w_shape.len() != 2 {
                        bail!(
                            "layer {}: dense weight shape {:?} is not 2-D",
                            layer.name,
                            layer.w_shape
                        );
                    }
                    let (d_in, d_out) = (layer.w_shape[0], layer.w_shape[1]);
                    if flat != d_in {
                        bail!(
                            "layer {}: input {} features, weights want {}",
                            layer.name,
                            flat,
                            d_in
                        );
                    }
                    dims = vec![d_out];
                    Lowering::Dense { d_in, d_out }
                }
                LayerKind::Conv => {
                    if layer.w_shape.len() != 4 {
                        bail!(
                            "layer {}: conv weight shape {:?} is not OIHW",
                            layer.name,
                            layer.w_shape
                        );
                    }
                    if dims.len() != 3 {
                        bail!("layer {}: conv wants CHW input, got {:?}", layer.name, dims);
                    }
                    let (ci, hi, wi) = (dims[0], dims[1], dims[2]);
                    let (o, wc, kh, kw) =
                        (layer.w_shape[0], layer.w_shape[1], layer.w_shape[2], layer.w_shape[3]);
                    if wc != ci || hi < kh || wi < kw {
                        bail!(
                            "layer {}: input {:?} incompatible with kernel {:?}",
                            layer.name,
                            dims,
                            layer.w_shape
                        );
                    }
                    let (ho, wo) = (hi - kh + 1, wi - kw + 1);
                    dims = vec![o, ho, wo];
                    col_elems = col_elems.max(ci * kh * kw * ho * wo);
                    Lowering::Conv { ci, hi, wi, o, kh, kw, ho, wo }
                }
            };
            let out_elems: usize = dims.iter().product();
            let pool = if layer.pool > 1 {
                if dims.len() != 3 {
                    bail!("layer {}: max-pool on a non-spatial output {:?}", layer.name, dims);
                }
                let (c, h, w) = (dims[0], dims[1], dims[2]);
                if h % layer.pool != 0 || w % layer.pool != 0 {
                    bail!(
                        "layer {}: {h}x{w} output is not divisible by max-pool window {}",
                        layer.name,
                        layer.pool
                    );
                }
                dims = vec![c, h / layer.pool, w / layer.pool];
                Some(PoolGeom { c, h, w, k: layer.pool })
            } else {
                None
            };
            let final_elems: usize = dims.iter().product();
            act_elems = act_elems.max(out_elems);
            max_w_len = max_w_len.max(layer.w_len());
            let max_width = max_stream_width(&layer.w_bits, layer.w_len());
            let w_uniform = stream_uniform_width(&layer.w_bits);
            let k = match lowering {
                Lowering::Dense { d_in, .. } => d_in,
                Lowering::Conv { ci, kh, kw, .. } => ci * kh * kw,
            };
            let (kernel, swar) = selector.select(max_width, w_uniform, layer.beta_w, grid, k);
            if let Some(prm) = &swar {
                match lowering {
                    Lowering::Dense { d_in, d_out } => {
                        sizing.has_dense = true;
                        sizing.dense_codes = sizing.dense_codes.max(d_in);
                        sizing.dense_lane_words = sizing
                            .dense_lane_words
                            .max(swar::panel_words(d_in, d_out, prm.lane_bits));
                        sizing.dense_lane_cols = sizing.dense_lane_cols.max(d_out);
                    }
                    Lowering::Conv { ci, o, kh, kw, ho, wo, .. } => {
                        let (kdim, p) = (ci * kh * kw, ho * wo);
                        sizing.conv_codes = sizing.conv_codes.max(o * kdim);
                        sizing.conv_lane_words = sizing
                            .conv_lane_words
                            .max(swar::panel_words(kdim, p, prm.lane_bits));
                        sizing.conv_rows = sizing.conv_rows.max(o);
                        sizing.conv_lane_cols = sizing.conv_lane_cols.max(p);
                    }
                }
            }
            // The grid handed to the next op: this layer's activation
            // quantization output (unsigned — it follows ReLU), when
            // every unit shares one sub-identity width. The final
            // layer's logits have no act stage; its `None` is unread.
            grid = layer.act.as_ref().and_then(|act| {
                let wa = stream_uniform_width(&act.a_bits)?;
                if wa >= IDENTITY_BITS {
                    return None;
                }
                Some(ActGrid { bits: wa, signed: false, beta: act.beta_a })
            });
            ops.push(PlannedOp {
                layer: li,
                lowering,
                kernel,
                max_width,
                swar,
                out_elems,
                pool,
                final_elems,
            });
        }
        // ok_or-style read instead of unwrap: ops is provably non-empty,
        // but a serving-path file must not carry a panic site.
        let num_classes = match ops.last() {
            Some(op) => op.final_elems,
            None => bail!("packed model has no layers"),
        };
        Ok(Self {
            ops,
            input_len,
            input_bits: model.input_bits,
            num_classes,
            act_elems,
            col_elems,
            max_w_len,
            swar_sizing: sizing,
        })
    }
}

/// Widest code in a weight width stream (the kernel-selector key).
fn max_stream_width(ws: &WidthStream, n: usize) -> u32 {
    match ws {
        WidthStream::Uniform(w) => *w,
        WidthStream::PerElement(v) => v.iter().take(n).copied().max().unwrap_or(0),
    }
}

/// The single nonzero width of a stream, if it has one — pruned
/// elements ride along; genuinely mixed or all-pruned streams are
/// `None` ([`swar::uniform_nonzero_width`] semantics).
fn stream_uniform_width(ws: &WidthStream) -> Option<u32> {
    match ws {
        WidthStream::Uniform(0) => None,
        WidthStream::Uniform(w) => Some(*w),
        WidthStream::PerElement(v) => swar::uniform_nonzero_width(v.iter().copied()),
    }
}

/// Reusable per-call working memory, laid out by the plan: two
/// ping-pong activation buffers (`a`/`b`), one im2col buffer (`col`),
/// the streaming-mode weight decode buffer (`wdec`), and the four SWAR
/// buffers — per-call scalar codes (`codes16`), per-call lane words
/// (`lanes`), and the scalar/lane-side correction sums
/// (`sums_s`/`sums_l`). Buffers grow to the plan-wide maxima on first
/// use and never shrink, so repeated
/// [`Engine::infer_batch_into`](super::Engine::infer_batch_into) calls
/// at a seen batch size allocate nothing — the property the
/// scratch-reuse tests pin via [`base_ptrs`](Self::base_ptrs) /
/// [`capacities`](Self::capacities).
#[derive(Debug, Default)]
pub struct Scratch {
    pub(super) a: Vec<f32>,
    pub(super) b: Vec<f32>,
    pub(super) col: Vec<f32>,
    pub(super) wdec: Vec<f32>,
    pub(super) codes16: Vec<u16>,
    pub(super) lanes: Vec<u64>,
    pub(super) sums_s: Vec<i64>,
    pub(super) sums_l: Vec<i64>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every buffer to the plan's requirement for an `n`-sample
    /// batch. Amortized free: a no-op once the buffers have seen `n`.
    pub(super) fn ensure(&mut self, plan: &ExecPlan, n: usize, streaming: bool) {
        grow(&mut self.a, n * plan.act_elems);
        grow(&mut self.b, n * plan.act_elems);
        grow(&mut self.col, plan.col_elems);
        if streaming {
            grow(&mut self.wdec, plan.max_w_len);
        }
        let sz = &plan.swar_sizing;
        let stream_only = |v: usize| if streaming { v } else { 0 };
        grow(&mut self.codes16, (n * sz.dense_codes).max(stream_only(sz.conv_codes)));
        grow(&mut self.lanes, sz.conv_lane_words.max(stream_only(sz.dense_lane_words)));
        let dense_rows = if sz.has_dense { n } else { 0 };
        grow(&mut self.sums_s, dense_rows.max(stream_only(sz.conv_rows)));
        grow(&mut self.sums_l, sz.conv_lane_cols.max(stream_only(sz.dense_lane_cols)));
    }

    /// Current capacities of (activation-a, activation-b, im2col,
    /// decode, swar-codes, swar-lanes, swar-scalar-sums,
    /// swar-lane-sums) — with [`base_ptrs`](Self::base_ptrs), the
    /// observable the O(1)-allocation tests assert stays fixed across
    /// calls.
    pub fn capacities(&self) -> [usize; 8] {
        [
            self.a.capacity(),
            self.b.capacity(),
            self.col.capacity(),
            self.wdec.capacity(),
            self.codes16.capacity(),
            self.lanes.capacity(),
            self.sums_s.capacity(),
            self.sums_l.capacity(),
        ]
    }

    /// Base addresses of the eight buffers; unchanged addresses across
    /// calls prove no buffer was reallocated.
    pub fn base_ptrs(&self) -> [usize; 8] {
        [
            self.a.as_ptr() as usize,
            self.b.as_ptr() as usize,
            self.col.as_ptr() as usize,
            self.wdec.as_ptr() as usize,
            self.codes16.as_ptr() as usize,
            self.lanes.as_ptr() as usize,
            self.sums_s.as_ptr() as usize,
            self.sums_l.as_ptr() as usize,
        ]
    }
}

fn grow<T: Default + Clone>(v: &mut Vec<T>, len: usize) {
    if v.len() < len {
        v.resize(len, T::default());
    }
}
