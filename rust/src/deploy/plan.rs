//! The compiled execution plan: every geometry decision of the forward
//! pass, resolved once at [`Engine`](super::Engine) construction.
//!
//! [`ExecPlan::build`] walks the packed model's layers with the same
//! shape checks the old hot loop re-ran per call — conv wants CHW, the
//! kernel must fit, dense input features must match, pool windows must
//! divide — and bakes the answers into a flat op list, so
//! `infer_batch` executes straight-line with no `bail!` left on the
//! hot path. Both layer kinds lower onto one unified matmul:
//!
//! * `Dense` → a single `(n × d_in) · (d_in × d_out)` GEMM per batch;
//! * `Conv`  → an [`Im2col`](super::kernels::im2col) step per sample,
//!   then a `(o × ci·kh·kw) · (ci·kh·kw × ho·wo)` GEMM whose output is
//!   already the NCHW result plane.
//!
//! Each op records the [`Kernel`] the [`KernelSelector`] chose for its
//! packed bit-widths — today always [`Kernel::F32Gemm`] (decode codes
//! to f32, run the blocked GEMM); this enum + selector pair is the seam
//! where per-width SWAR integer kernels plug in without another engine
//! rewrite. The plan also precomputes the [`Scratch`] layout: two
//! ping-pong activation buffers plus one im2col buffer (and, in
//! streaming mode, one decode buffer), each sized to the plan-wide
//! maximum, so a warm `infer_batch_into` call performs **zero** heap
//! allocations and `infer_batch` a fixed handful.

use anyhow::{bail, Result};

use crate::model::LayerKind;

use super::format::{PackedModel, WidthStream};

/// Kernel implementations a lowered matmul can dispatch to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Decode packed codes to f32, run the blocked f32 GEMM
    /// ([`super::kernels::gemm`]). The only kernel today, and forever
    /// the bit-identity reference the integer kernels are held to.
    F32Gemm,
}

/// Chooses the kernel for one lowered matmul, keyed on the widest
/// packed weight code in the layer — the dispatch seam for
/// bitwidth-specialized kernels. A 2/4/8-bit SWAR path will branch here
/// on `max_width` (and fall back to [`Kernel::F32Gemm`] for 16/32-bit
/// or mixed streams it cannot accelerate).
#[derive(Debug, Clone, Copy, Default)]
pub struct KernelSelector;

impl KernelSelector {
    /// Select the kernel for a layer whose widest weight code is
    /// `max_width` bits (0 = fully pruned layer).
    pub fn select(&self, _max_width: u32) -> Kernel {
        Kernel::F32Gemm
    }
}

/// How one layer's linear op lowers onto the unified matmul.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lowering {
    /// One batched GEMM: activations `(n × d_in)` · weights `(d_in × d_out)`.
    Dense { d_in: usize, d_out: usize },
    /// Per sample: im2col to `(ci·kh·kw) × (ho·wo)`, then weights
    /// `(o × ci·kh·kw)` · columns — output is the NCHW plane directly.
    Conv {
        ci: usize,
        hi: usize,
        wi: usize,
        o: usize,
        kh: usize,
        kw: usize,
        ho: usize,
        wo: usize,
    },
}

/// Geometry of a max-pool step baked into an op (`None` = no pool).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolGeom {
    /// Channels of the pooled NCHW tensor.
    pub c: usize,
    /// Input spatial dims (divisible by `k`, verified at build).
    pub h: usize,
    pub w: usize,
    /// Window == stride.
    pub k: usize,
}

/// One fully resolved step of the compiled forward: which packed layer,
/// how it lowers, which kernel runs it, and the element counts every
/// buffer slice is cut to.
#[derive(Debug, Clone)]
pub struct PlannedOp {
    /// Index into `PackedModel::layers`.
    pub layer: usize,
    pub lowering: Lowering,
    /// Kernel chosen by the [`KernelSelector`] for this op.
    pub kernel: Kernel,
    /// Widest packed weight code in the layer (the selector's key).
    pub max_width: u32,
    /// Per-sample elements produced by the matmul (pre-pool).
    pub out_elems: usize,
    /// Max-pool step after activation quantization, if any.
    pub pool: Option<PoolGeom>,
    /// Per-sample elements this op hands to the next (post-pool).
    pub final_elems: usize,
}

/// The compiled forward: ops plus the scratch-sizing maxima. Built once
/// per engine; immutable and `Sync` afterwards.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    pub ops: Vec<PlannedOp>,
    /// Per-sample input element count.
    pub input_len: usize,
    /// Input quantization width (mirror of the trainer's input grid).
    pub input_bits: u32,
    /// Output units of the last op — the logit count. Reading it here
    /// (a verified plan always has a last op) is what lets the engine
    /// drop its `expect` on `arch.layers.last()`.
    pub num_classes: usize,
    /// Per-sample peak of any activation buffer the plan touches
    /// (input included) — each ping-pong buffer holds `n ×` this.
    pub act_elems: usize,
    /// Peak per-sample im2col footprint (`ci·kh·kw × ho·wo`, maxed over
    /// conv ops); 0 for an all-dense plan.
    pub col_elems: usize,
    /// Largest decoded weight tensor (streaming-mode decode buffer).
    pub max_w_len: usize,
}

impl ExecPlan {
    /// Resolve every layer's geometry and kernel choice up front. All
    /// the shape `bail!`s of the old per-call loop live here now; an
    /// engine holding a built plan runs its hot path check-free.
    pub fn build(model: &PackedModel) -> Result<Self> {
        if model.layers.is_empty() {
            bail!("packed model has no layers");
        }
        let selector = KernelSelector;
        let input_len = model.input_len();
        let mut dims = model.input_shape.clone();
        let mut act_elems = input_len;
        let mut col_elems = 0usize;
        let mut max_w_len = 0usize;
        let mut ops = Vec::with_capacity(model.layers.len());
        for (li, layer) in model.layers.iter().enumerate() {
            let flat: usize = dims.iter().product();
            let lowering = match layer.kind {
                LayerKind::Dense => {
                    if layer.w_shape.len() != 2 {
                        bail!(
                            "layer {}: dense weight shape {:?} is not 2-D",
                            layer.name,
                            layer.w_shape
                        );
                    }
                    let (d_in, d_out) = (layer.w_shape[0], layer.w_shape[1]);
                    if flat != d_in {
                        bail!(
                            "layer {}: input {} features, weights want {}",
                            layer.name,
                            flat,
                            d_in
                        );
                    }
                    dims = vec![d_out];
                    Lowering::Dense { d_in, d_out }
                }
                LayerKind::Conv => {
                    if layer.w_shape.len() != 4 {
                        bail!(
                            "layer {}: conv weight shape {:?} is not OIHW",
                            layer.name,
                            layer.w_shape
                        );
                    }
                    if dims.len() != 3 {
                        bail!("layer {}: conv wants CHW input, got {:?}", layer.name, dims);
                    }
                    let (ci, hi, wi) = (dims[0], dims[1], dims[2]);
                    let (o, wc, kh, kw) =
                        (layer.w_shape[0], layer.w_shape[1], layer.w_shape[2], layer.w_shape[3]);
                    if wc != ci || hi < kh || wi < kw {
                        bail!(
                            "layer {}: input {:?} incompatible with kernel {:?}",
                            layer.name,
                            dims,
                            layer.w_shape
                        );
                    }
                    let (ho, wo) = (hi - kh + 1, wi - kw + 1);
                    dims = vec![o, ho, wo];
                    col_elems = col_elems.max(ci * kh * kw * ho * wo);
                    Lowering::Conv { ci, hi, wi, o, kh, kw, ho, wo }
                }
            };
            let out_elems: usize = dims.iter().product();
            let pool = if layer.pool > 1 {
                if dims.len() != 3 {
                    bail!("layer {}: max-pool on a non-spatial output {:?}", layer.name, dims);
                }
                let (c, h, w) = (dims[0], dims[1], dims[2]);
                if h % layer.pool != 0 || w % layer.pool != 0 {
                    bail!(
                        "layer {}: {h}x{w} output is not divisible by max-pool window {}",
                        layer.name,
                        layer.pool
                    );
                }
                dims = vec![c, h / layer.pool, w / layer.pool];
                Some(PoolGeom { c, h, w, k: layer.pool })
            } else {
                None
            };
            let final_elems: usize = dims.iter().product();
            act_elems = act_elems.max(out_elems);
            max_w_len = max_w_len.max(layer.w_len());
            let max_width = max_stream_width(&layer.w_bits, layer.w_len());
            ops.push(PlannedOp {
                layer: li,
                lowering,
                kernel: selector.select(max_width),
                max_width,
                out_elems,
                pool,
                final_elems,
            });
        }
        // ok_or-style read instead of unwrap: ops is provably non-empty,
        // but a serving-path file must not carry a panic site.
        let num_classes = match ops.last() {
            Some(op) => op.final_elems,
            None => bail!("packed model has no layers"),
        };
        Ok(Self {
            ops,
            input_len,
            input_bits: model.input_bits,
            num_classes,
            act_elems,
            col_elems,
            max_w_len,
        })
    }
}

/// Widest code in a weight width stream (the kernel-selector key).
fn max_stream_width(ws: &WidthStream, n: usize) -> u32 {
    match ws {
        WidthStream::Uniform(w) => *w,
        WidthStream::PerElement(v) => v.iter().take(n).copied().max().unwrap_or(0),
    }
}

/// Reusable per-call working memory, laid out by the plan: two
/// ping-pong activation buffers (`a`/`b`), one im2col buffer (`col`),
/// and the streaming-mode weight decode buffer (`wdec`). Buffers grow
/// to the plan-wide maxima on first use and never shrink, so repeated
/// [`Engine::infer_batch_into`](super::Engine::infer_batch_into) calls
/// at a seen batch size allocate nothing — the property the
/// scratch-reuse tests pin via [`base_ptrs`](Self::base_ptrs) /
/// [`capacities`](Self::capacities).
#[derive(Debug, Default)]
pub struct Scratch {
    pub(super) a: Vec<f32>,
    pub(super) b: Vec<f32>,
    pub(super) col: Vec<f32>,
    pub(super) wdec: Vec<f32>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every buffer to the plan's requirement for an `n`-sample
    /// batch. Amortized free: a no-op once the buffers have seen `n`.
    pub(super) fn ensure(&mut self, plan: &ExecPlan, n: usize, streaming: bool) {
        grow(&mut self.a, n * plan.act_elems);
        grow(&mut self.b, n * plan.act_elems);
        grow(&mut self.col, plan.col_elems);
        if streaming {
            grow(&mut self.wdec, plan.max_w_len);
        }
    }

    /// Current capacities of (activation-a, activation-b, im2col,
    /// decode) — with [`base_ptrs`](Self::base_ptrs), the observable
    /// the O(1)-allocation tests assert stays fixed across calls.
    pub fn capacities(&self) -> [usize; 4] {
        [self.a.capacity(), self.b.capacity(), self.col.capacity(), self.wdec.capacity()]
    }

    /// Base addresses of the four buffers; unchanged addresses across
    /// calls prove no buffer was reallocated.
    pub fn base_ptrs(&self) -> [usize; 4] {
        [
            self.a.as_ptr() as usize,
            self.b.as_ptr() as usize,
            self.col.as_ptr() as usize,
            self.wdec.as_ptr() as usize,
        ]
    }
}

fn grow(v: &mut Vec<f32>, len: usize) {
    if v.len() < len {
        v.resize(len, 0.0);
    }
}
