//! Register-blocked, cache-tiled f32 GEMM — the unified matmul every
//! lowered layer dispatches to ([`Kernel::F32Gemm`](super::super::plan::Kernel)).
//!
//! `C = A · B` over row-major slices: `A (m × k)`, `B (k × n)`,
//! `C (m × n)`, every element of `C` overwritten. Bias is *not* fused —
//! the dense lowering broadcasts it per column
//! ([`add_bias_cols`]) and the conv lowering per output-channel row
//! ([`add_bias_rows`]), both after the matmul, exactly where the old
//! naive loops added it.
//!
//! **Accumulation order is part of the contract.** Each output element
//! owns exactly one f32 accumulator, swept over `p = 0..k` strictly
//! ascending, and `k` is never split into panels — so the float
//! summation chain per element is identical to the seed's naive triple
//! loop regardless of the register/cache blocking around it, and
//! identical for a sample alone or inside any batch (rows are
//! independent). That is what keeps the engine ↔ reference cross-path
//! goldens *bit-for-bit* (`tests/deploy_roundtrip.rs`) and lets
//! `tests/kernels.rs` assert exact equality against the naive oracle
//! instead of a 1-ulp band. Blocking only reorders *which* elements are
//! computed when: an `MR × NR` register tile keeps `MR·NR` accumulators
//! live across the shared k sweep (each `a` and `b` load feeds several
//! multiplies), and an outer column block keeps the touched stripe of
//! `B` cache-resident across row tiles.

/// Register-tile rows: accumulators kept live per micro-kernel call.
pub const MR: usize = 4;
/// Register-tile columns (one `B` row segment reused across `MR` rows).
pub const NR: usize = 8;
/// Cache block over `C`/`B` columns (multiple of `NR`): the stripe of
/// `B` a full sweep of row tiles keeps hot.
const NC: usize = 256;

/// `C = A · B` (row-major, all elements of `C` overwritten). The blocked
/// hot path of both lowerings; bit-identical to [`gemm_naive`].
pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    let mut jc = 0;
    while jc < n {
        let jw = (n - jc).min(NC);
        column_block(a, b, c, m, k, n, jc, jw);
        jc += jw;
    }
}

/// All row tiles over one cache-resident column stripe `[j0, j0 + jw)`.
#[allow(clippy::too_many_arguments)]
fn column_block(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    j0: usize,
    jw: usize,
) {
    let mut i = 0;
    while i + MR <= m {
        let mut j = j0;
        while j + NR <= j0 + jw {
            micro_tile(a, b, c, i, j, k, n);
            j += NR;
        }
        if j < j0 + jw {
            scalar_block(a, b, c, i, i + MR, j, j0 + jw, k, n);
        }
        i += MR;
    }
    if i < m {
        scalar_block(a, b, c, i, m, j0, j0 + jw, k, n);
    }
}

/// The `MR × NR` register tile at `(i0, j0)`: `MR·NR` accumulators, one
/// shared strictly-ascending k sweep.
fn micro_tile(a: &[f32], b: &[f32], c: &mut [f32], i0: usize, j0: usize, k: usize, n: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    let rows = [
        &a[i0 * k..(i0 + 1) * k],
        &a[(i0 + 1) * k..(i0 + 2) * k],
        &a[(i0 + 2) * k..(i0 + 3) * k],
        &a[(i0 + 3) * k..(i0 + 4) * k],
    ];
    for p in 0..k {
        let brow = &b[p * n + j0..p * n + j0 + NR];
        for (accr, arow) in acc.iter_mut().zip(rows) {
            let av = arow[p];
            for (x, &bv) in accr.iter_mut().zip(brow) {
                *x += av * bv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        c[(i0 + r) * n + j0..(i0 + r) * n + j0 + NR].copy_from_slice(accr);
    }
}

/// Remainder path for the rows/columns a full tile does not cover: one
/// accumulator per element, the same ascending k sweep.
#[allow(clippy::too_many_arguments)]
fn scalar_block(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    k: usize,
    n: usize,
) {
    for i in i0..i1 {
        let arow = &a[i * k..(i + 1) * k];
        for j in j0..j1 {
            let mut acc = 0.0f32;
            for (p, &av) in arow.iter().enumerate() {
                acc += av * b[p * n + j];
            }
            c[i * n + j] = acc;
        }
    }
}

/// The unblocked triple-loop oracle the property tests and the
/// `bench_deploy` sanity row hold [`gemm`] to, bit-for-bit. Not used on
/// any serving path.
pub fn gemm_naive(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let mut acc = 0.0f32;
            for (p, &av) in arow.iter().enumerate() {
                acc += av * b[p * n + j];
            }
            c[i * n + j] = acc;
        }
    }
}

/// `c[i][j] += bias[j]` — the dense epilogue (bias per output feature).
pub fn add_bias_cols(c: &mut [f32], bias: &[f32], m: usize, n: usize) {
    for row in c.chunks_exact_mut(n).take(m) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// `c[i][j] += bias[i]` — the conv epilogue (bias per output channel,
/// broadcast over the `ho·wo` positions of row `i`).
pub fn add_bias_rows(c: &mut [f32], bias: &[f32], m: usize, n: usize) {
    for (row, &b) in c.chunks_exact_mut(n).zip(bias).take(m) {
        for v in row.iter_mut() {
            *v += b;
        }
    }
}

/// `out[s] = h[s] @ w + bias` for row-major `h (n, d_in)`, `w (d_in,
/// d_out)` — the dense layer as one batched GEMM. Allocating
/// convenience used by the reference path and tests; the engine runs
/// the same two calls into plan scratch.
pub fn dense(h: &[f32], w: &[f32], bias: &[f32], n: usize, d_in: usize, d_out: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * d_out];
    gemm(h, w, &mut out, n, d_in, d_out);
    add_bias_cols(&mut out, bias, n, d_out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_matches_hand_computation() {
        // h (1, 2) @ w (2, 3) + b
        let h = [1.0, 2.0];
        let w = [1.0, 0.0, -1.0, 0.5, 2.0, 1.0];
        let b = [10.0, 20.0, 30.0];
        let out = dense(&h, &w, &b, 1, 2, 3);
        assert_eq!(out, vec![1.0 + 1.0 + 10.0, 4.0 + 20.0, -1.0 + 2.0 + 30.0]);
    }

    #[test]
    fn gemm_overwrites_stale_output() {
        // Scratch reuse hands gemm a dirty output buffer; every element
        // must be written, none accumulated into.
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [1.0, 0.0, 0.0, 1.0];
        let mut c = [f32::NAN; 4];
        gemm(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn bias_epilogues_broadcast_on_the_right_axis() {
        let mut c = [0.0f32; 6];
        add_bias_cols(&mut c, &[1.0, 2.0, 3.0], 2, 3);
        assert_eq!(c, [1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
        let mut c = [0.0f32; 6];
        add_bias_rows(&mut c, &[1.0, 2.0], 2, 3);
        assert_eq!(c, [1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
    }
}
