//! Integer-native SWAR GEMM on packed code words — the payoff of the
//! trained 2/4/8-bit widths: the dot products run in the integer code
//! domain, never decoding weights to f32.
//!
//! **Operands.** Every lowered matmul has a *scalar side* (read one code
//! per step) and a *lane side* (read `64 / lane_bits` codes per step,
//! packed in one `u64` word):
//!
//! * dense — scalar side = activation codes (batch rows), lane side =
//!   weight codes (one lane per output feature, cached repack);
//! * conv — scalar side = weight codes (one row per output channel,
//!   cached repack), lane side = im2col column codes (one lane per
//!   output position, packed per call).
//!
//! Both sides are **offset-encoded unsigned**: a signed code `q` is
//! stored as `u = q + off` with `off` the grid magnitude bound, so every
//! lane is non-negative and a whole-word multiply by a scalar multiplies
//! all lanes at once with no sign corruption. The true dot product is
//! recovered exactly from per-row / per-lane-column sums:
//!
//! ```text
//! dot(r, j) = S(r, j) - l_off * Σᵢ s(r, i) - s_off * Σᵢ l(i, j)
//!                     + k * s_off * l_off
//! ```
//!
//! where `S` is the all-unsigned SWAR sum and `s`/`l` the stored offset
//! codes. Σ s is computed while encoding the per-call side; Σ l ships
//! with the cached repack. All integer arithmetic is exact, so the SWAR
//! kernel agrees **bit-for-bit** with a naive `i64` triple loop over the
//! raw codes — the oracle `tests/kernels.rs` holds it to — and with the
//! integer path of the fake-quant reference ([`super::super::reference`]).
//!
//! **Lane discipline.** Lane width is 16 (4 lanes/word) when the worst
//! per-step product `s_max * l_max` leaves at least [`MIN_FLUSH16`]
//! accumulations of in-lane headroom, else 32 (2 lanes/word). Lanes are
//! drained into `i32` accumulators every [`SwarParams::flush`] steps —
//! the largest count for which `flush * s_max * l_max` still fits a
//! lane, so cross-lane carries are impossible. The **accumulator bound**
//! is checked once at plan build: a layer is only SWAR-eligible when
//! `k * s_max * l_max <= i32::MAX`, so no `i32` accumulator can
//! overflow at the plan's declared k ([`decide`] falls back to
//! `F32Gemm` otherwise).
//!
//! **Rescale epilogue.** Activations enter as fake-quantized f32 values
//! `a_scale * q`; [`code_of`] recovers `q` exactly (the value sits
//! within a few ulp of the integer, far from any rounding boundary).
//! The output is `(dot as f32) * combined_scale` with `combined_scale =
//! step_size(w_bits, beta_w, true) * a_scale` — the same f32 arithmetic
//! `quant::step_size` decoding performs, computed once at plan build —
//! followed by the ordinary bias epilogue.

use anyhow::Result;

use crate::quant::step_size;

use super::super::format::PackedLayer;

/// Smallest acceptable 16-bit-lane flush cadence; below it the flush
/// overhead eats the 4-lane win and the kernel drops to 32-bit lanes.
pub const MIN_FLUSH16: u64 = 8;

/// Widest lane count a word can carry (16-bit lanes).
pub const MAX_LANES: usize = 4;

/// The incoming activation grid of a lowered matmul: every value is
/// `step_size(bits, beta, signed) * q` for an integer code `q`. `signed`
/// only for the first op (input quantization); hidden activations are
/// ReLU outputs on the unsigned grid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActGrid {
    pub bits: u32,
    pub signed: bool,
    pub beta: f32,
}

/// Everything the engine and the reference need to agree on for one
/// SWAR-lowered op, resolved once by [`decide`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwarParams {
    /// Uniform nonzero weight width (2, 4 or 8).
    pub w_bits: u32,
    /// Incoming activation code width.
    pub a_bits: u32,
    /// Whether the incoming codes are signed (first op only).
    pub a_signed: bool,
    /// Incoming activation grid step.
    pub a_scale: f32,
    /// `1.0 / a_scale`, precomputed for [`code_of`].
    pub inv_a_scale: f32,
    /// `step_size(w_bits, beta_w, true) * a_scale` — the fixed-point
    /// rescale applied to every integer dot product.
    pub combined_scale: f32,
    /// Offset added to weight codes (`2^(w-1) - 1`).
    pub w_off: i64,
    /// Offset added to activation codes (0 on unsigned grids).
    pub a_off: i64,
    /// Largest stored (offset) weight code.
    pub w_max: i64,
    /// Largest stored (offset) activation code.
    pub a_max: i64,
    /// Lane width in bits: 16 (4 lanes/word) or 32 (2 lanes/word).
    pub lane_bits: u32,
    /// Accumulation steps between lane drains (cross-lane-carry bound).
    pub flush: usize,
}

/// Lanes per `u64` word at `lane_bits`.
#[inline]
pub fn lanes_per_word(lane_bits: u32) -> usize {
    (64 / lane_bits) as usize
}

/// Words one lane panel needs: `cols` lanes over a `k`-deep sweep.
#[inline]
pub fn panel_words(k: usize, cols: usize, lane_bits: u32) -> usize {
    cols.div_ceil(lanes_per_word(lane_bits)) * k
}

/// `Some(w)` iff every nonzero width in the stream equals `w` (pruned
/// zero-width elements ride along as code 0); `None` for genuinely mixed
/// streams and for all-pruned ones (the latter is [`Kernel::Pruned`]
/// territory, decided before this is consulted).
///
/// [`Kernel::Pruned`]: super::super::plan::Kernel::Pruned
pub fn uniform_nonzero_width(widths: impl IntoIterator<Item = u32>) -> Option<u32> {
    let mut found = None;
    for w in widths {
        if w == 0 {
            continue;
        }
        match found {
            None => found = Some(w),
            Some(prev) if prev != w => return None,
            Some(_) => {}
        }
    }
    found
}

/// The SWAR eligibility + parameter decision, shared verbatim by
/// [`KernelSelector`](super::super::plan::KernelSelector) and the
/// fake-quant reference so both paths select identically. Returns `None`
/// (→ `F32Gemm`) unless:
///
/// * the weight stream is uniformly one width `w ∈ {2, 4, 8}` (pruned
///   elements allowed),
/// * the incoming activations sit on one shared grid of width ≤ 8, and
/// * the accumulator bound `k * w_max * a_max <= i32::MAX` holds.
pub fn decide(
    w_uniform: Option<u32>,
    beta_w: f32,
    incoming: Option<ActGrid>,
    k: usize,
) -> Option<SwarParams> {
    let w_bits = w_uniform?;
    if !matches!(w_bits, 2 | 4 | 8) {
        return None;
    }
    let grid = incoming?;
    if grid.bits == 0 || grid.bits > 8 {
        return None;
    }
    let w_off = (1i64 << (w_bits - 1)) - 1;
    let w_max = (1i64 << w_bits) - 2;
    let (a_off, a_max) = if grid.signed {
        let m = (1i64 << (grid.bits - 1)) - 1;
        (m, 2 * m)
    } else {
        (0, (1i64 << grid.bits) - 1)
    };
    if w_max == 0 || a_max == 0 {
        return None;
    }
    if (k as i64).checked_mul(w_max * a_max).map_or(true, |b| b > i32::MAX as i64) {
        return None;
    }
    let prod = (w_max * a_max) as u64;
    let (lane_bits, cap) = if u16::MAX as u64 / prod >= MIN_FLUSH16 {
        (16, u16::MAX as u64)
    } else {
        (32, u32::MAX as u64)
    };
    let a_scale = step_size(grid.bits, grid.beta, grid.signed);
    Some(SwarParams {
        w_bits,
        a_bits: grid.bits,
        a_signed: grid.signed,
        a_scale,
        inv_a_scale: 1.0 / a_scale,
        combined_scale: step_size(w_bits, beta_w, true) * a_scale,
        w_off,
        a_off,
        w_max,
        a_max,
        lane_bits,
        flush: (cap / prod) as usize,
    })
}

/// Exact inverse of the fake quantizer's `scale * n` store: recover the
/// integer grid code of an on-grid value. The value is within a few ulp
/// of the integer (never near a rounding boundary), so the engine and
/// the reference recover identical codes from their bit-identical
/// activation tensors.
#[inline]
pub fn code_of(v: f32, inv_scale: f32) -> i64 {
    (v * inv_scale).round_ties_even() as i64
}

// ---------------------------------------------------------------------------
// Packing — cached weight repacks and per-call activation encodes
// ---------------------------------------------------------------------------

/// Repack a dense layer's packed weight codes (`d_in × d_out` stream
/// order) into the lane panel: stripe `jb` holds lanes for output
/// features `jb*L .. jb*L+L` over the full `d_in` sweep, so the kernel's
/// inner loop reads one contiguous word stripe. `sums[j]` receives the
/// offset-code column sums the correction term needs. Pruned (0-width)
/// elements store the offset itself — the encoding of code 0.
pub fn pack_dense_weights(
    layer: &PackedLayer,
    d_in: usize,
    d_out: usize,
    prm: &SwarParams,
    words: &mut Vec<u64>,
    sums: &mut Vec<i64>,
) -> Result<()> {
    let lpw = lanes_per_word(prm.lane_bits);
    words.clear();
    words.resize(panel_words(d_in, d_out, prm.lane_bits), 0);
    sums.clear();
    sums.resize(d_out, 0);
    layer.with_codes(|i, _w, code| {
        let (ki, j) = (i / d_out, i % d_out);
        let u = code + prm.w_off;
        words[(j / lpw) * d_in + ki] |= (u as u64) << ((j % lpw) as u32 * prm.lane_bits);
        sums[j] += u;
    })
}

/// Repack a conv layer's packed weight codes (`o × ci·kh·kw` stream
/// order — already the scalar-side row-major layout) into offset `u16`
/// codes plus per-output-channel row sums.
pub fn pack_conv_weights(
    layer: &PackedLayer,
    o: usize,
    kdim: usize,
    prm: &SwarParams,
    codes: &mut Vec<u16>,
    sums: &mut Vec<i64>,
) -> Result<()> {
    codes.clear();
    codes.resize(o * kdim, 0);
    sums.clear();
    sums.resize(o, 0);
    layer.with_codes(|i, _w, code| {
        let u = code + prm.w_off;
        codes[i] = u as u16;
        sums[i / kdim] += u;
    })
}

/// Encode a row-major f32 activation block (`m × k`, every value on the
/// incoming grid) into offset scalar codes plus per-row sums — the
/// dense lowering's per-call scalar side. Resizes the buffers to exact
/// fit (within their grown capacity: no allocation on a warm scratch).
pub fn encode_scalar_rows(
    h: &[f32],
    m: usize,
    k: usize,
    prm: &SwarParams,
    codes: &mut Vec<u16>,
    sums: &mut Vec<i64>,
) {
    codes.resize(m * k, 0);
    sums.resize(m, 0);
    for r in 0..m {
        let row = &h[r * k..(r + 1) * k];
        let dst = &mut codes[r * k..(r + 1) * k];
        let mut total = 0i64;
        for (d, &v) in dst.iter_mut().zip(row) {
            let u = code_of(v, prm.inv_a_scale) + prm.a_off;
            *d = u as u16;
            total += u;
        }
        sums[r] = total;
    }
}

/// Encode a row-major f32 im2col matrix (`k × n`, every value on the
/// incoming grid) into the lane panel plus per-position lane-column
/// sums — the conv lowering's per-call lane side. Resizes the buffers
/// to exact fit (within their grown capacity: no allocation on a warm
/// scratch); every word in range is overwritten.
pub fn pack_lane_cols(
    col: &[f32],
    k: usize,
    n: usize,
    prm: &SwarParams,
    words: &mut Vec<u64>,
    sums: &mut Vec<i64>,
) {
    let lpw = lanes_per_word(prm.lane_bits);
    let nb = n.div_ceil(lpw);
    words.resize(panel_words(k, n, prm.lane_bits), 0);
    sums.resize(n, 0);
    for s in sums[..n].iter_mut() {
        *s = 0;
    }
    for jb in 0..nb {
        let stripe = &mut words[jb * k..(jb + 1) * k];
        for (i, w) in stripe.iter_mut().enumerate() {
            let mut word = 0u64;
            for l in 0..lpw {
                let j = jb * lpw + l;
                if j < n {
                    let u = code_of(col[i * n + j], prm.inv_a_scale) + prm.a_off;
                    word |= (u as u64) << (l as u32 * prm.lane_bits);
                    sums[j] += u;
                }
            }
            *w = word;
        }
    }
}

// ---------------------------------------------------------------------------
// The SWAR GEMM
// ---------------------------------------------------------------------------

/// Integer-native GEMM: `out[r, j] = scale * Σᵢ (s(r,i) - s_off) *
/// (l(i,j) - l_off)` over offset scalar codes `s` (`m × k` row-major)
/// and an offset lane panel `l` (`k`-deep stripes of `lanes_per_word`
/// columns each, `words.len() >= panel_words(k, n, lane_bits)`).
///
/// One whole-word multiply accumulates `lanes_per_word` products per
/// step; lanes drain into `i32` accumulators every `flush` steps (the
/// carry bound [`decide`] derived), and the main path keeps four
/// independent word chains in flight so the multiplies pipeline. Every
/// `out` element is overwritten; accumulation order is irrelevant —
/// integer sums are exact, so blocked == naive bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn swar_gemm(
    scalar: &[u16],
    scalar_sums: &[i64],
    words: &[u64],
    lane_sums: &[i64],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    prm: &SwarParams,
    s_off: i64,
    l_off: i64,
    scale: f32,
) {
    let lpw = lanes_per_word(prm.lane_bits);
    let mask = if prm.lane_bits == 64 { u64::MAX } else { (1u64 << prm.lane_bits) - 1 };
    let nb = n.div_ceil(lpw);
    let fl = prm.flush.max(1);
    let base = (k as i64) * s_off * l_off;
    let mut write = |r: usize, jb: usize, acc: &[i32; MAX_LANES]| {
        for (l, &a) in acc.iter().enumerate().take(lpw) {
            let j = jb * lpw + l;
            if j < n {
                let dot = a as i64 - l_off * scalar_sums[r] - s_off * lane_sums[j] + base;
                out[r * n + j] = dot as f32 * scale;
            }
        }
    };
    let mut jb = 0;
    // Quad-stripe main path: 4 independent u64 accumulation chains.
    while jb + 4 <= nb {
        let s0 = &words[jb * k..(jb + 1) * k];
        let s1 = &words[(jb + 1) * k..(jb + 2) * k];
        let s2 = &words[(jb + 2) * k..(jb + 3) * k];
        let s3 = &words[(jb + 3) * k..(jb + 4) * k];
        for r in 0..m {
            let srow = &scalar[r * k..(r + 1) * k];
            let mut acc = [[0i32; MAX_LANES]; 4];
            let mut i = 0;
            while i < k {
                let end = (i + fl).min(k);
                let (mut w0, mut w1, mut w2, mut w3) = (0u64, 0u64, 0u64, 0u64);
                for p in i..end {
                    let s = srow[p] as u64;
                    w0 += s0[p] * s;
                    w1 += s1[p] * s;
                    w2 += s2[p] * s;
                    w3 += s3[p] * s;
                }
                for (a, w) in acc.iter_mut().zip([w0, w1, w2, w3]) {
                    for (l, slot) in a.iter_mut().enumerate().take(lpw) {
                        *slot += ((w >> (l as u32 * prm.lane_bits)) & mask) as i32;
                    }
                }
                i = end;
            }
            for (q, a) in acc.iter().enumerate() {
                write(r, jb + q, a);
            }
        }
        jb += 4;
    }
    // Remainder stripes, one at a time.
    while jb < nb {
        let stripe = &words[jb * k..(jb + 1) * k];
        for r in 0..m {
            let srow = &scalar[r * k..(r + 1) * k];
            let mut acc = [0i32; MAX_LANES];
            let mut i = 0;
            while i < k {
                let end = (i + fl).min(k);
                let mut w = 0u64;
                for p in i..end {
                    w += stripe[p] * srow[p] as u64;
                }
                for (l, slot) in acc.iter_mut().enumerate().take(lpw) {
                    *slot += ((w >> (l as u32 * prm.lane_bits)) & mask) as i32;
                }
                i = end;
            }
            write(r, jb, &acc);
        }
        jb += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive oracle over raw (un-offset) codes: plain i64 triple loop.
    fn naive(
        qa: &[i64],
        qw: &[i64],
        m: usize,
        k: usize,
        n: usize,
        scale: f32,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for r in 0..m {
            for j in 0..n {
                let mut dot = 0i64;
                for i in 0..k {
                    dot += qa[r * k + i] * qw[i * n + j];
                }
                out[r * n + j] = dot as f32 * scale;
            }
        }
        out
    }

    /// Pack raw lane-side codes (`k × n` row-major) the way the dense
    /// weight repack lays them out.
    fn pack_lanes_raw(q: &[i64], k: usize, n: usize, off: i64, lane_bits: u32) -> (Vec<u64>, Vec<i64>) {
        let lpw = lanes_per_word(lane_bits);
        let mut words = vec![0u64; panel_words(k, n, lane_bits)];
        let mut sums = vec![0i64; n];
        for i in 0..k {
            for j in 0..n {
                let u = q[i * n + j] + off;
                words[(j / lpw) * k + i] |= (u as u64) << ((j % lpw) as u32 * lane_bits);
                sums[j] += u;
            }
        }
        (words, sums)
    }

    fn xorshift(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x
    }

    #[test]
    fn swar_matches_naive_over_widths_and_awkward_k() {
        let mut seed = 0x5117_2024u64;
        for &w_bits in &[2u32, 4, 8] {
            for &(a_bits, a_signed) in &[(2u32, false), (4, false), (8, false), (8, true)] {
                let prm = decide(
                    Some(w_bits),
                    1.5,
                    Some(ActGrid { bits: a_bits, signed: a_signed, beta: 6.0 }),
                    200,
                )
                .unwrap();
                for &k in &[1usize, 3, 17, 63, 64, 65, 129] {
                    let (m, n) = (3usize, 11usize);
                    let wq_max = (1i64 << (w_bits - 1)) - 1;
                    let qa_hi = if a_signed { (1i64 << (a_bits - 1)) - 1 } else { (1i64 << a_bits) - 1 };
                    let qa_lo = if a_signed { -qa_hi } else { 0 };
                    let qa: Vec<i64> = (0..m * k)
                        .map(|_| qa_lo + (xorshift(&mut seed) % (qa_hi - qa_lo + 1) as u64) as i64)
                        .collect();
                    let qw: Vec<i64> = (0..k * n)
                        .map(|_| -wq_max + (xorshift(&mut seed) % (2 * wq_max + 1) as u64) as i64)
                        .collect();
                    let (words, lane_sums) = pack_lanes_raw(&qw, k, n, prm.w_off, prm.lane_bits);
                    let scalar: Vec<u16> = qa.iter().map(|&q| (q + prm.a_off) as u16).collect();
                    let scalar_sums: Vec<i64> = (0..m)
                        .map(|r| qa[r * k..(r + 1) * k].iter().map(|&q| q + prm.a_off).sum())
                        .collect();
                    let mut out = vec![f32::NAN; m * n];
                    let scale = prm.combined_scale;
                    swar_gemm(
                        &scalar, &scalar_sums, &words, &lane_sums, &mut out, m, k, n, &prm,
                        prm.a_off, prm.w_off, scale,
                    );
                    let want = naive(&qa, &qw, m, k, n, scale);
                    assert_eq!(
                        out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "w={w_bits} a={a_bits}/{a_signed} k={k}"
                    );
                }
            }
        }
    }

    #[test]
    fn accumulator_never_overflows_at_the_declared_bound() {
        // Worst-case codes at the largest k decide() admits for 8x8.
        let grid = ActGrid { bits: 8, signed: false, beta: 6.0 };
        let prm = decide(Some(8), 1.0, Some(grid), 100).unwrap();
        let k_max = (i32::MAX as i64 / (prm.w_max * prm.a_max)) as usize;
        assert!(decide(Some(8), 1.0, Some(grid), k_max).is_some());
        assert!(decide(Some(8), 1.0, Some(grid), k_max + 1).is_none());
        // Run the kernel at a saturating-code slice of that k: every
        // lane accumulates its maximum product each step.
        let k = 4096usize;
        let (m, n) = (1usize, 5usize);
        let qa = vec![(1i64 << 8) - 1; m * k];
        let qw = vec![(1i64 << 7) - 1; k * n];
        let (words, lane_sums) = pack_lanes_raw(&qw, k, n, prm.w_off, prm.lane_bits);
        let scalar: Vec<u16> = qa.iter().map(|&q| (q + prm.a_off) as u16).collect();
        let scalar_sums: Vec<i64> =
            (0..m).map(|r| qa[r * k..(r + 1) * k].iter().sum::<i64>()).collect();
        let mut out = vec![0.0f32; m * n];
        swar_gemm(
            &scalar, &scalar_sums, &words, &lane_sums, &mut out, m, k, n, &prm, prm.a_off,
            prm.w_off, 1.0,
        );
        let want = (k as i64 * 255 * 127) as f32;
        assert!(out.iter().all(|&v| v == want));
    }

    #[test]
    fn decide_rejects_mixed_wide_and_gridless() {
        let grid = Some(ActGrid { bits: 8, signed: true, beta: 1.0 });
        assert!(decide(None, 1.0, grid, 10).is_none(), "mixed widths");
        assert!(decide(Some(16), 1.0, grid, 10).is_none(), "16-bit weights");
        assert!(decide(Some(32), 1.0, grid, 10).is_none(), "identity weights");
        assert!(decide(Some(4), 1.0, None, 10).is_none(), "no shared act grid");
        assert!(
            decide(Some(4), 1.0, Some(ActGrid { bits: 16, signed: false, beta: 6.0 }), 10)
                .is_none(),
            "16-bit activations"
        );
        assert!(decide(Some(4), 1.0, grid, 10).is_some());
    }

    #[test]
    fn lane_width_tracks_product_headroom() {
        let a8 = Some(ActGrid { bits: 8, signed: false, beta: 6.0 });
        let a4 = Some(ActGrid { bits: 4, signed: false, beta: 6.0 });
        assert_eq!(decide(Some(2), 1.0, a8, 10).unwrap().lane_bits, 16);
        assert_eq!(decide(Some(4), 1.0, a8, 10).unwrap().lane_bits, 16);
        assert_eq!(decide(Some(8), 1.0, a8, 10).unwrap().lane_bits, 32);
        assert_eq!(decide(Some(8), 1.0, a4, 10).unwrap().lane_bits, 16);
    }

    #[test]
    fn uniform_nonzero_width_ignores_pruned() {
        assert_eq!(uniform_nonzero_width([4, 0, 4, 4]), Some(4));
        assert_eq!(uniform_nonzero_width([0, 0]), None);
        assert_eq!(uniform_nonzero_width([2, 4]), None);
        assert_eq!(uniform_nonzero_width([8; 5]), Some(8));
    }

    #[test]
    fn code_of_inverts_the_quantizer_store() {
        use crate::quant::{quantize, step_size};
        for &(bits, signed, beta) in &[(8u32, true, 1.0f32), (4, false, 6.0), (2, false, 6.0)] {
            let s = step_size(bits, beta, signed);
            let inv = 1.0 / s;
            let hi = if signed { (1i64 << (bits - 1)) - 1 } else { (1i64 << bits) - 1 };
            let lo = if signed { -hi } else { 0 };
            for q in lo..=hi {
                let v = quantize(s * q as f32, bits, beta, signed);
                assert_eq!(code_of(v, inv), q, "bits={bits} signed={signed} q={q}");
            }
        }
    }
}
