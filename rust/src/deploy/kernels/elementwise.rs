//! The non-matmul kernels: ReLU, per-unit activation fake quantization,
//! non-overlapping max-pool, argmax. Moved out of the engine so both
//! forward paths (packed engine and fake-quant reference) run the exact
//! same element-wise code, and so the per-op profile can report their
//! share of compute separately from the GEMMs.

use crate::deploy::format::PackedAct;
use crate::quant::quantize;

pub fn relu_inplace(h: &mut [f32]) {
    for v in h.iter_mut() {
        *v = v.max(0.0);
    }
}

/// Per-unit activation fake quantization: ReLU output on the unsigned grid
/// `[0, beta_a]` at that unit's trained bit-width (0 = pruned unit).
pub fn quantize_activations(h: &mut [f32], act: &PackedAct, n: usize) {
    let units = h.len() / n;
    for s in 0..n {
        let block = &mut h[s * units..(s + 1) * units];
        for (u, v) in block.iter_mut().enumerate() {
            *v = match act.a_bits.get(u) {
                0 => 0.0,
                bits => quantize(*v, bits, act.beta_a, false),
            };
        }
    }
}

/// Non-overlapping `k x k` max pooling over NCHW, window == stride,
/// written into the first `n·c·(hh/k)·(ww/k)` elements of `dst` (scratch
/// reuse: `dst` may be longer). Assumes `k` divides both spatial dims —
/// inputs where it doesn't are rejected up front by `PackedModel::verify`'s
/// geometry walk and again by `ExecPlan::build` (the floor division here
/// would otherwise silently drop edge rows/cols).
#[allow(clippy::too_many_arguments)]
pub fn maxpool_into(
    src: &[f32],
    dst: &mut [f32],
    n: usize,
    c: usize,
    hh: usize,
    ww: usize,
    k: usize,
) {
    let ho = hh / k;
    let wo = ww / k;
    for sc in 0..n * c {
        let plane = &src[sc * hh * ww..(sc + 1) * hh * ww];
        let oplane = &mut dst[sc * ho * wo..(sc + 1) * ho * wo];
        for oy in 0..ho {
            for ox in 0..wo {
                let mut m = f32::NEG_INFINITY;
                for ky in 0..k {
                    for kx in 0..k {
                        m = m.max(plane[(oy * k + ky) * ww + ox * k + kx]);
                    }
                }
                oplane[oy * wo + ox] = m;
            }
        }
    }
}

/// Allocating [`maxpool_into`] (reference path and tests).
pub fn maxpool(h: &[f32], n: usize, c: usize, hh: usize, ww: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * c * (hh / k) * (ww / k)];
    maxpool_into(h, &mut out, n, c, hh, ww, k);
    out
}

/// Argmax index of a non-empty slice (first max wins, like
/// `Tensor::argmax_rows`).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for j in 1..row.len() {
        if row[j] > row[best] {
            best = j;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_2x2() {
        let h =
            [1.0, 2.0, 3.0, 4.0, 8.0, 7.0, 6.0, 5.0, 0.0, -1.0, -2.0, -3.0, 4.0, 4.0, 4.0, 4.0];
        let out = maxpool(&h, 1, 1, 4, 4, 2);
        assert_eq!(out, [8.0, 6.0, 4.0, 4.0]);
    }

    #[test]
    fn maxpool_into_writes_only_the_output_prefix() {
        let h = [1.0, 2.0, 3.0, 4.0];
        let mut dst = [0.0f32; 3];
        dst[1] = -7.0;
        dst[2] = 9.0;
        maxpool_into(&h, &mut dst, 1, 1, 2, 2, 2);
        assert_eq!(dst, [4.0, -7.0, 9.0]);
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn relu_clamps_negatives() {
        let mut h = [-1.0, 0.0, 2.5, -0.0];
        relu_inplace(&mut h);
        assert_eq!(h, [0.0, 0.0, 2.5, 0.0]);
    }
}
