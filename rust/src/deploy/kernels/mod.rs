//! The shared kernel layer of the deploy forward pass.
//!
//! Every lowered op the [`ExecPlan`](super::plan::ExecPlan) emits
//! executes through the functions here, and the fake-quant reference
//! ([`super::reference`]) routes through the *same* functions — so the
//! engine ↔ reference cross-path golden compares quantization fidelity,
//! never summation order. Three families:
//!
//! * [`gemm`] — the register-blocked, cache-tiled f32 GEMM (plus the
//!   naive oracle and the bias epilogues). Accumulation order is fixed
//!   and batch-size-independent: one accumulator per output element,
//!   k swept ascending and never split, so blocked == naive == seed
//!   loops *bit-for-bit*.
//! * [`im2col`] — valid-padding stride-1 conv lowering: scatter the
//!   image into `(ci·kh·kw) × (ho·wo)` columns whose row order matches
//!   OIHW weight memory, then conv is one GEMM per sample.
//! * [`elementwise`] — ReLU, per-unit activation fake quantization,
//!   non-overlapping max-pool, argmax.
//!
//! Everything is `panic-hygiene` scoped (`cgmq analyze`): no
//! unwrap/expect/panic! outside `#[cfg(test)]` — a malformed shape must
//! surface as a typed error at plan build, never as a dead serving
//! thread mid-GEMM. Integer SWAR kernels (dot products directly on
//! packed 2/4/8-bit code words) will live beside `gemm.rs` and be
//! chosen per op by the
//! [`KernelSelector`](super::plan::KernelSelector); the f32 kernels
//! stay as the bit-identity oracle.

pub mod elementwise;
pub mod gemm;
pub mod im2col;

pub use elementwise::{argmax, maxpool, maxpool_into, quantize_activations, relu_inplace};
pub use gemm::{add_bias_cols, add_bias_rows, dense, gemm, gemm_naive, MR, NR};
pub use im2col::{conv2d, im2col};
