//! The shared kernel layer of the deploy forward pass.
//!
//! Every lowered op the [`ExecPlan`](super::plan::ExecPlan) emits
//! executes through the functions here, and the fake-quant reference
//! ([`super::reference`]) routes through the *same* functions — so the
//! engine ↔ reference cross-path golden compares quantization fidelity,
//! never summation order. Three families:
//!
//! * [`gemm`] — the register-blocked, cache-tiled f32 GEMM (plus the
//!   naive oracle and the bias epilogues). Accumulation order is fixed
//!   and batch-size-independent: one accumulator per output element,
//!   k swept ascending and never split, so blocked == naive == seed
//!   loops *bit-for-bit*.
//! * [`im2col`] — valid-padding stride-1 conv lowering: scatter the
//!   image into `(ci·kh·kw) × (ho·wo)` columns whose row order matches
//!   OIHW weight memory, then conv is one GEMM per sample.
//! * [`elementwise`] — ReLU, per-unit activation fake quantization,
//!   non-overlapping max-pool, argmax.
//!
//! * [`swar`] — the integer-native SWAR GEMM: dot products computed
//!   directly on packed 2/4/8-bit code words (`u64` lanes, `i32`
//!   accumulators, per-gate fixed-point rescale), chosen per op by the
//!   [`KernelSelector`](super::plan::KernelSelector) when a layer's
//!   widths and incoming activation grid qualify. The f32 kernels stay
//!   both as the fallback for 16/32-bit and mixed-width layers and —
//!   through the fake-quant reference's independent integer oracle —
//!   as the bit-identity spec the SWAR path is held to.
//!
//! Everything is `panic-hygiene` scoped (`cgmq analyze`): no
//! unwrap/expect/panic! outside `#[cfg(test)]` — a malformed shape must
//! surface as a typed error at plan build, never as a dead serving
//! thread mid-GEMM.

pub mod elementwise;
pub mod gemm;
pub mod im2col;
pub mod swar;

pub use elementwise::{argmax, maxpool, maxpool_into, quantize_activations, relu_inplace};
pub use gemm::{add_bias_cols, add_bias_rows, dense, gemm, gemm_naive, MR, NR};
pub use im2col::{conv2d, im2col};
pub use swar::{
    code_of, decide, encode_scalar_rows, lanes_per_word, pack_conv_weights, pack_dense_weights,
    pack_lane_cols, panel_words, swar_gemm, uniform_nonzero_width, ActGrid, SwarParams,
};
