//! Valid-padding stride-1 im2col: lower one CHW image to the column
//! matrix that turns conv into a single GEMM.
//!
//! Row `(ic, ky, kx)` of the output holds the input values that kernel
//! tap multiplies at every output position, laid out `(oy, ox)`
//! row-major — so the column matrix is `(ci·kh·kw) × (ho·wo)` and the
//! conv becomes `weights (o × ci·kh·kw) · col`, whose output *is* the
//! NCHW result plane, no reshuffle. Two orders are load-bearing:
//!
//! * rows ascend `(ic, ky, kx)` — exactly the OIHW weight memory order,
//!   so the GEMM's ascending k sweep replays the seed conv's
//!   `ic → ky → kx` accumulation chain bit-for-bit;
//! * each row is filled with `wo`-length contiguous `copy_from_slice`
//!   runs (one per output row), not per-element gathers.

use super::gemm::{add_bias_rows, gemm};

/// Scatter one `ci × hi × wi` image into `col` (`ci·kh·kw` rows of
/// `ho·wo`), which must be at least that long; only that prefix is
/// written.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    img: &[f32],
    ci: usize,
    hi: usize,
    wi: usize,
    kh: usize,
    kw: usize,
    col: &mut [f32],
) {
    let ho = hi - kh + 1;
    let wo = wi - kw + 1;
    let p = ho * wo;
    let mut row = 0;
    for ic in 0..ci {
        let ch = &img[ic * hi * wi..(ic + 1) * hi * wi];
        for ky in 0..kh {
            for kx in 0..kw {
                let dst = &mut col[row * p..(row + 1) * p];
                for oy in 0..ho {
                    let src = &ch[(oy + ky) * wi + kx..(oy + ky) * wi + kx + wo];
                    dst[oy * wo..(oy + 1) * wo].copy_from_slice(src);
                }
                row += 1;
            }
        }
    }
}

/// Valid-padding stride-1 conv (NCHW input, OIHW weights, bias per
/// output channel) via im2col + GEMM. Allocating convenience used by
/// the reference path and tests; the engine runs the same calls into
/// plan scratch.
#[allow(clippy::too_many_arguments)]
pub fn conv2d(
    h: &[f32],
    w: &[f32],
    bias: &[f32],
    n: usize,
    ci: usize,
    hi: usize,
    wi: usize,
    o: usize,
    kh: usize,
    kw: usize,
) -> Vec<f32> {
    let ho = hi - kh + 1;
    let wo = wi - kw + 1;
    let kdim = ci * kh * kw;
    let p = ho * wo;
    let mut col = vec![0.0f32; kdim * p];
    let mut out = vec![0.0f32; n * o * p];
    for s in 0..n {
        let img = &h[s * ci * hi * wi..(s + 1) * ci * hi * wi];
        im2col(img, ci, hi, wi, kh, kw, &mut col);
        let planes = &mut out[s * o * p..(s + 1) * o * p];
        gemm(w, &col, planes, o, kdim, p);
        add_bias_rows(planes, bias, o, p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn im2col_rows_are_shifted_windows() {
        // 3x3 ramp, 2x2 kernel: row (ky, kx) holds the image shifted by
        // (ky, kx), flattened over the 2x2 output positions.
        let img: Vec<f32> = (0..9).map(|v| v as f32).collect();
        let mut col = vec![0.0f32; 4 * 4];
        im2col(&img, 1, 3, 3, 2, 2, &mut col);
        let want = [
            0.0, 1.0, 3.0, 4.0, // (ky 0, kx 0)
            1.0, 2.0, 4.0, 5.0, // (ky 0, kx 1)
            3.0, 4.0, 6.0, 7.0, // (ky 1, kx 0)
            4.0, 5.0, 7.0, 8.0, // (ky 1, kx 1)
        ];
        assert_eq!(col, want);
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel with weight 1 is a passthrough plus bias.
        let h: Vec<f32> = (0..9).map(|v| v as f32).collect();
        let out = conv2d(&h, &[1.0], &[0.5], 1, 1, 3, 3, 1, 1, 1);
        let expect: Vec<f32> = (0..9).map(|v| v as f32 + 0.5).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn conv_sums_window() {
        // 2x2 all-ones kernel over a 3x3 ramp.
        let h: Vec<f32> = (0..9).map(|v| v as f32).collect();
        let out = conv2d(&h, &[1.0; 4], &[0.0], 1, 1, 3, 3, 1, 2, 2);
        let expect = [0. + 1. + 3. + 4., 1. + 2. + 4. + 5., 3. + 4. + 6. + 7., 4. + 5. + 7. + 8.];
        assert_eq!(out, expect);
    }
}
