//! Multi-worker sharded serving: N threads over one shared engine.
//!
//! The serve path used to be single-threaded by construction — the engine
//! took `&mut self`, so a packed model could drive at most one
//! [`RequestBatcher`]. With the engine immutable ([`Engine::infer_batch`]
//! takes `&self`, decoded weights live in per-layer `OnceLock` slots),
//! serving scales out by plain sharding:
//!
//! ```text
//!            submit() — round-robin by global id
//!           /          |           \
//!      shard 0      shard 1     shard N-1      (mpsc channel each)
//!         |            |            |
//!      worker 0     worker 1    worker N-1     (std thread each)
//!      batcher      batcher      batcher       (size/deadline flushes)
//!           \          |           /
//!            one shared Arc<Engine>  — lock-free hot path
//!           \          |           /
//!            completions (mpsc, many-to-one)
//! ```
//!
//! Each worker owns a private [`RequestBatcher`] over the shared engine,
//! so the existing size/deadline flush triggers apply per shard and FIFO
//! order is preserved *within* a shard (requests routed to different
//! shards complete independently — that is the point). The front is
//! clock-free: workers stamp `Instant::now()` on arrival, and a worker
//! with pending requests sleeps on its channel only until the oldest
//! request's deadline, so `max_delay` holds under idle fronts too.
//!
//! Everything is `std` — threads + `mpsc` channels, no new dependencies.
//! [`WorkerPool::shutdown`] closes the front, drains every shard, joins
//! the workers and returns the per-shard [`BatcherStats`] (their counter
//! invariant holds shard-wise and therefore pool-wide).
//!
//! **Backpressure.** The channels themselves are unbounded, but admission
//! is not: each shard carries an atomic in-flight depth counter
//! (incremented at the front, decremented by the worker as it forwards
//! each completion), and [`WorkerPool::try_submit`] refuses new work with
//! a typed [`Submission::Shed`] once every shard's depth has reached
//! `queue_cap` — the load-shedding 429 the network front
//! ([`super::net`]) maps this to. [`WorkerPool::submit`] is the uncapped
//! path (benchmarks that want to measure the queue itself);
//! admission-controlled serving goes through `try_submit`, as
//! [`super::router::Router`] does. Both paths route through one private
//! admission choke point that maintains the pool-level [`PoolStats`]
//! (`submitted == accepted + shed` by construction), so stats readers
//! cannot under-report submissions whichever path fed the pool.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::batch::{BatchConfig, BatcherStats, Completion, RequestBatcher};
use super::engine::Engine;

/// Sizing/flush/admission policy of a [`WorkerPool`].
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    /// Worker threads == shards (>= 1).
    pub workers: usize,
    /// Per-shard batching policy (size/deadline flush triggers).
    pub batch: BatchConfig,
    /// Per-shard in-flight cap enforced by [`WorkerPool::try_submit`]
    /// (submitted-but-not-yet-completed requests per shard). `0` means
    /// unbounded — every `try_submit` is accepted, like `submit`.
    pub queue_cap: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self { workers: default_workers(), batch: BatchConfig::default(), queue_cap: 0 }
    }
}

/// Outcome of an admission-controlled [`WorkerPool::try_submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Submission {
    /// Enqueued on `shard`; its [`PoolCompletion`] will carry `id`.
    Accepted { id: u64, shard: usize },
    /// Every shard's in-flight depth was at `queue_cap`; nothing was
    /// enqueued. The caller decides the policy (429, retry, spill).
    Shed { queue_cap: usize },
}

/// Pool-level submission counters, maintained by the single admission
/// choke point every submission path goes through ([`WorkerPool::submit`]
/// and [`WorkerPool::try_submit`] both route via it), so a stats reader
/// can never under-count `submitted` no matter which path fed the pool.
///
/// Invariant: `submitted == accepted + shed` — every call that passed
/// validation was either enqueued or refused, never both, never neither.
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    /// Submissions that passed validation (accepted + shed).
    pub submitted: u64,
    /// Requests enqueued on a shard (also the next request id).
    pub accepted: u64,
    /// Requests refused because every shard was at `queue_cap`.
    pub shed: u64,
}

impl PoolStats {
    /// The choke-point invariant; linear in every counter, so sums of
    /// consistent stats stay consistent.
    pub fn consistent(&self) -> bool {
        self.submitted == self.accepted + self.shed
    }
}

/// Default worker count: available cores, capped at 8 shards (beyond
/// that, per-shard batches thin out faster than throughput grows for the
/// model sizes this crate ships).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// One finished request, as the pool reports it.
#[derive(Debug, Clone)]
pub struct PoolCompletion {
    /// Global submission id (monotone from 0 across all shards; the value
    /// [`WorkerPool::submit`] returned).
    pub id: u64,
    /// Shard that served the request (`id % workers` under [`submit`]'s
    /// round-robin; [`try_submit`] may route past a full shard).
    ///
    /// [`submit`]: WorkerPool::submit
    /// [`try_submit`]: WorkerPool::try_submit
    pub shard: usize,
    pub logits: Vec<f32>,
    /// Argmax class of `logits`.
    pub predicted: usize,
    /// Time spent queued in the shard before its batch was flushed.
    pub queue_delay: Duration,
    /// Flush start → this request's engine invocation starting (chunk
    /// wait inside a multi-call flush).
    pub batch_wait: Duration,
    /// Wall-clock duration of the engine invocation this request rode in.
    pub compute: Duration,
    /// Size of the engine invocation this request rode in.
    pub batch_size: usize,
    /// Instant the worker forwarded this completion — the end stamp for
    /// per-request latency (a collector draining later must not charge its
    /// own delay to the request).
    pub completed_at: Instant,
}

struct Job {
    id: u64,
    x: Vec<f32>,
}

/// Observed drain throughput of the whole pool: a monotone completion
/// counter against the pool's start instant. Lives behind an `Arc`
/// shared with every worker (each increments it as it forwards
/// completions), so the front can turn "how fast is this pool actually
/// draining" into an honest `Retry-After` hint for shed requests —
/// live [`BatcherStats`] are worker-private until shutdown, so this
/// counter is the only drain-rate signal observable while serving.
struct DrainMeter {
    started: Instant,
    /// Completions forwarded pool-wide.
    completed: AtomicU64,
}

/// N worker threads sharing one engine, fed round-robin through per-shard
/// batching queues.
pub struct WorkerPool {
    engine: Arc<Engine>,
    shards: Vec<Sender<Job>>,
    workers: Vec<JoinHandle<Result<BatcherStats>>>,
    completions: Receiver<PoolCompletion>,
    stats: PoolStats,
    /// Per-shard in-flight depth (front increments, worker decrements as
    /// it forwards each completion). The admission-control signal.
    depth: Vec<Arc<AtomicUsize>>,
    queue_cap: usize,
    /// Pool-wide drain-rate observation feeding [`Self::retry_after_hint`].
    meter: Arc<DrainMeter>,
}

impl WorkerPool {
    /// Spawn `cfg.workers` threads over `engine`. The engine's weight
    /// cache is preloaded up front so workers never race-decode layers on
    /// the first requests.
    pub fn new(engine: Arc<Engine>, cfg: PoolConfig) -> Result<Self> {
        if cfg.workers == 0 {
            bail!("worker pool needs at least one worker");
        }
        if cfg.batch.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        engine.preload()?;
        let (done_tx, completions) = mpsc::channel();
        let meter = Arc::new(DrainMeter { started: Instant::now(), completed: AtomicU64::new(0) });
        let mut shards = Vec::with_capacity(cfg.workers);
        let mut workers = Vec::with_capacity(cfg.workers);
        let mut depth = Vec::with_capacity(cfg.workers);
        for shard in 0..cfg.workers {
            let (job_tx, job_rx) = mpsc::channel::<Job>();
            let engine = Arc::clone(&engine);
            let done = done_tx.clone();
            let batch = cfg.batch;
            let shard_depth = Arc::new(AtomicUsize::new(0));
            let worker_depth = Arc::clone(&shard_depth);
            let worker_meter = Arc::clone(&meter);
            let handle = std::thread::Builder::new()
                .name(format!("cgmq-serve-{shard}"))
                .spawn(move || {
                    worker_loop(shard, engine, batch, job_rx, done, worker_depth, worker_meter)
                })
                .with_context(|| format!("spawning serve worker {shard}"))?;
            shards.push(job_tx);
            workers.push(handle);
            depth.push(shard_depth);
        }
        let queue_cap = cfg.queue_cap;
        Ok(Self {
            engine,
            shards,
            workers,
            completions,
            stats: PoolStats::default(),
            depth,
            queue_cap,
            meter,
        })
    }

    /// Convenience: load a `.cgmqm` file and serve it pooled.
    pub fn load(path: &std::path::Path, cfg: PoolConfig) -> Result<Self> {
        Self::new(Arc::new(Engine::load(path)?), cfg)
    }

    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Route one request round-robin to its shard; returns the global id
    /// its [`PoolCompletion`] will carry. Non-blocking and **uncapped** —
    /// `queue_cap` is not enforced on this path, but it goes through the
    /// same private `admit` choke point as [`try_submit`], so the depth
    /// counters *and* the [`PoolStats`] submission counters stay coherent
    /// however the pool is fed.
    ///
    /// [`try_submit`]: Self::try_submit
    pub fn submit(&mut self, x: Vec<f32>) -> Result<u64> {
        match self.admit(x, false)? {
            Submission::Accepted { id, .. } => Ok(id),
            Submission::Shed { .. } => bail!("uncapped admission unexpectedly shed a request"),
        }
    }

    /// Admission-controlled submission: route to the round-robin shard, or
    /// — when that shard's in-flight depth is at `queue_cap` — to the next
    /// shard with room; if every shard is full, shed the request instead
    /// of enqueueing it ([`Submission::Shed`]). Input-length validation
    /// failures and a shut-down pool are `Err`, not sheds.
    pub fn try_submit(&mut self, x: Vec<f32>) -> Result<Submission> {
        self.admit(x, true)
    }

    /// The single admission choke point both submission paths go through:
    /// validates, picks the shard, enqueues or sheds, and maintains the
    /// [`PoolStats`] counters — so `submitted == accepted + shed` holds by
    /// construction for any mix of `submit` and `try_submit` calls.
    /// Validation failures and a shut-down pool are `Err` and count as
    /// nothing.
    fn admit(&mut self, x: Vec<f32>, enforce_cap: bool) -> Result<Submission> {
        if x.len() != self.engine.input_len() {
            bail!("request has {} values, model wants {}", x.len(), self.engine.input_len());
        }
        let n = self.shards.len();
        let start = (self.stats.accepted % n as u64) as usize;
        let shard = (0..n).map(|k| (start + k) % n).find(|&s| {
            if !enforce_cap || self.queue_cap == 0 {
                return true;
            }
            // ordering: relaxed — admission is the only incrementer (the
            // pool front takes &mut self), so a stale worker decrement can
            // only make this shed early, never over-admit past the cap.
            self.depth[s].load(Ordering::Relaxed) < self.queue_cap
        });
        match shard {
            Some(shard) => {
                let id = self.stats.accepted;
                // ordering: relaxed — see the cap check above; the job
                // itself rides the channel, which orders the handoff.
                self.depth[shard].fetch_add(1, Ordering::Relaxed);
                if self.shards[shard].send(Job { id, x }).is_err() {
                    // ordering: relaxed — undo on a dead shard; nothing
                    // raced the slot (the send failed).
                    self.depth[shard].fetch_sub(1, Ordering::Relaxed);
                    bail!("serve worker {shard} has shut down");
                }
                self.stats.submitted += 1;
                self.stats.accepted += 1;
                Ok(Submission::Accepted { id, shard })
            }
            None => {
                self.stats.submitted += 1;
                self.stats.shed += 1;
                Ok(Submission::Shed { queue_cap: self.queue_cap })
            }
        }
    }

    /// Requests accepted so far (`submit` + admitted `try_submit` calls);
    /// also the next global id.
    pub fn accepted(&self) -> u64 {
        self.stats.accepted
    }

    /// The pool-level submission counters (see [`PoolStats`]). Readers
    /// such as [`super::router::Router::stats`] fold these into their own
    /// accounting instead of re-counting per call site, so no submission
    /// path can escape the books.
    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Completions that have arrived so far (non-blocking).
    pub fn try_completions(&mut self) -> Vec<PoolCompletion> {
        self.completions.try_iter().collect()
    }

    /// Per-shard queue depths at this instant, sampled from the same
    /// admission counters [`try_submit`](WorkerPool::try_submit) gates
    /// on — the windowed signal plane's queue-depth gauge. Indexed by
    /// shard.
    pub fn queue_depths(&self) -> Vec<u64> {
        // ordering: relaxed — gauge sample of monotonically adjusted
        // counters; staleness only skews the display, never admission.
        self.depth.iter().map(|d| d.load(Ordering::Relaxed) as u64).collect()
    }

    /// `Retry-After` hint (whole seconds) for a shed request: the time
    /// the current in-flight backlog needs to clear at the pool's
    /// *observed* drain rate (completions per second since the pool
    /// started), rounded up and clamped to `[1, 30]`. Before the first
    /// completion lands there is no observed rate, and the sub-second
    /// batching deadlines make 1s the smallest honest fallback.
    pub fn retry_after_hint(&self) -> u64 {
        // ordering: relaxed — monotone, hint-only reads; staleness only
        // skews the advisory delay, never correctness.
        let completed = self.meter.completed.load(Ordering::Relaxed);
        // ordering: relaxed — same hint-only read as the completed counter.
        let in_flight: u64 = self.depth.iter().map(|d| d.load(Ordering::Relaxed) as u64).sum();
        let elapsed = self.meter.started.elapsed().as_secs_f64();
        if completed == 0 || elapsed <= 0.0 {
            return 1;
        }
        let rate = completed as f64 / elapsed;
        ((in_flight as f64 / rate).ceil() as u64).clamp(1, 30)
    }

    /// Close the front, let every worker drain its shard, join them, and
    /// return the still-uncollected completions plus per-shard stats
    /// (indexed by shard). Every submitted request is accounted for:
    /// summed `completed` equals the number of `submit` calls.
    pub fn shutdown(self) -> Result<(Vec<PoolCompletion>, Vec<BatcherStats>)> {
        drop(self.shards); // workers see Disconnected, drain, and exit
        let mut stats = Vec::with_capacity(self.workers.len());
        for (shard, handle) in self.workers.into_iter().enumerate() {
            let s = handle
                .join()
                .map_err(|_| anyhow!("serve worker {shard} panicked"))?
                .with_context(|| format!("serve worker {shard}"))?;
            stats.push(s);
        }
        // All senders are gone; this drains every buffered completion.
        let rest: Vec<PoolCompletion> = self.completions.try_iter().collect();
        Ok((rest, stats))
    }
}

/// One shard: receive jobs, batch them, forward completions. Sleeps on
/// the channel — until the oldest pending request's deadline when the
/// queue is non-empty, indefinitely when it is — so deadline flushes fire
/// on time without spinning.
fn worker_loop(
    shard: usize,
    engine: Arc<Engine>,
    cfg: BatchConfig,
    jobs: Receiver<Job>,
    done: Sender<PoolCompletion>,
    depth: Arc<AtomicUsize>,
    meter: Arc<DrainMeter>,
) -> Result<BatcherStats> {
    let mut batcher = RequestBatcher::new(engine, cfg)?;
    // The batcher's ids are shard-local; submission order is FIFO on both
    // sides, so the front's global ids map positionally.
    let mut global_ids: VecDeque<u64> = VecDeque::new();
    let forward = |comps: Vec<Completion>, ids: &mut VecDeque<u64>| -> Result<()> {
        let completed_at = Instant::now();
        for c in comps {
            let id = ids
                .pop_front()
                .ok_or_else(|| anyhow!("shard {shard}: completion without a pending global id"))?;
            done.send(PoolCompletion {
                id,
                shard,
                logits: c.logits,
                predicted: c.predicted,
                queue_delay: c.queue_delay,
                batch_wait: c.batch_wait,
                compute: c.compute,
                batch_size: c.batch_size,
                completed_at,
            })
            .map_err(|_| anyhow!("completion receiver dropped"))?;
            // Forwarded = no longer in flight: free a slot for admission.
            // ordering: relaxed — the admission side tolerates staleness
            // (sheds early at worst); the completion rides the channel.
            depth.fetch_sub(1, Ordering::Relaxed);
            // ordering: relaxed — drain-rate observation only; feeds the
            // advisory Retry-After hint, nothing synchronizes on it.
            meter.completed.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    };
    loop {
        let job = match batcher.oldest_enqueued() {
            // Idle shard: block until work arrives or the front closes.
            None => match jobs.recv() {
                Ok(j) => Some(j),
                Err(_) => break,
            },
            // Pending requests: sleep only until the oldest one's deadline.
            Some(oldest) => {
                let deadline = oldest + cfg.max_delay;
                match jobs.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
                    Ok(j) => Some(j),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        };
        if let Some(job) = job {
            global_ids.push_back(job.id);
            let comps = batcher.submit_at(job.x, Instant::now())?;
            forward(comps, &mut global_ids)?;
        }
        let comps = batcher.poll_at(Instant::now())?;
        forward(comps, &mut global_ids)?;
    }
    // Front closed: drain whatever is still queued, then report.
    let comps = batcher.flush_at(Instant::now())?;
    forward(comps, &mut global_ids)?;
    debug_assert!(global_ids.is_empty(), "shard {shard} dropped requests");
    Ok(batcher.stats())
}
