//! Sliding-window telemetry: ring-of-epoch-buckets counters and
//! histograms under the cumulative spine of [`super`].
//!
//! The cumulative counters answer "what happened since boot"; a scheduler
//! or cascade router needs "what is happening *now*". Both
//! [`WindowedCounter`] and [`WindowedHistogram`] keep a fixed ring of
//! [`WINDOW_SLOTS`] epoch buckets and rotate **lazily**: there is no
//! background thread — the recorder that first touches a slot whose epoch
//! tag is stale claims it (one compare-exchange) and resets it in place.
//! Rotation is therefore allocation-free and costs O(1) per record
//! (O(`BUCKETS`) stores on the one record per epoch that wins a claim).
//!
//! Time comes exclusively from the caller as a [`Duration`] since the
//! telemetry [`Clock`](super::Clock)'s epoch, so everything here is
//! bit-deterministic under `ManualClock` — the rotation edge cases
//! (jumps larger than the whole window, sub-epoch repeated reads,
//! rotation racing `record`) are pinned by `tests/telemetry.rs`.
//!
//! **Consistency contract.** All cells are relaxed atomics; a reader
//! racing recorders may tear by a few in-flight samples (same caveat as
//! [`Histogram::snapshot`](super::Histogram::snapshot)). One additional
//! documented race is inherent to lazy rotation: a recorder still writing
//! into an epoch that just expired can have its sample either dropped
//! with the dying slot or folded into the fresh one — bounded by the
//! number of in-flight recorders, and impossible under test-sequenced
//! `ManualClock` time, which is what the merge-consistency property test
//! exploits.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::{bucket_index, HistogramSnapshot, BUCKETS, STAGES, STATUS_CODES};

/// Epoch buckets per window ring. With [`DEFAULT_WINDOW_EPOCH`] this makes
/// every windowed series cover the trailing
/// `WINDOW_SLOTS × DEFAULT_WINDOW_EPOCH` = 10 s.
pub const WINDOW_SLOTS: usize = 10;

/// Production epoch length of every windowed series (1 s; the window is
/// [`WINDOW_SLOTS`] of these).
pub const DEFAULT_WINDOW_EPOCH: Duration = Duration::from_secs(1);

/// Sliding-window event counter: a ring of [`WINDOW_SLOTS`] epoch
/// buckets, each tagged with the epoch number it currently holds.
///
/// [`record`](Self::record) adds to the current epoch's bucket (claiming
/// and resetting it first if its tag is stale); [`total`](Self::total)
/// sums every bucket whose tag is still inside the window. A bucket
/// whose epoch expired is simply *excluded* by readers until a future
/// recorder reclaims it — reads never mutate, so an idle series decays
/// to zero without any writer running.
pub struct WindowedCounter {
    epoch_us: u64,
    /// Epoch tag of each slot (slot `i` legitimately holds only epochs
    /// `≡ i (mod WINDOW_SLOTS)`, so a tag outside the trailing window
    /// uniquely identifies a stale slot).
    epochs: [AtomicU64; WINDOW_SLOTS],
    /// Event count per slot (`cgmq analyze` counter-choke: mutated only
    /// in [`record`](Self::record)).
    hits: [AtomicU64; WINDOW_SLOTS],
}

impl WindowedCounter {
    /// A counter over a `WINDOW_SLOTS × epoch` sliding window. A zero
    /// epoch is clamped to 1 µs so epoch arithmetic never divides by 0.
    pub fn new(epoch: Duration) -> Self {
        WindowedCounter {
            epoch_us: (epoch.as_micros() as u64).max(1),
            epochs: std::array::from_fn(|_| AtomicU64::new(0)),
            hits: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Full window span in microseconds.
    pub fn window_us(&self) -> u64 {
        self.epoch_us * WINDOW_SLOTS as u64
    }

    fn epoch_of(&self, now: Duration) -> u64 {
        now.as_micros() as u64 / self.epoch_us
    }

    /// Count `n` events at time `now`. Sole mutation point of the ring
    /// cells (counter-choke enforced).
    pub fn record(&self, now: Duration, n: u64) {
        let e = self.epoch_of(now);
        let i = (e % WINDOW_SLOTS as u64) as usize;
        // ordering: relaxed — epoch tags and cells are independent display
        // counters; nothing is published under them (see module docs for
        // the bounded lazy-rotation race).
        let seen = self.epochs[i].load(Ordering::Relaxed);
        if seen != e {
            let tag = &self.epochs[i];
            // ordering: relaxed — one CAS winner per epoch resets the
            // slot; losers see the new tag and just add. A racing reader
            // at worst sees the old value excluded or the fresh zero.
            if tag.compare_exchange(seen, e, Ordering::Relaxed, Ordering::Relaxed).is_ok() {
                // ordering: relaxed — reset of a slot this thread just
                // claimed; readers key off the epoch tag, not this store.
                self.hits[i].store(0, Ordering::Relaxed);
            }
        }
        // ordering: relaxed — monotonic within-epoch counter, display only.
        self.hits[i].fetch_add(n, Ordering::Relaxed);
    }

    /// Events inside the trailing window at time `now` (buckets whose
    /// epoch tag expired are excluded without being touched).
    pub fn total(&self, now: Duration) -> u64 {
        let cur = self.epoch_of(now);
        let mut sum = 0u64;
        for i in 0..WINDOW_SLOTS {
            // ordering: relaxed — display read; a torn tag/value pair only
            // mis-places a handful of in-flight samples.
            let tag = self.epochs[i].load(Ordering::Relaxed);
            if tag <= cur && cur - tag < WINDOW_SLOTS as u64 {
                // ordering: relaxed — display read of a slot counter.
                sum += self.hits[i].load(Ordering::Relaxed);
            }
        }
        sum
    }

    /// Events per second over the window at time `now` — the arrival-rate
    /// estimator (`total / window`; the current epoch is partial, so the
    /// estimate lags a ramp by at most one epoch).
    pub fn rate_per_sec(&self, now: Duration) -> f64 {
        self.total(now) as f64 * 1e6 / self.window_us() as f64
    }
}

/// One epoch slot of a [`WindowedHistogram`] — the same cell layout as the
/// cumulative [`Histogram`](super::Histogram), reset in place on claim.
struct WindowSlot {
    /// Log₂ buckets (counter-choke: mutated only in `record`).
    cells: [AtomicU64; BUCKETS],
    /// Samples in this slot (counter-choke: mutated only in `record`).
    recorded: AtomicU64,
    /// Sample sum in this slot (counter-choke: mutated only in `record`).
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for WindowSlot {
    fn default() -> Self {
        WindowSlot {
            cells: std::array::from_fn(|_| AtomicU64::new(0)),
            recorded: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl WindowSlot {
    /// In-place reset by the claim winner (stores only; readers key off
    /// the ring's epoch tag).
    fn reset(&self) {
        for c in &self.cells {
            // ordering: relaxed — reset of a slot the caller just claimed.
            c.store(0, Ordering::Relaxed);
        }
        // ordering: relaxed — as above.
        self.recorded.store(0, Ordering::Relaxed);
        // ordering: relaxed — as above.
        self.sum_us.store(0, Ordering::Relaxed);
        // ordering: relaxed — as above.
        self.max_us.store(0, Ordering::Relaxed);
    }

    /// Fold this slot into `acc` (display read).
    fn merge_into(&self, acc: &mut HistogramSnapshot) {
        for (i, c) in self.cells.iter().enumerate() {
            // ordering: relaxed — display read of a monotonic counter.
            acc.counts[i] += c.load(Ordering::Relaxed);
        }
        // ordering: relaxed — display read of a monotonic counter.
        acc.count += self.recorded.load(Ordering::Relaxed);
        // ordering: relaxed — display read of a monotonic counter.
        acc.sum_us += self.sum_us.load(Ordering::Relaxed);
        // ordering: relaxed — display read of a lossy running max.
        acc.max_us = acc.max_us.max(self.max_us.load(Ordering::Relaxed));
    }
}

/// Sliding-window log₂ histogram: the value distribution of the trailing
/// window, with the same bucket geometry (and therefore the same
/// [`quantile_bounds`](HistogramSnapshot::quantile_bounds) bracket
/// guarantee) as the cumulative [`Histogram`](super::Histogram).
///
/// Values are plain `u64`s, not `Duration`s: the stage histograms record
/// microseconds, the confidence-margin histogram records milli-logits —
/// the window layer does not care.
pub struct WindowedHistogram {
    epoch_us: u64,
    epochs: [AtomicU64; WINDOW_SLOTS],
    ring: [WindowSlot; WINDOW_SLOTS],
}

impl WindowedHistogram {
    /// A histogram over a `WINDOW_SLOTS × epoch` sliding window.
    pub fn new(epoch: Duration) -> Self {
        WindowedHistogram {
            epoch_us: (epoch.as_micros() as u64).max(1),
            epochs: std::array::from_fn(|_| AtomicU64::new(0)),
            ring: std::array::from_fn(|_| WindowSlot::default()),
        }
    }

    /// Full window span in microseconds.
    pub fn window_us(&self) -> u64 {
        self.epoch_us * WINDOW_SLOTS as u64
    }

    fn epoch_of(&self, now: Duration) -> u64 {
        now.as_micros() as u64 / self.epoch_us
    }

    /// Record one sample with value `v` at time `now`. Sole mutation
    /// point of the slot counters (counter-choke enforced).
    pub fn record(&self, now: Duration, v: u64) {
        let e = self.epoch_of(now);
        let i = (e % WINDOW_SLOTS as u64) as usize;
        // ordering: relaxed — same lazy-rotation protocol as
        // WindowedCounter::record (see module docs for the bounded race).
        let seen = self.epochs[i].load(Ordering::Relaxed);
        if seen != e {
            let tag = &self.epochs[i];
            // ordering: relaxed — one CAS winner per epoch resets the slot.
            if tag.compare_exchange(seen, e, Ordering::Relaxed, Ordering::Relaxed).is_ok() {
                self.ring[i].reset();
            }
        }
        let slot = &self.ring[i];
        let b = bucket_index(v);
        // ordering: relaxed — independent monotonic counters; readers only
        // snapshot for display.
        slot.cells[b].fetch_add(1, Ordering::Relaxed);
        // ordering: relaxed — same monotonic-counter contract as cells.
        slot.recorded.fetch_add(1, Ordering::Relaxed);
        // ordering: relaxed — same monotonic-counter contract as cells.
        slot.sum_us.fetch_add(v, Ordering::Relaxed);
        // ordering: relaxed — lossy running max, display only.
        slot.max_us.fetch_max(v, Ordering::Relaxed);
    }

    /// Merge every in-window slot into one [`HistogramSnapshot`] at time
    /// `now`. Expired slots are excluded untouched, so an idle window
    /// snapshots as empty (`count == 0`,
    /// [`quantile_bounds`](HistogramSnapshot::quantile_bounds) `None`).
    pub fn snapshot(&self, now: Duration) -> HistogramSnapshot {
        let cur = self.epoch_of(now);
        let mut acc = HistogramSnapshot::default();
        for i in 0..WINDOW_SLOTS {
            // ordering: relaxed — display read of the slot's epoch tag.
            let tag = self.epochs[i].load(Ordering::Relaxed);
            if tag <= cur && cur - tag < WINDOW_SLOTS as u64 {
                self.ring[i].merge_into(&mut acc);
            }
        }
        acc
    }
}

/// One model's windowed signal plane: arrivals, responses by status, the
/// per-stage and whole-request latency distributions, and the top-logit
/// confidence margin distribution (milli-logits) — everything ROADMAP's
/// SLA-aware batching and cascade routing read live.
pub struct ModelWindow {
    /// Keyed infer requests entering admission (req/s estimator).
    pub(super) arrivals: WindowedCounter,
    /// Infer responses by status, index-aligned with
    /// [`STATUS_CODES`](super::STATUS_CODES).
    pub(super) by_status: [WindowedCounter; STATUS_CODES.len()],
    /// Per-stage latency (µs), beside the cumulative stage histograms.
    pub(super) stages: [WindowedHistogram; STAGES],
    /// Whole-request latency (µs; sum of the touched stages) — what the
    /// `/livez` p99 bound is checked against.
    pub(super) total: WindowedHistogram,
    /// Top-logit margin (milli-logits) of 200 replies — the cascade
    /// routing confidence signal.
    pub(super) margin: WindowedHistogram,
}

impl ModelWindow {
    /// A windowed plane with `epoch`-sized buckets everywhere.
    pub fn new(epoch: Duration) -> Self {
        ModelWindow {
            arrivals: WindowedCounter::new(epoch),
            by_status: std::array::from_fn(|_| WindowedCounter::new(epoch)),
            stages: std::array::from_fn(|_| WindowedHistogram::new(epoch)),
            total: WindowedHistogram::new(epoch),
            margin: WindowedHistogram::new(epoch),
        }
    }

    /// Copy the in-window state out at time `now`.
    pub fn snapshot(&self, now: Duration) -> WindowSnapshot {
        WindowSnapshot {
            window_us: self.arrivals.window_us(),
            arrivals: self.arrivals.total(now),
            by_status: std::array::from_fn(|i| self.by_status[i].total(now)),
            stages: std::array::from_fn(|i| self.stages[i].snapshot(now)),
            total: self.total.snapshot(now),
            margin: self.margin.snapshot(now),
        }
    }
}

/// Plain-value copy of a [`ModelWindow`] at one instant. Integer-only so
/// model snapshots stay `Eq`-comparable; rates are derived on demand.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WindowSnapshot {
    /// Window span in microseconds (all series in one snapshot share it).
    pub window_us: u64,
    /// Keyed infer requests that entered admission inside the window.
    pub arrivals: u64,
    /// Infer responses by status inside the window, index-aligned with
    /// [`STATUS_CODES`](super::STATUS_CODES).
    pub by_status: [u64; STATUS_CODES.len()],
    /// Per-stage latency distribution inside the window (µs).
    pub stages: [HistogramSnapshot; STAGES],
    /// Whole-request latency distribution inside the window (µs).
    pub total: HistogramSnapshot,
    /// Top-logit margin distribution inside the window (milli-logits).
    pub margin: HistogramSnapshot,
}

impl Default for WindowSnapshot {
    fn default() -> Self {
        WindowSnapshot {
            window_us: DEFAULT_WINDOW_EPOCH.as_micros() as u64 * WINDOW_SLOTS as u64,
            arrivals: 0,
            by_status: [0; STATUS_CODES.len()],
            stages: [HistogramSnapshot::default(); STAGES],
            total: HistogramSnapshot::default(),
            margin: HistogramSnapshot::default(),
        }
    }
}

impl WindowSnapshot {
    /// Arrival-rate estimate in requests/second over the window.
    pub fn arrival_rate_per_sec(&self) -> f64 {
        self.arrivals as f64 * 1e6 / self.window_us.max(1) as f64
    }

    /// Responses inside the window across every status.
    pub fn responses(&self) -> u64 {
        self.by_status.iter().sum()
    }

    /// In-window count for one status code (0 outside the taxonomy).
    pub fn status_count(&self, code: u16) -> u64 {
        STATUS_CODES
            .iter()
            .position(|&c| c == code)
            .map_or(0, |i| self.by_status[i])
    }

    /// In-window shed fraction: 429s over all responses (0 when idle).
    pub fn shed_rate(&self) -> f64 {
        let total = self.responses();
        if total == 0 {
            0.0
        } else {
            self.status_count(429) as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const E: Duration = Duration::from_micros(1_000); // 1 ms epochs

    #[test]
    fn counter_sums_only_the_trailing_window() {
        let c = WindowedCounter::new(E);
        let mut now = Duration::ZERO;
        c.record(now, 3);
        now += E; // next epoch
        c.record(now, 4);
        assert_eq!(c.total(now), 7);
        // Jump to the last epoch that still sees the first record.
        now = E * (WINDOW_SLOTS as u32 - 1);
        assert_eq!(c.total(now), 7);
        now += E; // first record expires, second survives
        assert_eq!(c.total(now), 4);
    }

    #[test]
    fn slot_reuse_resets_the_stale_bucket() {
        let c = WindowedCounter::new(E);
        c.record(Duration::ZERO, 10);
        // Same slot index, WINDOW_SLOTS epochs later: must not inherit 10.
        let later = E * WINDOW_SLOTS as u32;
        c.record(later, 1);
        assert_eq!(c.total(later), 1);
    }

    #[test]
    fn histogram_window_decays_to_empty() {
        let h = WindowedHistogram::new(E);
        h.record(Duration::ZERO, 500);
        h.record(Duration::ZERO, 2_000);
        let s = h.snapshot(Duration::ZERO);
        assert_eq!(s.count, 2);
        assert_eq!(s.sum_us, 2_500);
        let gone = h.snapshot(E * WINDOW_SLOTS as u32);
        assert_eq!(gone, HistogramSnapshot::default());
        assert_eq!(gone.quantile_bounds(0.99), None);
    }

    #[test]
    fn rate_is_total_over_window_span() {
        let c = WindowedCounter::new(Duration::from_millis(100));
        let now = Duration::from_millis(50);
        c.record(now, 5);
        // 5 events over a 1 s window (10 × 100 ms).
        assert!((c.rate_per_sec(now) - 5.0).abs() < 1e-9);
    }
}
