//! PJRT runtime: load + execute the AOT-compiled HLO-text artifacts.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`. The
//! interchange format is HLO *text* (see `python/compile/aot.py` — jax
//! ≥ 0.5 serialized protos are rejected by xla_extension 0.5.1).
//!
//! `ArtifactSet` is manifest-driven: `artifacts/manifest.json` records the
//! exact input/output order, shapes and dtypes of every artifact, and every
//! `Executable::run` call validates its inputs against that record, so a
//! compile-path/run-path drift fails loudly with tensor names instead of
//! producing garbage.
//!
//! The PJRT dependency is gated behind the `pjrt` cargo feature: without it
//! the crate builds against a stub backend (`backend_stub`) whose client
//! construction fails with an actionable error, so everything that doesn't
//! execute artifacts — unit tests, the cost model, CLI plumbing — builds
//! and runs in environments without the `xla_extension` native library.

#[cfg(not(feature = "pjrt"))]
mod backend_stub;
#[cfg(not(feature = "pjrt"))]
use backend_stub as xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::model::ArchSpec;
use crate::tensor::{Tensor, TensorI32};
use crate::util::json::{self, Json};

/// Input/output tensor spec from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

/// A typed argument for an artifact call.
#[derive(Debug, Clone, Copy)]
pub enum Arg<'a> {
    F32(&'a Tensor),
    I32(&'a TensorI32),
}

impl<'a> Arg<'a> {
    fn shape(&self) -> &[usize] {
        match self {
            Arg::F32(t) => t.shape(),
            Arg::I32(t) => &t.shape,
        }
    }

    fn dtype(&self) -> Dtype {
        match self {
            Arg::F32(_) => Dtype::F32,
            Arg::I32(_) => Dtype::I32,
        }
    }
}

/// Cumulative execution statistics for one artifact (perf reporting).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
}

/// One compiled artifact + its manifest contract.
pub struct Executable {
    pub name: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<String>,
    exe: xla::PjRtLoadedExecutable,
    stats: std::cell::Cell<ExecStats>,
}

impl Executable {
    /// Execute with host tensors; returns output tensors in manifest order.
    ///
    /// The lowered modules return a tuple (aot.py lowers with
    /// `return_tuple=True`), which is decomposed here.
    pub fn run(&self, args: &[Arg]) -> Result<Vec<Tensor>> {
        self.validate(args)?;
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| -> Result<xla::Literal> {
                match a {
                    Arg::F32(t) => {
                        let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                        Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
                    }
                    Arg::I32(t) => {
                        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                        Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
                    }
                }
            })
            .collect::<Result<_>>()?;

        let t0 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
        let mut s = self.stats.get();
        s.calls += 1;
        s.total_secs += t0.elapsed().as_secs_f64();
        self.stats.set(s);

        if tuple.len() != self.outputs.len() {
            bail!(
                "{}: artifact returned {} outputs, manifest says {}",
                self.name,
                tuple.len(),
                self.outputs.len()
            );
        }
        tuple
            .into_iter()
            .enumerate()
            .map(|(i, lit)| {
                let shape = lit.array_shape().with_context(|| {
                    format!("{}: output '{}' shape", self.name, self.outputs[i])
                })?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<f32>().with_context(|| {
                    format!("{}: output '{}' to f32", self.name, self.outputs[i])
                })?;
                Tensor::new(dims, data)
            })
            .collect()
    }

    fn validate(&self, args: &[Arg]) -> Result<()> {
        if args.len() != self.inputs.len() {
            bail!("{}: got {} args, manifest wants {}", self.name, args.len(), self.inputs.len());
        }
        for (arg, spec) in args.iter().zip(&self.inputs) {
            if arg.shape() != spec.shape.as_slice() {
                bail!(
                    "{}: input '{}' shape {:?} != manifest {:?}",
                    self.name,
                    spec.name,
                    arg.shape(),
                    spec.shape
                );
            }
            if arg.dtype() != spec.dtype {
                bail!(
                    "{}: input '{}' dtype {:?} != manifest {:?}",
                    self.name,
                    spec.name,
                    arg.dtype(),
                    spec.dtype
                );
            }
        }
        Ok(())
    }

    pub fn stats(&self) -> ExecStats {
        self.stats.get()
    }
}

/// All compiled artifacts of one run + the parsed manifest.
pub struct ArtifactSet {
    pub dir: PathBuf,
    manifest: Json,
    executables: HashMap<String, Executable>,
    client: xla::PjRtClient,
}

impl ArtifactSet {
    /// Open the artifact directory and start a PJRT CPU client. No
    /// executables are compiled yet — `load` compiles on demand.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        if !manifest_path.exists() {
            bail!(
                "{} not found — run `make artifacts` first (python AOT compile path)",
                manifest_path.display()
            );
        }
        let manifest = json::parse_file(&manifest_path)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { dir: dir.to_path_buf(), manifest, executables: HashMap::new(), client })
    }

    /// Compile one artifact by name (idempotent).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.executables.contains_key(name) {
            let entry = self
                .manifest
                .get("artifacts")?
                .opt(name)
                .with_context(|| format!("artifact '{name}' not in manifest"))?;
            let file = self.dir.join(entry.get("file")?.as_str()?);
            let inputs = entry
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|io| -> Result<IoSpec> {
                    Ok(IoSpec {
                        name: io.get("name")?.as_str()?.to_string(),
                        shape: io.get("shape")?.as_usize_vec()?,
                        dtype: Dtype::parse(io.get("dtype")?.as_str()?)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let outputs = entry
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(|o| Ok(o.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;

            let proto = xla::HloModuleProto::from_text_file(
                file.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("PJRT compile of {name}"))?;
            self.executables.insert(
                name.to_string(),
                Executable {
                    name: name.to_string(),
                    inputs,
                    outputs,
                    exe,
                    stats: std::cell::Cell::new(ExecStats::default()),
                },
            );
        }
        Ok(&self.executables[name])
    }

    pub fn get(&self, name: &str) -> Result<&Executable> {
        self.executables.get(name).with_context(|| format!("artifact '{name}' not loaded"))
    }

    /// Verify that the Rust-side ArchSpec matches the manifest's record of
    /// the Python-side arch (names, kinds, shapes, MACs). Startup guard.
    pub fn verify_arch(&self, arch: &ArchSpec) -> Result<()> {
        let rec = self
            .manifest
            .get("archs")?
            .opt(arch.name)
            .with_context(|| format!("arch '{}' not in manifest", arch.name))?;
        let in_shape = rec.get("input_shape")?.as_usize_vec()?;
        if in_shape != arch.input_shape {
            bail!("{}: input_shape {:?} != manifest {:?}", arch.name, arch.input_shape, in_shape);
        }
        if rec.get("train_batch")?.as_usize()? != arch.train_batch
            || rec.get("eval_batch")?.as_usize()? != arch.eval_batch
        {
            bail!("{}: batch sizes drifted from manifest", arch.name);
        }
        let layers = rec.get("layers")?.as_arr()?;
        if layers.len() != arch.layers.len() {
            bail!("{}: {} layers != manifest {}", arch.name, arch.layers.len(), layers.len());
        }
        for (l, lr) in arch.layers.iter().zip(layers) {
            if lr.get("name")?.as_str()? != l.name {
                bail!("{}: layer name mismatch {}", arch.name, l.name);
            }
            if lr.get("w_shape")?.as_usize_vec()? != l.w_shape
                || lr.get("act_shape")?.as_usize_vec()? != l.act_shape
            {
                bail!("{}: layer {} shape drifted", arch.name, l.name);
            }
            if lr.get("macs")?.as_usize()? as u64 != l.macs() {
                bail!("{}: layer {} MACs drifted", arch.name, l.name);
            }
            if lr.get("quant_act")?.as_bool()? != l.quant_act {
                bail!("{}: layer {} quant_act drifted", arch.name, l.name);
            }
        }
        Ok(())
    }

    /// Per-artifact cumulative execution stats.
    pub fn all_stats(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<_> =
            self.executables.iter().map(|(k, e)| (k.clone(), e.stats())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    pub fn manifest(&self) -> &Json {
        &self.manifest
    }
}

/// Default artifact directory: `$CGMQ_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("CGMQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
