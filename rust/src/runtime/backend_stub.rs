//! Stub PJRT backend, compiled when the `pjrt` feature is off.
//!
//! Mirrors the exact slice of the `xla` crate's API that `runtime::mod`
//! uses, so the whole crate (and its unit tests, CLI plumbing, cost model,
//! data pipeline, ...) builds and tests in environments without the
//! `xla_extension` native library. Every entry point that would touch PJRT
//! fails fast with an actionable error; nothing silently pretends to
//! execute a model. `ArtifactSet::open` calls [`PjRtClient::cpu`] first,
//! so that error is what users of a stub build actually see.

use std::fmt;

/// Error type standing in for `xla::Error` (converts into `anyhow::Error`
/// through the usual `std::error::Error` blanket impl).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "cgmq was built without the `pjrt` feature: the PJRT/XLA runtime is \
                           unavailable. To execute artifacts, add the `xla` dependency to \
                           Cargo.toml (see the commented line under [features]; needs a vendored \
                           xla-rs checkout plus its xla_extension native library), then rebuild \
                           with `cargo build --features pjrt`.";

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE.to_string()))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

pub struct ArrayShape;

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &[]
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
