//! Minimal TOML-subset parser for the config system.
//!
//! Supports the subset the `configs/*.toml` files use: `[section]` and
//! `[section.sub]` headers, `key = value` with string / bool / integer /
//! float / homogeneous-array values, `#` comments. Values land in a flat
//! `section.key -> Value` map that `config::Config` consumes. Unknown keys
//! are preserved so the config layer can reject typos explicitly.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            _ => bail!("expected integer, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            _ => bail!("expected float, got {self:?}"),
        }
    }

    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        match self {
            Value::Arr(v) => v.iter().map(|x| x.as_f64()).collect(),
            _ => bail!("expected array, got {self:?}"),
        }
    }
}

/// Flat `section.key -> Value` document.
#[derive(Debug, Default, Clone)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }
}

pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .with_context(|| format!("line {}: malformed section header", lineno + 1))?
                .trim();
            if name.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let val = parse_value(line[eq + 1..].trim())
            .with_context(|| format!("line {}: bad value", lineno + 1))?;
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        if doc.entries.insert(full.clone(), val).is_some() {
            bail!("line {}: duplicate key '{full}'", lineno + 1);
        }
    }
    Ok(doc)
}

pub fn parse_file(path: &std::path::Path) -> Result<Doc> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

/// Strip a trailing `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').context("unterminated string")?;
        if inner.contains('"') {
            bail!("embedded quote in string");
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').context("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    // TOML floats always contain '.' or an exponent; else integer.
    if s.contains('.') || s.contains('e') || s.contains('E') {
        if let Ok(f) = s.parse::<f64>() {
            return Ok(Value::Float(f));
        }
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    bail!("cannot parse value '{s}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
# comment
top = 1
[train]
epochs = 250           # paper schedule
lr = 0.001
name = "lenet5"
verbose = true
bounds = [0.4, 0.9, 1.4]
[train.gates]
init = 5.5
"#,
        )
        .unwrap();
        assert_eq!(doc.get("top").unwrap().as_i64().unwrap(), 1);
        assert_eq!(doc.get("train.epochs").unwrap().as_i64().unwrap(), 250);
        assert_eq!(doc.get("train.lr").unwrap().as_f64().unwrap(), 0.001);
        assert_eq!(doc.get("train.name").unwrap().as_str().unwrap(), "lenet5");
        assert!(doc.get("train.verbose").unwrap().as_bool().unwrap());
        assert_eq!(
            doc.get("train.bounds").unwrap().as_f64_vec().unwrap(),
            vec![0.4, 0.9, 1.4]
        );
        assert_eq!(doc.get("train.gates.init").unwrap().as_f64().unwrap(), 5.5);
    }

    #[test]
    fn int_vs_float() {
        let doc = parse("a = 3\nb = 3.0\n").unwrap();
        assert!(matches!(doc.get("a").unwrap(), Value::Int(3)));
        assert!(matches!(doc.get("b").unwrap(), Value::Float(_)));
        // ints coerce to f64 on demand
        assert_eq!(doc.get("a").unwrap().as_f64().unwrap(), 3.0);
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(parse("a = 1\na = 2\n").is_err());
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("novalue\n").is_err());
        assert!(parse("x = \"unterminated\n").is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = parse("x = \"a # b\"\n").unwrap();
        assert_eq!(doc.get("x").unwrap().as_str().unwrap(), "a # b");
    }
}
