//! SplitMix64 — the crate's deterministic RNG.
//!
//! Bit-exact mirror of `python/compile/data_synth.py::SplitMix64`; the
//! cross-language goldens in `artifacts/goldens.json` pin the stream. Used
//! by the data pipeline, weight init, shufflers and the property-test
//! helpers, so every run of the system is reproducible from a single seed.

/// SplitMix64 PRNG (Steele et al., "Fast splittable pseudorandom number
/// generators", OOPSLA 2014). Tiny state, passes BigCrush, splittable.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        mix(self.state)
    }

    /// Uniform in [0, 1): top 53 bits (identical to the Python mirror).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller (cosine branch) — same call order as
    /// the Python mirror so noise streams match.
    #[inline]
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Per-sample stream seed: mirrors `data_synth.sample_seed` exactly.
#[inline]
pub fn sample_seed(seed: u64, index: u64) -> u64 {
    let s = seed ^ (index.wrapping_add(1)).wrapping_mul(0xD1B5_4A32_D192_ED03);
    mix(s.wrapping_add(0x9E37_79B9_7F4A_7C15))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_vector_matches_python() {
        // Pinned in python/tests/test_data.py::test_splitmix64_reference_vector
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(42);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = SplitMix64::new(7);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gauss();
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_seed_decorrelates_indices() {
        let a = sample_seed(1, 0);
        let b = sample_seed(1, 1);
        assert_ne!(a, b);
        assert_ne!(sample_seed(1, 0), sample_seed(2, 0));
    }
}
