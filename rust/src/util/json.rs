//! Minimal JSON parser + serializer.
//!
//! The offline build environment vendors no serde facade, so the crate
//! carries its own RFC 8259 subset implementation. It parses the artifact
//! `manifest.json`, the cross-language `goldens.json` and checkpoint
//! metadata, and serializes metrics/result records. Numbers are f64 (ample
//! for shapes, stats and test vectors).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value. Objects use BTreeMap for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------------- access
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Array of numbers -> Vec<f32> (goldens test vectors, shapes).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ------------------------------------------------------------ construct
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    // ------------------------------------------------------------ serialize
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Serialization goes through `Display`, so both `json.to_string()` and
/// `format!`/`println!` interpolation produce the compact wire form.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser (recursive descent over bytes)
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing data at byte {}", p.i);
    }
    Ok(v)
}

/// Parse a JSON file from disk.
pub fn parse_file(path: &std::path::Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.b[self.i] as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number '{s}'"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = parse(t).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x\ny"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn numbers_scientific() {
        assert_eq!(parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(parse("-2.5E-2").unwrap().as_f64().unwrap(), -0.025);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse("\"\\u0041\\u00e9\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo → ünïcode\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → ünïcode");
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integral_floats_serialize_as_ints() {
        assert_eq!(Json::Num(128.0).to_string(), "128");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn object_access_helpers() {
        let v = parse(r#"{"shape": [128, 784], "name": "x", "ok": true}"#).unwrap();
        assert_eq!(v.get("shape").unwrap().as_usize_vec().unwrap(), vec![128, 784]);
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "x");
        assert!(v.get("ok").unwrap().as_bool().unwrap());
        assert!(v.get("missing").is_err());
    }
}
