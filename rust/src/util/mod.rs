//! Substrate utilities built from scratch for the offline environment:
//! a JSON parser/serializer (manifest, goldens, metrics), a TOML-subset
//! parser (config files), and the deterministic RNG shared bit-for-bit
//! with the Python data generator.

pub mod json;
pub mod rng;
pub mod toml;
