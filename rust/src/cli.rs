//! Tiny CLI argument parser (the offline environment vendors no clap).
//!
//! Grammar: `cgmq <command> [--flag value]... [--switch]...`. Flags may be
//! given as `--flag value` or `--flag=value`. Unknown flags are rejected by
//! the command handlers via `finish()`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut it = argv.iter().peekable();
        let command = it.next().cloned().unwrap_or_default();
        let mut flags = BTreeMap::new();
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected positional argument '{a}'");
            };
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                flags.insert(name.to_string(), it.next().unwrap().clone());
            } else {
                flags.insert(name.to_string(), "true".to_string()); // boolean switch
            }
        }
        Ok(Self { command, flags, consumed: Default::default() })
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(name.to_string());
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| anyhow::anyhow!("--{name}: bad number '{v}'"))?)),
        }
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| anyhow::anyhow!("--{name}: bad integer '{v}'"))?)),
        }
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Reject any flag no handler asked about (typo guard).
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        for k in self.flags.keys() {
            if !consumed.contains(k) {
                bail!("unknown flag --{k} for command '{}'", self.command);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = Args::parse(&argv(&["train", "--arch", "mlp", "--bound=0.9", "--quick"])).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("arch"), Some("mlp"));
        assert_eq!(a.get_f64("bound").unwrap(), Some(0.9));
        assert!(a.get_bool("quick"));
        assert_eq!(a.get("missing"), None);
        a.finish().unwrap();
    }

    #[test]
    fn rejects_unconsumed() {
        let a = Args::parse(&argv(&["train", "--tpyo", "1"])).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&argv(&["train", "stray"])).is_err());
    }

    #[test]
    fn bad_number_reported() {
        let a = Args::parse(&argv(&["x", "--bound", "abc"])).unwrap();
        assert!(a.get_f64("bound").is_err());
    }
}
