//! Tiny CLI argument parser (the offline environment vendors no clap).
//!
//! Grammar: `cgmq <command> [--flag value]... [--switch]...`. Flags may be
//! given as `--flag value` or `--flag=value`; a flag given twice is a hard
//! parse error (silent last-wins hides typos in long invocations). Values
//! starting with a single dash (negative numbers) are accepted. Unknown
//! flags are rejected by the command handlers via `finish()`, which lists
//! *every* unconsumed flag at once instead of failing on the first.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut it = argv.iter().peekable();
        let command = it.next().cloned().unwrap_or_default();
        let mut flags = BTreeMap::new();
        let mut insert = |k: &str, v: String| -> Result<()> {
            if flags.insert(k.to_string(), v).is_some() {
                bail!("duplicate flag --{k}");
            }
            Ok(())
        };
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected positional argument '{a}'");
            };
            if name.is_empty() {
                bail!("empty flag name '--'");
            }
            if let Some((k, v)) = name.split_once('=') {
                insert(k, v.to_string())?;
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                insert(name, it.next().unwrap().clone())?;
            } else {
                insert(name, "true".to_string())?; // boolean switch
            }
        }
        Ok(Self { command, flags, consumed: Default::default() })
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(name.to_string());
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| anyhow::anyhow!("--{name}: bad number '{v}'"))?)),
        }
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| anyhow::anyhow!("--{name}: bad integer '{v}'"))?)),
        }
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Reject every flag no handler asked about (typo guard), listing all
    /// of them at once.
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<String> = self
            .flags
            .keys()
            .filter(|k| !consumed.contains(k.as_str()))
            .map(|k| format!("--{k}"))
            .collect();
        if !unknown.is_empty() {
            bail!("unknown flags for command '{}': {}", self.command, unknown.join(", "));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = Args::parse(&argv(&["train", "--arch", "mlp", "--bound=0.9", "--quick"])).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("arch"), Some("mlp"));
        assert_eq!(a.get_f64("bound").unwrap(), Some(0.9));
        assert!(a.get_bool("quick"));
        assert_eq!(a.get("missing"), None);
        a.finish().unwrap();
    }

    #[test]
    fn equals_form_matches_space_form() {
        let a = Args::parse(&argv(&["x", "--seed=7"])).unwrap();
        let b = Args::parse(&argv(&["x", "--seed", "7"])).unwrap();
        assert_eq!(a.get_usize("seed").unwrap(), Some(7));
        assert_eq!(b.get_usize("seed").unwrap(), Some(7));
    }

    #[test]
    fn boolean_switches() {
        // trailing switch, switch followed by another flag, explicit value
        let a = Args::parse(&argv(&["x", "--verbose", "--arch", "mlp", "--force"])).unwrap();
        assert!(a.get_bool("verbose"));
        assert!(a.get_bool("force"));
        assert_eq!(a.get("arch"), Some("mlp"));
        let b = Args::parse(&argv(&["x", "--flag=yes"])).unwrap();
        assert!(b.get_bool("flag"));
        let c = Args::parse(&argv(&["x", "--flag=no"])).unwrap();
        assert!(!c.get_bool("flag"));
    }

    #[test]
    fn negative_number_values() {
        // A value starting with a single '-' is a value, not a flag.
        let a = Args::parse(&argv(&["x", "--bound", "-0.5", "--offset=-3.25"])).unwrap();
        assert_eq!(a.get_f64("bound").unwrap(), Some(-0.5));
        assert_eq!(a.get_f64("offset").unwrap(), Some(-3.25));
        a.finish().unwrap();
    }

    #[test]
    fn duplicate_flags_rejected() {
        for bad in [
            &["x", "--seed", "1", "--seed", "2"][..],
            &["x", "--seed=1", "--seed=2"][..],
            &["x", "--seed", "1", "--seed=2"][..],
            &["x", "--quick", "--quick"][..],
        ] {
            let err = Args::parse(&argv(bad)).unwrap_err().to_string();
            assert!(err.contains("duplicate flag"), "{bad:?}: {err}");
        }
    }

    #[test]
    fn rejects_unconsumed() {
        let a = Args::parse(&argv(&["train", "--tpyo", "1"])).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn finish_lists_all_unconsumed_flags() {
        let a =
            Args::parse(&argv(&["train", "--tpyo", "1", "--arch", "mlp", "--wrnog=2"])).unwrap();
        let _ = a.get("arch"); // consumed; must not be reported
        let err = a.finish().unwrap_err().to_string();
        assert!(err.contains("--tpyo"), "{err}");
        assert!(err.contains("--wrnog"), "{err}");
        assert!(!err.contains("--arch"), "{err}");
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&argv(&["train", "stray"])).is_err());
    }

    #[test]
    fn rejects_empty_flag_name() {
        assert!(Args::parse(&argv(&["train", "--"])).is_err());
    }

    #[test]
    fn bad_number_reported() {
        let a = Args::parse(&argv(&["x", "--bound", "abc"])).unwrap();
        assert!(a.get_f64("bound").is_err());
    }
}
