//! Benchmark harness: regenerates every table of the paper's evaluation
//! (Tables 1-3), the constraint-satisfaction trace (G1), the granularity
//! ablation (A1) and the penalty-tuning comparison (A2), printing rows in
//! the paper's format and writing machine-readable JSON next to them.
//!
//! Every row is a [`SessionBuilder`] pipeline. The float pretraining
//! (phase-1 input state) is shared across all rows of a table through a
//! cached checkpoint — exactly how the paper runs it ("all different
//! choices of CGMQ start with the same pre-trained model") — so a row is
//! `[LoadCheckpoint, Calibrate, RangeLearn, CgmqLoop]`, with extra
//! `CgmqLoop` stages appended ad hoc when a short CI schedule needs a
//! longer horizon to reach the bound. Each row also streams its per-epoch
//! trajectory as JSONL (`<run_id>.epochs.jsonl` in `out_dir`) via
//! [`JsonlMetricsObserver`], so table JSON and epoch trajectories can be
//! scraped without parsing stdout.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::baselines::{bb_proxy, penalty};
use crate::config::Config;
use crate::direction::DirKind;
use crate::gates::Granularity;
use crate::session::{
    Calibrate, CgmqLoop, JsonlMetricsObserver, LoadCheckpoint, Pretrain, RangeLearn, RunResult,
    Session, SessionBuilder,
};
use crate::util::json::Json;

pub const PAPER_BOUNDS: [f64; 5] = [0.40, 0.90, 1.40, 2.00, 5.00];
pub const DIRS: [DirKind; 3] = [DirKind::Dir1, DirKind::Dir2, DirKind::Dir3];

/// Ensure a float-pretrained checkpoint exists for this config; returns its
/// path. All table rows resume from it.
pub fn ensure_pretrained(cfg: &Config) -> Result<PathBuf> {
    let path = Path::new(&cfg.out_dir)
        .join(format!("pretrained-{}-s{}-n{}.ckpt", cfg.arch, cfg.seed, cfg.train_size));
    if path.exists() {
        return Ok(path);
    }
    eprintln!(
        "[bench] pretraining {} for {} epochs (cached at {}) ...",
        cfg.arch,
        cfg.pretrain_epochs,
        path.display()
    );
    let mut session = SessionBuilder::new(cfg.clone()).stage(Pretrain::default()).build()?;
    session.run()?;
    session.ctx.save_params(&path)?;
    Ok(path)
}

/// Open a session resumed from the shared pretrained checkpoint, with
/// calibration + range learning queued (the phase-3 input state every
/// baseline and CGMQ row starts from). Skips the float-accuracy pass —
/// baseline drivers report quantized accuracy only.
pub fn resumed_session(cfg: &Config, ckpt: &Path) -> Result<Session> {
    let mut session = SessionBuilder::new(cfg.clone())
        .stage(LoadCheckpoint::new(ckpt).skip_float_eval())
        .stage(Calibrate)
        .stage(RangeLearn::default())
        .build()?;
    session.run()?;
    Ok(session)
}

/// Run one CGMQ row from the shared pretrained checkpoint.
pub fn run_row(base: &Config, dir: DirKind, gran: Granularity, bound: f64) -> Result<RunResult> {
    let mut cfg = base.clone();
    cfg.direction = dir;
    cfg.granularity = gran;
    cfg.bound_rbop_percent = bound;
    cfg.lr_gates = Config::paper_gate_lr(dir) * base.gate_lr_scale;
    cfg.validate()?;
    let ckpt = ensure_pretrained(base)?;
    let jsonl_path = Path::new(&cfg.out_dir).join(format!("{}.epochs.jsonl", cfg.run_id()));
    let mut session = SessionBuilder::new(cfg.clone())
        .stage(LoadCheckpoint::new(&ckpt))
        .stage(Calibrate)
        .stage(RangeLearn::default())
        .stage(CgmqLoop::default())
        .observer(JsonlMetricsObserver::create(&jsonl_path)?)
        .build()?;
    session.run()?;
    // The paper's guarantee is "satisfied after sufficiently many
    // iterations" (§3); dir2/dir3's descent speed scales with 1/(lr_g *
    // steps), so short CI schedules may need extra epochs at tight bounds.
    // Extend in chunks (capped at 8x) until a satisfying model exists.
    let mut extra = 0;
    while session.final_model().is_err() && extra < 8 * cfg.cgmq_epochs {
        session.run_stage(CgmqLoop::epochs(cfg.cgmq_epochs.max(1)))?;
        extra += cfg.cgmq_epochs.max(1);
    }
    if extra > 0 {
        eprintln!("[bench]   (extended {} by {extra} epochs to reach the bound)", cfg.run_id());
    }
    // If even the extended horizon did not reach the bound (a slow dir on a
    // CI schedule), report the row honestly as unsatisfied instead of
    // aborting the table; the paper-scale schedule always converges
    // (property-tested guarantee in tests/trainer_invariants.rs).
    let r = match session.result() {
        Ok(r) => r,
        Err(_) => {
            let float_acc =
                session.ctx.float_acc.context("LoadCheckpoint records float accuracy")?;
            let last = session.metrics().last().expect("at least one epoch ran").clone();
            RunResult {
                run_id: cfg.run_id(),
                float_acc,
                quant_acc: last.test_acc,
                rbop_percent: last.rbop_percent,
                bound_rbop_percent: cfg.bound_rbop_percent,
                satisfied: false,
                mean_weight_bits: last.mean_weight_bits,
                rbop_trace: session.ctx.rbop_trace.clone(),
            }
        }
    };
    eprintln!(
        "[bench] {}: acc {:.2}% rbop {:.3}% (bound {:.2}%) sat={}",
        r.run_id,
        100.0 * r.quant_acc,
        r.rbop_percent,
        r.bound_rbop_percent,
        r.satisfied
    );
    Ok(r)
}

fn write_json(path: &Path, v: &Json) -> Result<()> {
    if let Some(d) = path.parent() {
        std::fs::create_dir_all(d)?;
    }
    std::fs::write(path, v.to_string()).with_context(|| format!("writing {}", path.display()))
}

// ---------------------------------------------------------------------------
// Table 1 — method comparison at bound 0.40%
// ---------------------------------------------------------------------------

pub fn table1(base: &Config) -> Result<String> {
    let ckpt = ensure_pretrained(base)?;
    // FP32 row
    let mut session = SessionBuilder::new(base.clone()).stage(LoadCheckpoint::new(&ckpt)).build()?;
    session.run()?;
    let fp32_acc = session.ctx.float_acc.context("LoadCheckpoint records float accuracy")?;
    drop(session);

    let mut rows: Vec<Json> = Vec::new();
    let mut out = String::new();
    out.push_str(&format!("Table 1: Results on {} ({}).\n", base.arch, data_label(base)));
    out.push_str(
        "| Method | Hyperpar.       | Acc (%) | Rel. GBOPs (%) | Bound rel. GBOPs (%) |\n",
    );
    out.push_str(
        "|--------|-----------------|---------|----------------|----------------------|\n",
    );
    out.push_str(&format!(
        "| FP32   | -               | {:6.2}  | 100            | 100                  |\n",
        100.0 * fp32_acc
    ));
    out.push_str(&format!(
        "| BB*    | mu = 0.01       | {:.2} ± {:.2} | {:.2} ± {:.2} | -          |\n",
        bb_proxy::BB_PAPER_ACC,
        bb_proxy::BB_PAPER_ACC_STD,
        bb_proxy::BB_PAPER_RBOP,
        bb_proxy::BB_PAPER_RBOP_STD,
    ));
    rows.push(Json::obj(vec![
        ("method", Json::str("fp32")),
        ("acc", Json::num(100.0 * fp32_acc)),
        ("rbop", Json::num(100.0)),
    ]));

    let bound = 0.40;
    for gran in [Granularity::Layer, Granularity::Individual] {
        for dir in DIRS {
            let r = run_row(base, dir, gran, bound)?;
            out.push_str(&format!(
                "| CGMQ   | {}, {:<6} | {:6.2}  | {:14.2} | {:20.2} |\n",
                dir.label(),
                gran.label(),
                100.0 * r.quant_acc,
                r.rbop_percent,
                bound
            ));
            rows.push(result_json("cgmq", &r));
        }
    }
    out.push_str("(* BB row quotes van Baalen et al. 2020, pruning active.)\n");
    write_json(&Path::new(&base.out_dir).join("table1.json"), &Json::Arr(rows))?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Tables 2 & 3 — bound sweeps (layer / individual granularity)
// ---------------------------------------------------------------------------

pub fn table_sweep(base: &Config, gran: Granularity) -> Result<String> {
    let table_no = match gran {
        Granularity::Layer => 2,
        Granularity::Individual => 3,
    };
    let mut rows: Vec<Json> = Vec::new();
    let mut out = String::new();
    out.push_str(&format!(
        "Table {}: Acc (%) and RGBOP (%) vs bound (BGBOP), {} gates, {} ({}).\n",
        table_no,
        gran.label(),
        base.arch,
        data_label(base)
    ));
    out.push_str("| BGBOP (%) | dir1 Acc | dir1 RGBOP | dir2 Acc | dir2 RGBOP | dir3 Acc | dir3 RGBOP |\n");
    out.push_str("|-----------|----------|------------|----------|------------|----------|------------|\n");
    for bound in PAPER_BOUNDS {
        let mut cells = Vec::new();
        for dir in DIRS {
            let r = run_row(base, dir, gran, bound)?;
            cells.push(format!("{:8.2} | {:10.2}", 100.0 * r.quant_acc, r.rbop_percent));
            rows.push(result_json("cgmq", &r));
        }
        out.push_str(&format!("| {:9.2} | {} |\n", bound, cells.join(" | ")));
    }
    write_json(&Path::new(&base.out_dir).join(format!("table{table_no}.json")), &Json::Arr(rows))?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// A2 — penalty method needs tuning, CGMQ doesn't
// ---------------------------------------------------------------------------

pub fn penalty_comparison(base: &Config, lambdas: &[f32]) -> Result<String> {
    let ckpt = ensure_pretrained(base)?;
    let mut out = String::new();
    out.push_str(&format!(
        "A2: penalty method (DQ-style) vs CGMQ at bound {:.2}% ({}, {} epochs).\n",
        base.bound_rbop_percent, base.arch, base.cgmq_epochs
    ));
    out.push_str("| method        | lambda | Acc (%) | RGBOP (%) | satisfied |\n");
    out.push_str("|---------------|--------|---------|-----------|-----------|\n");
    let mut rows = Vec::new();
    for &lambda in lambdas {
        let jsonl_path =
            Path::new(&base.out_dir).join(format!("a2-penalty-l{lambda}.epochs.jsonl"));
        let mut session = SessionBuilder::new(base.clone())
            .stage(LoadCheckpoint::new(&ckpt).skip_float_eval())
            .stage(Calibrate)
            .stage(RangeLearn::default())
            .stage(penalty::PenaltyStage::new(lambda))
            .observer(JsonlMetricsObserver::create(&jsonl_path)?)
            .build()?;
        session.run()?;
        let r = penalty::result(&session.ctx, lambda)?;
        out.push_str(&format!(
            "| penalty       | {:6} | {:7.2} | {:9.2} | {:9} |\n",
            lambda,
            100.0 * r.test_acc,
            r.rbop_percent,
            r.satisfied
        ));
        rows.push(Json::obj(vec![
            ("method", Json::str("penalty")),
            ("lambda", Json::num(lambda as f64)),
            ("acc", Json::num(100.0 * r.test_acc)),
            ("rbop", Json::num(r.rbop_percent)),
            ("satisfied", Json::Bool(r.satisfied)),
        ]));
    }
    // CGMQ reference row — no hyperparameter, guaranteed satisfaction.
    let r = run_row(base, base.direction, base.granularity, base.bound_rbop_percent)?;
    out.push_str(&format!(
        "| CGMQ ({})   | {:6} | {:7.2} | {:9.2} | {:9} |\n",
        base.direction.label(),
        "-",
        100.0 * r.quant_acc,
        r.rbop_percent,
        r.satisfied
    ));
    rows.push(result_json("cgmq", &r));
    write_json(&Path::new(&base.out_dir).join("a2_penalty.json"), &Json::Arr(rows))?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Deploy rows — packed-model size + engine throughput (no artifacts needed)
// ---------------------------------------------------------------------------

/// Latency percentiles (ms) of a sorted-or-not set of per-request
/// durations in seconds.
///
/// Ceil-based nearest rank: index `ceil((len - 1) * p)`, so a tail
/// percentile never rounds *down* onto a faster request — p99 of 100
/// requests reads the slowest sample (index 99), where the previous
/// `round()` rule read index 98 and under-reported tail latency.
pub fn percentiles_ms(durs: &mut [f64]) -> (f64, f64, f64) {
    durs.sort_by(f64::total_cmp);
    let pick = |p: f64| durs[((durs.len() - 1) as f64 * p).ceil() as usize] * 1e3;
    (pick(0.50), pick(0.90), pick(0.99))
}

/// Measure one packed model: the naive single-request path (streaming
/// decode per call) vs the batched serve path ([`RequestBatcher`] over an
/// unpack-once engine) vs the sharded worker pool at 1 and `workers`
/// workers. Returns the `serve-bench` JSON report.
pub fn serve_bench(
    model_path: &Path,
    requests: usize,
    batch: usize,
    deadline: std::time::Duration,
    workers: usize,
    seed: u64,
) -> Result<Json> {
    use crate::deploy::{BatchConfig, DecodeMode, Engine, RequestBatcher};
    let single = Engine::load(model_path)?.with_mode(DecodeMode::Streaming);
    let bcfg = BatchConfig { max_batch: batch, max_delay: deadline };
    let batcher = RequestBatcher::new(Engine::load(model_path)?, bcfg)?;
    let mut report = serve_bench_engines(single, batcher, requests, seed)?;
    let shared = std::sync::Arc::new(Engine::load(model_path)?);
    let pooled = pool_comparison(shared, requests, workers, bcfg, seed)?;
    if let Json::Obj(m) = &mut report {
        m.insert("model".into(), Json::str(model_path.display().to_string()));
        m.insert("pool".into(), pooled);
    }
    Ok(report)
}

/// The 1-vs-N-worker pool row: same engine, same shard batching policy,
/// only the worker count differs. `speedup` is N-worker throughput over
/// 1-worker throughput.
pub fn pool_comparison(
    engine: std::sync::Arc<crate::deploy::Engine>,
    requests: usize,
    workers: usize,
    batch: crate::deploy::BatchConfig,
    seed: u64,
) -> Result<Json> {
    let one = pool_bench_engine(&engine, requests, 1, batch, seed)?;
    let n = if workers > 1 {
        pool_bench_engine(&engine, requests, workers, batch, seed)?
    } else {
        one.clone()
    };
    let rps1 = one.get("throughput_rps")?.as_f64()?;
    let rps_n = n.get("throughput_rps")?.as_f64()?;
    Ok(Json::obj(vec![
        ("workers", Json::num(workers as f64)),
        ("one_worker", one),
        ("n_workers", n),
        ("speedup", Json::num(rps_n / rps1)),
    ]))
}

/// Drive `requests` synthetic requests through a [`WorkerPool`] of
/// `workers` shards over the shared `engine`; returns throughput +
/// latency percentiles + merged shard stats as JSON.
pub fn pool_bench_engine(
    engine: &std::sync::Arc<crate::deploy::Engine>,
    requests: usize,
    workers: usize,
    batch: crate::deploy::BatchConfig,
    seed: u64,
) -> Result<Json> {
    use std::time::Instant;

    use crate::deploy::{BatcherStats, PoolConfig, WorkerPool};
    if requests == 0 {
        anyhow::bail!("pool bench needs at least one request");
    }
    let in_len = engine.input_len();
    let ds = crate::data::Dataset::synth(seed, requests);
    if ds.sample_len != in_len {
        anyhow::bail!("synth samples have {} values, model wants {in_len}", ds.sample_len);
    }
    let pool_cfg = PoolConfig { workers, batch, queue_cap: 0 };
    let mut pool = WorkerPool::new(std::sync::Arc::clone(engine), pool_cfg)?;
    let t0 = Instant::now();
    let mut submitted_at: Vec<Instant> = Vec::with_capacity(requests);
    let mut lat = vec![0.0f64; requests];
    let mut done = 0usize;
    // Latency is stamped by the *worker* at forward time
    // (`PoolCompletion::completed_at`), not by this collector loop —
    // completions drained late (especially after shutdown) must not have
    // the collector's own delay or thread-join time charged to them.
    for i in 0..requests {
        submitted_at.push(Instant::now());
        pool.submit(ds.images[i * in_len..(i + 1) * in_len].to_vec())?;
        for c in pool.try_completions() {
            let served = c.completed_at.duration_since(submitted_at[c.id as usize]);
            lat[c.id as usize] = served.as_secs_f64();
            done += 1;
        }
    }
    let (rest, shard_stats) = pool.shutdown()?;
    for c in rest {
        let served = c.completed_at.duration_since(submitted_at[c.id as usize]);
        lat[c.id as usize] = served.as_secs_f64();
        done += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    if done != requests {
        anyhow::bail!("pool completed {done} of {requests} requests");
    }
    let mut stats = BatcherStats::default();
    for (shard, s) in shard_stats.iter().enumerate() {
        if !s.consistent() {
            anyhow::bail!("shard {shard} batcher stats violate the flush invariant: {s:?}");
        }
        stats.merge(s);
    }
    let (p50, p90, p99) = percentiles_ms(&mut lat);
    Ok(Json::obj(vec![
        ("workers", Json::num(workers as f64)),
        ("throughput_rps", Json::num(requests as f64 / wall)),
        ("p50_ms", Json::num(p50)),
        ("p90_ms", Json::num(p90)),
        ("p99_ms", Json::num(p99)),
        ("flushes", Json::num(stats.flushes as f64)),
        ("engine_calls", Json::num(stats.engine_calls as f64)),
        ("mean_batch", Json::num(stats.mean_batch())),
        ("queue_wait_mean_us", Json::num(mean_wait_us(&stats))),
        ("queue_wait_max_us", Json::num(stats.queue_wait_max_us() as f64)),
    ]))
}

/// Mean enqueue-to-flush wait per completed request (µs).
fn mean_wait_us(stats: &crate::deploy::BatcherStats) -> f64 {
    if stats.completed == 0 {
        0.0
    } else {
        stats.queue_wait_us() as f64 / stats.completed as f64
    }
}

/// One model behind the router in a [`router_bench`] run.
pub struct RouterBenchSpec {
    /// Model key requests are routed by.
    pub key: String,
    /// Engine serving the key at the start of the run.
    pub engine: std::sync::Arc<crate::deploy::Engine>,
    /// Engine to hot-swap behind the key at the halfway mark (exercises
    /// load-new → swap → drain-old mid-traffic); `None` = no swap.
    pub swap_to: Option<std::sync::Arc<crate::deploy::Engine>>,
}

/// Drive `requests` synthetic requests round-robin across the models of a
/// [`Router`](crate::deploy::Router) built from `specs` (all pools use
/// `pool`, including its `queue_cap` admission bound), hot-swapping any
/// model with a `swap_to` engine at the halfway mark. Returns aggregate
/// and per-model throughput, shed counts/rates, swap counts and latency
/// percentiles as JSON, and bails if any per-model accounting invariant
/// (`submitted == accepted + shed`, `completed == accepted` after drain)
/// is violated.
pub fn router_bench(
    specs: &[RouterBenchSpec],
    requests: usize,
    pool: crate::deploy::PoolConfig,
    seed: u64,
) -> Result<Json> {
    use std::collections::BTreeMap;
    use std::sync::Arc;
    use std::time::Instant;

    use crate::deploy::{Router, Submission};
    if specs.is_empty() {
        anyhow::bail!("router bench needs at least one model");
    }
    if requests == 0 {
        anyhow::bail!("router bench needs at least one request");
    }
    let in_len = specs[0].engine.input_len();
    for s in &specs[1..] {
        if s.engine.input_len() != in_len {
            anyhow::bail!(
                "model '{}' wants {} input values, '{}' wants {} — one synthetic \
                 request stream cannot drive both",
                s.key,
                s.engine.input_len(),
                specs[0].key,
                in_len
            );
        }
    }
    let ds = crate::data::Dataset::synth(seed, requests);
    if ds.sample_len != in_len {
        anyhow::bail!("synth samples have {} values, models want {in_len}", ds.sample_len);
    }

    let mut router = Router::new(pool);
    for s in specs {
        router.add_model(s.key.clone(), Arc::clone(&s.engine))?;
    }
    // Per key: submit stamp per accepted id (ids are contiguous from 0 in
    // acceptance order, across swaps too) and the matching latency slot.
    fn record(
        key: &str,
        comps: Vec<crate::deploy::PoolCompletion>,
        stamps: &[std::time::Instant],
        slots: &mut [Option<f64>],
    ) -> Result<()> {
        for c in comps {
            let id = c.id as usize;
            if id >= stamps.len() || slots[id].is_some() {
                anyhow::bail!("model '{key}': unknown or duplicate completion id {id}");
            }
            slots[id] = Some(c.completed_at.duration_since(stamps[id]).as_secs_f64());
        }
        Ok(())
    }
    let mut submit_at: BTreeMap<&str, Vec<Instant>> =
        specs.iter().map(|s| (s.key.as_str(), Vec::new())).collect();
    let mut lat: BTreeMap<&str, Vec<Option<f64>>> =
        specs.iter().map(|s| (s.key.as_str(), Vec::new())).collect();

    let swap_at = requests / 2;
    let mut swapped = false;
    let t0 = Instant::now();
    for i in 0..requests {
        if !swapped && i >= swap_at {
            swapped = true;
            for s in specs {
                if let Some(to) = &s.swap_to {
                    router.swap_model(&s.key, Arc::clone(to))?;
                }
            }
        }
        let key = specs[i % specs.len()].key.as_str();
        let now = Instant::now();
        let x = ds.images[i * in_len..(i + 1) * in_len].to_vec();
        if let Submission::Accepted { .. } = router.try_submit(key, x)? {
            submit_at.get_mut(key).expect("known key").push(now);
            lat.get_mut(key).expect("known key").push(None);
        }
        let comps = router.try_completions(key)?;
        record(key, comps, &submit_at[key], lat.get_mut(key).expect("known key"))?;
    }
    // Live snapshot through the one-call stats surface (what `/stats`
    // serves) — catches an accounting violation before the drain below
    // folds in the shard counters.
    for (key, s) in router.stats_all() {
        if !s.consistent() {
            anyhow::bail!("model '{key}' live stats violate the routing invariant: {s:?}");
        }
    }
    let reports = router.shutdown()?;
    let wall = t0.elapsed().as_secs_f64();

    let mut models = BTreeMap::new();
    let mut total = crate::deploy::RouteStats::default();
    for (key, report) in reports {
        let s = report.stats;
        record(
            &key,
            report.completions,
            &submit_at[key.as_str()],
            lat.get_mut(key.as_str()).expect("known key"),
        )?;
        if !s.consistent() {
            anyhow::bail!("model '{key}' stats violate the routing invariant: {s:?}");
        }
        if s.completed != s.accepted {
            anyhow::bail!(
                "model '{key}' lost requests: accepted {} but completed {}",
                s.accepted,
                s.completed
            );
        }
        let mut durs: Vec<f64> = lat[key.as_str()]
            .iter()
            .map(|d| (*d).context("accepted request never completed"))
            .collect::<Result<_>>()?;
        let (p50, p90, p99) =
            if durs.is_empty() { (0.0, 0.0, 0.0) } else { percentiles_ms(&mut durs) };
        total.submitted += s.submitted;
        total.accepted += s.accepted;
        total.completed += s.completed;
        total.shed += s.shed;
        total.swaps += s.swaps;
        let mut model_json = s.to_json();
        if let Json::Obj(m) = &mut model_json {
            m.insert("p50_ms".into(), Json::num(p50));
            m.insert("p90_ms".into(), Json::num(p90));
            m.insert("p99_ms".into(), Json::num(p99));
        }
        models.insert(key, model_json);
    }
    Ok(Json::obj(vec![
        ("requests", Json::num(requests as f64)),
        ("workers", Json::num(pool.workers as f64)),
        ("queue_cap", Json::num(pool.queue_cap as f64)),
        ("wall_s", Json::num(wall)),
        ("throughput_rps", Json::num(total.completed as f64 / wall)),
        ("submitted", Json::num(total.submitted as f64)),
        ("accepted", Json::num(total.accepted as f64)),
        ("shed", Json::num(total.shed as f64)),
        ("shed_rate", Json::num(total.shed_rate())),
        ("swaps", Json::num(total.swaps as f64)),
        ("models", Json::Obj(models)),
    ]))
}

/// [`router_bench`] over `.cgmqm` files: load each `(key, path)` pair;
/// with `swap`, load a second engine per path and hot-swap it in at the
/// halfway mark (the `cgmq route-bench --swap` path).
pub fn router_bench_files(
    models: &[(String, PathBuf)],
    swap: bool,
    requests: usize,
    pool: crate::deploy::PoolConfig,
    seed: u64,
) -> Result<Json> {
    use crate::deploy::Engine;
    let specs: Vec<RouterBenchSpec> = models
        .iter()
        .map(|(key, path)| {
            Ok(RouterBenchSpec {
                key: key.clone(),
                engine: std::sync::Arc::new(Engine::load(path)?),
                swap_to: if swap {
                    Some(std::sync::Arc::new(Engine::load(path)?))
                } else {
                    None
                },
            })
        })
        .collect::<Result<_>>()?;
    router_bench(&specs, requests, pool, seed)
}

/// One `cgmq load-bench` run: the loopback load generator over the HTTP
/// serving front ([`crate::deploy::net::Server`]).
pub struct LoadBenchSpec {
    /// Server address, e.g. `127.0.0.1:8080`.
    pub addr: String,
    /// Model key to drive (`POST /v1/models/{key}/infer`).
    pub key: String,
    /// Distinct requests to complete (shed retries do not count extra).
    pub requests: usize,
    /// Concurrent client threads (each with one keep-alive connection).
    pub clients: usize,
    /// Target open-loop arrival rate across all clients, requests/s;
    /// `0` = unpaced burst (saturate the admission bound).
    pub rate_rps: f64,
    /// Seed of the synthetic request stream (`Dataset::synth`).
    pub seed: u64,
    /// Load this `.cgmqm` locally and assert every HTTP logits row is
    /// bit-identical to the direct [`Engine::infer_batch`] output.
    ///
    /// [`Engine::infer_batch`]: crate::deploy::Engine::infer_batch
    pub verify_model: Option<PathBuf>,
    /// Additionally require every pipeline stage histogram on `/metrics`
    /// to have recorded samples during the run (the smoke test's "the
    /// telemetry spine is actually wired" assertion).
    pub require_stages: bool,
    /// Additionally require the windowed signal plane to be live after
    /// the run: `GET /livez` answers 200, the model's windowed
    /// arrival-rate gauge is positive, and the windowed margin histogram
    /// recorded samples (the `watch-smoke` assertions).
    pub require_window: bool,
    /// `POST /admin/shutdown` after the run (graceful server drain).
    pub shutdown: bool,
}

/// Parse a Prometheus text exposition into a `series -> value` map, keyed
/// by the full series string including labels (comments and `# HELP`/`#
/// TYPE` lines skipped).
pub fn parse_prometheus(text: &str) -> std::collections::BTreeMap<String, f64> {
    let mut out = std::collections::BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some((series, value)) = line.rsplit_once(' ') {
            if let Ok(v) = value.parse::<f64>() {
                out.insert(series.to_string(), v);
            }
        }
    }
    out
}

/// `GET /metrics` from `addr`, parsed.
fn scrape_metrics(addr: &str) -> Result<std::collections::BTreeMap<String, f64>> {
    use crate::deploy::net::HttpClient;
    let mut client = HttpClient::connect(addr, std::time::Duration::from_secs(5))?;
    let (status, text) = client.request("GET", "/metrics", None)?;
    if status != 200 {
        anyhow::bail!("GET /metrics: unexpected HTTP {status}: {text}");
    }
    Ok(parse_prometheus(&text))
}

/// What one load-bench client thread brings home.
#[derive(Default)]
struct LoadClientOut {
    /// `(request index, seconds from first attempt to 200, logits)`.
    results: Vec<(usize, f64, Vec<f32>)>,
    /// HTTP attempts (accepted + shed).
    attempts: u64,
    /// 429 responses observed (each retried until accepted).
    shed: u64,
}

/// Drive `spec.requests` synthetic requests at the server from
/// `spec.clients` threads. A 429 is counted as a shed and the request is
/// retried with backoff until accepted — so every request finishes, and
/// with `verify_model` every response is held to bit-identity against the
/// locally loaded engine. `/metrics` is scraped before and after the run
/// and the server-side accept/shed counter deltas must equal the client
/// tallies bit-exactly (bails otherwise — the non-zero exit of `cgmq
/// load-bench`). Returns throughput / shed rate / latency percentiles /
/// server-side counts as JSON.
pub fn load_bench(spec: &LoadBenchSpec) -> Result<Json> {
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use crate::deploy::net::HttpClient;
    if spec.requests == 0 {
        anyhow::bail!("load bench needs at least one request");
    }
    if spec.clients == 0 {
        anyhow::bail!("load bench needs at least one client");
    }
    let ds = crate::data::Dataset::synth(spec.seed, spec.requests);
    let in_len = ds.sample_len;
    let expect = match &spec.verify_model {
        Some(path) => {
            let engine = crate::deploy::Engine::load(path)?;
            if engine.input_len() != in_len {
                anyhow::bail!(
                    "synth samples have {in_len} values, verify model wants {}",
                    engine.input_len()
                );
            }
            let c = engine.num_classes();
            Some((engine.infer_batch(&ds.images, spec.requests)?, c))
        }
        None => None,
    };
    let images = Arc::new(ds.images);

    // Scrape `/metrics` before and after the run: the *deltas* of the
    // server-side accept/shed counters must match what the clients
    // observed, bit-exactly — the end-to-end proof that the telemetry
    // spine counts the same events the HTTP responses report.
    let before = scrape_metrics(&spec.addr)?;

    let target = format!("/v1/models/{}/infer", spec.key);
    let (requests, clients, rate) = (spec.requests, spec.clients, spec.rate_rps);
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(clients);
    for t in 0..clients {
        let (addr, target, images) = (spec.addr.clone(), target.clone(), Arc::clone(&images));
        let handle = std::thread::Builder::new()
            .name(format!("cgmq-load-{t}"))
            .spawn(move || -> Result<LoadClientOut> {
                let mut client = HttpClient::connect(&addr, Duration::from_secs(5))?;
                let mut out = LoadClientOut::default();
                let mut i = t;
                while i < requests {
                    if rate > 0.0 {
                        // Open-loop schedule: request i is due at t0 + i/rate,
                        // regardless of how earlier requests fared.
                        let due = t0 + Duration::from_secs_f64(i as f64 / rate);
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                    }
                    let x = &images[i * in_len..(i + 1) * in_len];
                    let body = Json::obj(vec![("x", Json::arr_f32(x))]).to_string();
                    let started = Instant::now();
                    let mut backoff = Duration::from_micros(500);
                    loop {
                        out.attempts += 1;
                        let (status, text) = client.request("POST", &target, Some(&body))?;
                        match status {
                            200 => {
                                let parsed = crate::util::json::parse(&text)?;
                                let logits = parsed.get("logits")?.as_f32_vec()?;
                                out.results.push((i, started.elapsed().as_secs_f64(), logits));
                                break;
                            }
                            429 => {
                                out.shed += 1;
                                std::thread::sleep(backoff);
                                backoff = (backoff * 2).min(Duration::from_millis(10));
                            }
                            s => anyhow::bail!("POST {target}: unexpected HTTP {s}: {text}"),
                        }
                    }
                    i += clients;
                }
                Ok(out)
            })
            .context("spawning load client")?;
        handles.push(handle);
    }
    let (mut attempts, mut shed) = (0u64, 0u64);
    let mut lat = vec![f64::NAN; requests];
    let mut verified = 0usize;
    for handle in handles {
        let out = handle.join().map_err(|_| anyhow::anyhow!("load client panicked"))??;
        attempts += out.attempts;
        shed += out.shed;
        for (i, secs, logits) in out.results {
            lat[i] = secs;
            if let Some((expect, c)) = &expect {
                let row = &expect[i * c..(i + 1) * c];
                if logits.len() != *c
                    || logits.iter().zip(row).any(|(a, b)| a.to_bits() != b.to_bits())
                {
                    anyhow::bail!(
                        "request {i}: HTTP logits drifted from the direct engine output"
                    );
                }
                verified += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    if lat.iter().any(|d| d.is_nan()) {
        anyhow::bail!("load bench lost requests (client thread under-reported)");
    }
    let after = scrape_metrics(&spec.addr)?;
    let key = &spec.key;
    let delta = |name: &str| -> u64 {
        let series = format!("{name}{{model=\"{key}\"}}");
        let b = before.get(&series).copied().unwrap_or(0.0) as u64;
        let a = after.get(&series).copied().unwrap_or(0.0) as u64;
        a.saturating_sub(b)
    };
    let server_accepted = delta(crate::deploy::telemetry::M_ACCEPTED);
    let server_shed = delta(crate::deploy::telemetry::M_SHED);
    if server_accepted != requests as u64 {
        anyhow::bail!(
            "/metrics accept drift: server counted {server_accepted} accepted, \
             clients completed {requests}"
        );
    }
    if server_shed != shed {
        anyhow::bail!(
            "/metrics shed drift: server counted {server_shed} sheds, \
             clients observed {shed} 429s"
        );
    }
    if spec.require_stages {
        for stage in crate::deploy::telemetry::Stage::ALL {
            let s = stage.as_str();
            let series = format!(
                "{}_count{{model=\"{key}\",stage=\"{s}\"}}",
                crate::deploy::telemetry::M_STAGE_SECONDS
            );
            let b = before.get(&series).copied().unwrap_or(0.0) as u64;
            let a = after.get(&series).copied().unwrap_or(0.0) as u64;
            if a <= b {
                anyhow::bail!(
                    "stage histogram '{s}' recorded no samples during the run \
                     (the telemetry spine is not wired through this stage)"
                );
            }
        }
    }
    if spec.require_window {
        // The scrape above happened right after the last 200, so the
        // trailing window still covers the burst: the windowed series
        // must be visibly live, and the readiness probe healthy.
        let rate_series = format!(
            "{}{{model=\"{key}\"}}",
            crate::deploy::telemetry::M_ARRIVAL_RATE_WINDOW
        );
        let rate = after.get(&rate_series).copied().unwrap_or(0.0);
        if rate <= 0.0 {
            anyhow::bail!(
                "windowed arrival rate is {rate} right after the run \
                 (the windowed signal plane is not wired)"
            );
        }
        let margin_series = format!(
            "{}_count{{model=\"{key}\"}}",
            crate::deploy::telemetry::M_MARGIN_WINDOW
        );
        let margins = after.get(&margin_series).copied().unwrap_or(0.0) as u64;
        if margins == 0 {
            anyhow::bail!(
                "windowed margin histogram recorded no samples \
                 (the reply path is not feeding the confidence signal)"
            );
        }
        let mut client = HttpClient::connect(&spec.addr, Duration::from_secs(5))?;
        let (status, text) = client.request("GET", "/livez", None)?;
        if status != 200 {
            anyhow::bail!("GET /livez: expected a healthy 200, got HTTP {status}: {text}");
        }
    }
    if spec.shutdown {
        let mut client = HttpClient::connect(&spec.addr, Duration::from_secs(5))?;
        let (status, text) = client.request("POST", "/admin/shutdown", Some("{}"))?;
        if status != 200 {
            anyhow::bail!("POST /admin/shutdown: unexpected HTTP {status}: {text}");
        }
    }
    let (p50, p90, p99) = percentiles_ms(&mut lat);
    Ok(Json::obj(vec![
        ("addr", Json::str(spec.addr.clone())),
        ("key", Json::str(spec.key.clone())),
        ("requests", Json::num(requests as f64)),
        ("clients", Json::num(clients as f64)),
        ("rate_rps", Json::num(rate)),
        ("wall_s", Json::num(wall)),
        ("throughput_rps", Json::num(requests as f64 / wall)),
        ("attempts", Json::num(attempts as f64)),
        ("shed", Json::num(shed as f64)),
        ("server_accepted", Json::num(server_accepted as f64)),
        ("server_shed", Json::num(server_shed as f64)),
        ("shed_rate", Json::num(if attempts == 0 { 0.0 } else { shed as f64 / attempts as f64 })),
        ("p50_ms", Json::num(p50)),
        ("p90_ms", Json::num(p90)),
        ("p99_ms", Json::num(p99)),
        ("verified", Json::num(verified as f64)),
    ]))
}

/// Format one quantile-bound cell of the watch table. `null` is the
/// documented empty-histogram sentinel — zero samples have no quantile,
/// so the cell renders as `—` rather than a misleading 0. Numbers are
/// divided by `scale` and printed with `prec` decimals.
fn watch_cell(bound: Option<&Json>, scale: f64, prec: usize) -> String {
    match bound {
        Some(Json::Num(n)) => format!("{:.*}", prec, n / scale),
        _ => "—".to_string(),
    }
}

/// Render the windowed signal plane of one parsed `/stats` body as the
/// `cgmq watch` frame: a summary line plus one row per model — arrival
/// rate (req/s over the trailing window), windowed shed %, queue depth
/// (summed across shards), in-flight, p50/p99 whole-request bounds (ms),
/// and the margin p10 bound (logits, the cascade-routing confidence
/// floor). Deterministic over a given `/stats` body, which is what the
/// fixture test in `net_serve.rs` pins.
pub fn render_watch_table(stats: &Json) -> Result<String> {
    let models = stats.get("models")?.as_obj()?;
    let served = stats.get("served")?.as_f64()?;
    let window_s = models
        .values()
        .next()
        .and_then(|m| m.opt("window"))
        .and_then(|w| w.opt("window_us"))
        .and_then(|n| n.as_f64().ok())
        .map_or(0.0, |us| us / 1e6);
    let mut out = String::new();
    out.push_str(&format!("window {window_s:.0}s · served {served:.0}\n"));
    out.push_str(
        "| model | req/s | shed % | queue | in-flight | p50 ms | p99 ms | margin p10 |\n",
    );
    out.push_str(
        "|-------|-------|--------|-------|-----------|--------|--------|------------|\n",
    );
    for (key, m) in models {
        let w = m.get("window").context("model entry has no window section")?;
        let rate = w.get("arrival_rate_per_sec")?.as_f64()?;
        let shed = w.get("shed_rate")?.as_f64()? * 100.0;
        let mut queue = 0.0;
        for d in m.get("queue_depth")?.as_arr()? {
            queue += d.as_f64()?;
        }
        let in_flight = m.get("in_flight")?.as_f64()?;
        let total = w.get("total")?;
        let p50 = watch_cell(total.opt("p50_le"), 1e3, 2); // µs → ms
        let p99 = watch_cell(total.opt("p99_le"), 1e3, 2);
        // milli-logits → logits
        let p10 = watch_cell(w.get("margin")?.opt("p10_le"), 1e3, 3);
        out.push_str(&format!(
            "| {key} | {rate:.1} | {shed:.1} | {queue:.0} | {in_flight:.0} | {p50} | {p99} \
             | {p10} |\n"
        ));
    }
    Ok(out)
}

/// One `cgmq watch` frame: `GET /stats` from `addr`, rendered with
/// [`render_watch_table`].
pub fn watch_once(addr: &str) -> Result<String> {
    use crate::deploy::net::HttpClient;
    let mut client = HttpClient::connect(addr, std::time::Duration::from_secs(5))?;
    let (status, text) = client.request("GET", "/stats", None)?;
    if status != 200 {
        anyhow::bail!("GET /stats: unexpected HTTP {status}: {text}");
    }
    let stats = crate::util::json::parse(&text)?;
    render_watch_table(&stats)
}

/// Loopback HTTP serving row: stand a [`Server`](crate::deploy::net::Server)
/// up on an ephemeral port over `models`, drive the first key with the
/// [`load_bench`] client fleet, drain gracefully (bailing if any accepted
/// request was lost) and fold the server-side stats into the report.
pub fn net_bench(
    models: Vec<(String, std::sync::Arc<crate::deploy::Engine>)>,
    requests: usize,
    clients: usize,
    pool: crate::deploy::PoolConfig,
    seed: u64,
) -> Result<Json> {
    use crate::deploy::net::{Server, ServerConfig};
    let key = models.first().context("net bench needs at least one model")?.0.clone();
    let cfg = ServerConfig { pool, ..ServerConfig::default() };
    let server = Server::bind("127.0.0.1:0", models, cfg)?;
    let spec = LoadBenchSpec {
        addr: server.local_addr().to_string(),
        key,
        requests,
        clients,
        rate_rps: 0.0,
        seed,
        verify_model: None,
        require_stages: false,
        require_window: false,
        shutdown: false,
    };
    let bench = load_bench(&spec);
    let report = server.finish()?;
    let mut bench = bench?; // after finish: a failed bench must still drain the server
    report.verify_drained()?;
    if let Json::Obj(m) = &mut bench {
        m.insert("server".into(), report.to_json());
    }
    Ok(bench)
}

/// Core of [`serve_bench`], reusable with pre-built engines (deploy table).
pub fn serve_bench_engines(
    single: crate::deploy::Engine,
    mut batcher: crate::deploy::RequestBatcher,
    requests: usize,
    seed: u64,
) -> Result<Json> {
    use std::time::Instant;
    if requests == 0 {
        anyhow::bail!("serve bench needs at least one request");
    }
    let in_len = single.input_len();
    let ds = crate::data::Dataset::synth(seed, requests);
    if ds.sample_len != in_len {
        anyhow::bail!("synth samples have {} values, model wants {in_len}", ds.sample_len);
    }

    // Path A: one naive engine call per request, weights decoded each time.
    let t0 = Instant::now();
    let mut single_lat: Vec<f64> = Vec::with_capacity(requests);
    for i in 0..requests {
        let r0 = Instant::now();
        std::hint::black_box(single.infer(&ds.images[i * in_len..(i + 1) * in_len])?);
        single_lat.push(r0.elapsed().as_secs_f64());
    }
    let single_wall = t0.elapsed().as_secs_f64();

    // Path B: the batched serve path.
    fn record(
        completions: Vec<crate::deploy::Completion>,
        submit_at: &[Instant],
        batched_lat: &mut [f64],
        done: &mut usize,
    ) {
        let now = Instant::now();
        for c in completions {
            let waited = now.duration_since(submit_at[c.id as usize]);
            batched_lat[c.id as usize] = waited.as_secs_f64();
            *done += 1;
        }
    }
    let t0 = Instant::now();
    let mut submit_at: Vec<Instant> = Vec::with_capacity(requests);
    let mut batched_lat: Vec<f64> = vec![0.0; requests];
    let mut done = 0usize;
    for i in 0..requests {
        let now = Instant::now();
        submit_at.push(now);
        let completions = batcher.submit_at(ds.images[i * in_len..(i + 1) * in_len].to_vec(), now)?;
        record(completions, &submit_at, &mut batched_lat, &mut done);
        let completions = batcher.poll_at(Instant::now())?;
        record(completions, &submit_at, &mut batched_lat, &mut done);
    }
    let completions = batcher.flush_at(Instant::now())?;
    record(completions, &submit_at, &mut batched_lat, &mut done);
    let batched_wall = t0.elapsed().as_secs_f64();
    if done != requests {
        anyhow::bail!("serve path completed {done} of {requests} requests");
    }
    let stats = batcher.stats();

    let (sp50, sp90, sp99) = percentiles_ms(&mut single_lat);
    let (bp50, bp90, bp99) = percentiles_ms(&mut batched_lat);
    let single_rps = requests as f64 / single_wall;
    let batched_rps = requests as f64 / batched_wall;
    Ok(Json::obj(vec![
        ("requests", Json::num(requests as f64)),
        ("batch", Json::num(stats.mean_batch().max(1.0))),
        (
            "single",
            Json::obj(vec![
                ("throughput_rps", Json::num(single_rps)),
                ("p50_ms", Json::num(sp50)),
                ("p90_ms", Json::num(sp90)),
                ("p99_ms", Json::num(sp99)),
            ]),
        ),
        (
            "batched",
            Json::obj(vec![
                ("throughput_rps", Json::num(batched_rps)),
                ("p50_ms", Json::num(bp50)),
                ("p90_ms", Json::num(bp90)),
                ("p99_ms", Json::num(bp99)),
                ("flushes", Json::num(stats.flushes as f64)),
                ("engine_calls", Json::num(stats.engine_calls as f64)),
                ("mean_batch", Json::num(stats.mean_batch())),
                ("queue_wait_mean_us", Json::num(mean_wait_us(&stats))),
                ("queue_wait_max_us", Json::num(stats.queue_wait_max_us() as f64)),
            ]),
        ),
        ("speedup", Json::num(batched_rps / single_rps)),
    ]))
}

/// A deterministic synthetic mixed-precision snapshot state: He-init
/// params, calibrated weight ranges, fixed activation ranges, and gates
/// cycling through the given T(g) levels. Stand-in for a trained model
/// wherever the deploy path must run without artifacts or training (the
/// deploy table and `benches/bench_deploy.rs`).
pub struct SyntheticDeployState {
    pub params: Vec<crate::tensor::Tensor>,
    pub betas_w: crate::tensor::Tensor,
    pub betas_a: crate::tensor::Tensor,
    pub gates: crate::gates::GateSet,
}

/// Default level cycle for [`synthetic_deploy_state`].
pub const DEPLOY_LEVELS: [u32; 8] = [2, 4, 8, 16, 32, 4, 8, 2];

pub fn synthetic_deploy_state(
    arch: &crate::model::ArchSpec,
    levels: &[u32],
    seed: u64,
) -> SyntheticDeployState {
    use crate::quant::gate_for_bits;
    let params = arch.init_params(seed);
    let n_layers = arch.layers.len();
    let mut betas_w = crate::tensor::Tensor::zeros(&[n_layers]);
    for li in 0..n_layers {
        betas_w.data_mut()[li] = params[2 * li].abs_max().max(1e-3);
    }
    let betas_a = crate::tensor::Tensor::full(&[arch.n_quant_act()], 6.0);
    let mut gates = crate::gates::GateSet::new(arch, crate::gates::Granularity::Individual);
    for t in gates.gates_w.iter_mut().chain(gates.gates_a.iter_mut()) {
        for (i, g) in t.data_mut().iter_mut().enumerate() {
            *g = gate_for_bits(levels[i % levels.len()]);
        }
    }
    SyntheticDeployState { params, betas_w, betas_a, gates }
}

/// A deterministic synthetic *uniform-width* snapshot state: every
/// weight and activation gate pinned to one `T(g)` level at `Layer`
/// granularity — the SWAR-eligible counterpart of
/// [`synthetic_deploy_state`] (whose per-element level cycle
/// deliberately mixes widths and therefore pins the `F32Gemm`
/// fallback). The kernel width sweep and the SWAR speedup benches
/// export these.
pub fn uniform_deploy_state(
    arch: &crate::model::ArchSpec,
    bits: u32,
    seed: u64,
) -> SyntheticDeployState {
    use crate::quant::gate_for_bits;
    let params = arch.init_params(seed);
    let n_layers = arch.layers.len();
    let mut betas_w = crate::tensor::Tensor::zeros(&[n_layers]);
    for li in 0..n_layers {
        betas_w.data_mut()[li] = params[2 * li].abs_max().max(1e-3);
    }
    let betas_a = crate::tensor::Tensor::full(&[arch.n_quant_act()], 6.0);
    let mut gates = crate::gates::GateSet::new(arch, crate::gates::Granularity::Layer);
    for t in gates.gates_w.iter_mut().chain(gates.gates_a.iter_mut()) {
        for g in t.data_mut().iter_mut() {
            *g = gate_for_bits(bits);
        }
    }
    SyntheticDeployState { params, betas_w, betas_a, gates }
}

/// The deploy rows: per arch, packed artifact size vs fp32, the
/// single-vs-batched engine throughput, the sharded pool at 1 vs
/// `workers` workers (throughput + tail latency), the two-variant
/// router front with a bounded queue (throughput + shed rate), the
/// loopback HTTP front ([`net_bench`]: throughput + client-observed 429
/// rate), and the warm engine's per-op compute split
/// ([`Engine::profile_batch`]: MatMul / Im2col / Elem shares of one
/// batched forward), on deterministic synthetic snapshots. Writes
/// `table_deploy.json` next to the text table.
pub fn deploy_table(
    base: &Config,
    requests: usize,
    batch: usize,
    workers: usize,
) -> Result<String> {
    use crate::deploy::{BatchConfig, DecodeMode, Engine, PackedModel, PoolConfig, RequestBatcher};
    let mut out = String::new();
    out.push_str(&format!(
        "Deploy: packed .cgmqm artifacts + engine serve path \
         ({requests} requests, batch {batch}, {workers} workers).\n"
    ));
    out.push_str(
        "| Arch   | Packed KiB | FP32 KiB | Single req/s | Batched req/s | Speedup | Pool x1 req/s | Pool xN req/s | Pool gain | Q-wait µs | Route req/s | Shed % | Net req/s | Net shed % | MatMul % | Im2col % | Elem % |\n",
    );
    out.push_str(
        "|--------|------------|----------|--------------|---------------|---------|---------------|---------------|-----------|-----------|-------------|--------|-----------|------------|----------|----------|--------|\n",
    );
    let mut rows = Vec::new();
    let bcfg = BatchConfig { max_batch: batch, max_delay: std::time::Duration::from_micros(200) };
    for arch in [crate::model::mlp(), crate::model::lenet5()] {
        let s = synthetic_deploy_state(&arch, &DEPLOY_LEVELS, 7);
        let model = PackedModel::from_state(&arch, &s.params, &s.betas_w, &s.betas_a, &s.gates)?;
        let packed_bytes = model.encoded_len()?;
        let fp32_bytes: u64 = arch.layers.iter().map(|l| l.w_len() as u64 * 4).sum();
        let single = Engine::new(model.clone())?.with_mode(DecodeMode::Streaming);
        let batcher = RequestBatcher::new(Engine::new(model.clone())?, bcfg)?;
        let bench = serve_bench_engines(single, batcher, requests, base.seed)?;
        let shared = std::sync::Arc::new(Engine::new(model.clone())?);
        // Per-op compute split of one warm batched forward (cache filled
        // by preload, so the decode span is ~0 and the MatMul / Im2col /
        // Elem shares describe the steady serve state).
        shared.preload()?;
        let in_len = shared.input_len();
        let xs: Vec<f32> =
            (0..batch.max(1) * in_len).map(|i| (i % 251) as f32 / 251.0 - 0.5).collect();
        let (_, prof) = shared.profile_batch(&xs, batch.max(1))?;
        let (mm_pct, im_pct, el_pct) = (
            prof.share_pct(prof.matmul),
            prof.share_pct(prof.im2col),
            prof.share_pct(prof.elementwise),
        );
        let pool =
            pool_comparison(std::sync::Arc::clone(&shared), requests, workers, bcfg, base.seed)?;
        // Net row: the same shared engine behind the loopback HTTP front,
        // driven by the load-bench client fleet (server drain is asserted
        // lossless).
        let net = net_bench(
            vec![(format!("{}-net", arch.name), shared)],
            requests,
            4,
            PoolConfig { workers, batch: bcfg, queue_cap: batch },
            base.seed,
        )?;
        // Router row: two budget variants of this arch behind one front,
        // per-shard queues capped at one batch so overload sheds instead
        // of queueing unboundedly.
        let s2 = synthetic_deploy_state(&arch, &DEPLOY_LEVELS, 8);
        let model2 =
            PackedModel::from_state(&arch, &s2.params, &s2.betas_w, &s2.betas_a, &s2.gates)?;
        let specs = vec![
            RouterBenchSpec {
                key: format!("{}-a", arch.name),
                engine: std::sync::Arc::new(Engine::new(model)?),
                swap_to: None,
            },
            RouterBenchSpec {
                key: format!("{}-b", arch.name),
                engine: std::sync::Arc::new(Engine::new(model2)?),
                swap_to: None,
            },
        ];
        let route = router_bench(
            &specs,
            requests,
            PoolConfig { workers, batch: bcfg, queue_cap: batch },
            base.seed,
        )?;
        let single_rps = bench.get("single")?.get("throughput_rps")?.as_f64()?;
        let batched_rps = bench.get("batched")?.get("throughput_rps")?.as_f64()?;
        let pool1_rps = pool.get("one_worker")?.get("throughput_rps")?.as_f64()?;
        let pool_n_rps = pool.get("n_workers")?.get("throughput_rps")?.as_f64()?;
        // Stage breakdown: mean enqueue-to-flush wait inside the N-worker
        // pool's shard batchers (the dominant server-side latency stage
        // under load).
        let qwait_us = pool.get("n_workers")?.get("queue_wait_mean_us")?.as_f64()?;
        let route_rps = route.get("throughput_rps")?.as_f64()?;
        let shed_rate = route.get("shed_rate")?.as_f64()?;
        let net_rps = net.get("throughput_rps")?.as_f64()?;
        let net_shed_rate = net.get("shed_rate")?.as_f64()?;
        out.push_str(&format!(
            "| {:<6} | {:10.1} | {:8.1} | {:12.1} | {:13.1} | {:6.2}x | {:13.1} | {:13.1} | {:8.2}x | {:9.1} | {:11.1} | {:5.1}% | {:9.1} | {:9.1}% | {:7.1}% | {:7.1}% | {:5.1}% |\n",
            arch.name,
            packed_bytes as f64 / 1024.0,
            fp32_bytes as f64 / 1024.0,
            single_rps,
            batched_rps,
            batched_rps / single_rps,
            pool1_rps,
            pool_n_rps,
            pool_n_rps / pool1_rps,
            qwait_us,
            route_rps,
            100.0 * shed_rate,
            net_rps,
            100.0 * net_shed_rate,
            mm_pct,
            im_pct,
            el_pct
        ));
        let mut j = bench;
        if let Json::Obj(m) = &mut j {
            m.insert("arch".into(), Json::str(arch.name));
            m.insert("packed_bytes".into(), Json::num(packed_bytes as f64));
            m.insert("fp32_bytes".into(), Json::num(fp32_bytes as f64));
            m.insert("pool".into(), pool);
            m.insert("router".into(), route);
            m.insert("net".into(), net);
            m.insert(
                "op_shares".into(),
                Json::obj(vec![
                    ("decode_pct", Json::num(prof.share_pct(prof.decode))),
                    ("matmul_pct", Json::num(mm_pct)),
                    ("im2col_pct", Json::num(im_pct)),
                    ("elementwise_pct", Json::num(el_pct)),
                ]),
            );
        }
        rows.push(j);
    }
    write_json(&Path::new(&base.out_dir).join("table_deploy.json"), &Json::Arr(rows))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::percentiles_ms;

    #[test]
    fn percentiles_use_ceil_nearest_rank() {
        // 100 known durations: 0.001s .. 0.100s. Under the old round()
        // rule p99 read index round(99 * 0.99) = 98 (99 ms); ceil-based
        // nearest rank reads the slowest sample.
        let mut durs: Vec<f64> = (1..=100).map(|i| i as f64 * 1e-3).collect();
        let (p50, p90, p99) = percentiles_ms(&mut durs);
        assert_eq!(p50, 51.0); // ceil(99 * 0.50) = 50 -> 51 ms
        assert_eq!(p90, 91.0); // ceil(99 * 0.90) = 90 -> 91 ms
        assert_eq!(p99, 100.0); // ceil(99 * 0.99) = 99 -> the tail sample

        // Unsorted input is sorted in place; a single sample is every
        // percentile of itself.
        let mut one = vec![0.007];
        assert_eq!(percentiles_ms(&mut one), (7.0, 7.0, 7.0));
        let mut shuffled = vec![0.003, 0.001, 0.002];
        let (p50, p90, p99) = percentiles_ms(&mut shuffled);
        assert_eq!((p50, p90, p99), (2.0, 3.0, 3.0));
    }
}

fn result_json(method: &str, r: &RunResult) -> Json {
    let mut j = r.to_json();
    if let Json::Obj(m) = &mut j {
        m.insert("method".into(), Json::str(method));
    }
    j
}

fn data_label(cfg: &Config) -> &'static str {
    match cfg.data {
        crate::config::DataSource::Synth => "SynthMNIST substitution — see DESIGN.md §2",
        crate::config::DataSource::Mnist(_) => "MNIST",
    }
}
