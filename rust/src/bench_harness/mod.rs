//! Benchmark harness: regenerates every table of the paper's evaluation
//! (Tables 1-3), the constraint-satisfaction trace (G1), the granularity
//! ablation (A1) and the penalty-tuning comparison (A2), printing rows in
//! the paper's format and writing machine-readable JSON next to them.
//!
//! Every row is a [`SessionBuilder`] pipeline. The float pretraining
//! (phase-1 input state) is shared across all rows of a table through a
//! cached checkpoint — exactly how the paper runs it ("all different
//! choices of CGMQ start with the same pre-trained model") — so a row is
//! `[LoadCheckpoint, Calibrate, RangeLearn, CgmqLoop]`, with extra
//! `CgmqLoop` stages appended ad hoc when a short CI schedule needs a
//! longer horizon to reach the bound. Each row also streams its per-epoch
//! trajectory as JSONL (`<run_id>.epochs.jsonl` in `out_dir`) via
//! [`JsonlMetricsObserver`], so table JSON and epoch trajectories can be
//! scraped without parsing stdout.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::baselines::{bb_proxy, penalty};
use crate::config::Config;
use crate::direction::DirKind;
use crate::gates::Granularity;
use crate::session::{
    Calibrate, CgmqLoop, JsonlMetricsObserver, LoadCheckpoint, Pretrain, RangeLearn, RunResult,
    Session, SessionBuilder,
};
use crate::util::json::Json;

pub const PAPER_BOUNDS: [f64; 5] = [0.40, 0.90, 1.40, 2.00, 5.00];
pub const DIRS: [DirKind; 3] = [DirKind::Dir1, DirKind::Dir2, DirKind::Dir3];

/// Ensure a float-pretrained checkpoint exists for this config; returns its
/// path. All table rows resume from it.
pub fn ensure_pretrained(cfg: &Config) -> Result<PathBuf> {
    let path = Path::new(&cfg.out_dir)
        .join(format!("pretrained-{}-s{}-n{}.ckpt", cfg.arch, cfg.seed, cfg.train_size));
    if path.exists() {
        return Ok(path);
    }
    eprintln!(
        "[bench] pretraining {} for {} epochs (cached at {}) ...",
        cfg.arch,
        cfg.pretrain_epochs,
        path.display()
    );
    let mut session = SessionBuilder::new(cfg.clone()).stage(Pretrain::default()).build()?;
    session.run()?;
    session.ctx.save_params(&path)?;
    Ok(path)
}

/// Open a session resumed from the shared pretrained checkpoint, with
/// calibration + range learning queued (the phase-3 input state every
/// baseline and CGMQ row starts from). Skips the float-accuracy pass —
/// baseline drivers report quantized accuracy only.
pub fn resumed_session(cfg: &Config, ckpt: &Path) -> Result<Session> {
    let mut session = SessionBuilder::new(cfg.clone())
        .stage(LoadCheckpoint::new(ckpt).skip_float_eval())
        .stage(Calibrate)
        .stage(RangeLearn::default())
        .build()?;
    session.run()?;
    Ok(session)
}

/// Run one CGMQ row from the shared pretrained checkpoint.
pub fn run_row(base: &Config, dir: DirKind, gran: Granularity, bound: f64) -> Result<RunResult> {
    let mut cfg = base.clone();
    cfg.direction = dir;
    cfg.granularity = gran;
    cfg.bound_rbop_percent = bound;
    cfg.lr_gates = Config::paper_gate_lr(dir) * base.gate_lr_scale;
    cfg.validate()?;
    let ckpt = ensure_pretrained(base)?;
    let jsonl_path = Path::new(&cfg.out_dir).join(format!("{}.epochs.jsonl", cfg.run_id()));
    let mut session = SessionBuilder::new(cfg.clone())
        .stage(LoadCheckpoint::new(&ckpt))
        .stage(Calibrate)
        .stage(RangeLearn::default())
        .stage(CgmqLoop::default())
        .observer(JsonlMetricsObserver::create(&jsonl_path)?)
        .build()?;
    session.run()?;
    // The paper's guarantee is "satisfied after sufficiently many
    // iterations" (§3); dir2/dir3's descent speed scales with 1/(lr_g *
    // steps), so short CI schedules may need extra epochs at tight bounds.
    // Extend in chunks (capped at 8x) until a satisfying model exists.
    let mut extra = 0;
    while session.final_model().is_err() && extra < 8 * cfg.cgmq_epochs {
        session.run_stage(CgmqLoop::epochs(cfg.cgmq_epochs.max(1)))?;
        extra += cfg.cgmq_epochs.max(1);
    }
    if extra > 0 {
        eprintln!("[bench]   (extended {} by {extra} epochs to reach the bound)", cfg.run_id());
    }
    // If even the extended horizon did not reach the bound (a slow dir on a
    // CI schedule), report the row honestly as unsatisfied instead of
    // aborting the table; the paper-scale schedule always converges
    // (property-tested guarantee in tests/trainer_invariants.rs).
    let r = match session.result() {
        Ok(r) => r,
        Err(_) => {
            let float_acc =
                session.ctx.float_acc.context("LoadCheckpoint records float accuracy")?;
            let last = session.metrics().last().expect("at least one epoch ran").clone();
            RunResult {
                run_id: cfg.run_id(),
                float_acc,
                quant_acc: last.test_acc,
                rbop_percent: last.rbop_percent,
                bound_rbop_percent: cfg.bound_rbop_percent,
                satisfied: false,
                mean_weight_bits: last.mean_weight_bits,
                rbop_trace: session.ctx.rbop_trace.clone(),
            }
        }
    };
    eprintln!(
        "[bench] {}: acc {:.2}% rbop {:.3}% (bound {:.2}%) sat={}",
        r.run_id,
        100.0 * r.quant_acc,
        r.rbop_percent,
        r.bound_rbop_percent,
        r.satisfied
    );
    Ok(r)
}

fn write_json(path: &Path, v: &Json) -> Result<()> {
    if let Some(d) = path.parent() {
        std::fs::create_dir_all(d)?;
    }
    std::fs::write(path, v.to_string()).with_context(|| format!("writing {}", path.display()))
}

// ---------------------------------------------------------------------------
// Table 1 — method comparison at bound 0.40%
// ---------------------------------------------------------------------------

pub fn table1(base: &Config) -> Result<String> {
    let ckpt = ensure_pretrained(base)?;
    // FP32 row
    let mut session = SessionBuilder::new(base.clone()).stage(LoadCheckpoint::new(&ckpt)).build()?;
    session.run()?;
    let fp32_acc = session.ctx.float_acc.context("LoadCheckpoint records float accuracy")?;
    drop(session);

    let mut rows: Vec<Json> = Vec::new();
    let mut out = String::new();
    out.push_str(&format!("Table 1: Results on {} ({}).\n", base.arch, data_label(base)));
    out.push_str(
        "| Method | Hyperpar.       | Acc (%) | Rel. GBOPs (%) | Bound rel. GBOPs (%) |\n",
    );
    out.push_str(
        "|--------|-----------------|---------|----------------|----------------------|\n",
    );
    out.push_str(&format!(
        "| FP32   | -               | {:6.2}  | 100            | 100                  |\n",
        100.0 * fp32_acc
    ));
    out.push_str(&format!(
        "| BB*    | mu = 0.01       | {:.2} ± {:.2} | {:.2} ± {:.2} | -          |\n",
        bb_proxy::BB_PAPER_ACC,
        bb_proxy::BB_PAPER_ACC_STD,
        bb_proxy::BB_PAPER_RBOP,
        bb_proxy::BB_PAPER_RBOP_STD,
    ));
    rows.push(Json::obj(vec![
        ("method", Json::str("fp32")),
        ("acc", Json::num(100.0 * fp32_acc)),
        ("rbop", Json::num(100.0)),
    ]));

    let bound = 0.40;
    for gran in [Granularity::Layer, Granularity::Individual] {
        for dir in DIRS {
            let r = run_row(base, dir, gran, bound)?;
            out.push_str(&format!(
                "| CGMQ   | {}, {:<6} | {:6.2}  | {:14.2} | {:20.2} |\n",
                dir.label(),
                gran.label(),
                100.0 * r.quant_acc,
                r.rbop_percent,
                bound
            ));
            rows.push(result_json("cgmq", &r));
        }
    }
    out.push_str("(* BB row quotes van Baalen et al. 2020, pruning active.)\n");
    write_json(&Path::new(&base.out_dir).join("table1.json"), &Json::Arr(rows))?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Tables 2 & 3 — bound sweeps (layer / individual granularity)
// ---------------------------------------------------------------------------

pub fn table_sweep(base: &Config, gran: Granularity) -> Result<String> {
    let table_no = match gran {
        Granularity::Layer => 2,
        Granularity::Individual => 3,
    };
    let mut rows: Vec<Json> = Vec::new();
    let mut out = String::new();
    out.push_str(&format!(
        "Table {}: Acc (%) and RGBOP (%) vs bound (BGBOP), {} gates, {} ({}).\n",
        table_no,
        gran.label(),
        base.arch,
        data_label(base)
    ));
    out.push_str("| BGBOP (%) | dir1 Acc | dir1 RGBOP | dir2 Acc | dir2 RGBOP | dir3 Acc | dir3 RGBOP |\n");
    out.push_str("|-----------|----------|------------|----------|------------|----------|------------|\n");
    for bound in PAPER_BOUNDS {
        let mut cells = Vec::new();
        for dir in DIRS {
            let r = run_row(base, dir, gran, bound)?;
            cells.push(format!("{:8.2} | {:10.2}", 100.0 * r.quant_acc, r.rbop_percent));
            rows.push(result_json("cgmq", &r));
        }
        out.push_str(&format!("| {:9.2} | {} |\n", bound, cells.join(" | ")));
    }
    write_json(&Path::new(&base.out_dir).join(format!("table{table_no}.json")), &Json::Arr(rows))?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// A2 — penalty method needs tuning, CGMQ doesn't
// ---------------------------------------------------------------------------

pub fn penalty_comparison(base: &Config, lambdas: &[f32]) -> Result<String> {
    let ckpt = ensure_pretrained(base)?;
    let mut out = String::new();
    out.push_str(&format!(
        "A2: penalty method (DQ-style) vs CGMQ at bound {:.2}% ({}, {} epochs).\n",
        base.bound_rbop_percent, base.arch, base.cgmq_epochs
    ));
    out.push_str("| method        | lambda | Acc (%) | RGBOP (%) | satisfied |\n");
    out.push_str("|---------------|--------|---------|-----------|-----------|\n");
    let mut rows = Vec::new();
    for &lambda in lambdas {
        let jsonl_path =
            Path::new(&base.out_dir).join(format!("a2-penalty-l{lambda}.epochs.jsonl"));
        let mut session = SessionBuilder::new(base.clone())
            .stage(LoadCheckpoint::new(&ckpt).skip_float_eval())
            .stage(Calibrate)
            .stage(RangeLearn::default())
            .stage(penalty::PenaltyStage::new(lambda))
            .observer(JsonlMetricsObserver::create(&jsonl_path)?)
            .build()?;
        session.run()?;
        let r = penalty::result(&session.ctx, lambda)?;
        out.push_str(&format!(
            "| penalty       | {:6} | {:7.2} | {:9.2} | {:9} |\n",
            lambda,
            100.0 * r.test_acc,
            r.rbop_percent,
            r.satisfied
        ));
        rows.push(Json::obj(vec![
            ("method", Json::str("penalty")),
            ("lambda", Json::num(lambda as f64)),
            ("acc", Json::num(100.0 * r.test_acc)),
            ("rbop", Json::num(r.rbop_percent)),
            ("satisfied", Json::Bool(r.satisfied)),
        ]));
    }
    // CGMQ reference row — no hyperparameter, guaranteed satisfaction.
    let r = run_row(base, base.direction, base.granularity, base.bound_rbop_percent)?;
    out.push_str(&format!(
        "| CGMQ ({})   | {:6} | {:7.2} | {:9.2} | {:9} |\n",
        base.direction.label(),
        "-",
        100.0 * r.quant_acc,
        r.rbop_percent,
        r.satisfied
    ));
    rows.push(result_json("cgmq", &r));
    write_json(&Path::new(&base.out_dir).join("a2_penalty.json"), &Json::Arr(rows))?;
    Ok(out)
}

fn result_json(method: &str, r: &RunResult) -> Json {
    let mut j = r.to_json();
    if let Json::Obj(m) = &mut j {
        m.insert("method".into(), Json::str(method));
    }
    j
}

fn data_label(cfg: &Config) -> &'static str {
    match cfg.data {
        crate::config::DataSource::Synth => "SynthMNIST substitution — see DESIGN.md §2",
        crate::config::DataSource::Mnist(_) => "MNIST",
    }
}
