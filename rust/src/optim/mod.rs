//! Optimizers (paper Section 4.2): Adam for weights and quantization
//! ranges, plain gradient descent (no momentum) for the gate variables.
//!
//! All state lives on the host; updates are elementwise over the parameter
//! tensors returned by the XLA step artifacts.

use anyhow::Result;

use crate::tensor::Tensor;

/// Adam (Kingma & Ba, 2015) with the standard bias correction.
#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    t: u64,
}

impl Adam {
    pub fn new(lr: f32, shapes: &[Vec<usize>]) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            v: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            t: 0,
        }
    }

    /// One update step; `params[i] -= lr * mhat / (sqrt(vhat) + eps)`.
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) -> Result<()> {
        anyhow::ensure!(params.len() == grads.len(), "params/grads length mismatch");
        anyhow::ensure!(params.len() == self.m.len(), "optimizer built for different params");
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for ((p, g), (m, v)) in
            params.iter_mut().zip(grads).zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            anyhow::ensure!(p.shape() == g.shape(), "param/grad shape mismatch");
            let (b1, b2, eps, lr) = (self.beta1, self.beta2, self.eps, self.lr);
            m.zip_inplace(g, |m, g| b1 * m + (1.0 - b1) * g)?;
            v.zip_inplace(g, |v, g| b2 * v + (1.0 - b2) * g * g)?;
            let pd = p.data_mut();
            let md = m.data();
            let vd = v.data();
            for i in 0..pd.len() {
                let mhat = md[i] / b1t;
                let vhat = vd[i] / b2t;
                pd[i] -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
        Ok(())
    }

    pub fn reset(&mut self) {
        self.t = 0;
        for t in self.m.iter_mut().chain(self.v.iter_mut()) {
            t.map_inplace(|_| 0.0);
        }
    }
}

/// Plain SGD (used by the float-pretraining fallback and tests).
#[derive(Debug, Clone)]
pub struct Sgd {
    pub lr: f32,
}

impl Sgd {
    pub fn new(lr: f32) -> Self {
        Self { lr }
    }

    pub fn step(&self, params: &mut [Tensor], grads: &[Tensor]) -> Result<()> {
        anyhow::ensure!(params.len() == grads.len(), "params/grads length mismatch");
        for (p, g) in params.iter_mut().zip(grads) {
            let lr = self.lr;
            p.zip_inplace(g, move |p, g| p - lr * g)?;
        }
        Ok(())
    }
}

/// Gate update: plain GD over the constructed direction, `g -= eta_g * dir`
/// (paper Section 2.2 — explicitly *without* momentum, since dir is not a
/// gradient and momentum would mix Sat and Unsat phases).
#[derive(Debug, Clone)]
pub struct GateGd {
    pub lr: f32,
}

impl GateGd {
    pub fn new(lr: f32) -> Self {
        Self { lr }
    }

    pub fn step(&self, gates: &mut [Tensor], dirs: &[Tensor]) -> Result<()> {
        anyhow::ensure!(gates.len() == dirs.len(), "gates/dirs length mismatch");
        for (g, d) in gates.iter_mut().zip(dirs) {
            let lr = self.lr;
            g.zip_inplace(d, move |g, d| g - lr * d)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam on a convex quadratic converges to the minimum.
    #[test]
    fn adam_minimizes_quadratic() {
        let mut p = vec![Tensor::new(vec![2], vec![5.0, -3.0]).unwrap()];
        let mut adam = Adam::new(0.1, &[vec![2]]);
        for _ in 0..500 {
            let g = p[0].map(|x| 2.0 * x); // d/dx x^2
            adam.step(&mut p, &[g]).unwrap();
        }
        assert!(p[0].abs_max() < 1e-3, "{:?}", p[0].data());
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // Bias correction makes the first step ~= lr * sign(grad).
        let mut p = vec![Tensor::scalar(0.0)];
        let mut adam = Adam::new(0.01, &[vec![]]);
        adam.step(&mut p, &[Tensor::scalar(3.7)]).unwrap();
        assert!((p[0].data()[0] + 0.01).abs() < 1e-4);
    }

    #[test]
    fn sgd_step() {
        let mut p = vec![Tensor::new(vec![2], vec![1.0, 2.0]).unwrap()];
        Sgd::new(0.5).step(&mut p, &[Tensor::new(vec![2], vec![2.0, -2.0]).unwrap()]).unwrap();
        assert_eq!(p[0].data(), &[0.0, 3.0]);
    }

    #[test]
    fn gate_gd_descends_direction() {
        let mut g = vec![Tensor::scalar(5.5)];
        GateGd::new(0.01).step(&mut g, &[Tensor::scalar(100.0)]).unwrap();
        assert!((g[0].data()[0] - 4.5).abs() < 1e-6);
        // negative dir grows the gate
        GateGd::new(0.01).step(&mut g, &[Tensor::scalar(-50.0)]).unwrap();
        assert!((g[0].data()[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn mismatched_shapes_rejected() {
        let mut p = vec![Tensor::zeros(&[2])];
        let mut adam = Adam::new(0.1, &[vec![2]]);
        assert!(adam.step(&mut p, &[Tensor::zeros(&[3])]).is_err());
        assert!(Sgd::new(0.1).step(&mut p, &[]).is_err());
    }

    #[test]
    fn adam_reset_clears_state() {
        let mut p = vec![Tensor::scalar(1.0)];
        let mut adam = Adam::new(0.1, &[vec![]]);
        adam.step(&mut p, &[Tensor::scalar(1.0)]).unwrap();
        adam.reset();
        let mut q = vec![Tensor::scalar(1.0)];
        let mut fresh = Adam::new(0.1, &[vec![]]);
        fresh.step(&mut q, &[Tensor::scalar(1.0)]).unwrap();
        let mut p2 = vec![Tensor::scalar(1.0)];
        adam.step(&mut p2, &[Tensor::scalar(1.0)]).unwrap();
        assert_eq!(p2[0].data()[0], q[0].data()[0]);
    }
}
