//! Baseline quantization methods the paper compares against (or that its
//! qualitative discussion references), re-implemented on the same substrate
//! so every comparison runs on identical data/model/training code:
//!
//! * `fixed_qat`  — uniform b-bit quantization-aware training (Verhoef et
//!   al. 2019 style, single bit-width, no search);
//! * `penalty`    — DQ-style penalty method (Uhlich et al. 2020): the cost
//!   constraint enters as a soft regularizer whose weight λ must be tuned —
//!   *no satisfaction guarantee* (the paper's §3 criticism, experiment A2);
//! * `bb_proxy`   — a deterministic Bayesian-Bits-like proxy (van Baalen et
//!   al. 2020): a constant prior pressure toward lower bit-widths whose
//!   strength must be iteratively re-tuned to land on a target budget;
//! * `myqasr`     — the myQASR heuristic (Fish et al. 2023): rank layers by
//!   activation statistics, lower the most quantization-tolerant layer one
//!   step at a time until the budget holds, then finetune at fixed bits.

pub mod bb_proxy;
pub mod fixed_qat;
pub mod myqasr;
pub mod penalty;

use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

/// Deployment report for a trained snapshot: per-layer bit histograms,
/// weight memory, RBOP, and the *actual* packed `.cgmqm` artifact sizes —
/// what an edge integrator needs to provision the device the bound was
/// derived from. `packed_weight_bytes` / `packed_file_bytes` come from the
/// same packer that writes `cgmq export --format packed`, so the memory
/// report and a real `.cgmqm` file can be cross-checked byte-for-byte
/// (pinned by `tests/deploy_roundtrip.rs`).
pub fn export_report(cfg: &crate::config::Config, ckpt: &Path) -> Result<Json> {
    let (model, arch, gates) = load_packable_snapshot(cfg, ckpt)?;
    let gran = gates.granularity;

    let gw = gates.materialize_all_w(&arch);
    let ga = gates.materialize_all_a(&arch);
    let bops = crate::cost::model_bops(&arch, &gw, &ga)?;
    let payload = model.layer_payload_bytes();
    let mut layers = Vec::new();
    for (li, layer) in arch.layers.iter().enumerate() {
        let bits = crate::quant::bitwidths(&gw[li]);
        let mut hist = std::collections::BTreeMap::new();
        for b in bits {
            *hist.entry(b).or_insert(0u64) += 1;
        }
        let mem_bits: u64 = hist.iter().map(|(&b, &c)| b as u64 * c).sum();
        layers.push(Json::obj(vec![
            ("name", Json::str(layer.name)),
            (
                "weight_bit_histogram",
                Json::Obj(
                    hist.iter().map(|(b, c)| (b.to_string(), Json::num(*c as f64))).collect(),
                ),
            ),
            ("weight_memory_bytes", Json::num(mem_bits as f64 / 8.0)),
            ("packed_weight_bytes", Json::num(payload[li] as f64)),
        ]));
    }
    Ok(Json::obj(vec![
        ("arch", Json::str(arch.name)),
        ("granularity", Json::str(gran.label())),
        ("rbop_percent", Json::num(crate::cost::rbop_percent(&arch, bops))),
        (
            "total_weight_memory_bytes",
            Json::num(crate::cost::weight_memory_bits(&gw) as f64 / 8.0),
        ),
        (
            "fp32_weight_memory_bytes",
            Json::num(arch.layers.iter().map(|l| l.w_len() as f64 * 4.0).sum()),
        ),
        ("packed_total_weight_bytes", Json::num(model.total_payload_bytes() as f64)),
        ("packed_file_bytes", Json::num(model.encoded_len()? as f64)),
        ("mean_weight_bits", Json::num(gates.mean_weight_bits(&arch))),
        ("layers", Json::Arr(layers)),
    ]))
}

/// Load a full snapshot checkpoint (params + ranges + gates) and pack it.
/// Shared by the JSON report and `cgmq export --format packed`, so both
/// views of the deliverable come from the same bytes.
pub fn load_packable_snapshot(
    cfg: &crate::config::Config,
    ckpt: &Path,
) -> Result<(crate::deploy::PackedModel, crate::model::ArchSpec, crate::gates::GateSet)> {
    let arch = crate::model::arch_by_name(&cfg.arch)?;
    let c = crate::checkpoint::Checkpoint::load(ckpt)?;
    if let Some(a) = c.meta.get("arch") {
        if a != arch.name {
            anyhow::bail!("checkpoint is for arch '{a}', config says '{}'", arch.name);
        }
    }
    let gran = match c.meta.get("granularity").map(|s| s.as_str()) {
        Some("layer") => crate::gates::Granularity::Layer,
        _ => crate::gates::Granularity::Individual,
    };
    let mut gates = crate::gates::GateSet::new(&arch, gran);
    gates.gates_w = c.get_all("gates_w")?;
    gates.gates_a = c.get_all("gates_a")?;
    if gates.gates_w.len() != arch.layers.len() || gates.gates_a.len() != arch.n_quant_act() {
        anyhow::bail!(
            "checkpoint has {} weight / {} activation gate tensors, arch '{}' wants {} / {}",
            gates.gates_w.len(),
            gates.gates_a.len(),
            arch.name,
            arch.layers.len(),
            arch.n_quant_act()
        );
    }
    let params = c.get_all("params")?;
    let betas_w = c.get("betas_w")?.clone();
    let betas_a = c.get("betas_a")?.clone();
    let model = crate::deploy::PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates)?;
    Ok((model, arch, gates))
}
