//! Bayesian-Bits-like baseline (van Baalen et al. 2020), deterministic
//! mean-field proxy + the paper's quoted numbers.
//!
//! True BB learns stochastic gates by variational inference with a prior
//! that penalizes higher bit-widths; at convergence the gate posterior is
//! driven by a *constant* regularization pressure whose strength μ the
//! practitioner must re-tune until the compressed model lands on the wanted
//! budget (the paper's §3 criticism: "a hyperparameter ... can be
//! iteratively modified to meet finally the predefined cost constraint").
//!
//! The proxy keeps exactly that control structure and drops the sampling
//! machinery (which this substrate cannot reproduce faithfully and whose
//! variance is irrelevant to the comparison): gates feel a constant
//! downward pressure `μ · |g|` (higher bit-widths pay more, mirroring the
//! BB prior), with **no constraint feedback**. `tune_mu` then performs the
//! outer bisection loop a BB practitioner runs by hand — several complete
//! trainings — to hit a target budget. Each inner training is a fresh
//! [`TrainCtx`] (typically a session resumed from a shared pretrained
//! checkpoint); the contrast measured in experiment A2/T1 is: CGMQ = 1
//! training, BB-style = `iterations` trainings.
//!
//! Table 1 also quotes BB's published MNIST numbers (99.30 ± 0.03 @ 0.36%)
//! directly, as the paper itself does.

use anyhow::Result;

use crate::cost::{model_bops, rbop_percent};
use crate::session::{GatePolicy, PolicyInputs, TrainCtx};
use crate::tensor::Tensor;

/// BB's published LeNet-5/MNIST row (van Baalen et al. 2020, Table;
/// pruning active, which is why its RBOP undercuts the no-pruning floor).
pub const BB_PAPER_ACC: f64 = 99.30;
pub const BB_PAPER_ACC_STD: f64 = 0.03;
pub const BB_PAPER_RBOP: f64 = 0.36;
pub const BB_PAPER_RBOP_STD: f64 = 0.01;

/// Constant prior-pressure policy (no constraint feedback).
pub struct BbProxyPolicy {
    pub mu: f32,
}

impl GatePolicy for BbProxyPolicy {
    fn dirs(&self, t: &PolicyInputs) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let mu = self.mu;
        let dirs_w = t.gates.gates_w.iter().map(|g| g.map(|v| mu * v.abs())).collect();
        let dirs_a = t.gates.gates_a.iter().map(|g| g.map(|v| mu * v.abs())).collect();
        Ok((dirs_w, dirs_a))
    }
}

#[derive(Debug, Clone)]
pub struct BbProxyResult {
    pub mu: f32,
    pub test_acc: f64,
    pub rbop_percent: f64,
    pub satisfied: bool,
    /// Number of complete trainings the tuning loop consumed.
    pub trainings: usize,
}

/// One full proxy training at fixed μ (context must be pretrained+calibrated).
pub fn run(ctx: &mut TrainCtx, mu: f32, epochs: usize) -> Result<BbProxyResult> {
    let policy = BbProxyPolicy { mu };
    for _ in 0..epochs {
        ctx.qat_epoch_with(Some(&policy))?;
    }
    let bops = model_bops(
        &ctx.arch,
        &ctx.gates.materialize_all_w(&ctx.arch),
        &ctx.gates.materialize_all_a(&ctx.arch),
    )?;
    Ok(BbProxyResult {
        mu,
        test_acc: ctx.evaluate()?,
        rbop_percent: rbop_percent(&ctx.arch, bops),
        satisfied: ctx.constraint.is_satisfied(&ctx.arch, bops),
        trainings: 1,
    })
}

/// The practitioner's outer loop: bisect μ over full trainings until the
/// budget holds (or the iteration cap runs out). `make_ctx` must return a
/// freshly pretrained+calibrated context each call.
pub fn tune_mu(
    mut make_ctx: impl FnMut() -> Result<TrainCtx>,
    epochs: usize,
    max_iters: usize,
) -> Result<BbProxyResult> {
    let (mut lo, mut hi) = (1e-4f32, 1.0f32);
    let mut best: Option<BbProxyResult> = None;
    let mut trainings = 0;
    for _ in 0..max_iters {
        let mu = (lo * hi).sqrt(); // geometric bisection
        let mut ctx = make_ctx()?;
        let mut r = run(&mut ctx, mu, epochs)?;
        trainings += 1;
        r.trainings = trainings;
        if r.satisfied {
            // budget holds — try weaker pressure for better accuracy
            hi = mu;
            if best.as_ref().map(|b| r.test_acc > b.test_acc).unwrap_or(true) {
                best = Some(r);
            }
        } else {
            lo = mu;
        }
    }
    best.ok_or_else(|| {
        anyhow::anyhow!("bb_proxy: no μ in [1e-4, 1] satisfied the budget in {max_iters} trainings")
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::direction::{DirConfig, DirKind, Sat};
    use crate::gates::{GateSet, Granularity};
    use crate::model::mlp;

    #[test]
    fn pressure_scales_with_gate_value() {
        let arch = mlp();
        let mut gates = GateSet::new(&arch, Granularity::Layer);
        gates.gates_w[0] = Tensor::scalar(4.0);
        gates.gates_w[1] = Tensor::scalar(1.0);
        let params = arch.init_params(0);
        let grads: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        let act = vec![Tensor::zeros(&[128]), Tensor::zeros(&[64])];
        let cfg = DirConfig::new(DirKind::Dir1);
        let inputs = PolicyInputs {
            arch: &arch,
            sat: Sat::Unsatisfied,
            grads: &grads,
            params: &params,
            act_grads: &act,
            act_means: &act,
            gates: &gates,
            dir_cfg: &cfg,
        };
        let (dw, _) = BbProxyPolicy { mu: 0.5 }.dirs(&inputs).unwrap();
        assert_eq!(dw[0].data()[0], 2.0); // 0.5 * 4.0 — 32-bit layer pays most
        assert_eq!(dw[1].data()[0], 0.5);
    }
}
