//! myQASR-style heuristic baseline (Fish et al. 2023).
//!
//! Label-free mixed-precision search: using a small calibration set, rank
//! layers by the magnitude of their activation statistics (myQASR uses the
//! median of activations; on this substrate we use the batch-mean absolute
//! activation the qat_step artifact already reports — same monotone role:
//! smaller statistic ⇒ more quantization-tolerant). Then repeatedly lower
//! by one power-of-2 step the bit-width of the *most tolerant layer among
//! those at the current maximum bit-width*, until the budget holds.
//! Finally the bit-widths are frozen and the network finetunes.
//!
//! Properties mirrored from the paper's discussion: layer granularity only,
//! at most two distinct bit-widths in flight during the descent, no
//! training signal in the search itself.
//!
//! On the staged API the whole heuristic is one custom [`Stage`]
//! ([`MyQasrStage`]): run it after `[Pretrain, Calibrate, RangeLearn]` in a
//! [`SessionBuilder`](crate::session::SessionBuilder) pipeline and read the
//! outcome back with [`result`].

use anyhow::{bail, Result};

use crate::cost::model_bops;
use crate::gates::Granularity;
use crate::metrics::Stopwatch;
use crate::quant::{gate_for_bits, transform_t};
use crate::session::stage::{Finetune, Stage, StageReport};
use crate::session::TrainCtx;
use crate::tensor::Tensor;
use crate::BIT_LEVELS;

#[derive(Debug, Clone)]
pub struct MyQasrResult {
    pub test_acc: f64,
    pub rbop_percent: f64,
    pub satisfied: bool,
    /// (layer name, weight bits) after the descent.
    pub assignment: Vec<(String, u32)>,
}

/// The myQASR heuristic as a pipeline stage: bit-width descent until the
/// budget holds, then QAT finetuning at the frozen assignment.
///
/// Requires layer granularity and a pretrained + calibrated context.
#[derive(Debug, Clone, Default)]
pub struct MyQasrStage {
    /// Finetuning epochs after the descent; `None` -> `cfg.cgmq_epochs`.
    pub epochs: Option<usize>,
}

impl MyQasrStage {
    pub fn epochs(epochs: usize) -> Self {
        Self { epochs: Some(epochs) }
    }
}

impl Stage for MyQasrStage {
    fn name(&self) -> &str {
        "myqasr"
    }

    fn run(&mut self, ctx: &mut TrainCtx) -> Result<StageReport> {
        let total = Stopwatch::start();
        if ctx.gates.granularity != Granularity::Layer {
            bail!("myqasr baseline requires layer granularity");
        }
        let stats = activation_stats(ctx)?;
        let n_act = stats.len(); // quantized-activation layers

        // Joint per-layer bit-width (weights + activations move together,
        // as in myQASR's per-layer setting). Output layer (no quantized
        // activation) keeps its weight bits at the running level of the
        // *preceding* rank.
        let mut bits: Vec<u32> = vec![32; n_act];
        loop {
            let assigned: Vec<(usize, u32)> = bits.iter().cloned().enumerate().collect();
            apply_assignment(ctx, &assigned)?;
            let bops = model_bops(
                &ctx.arch,
                &ctx.gates.materialize_all_w(&ctx.arch),
                &ctx.gates.materialize_all_a(&ctx.arch),
            )?;
            if ctx.constraint.is_satisfied(&ctx.arch, bops) {
                break;
            }
            // candidate: among layers at the current max bit-width, the one
            // with the smallest activation statistic.
            let max_bits = *bits.iter().max().unwrap();
            let candidate = (0..n_act)
                .filter(|&i| bits[i] == max_bits)
                .min_by(|&a, &b| stats[a].partial_cmp(&stats[b]).unwrap())
                .unwrap();
            match next_lower(bits[candidate]) {
                Some(b) => bits[candidate] = b,
                None => bail!("myqasr: budget unreachable even at all-2-bit"),
            }
        }

        let mut report = Finetune { epochs: self.epochs }.run(ctx)?;
        report.stage = self.name().to_string();
        report.secs = total.secs();
        Ok(report)
    }
}

fn next_lower(bits: u32) -> Option<u32> {
    let i = BIT_LEVELS.iter().position(|&b| b == bits)?;
    if i == 0 {
        None
    } else {
        Some(BIT_LEVELS[i - 1])
    }
}

/// Per-layer activation statistic from one calibration batch (mean |act|).
fn activation_stats(ctx: &TrainCtx) -> Result<Vec<f64>> {
    // The calibrate artifact is reused here (cheaper: float forward, act
    // maxes) — the ranking only needs a monotone per-layer magnitude.
    let name = format!("{}_calibrate", ctx.arch.name);
    let batch = crate::data::Batcher::sequential(&ctx.train_data, ctx.arch.train_batch)
        .into_iter()
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty dataset"))?;
    let mut x_shape = vec![ctx.arch.train_batch];
    x_shape.extend_from_slice(&ctx.arch.input_shape);
    let x = Tensor::new(x_shape, batch.images.clone())?;
    let mut args: Vec<crate::runtime::Arg> =
        ctx.params.iter().map(crate::runtime::Arg::F32).collect();
    args.push(crate::runtime::Arg::F32(&x));
    let out = ctx.artifacts.get(&name)?.run(&args)?;
    Ok(out[1].data().iter().map(|&v| v as f64).collect())
}

/// Summarize a finished myQASR run from the context state.
pub fn result(ctx: &TrainCtx) -> Result<MyQasrResult> {
    let acc = ctx.evaluate()?;
    summarize(ctx, acc)
}

fn summarize(ctx: &TrainCtx, test_acc: f64) -> Result<MyQasrResult> {
    let (rbop, satisfied) = ctx.constraint_status()?;
    let assignment = ctx
        .arch
        .layers
        .iter()
        .enumerate()
        .map(|(li, l)| (l.name.to_string(), transform_t(ctx.gates.gates_w[li].data()[0])))
        .collect();
    Ok(MyQasrResult { test_acc, rbop_percent: rbop, satisfied, assignment })
}

/// Run the heuristic: descend bit-widths until the budget holds, then
/// finetune for `epochs`. Context must be pretrained + calibrated and use
/// layer granularity.
pub fn run(ctx: &mut TrainCtx, epochs: usize) -> Result<MyQasrResult> {
    let report = MyQasrStage::epochs(epochs).run(ctx)?;
    match report.test_acc {
        // The final finetune epoch already evaluated this exact state.
        Some(acc) => summarize(ctx, acc),
        None => result(ctx),
    }
}

/// Write a per-quant-act-layer bit assignment into the gate set (weights of
/// the final, non-quant-act layer follow the last assigned level).
fn apply_assignment(ctx: &mut TrainCtx, bits: &[(usize, u32)]) -> Result<()> {
    let mut last = 32;
    let mut ai = 0;
    for (li, layer) in ctx.arch.layers.iter().enumerate() {
        if layer.quant_act {
            let (_, b) = bits[ai];
            ctx.gates.gates_w[li] = Tensor::scalar(gate_for_bits(b));
            ctx.gates.gates_a[ai] = Tensor::scalar(gate_for_bits(b));
            last = b;
            ai += 1;
        } else {
            ctx.gates.gates_w[li] = Tensor::scalar(gate_for_bits(last));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_lower_walks_the_ladder() {
        assert_eq!(next_lower(32), Some(16));
        assert_eq!(next_lower(16), Some(8));
        assert_eq!(next_lower(8), Some(4));
        assert_eq!(next_lower(4), Some(2));
        assert_eq!(next_lower(2), None);
    }
}
