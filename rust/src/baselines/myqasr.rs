//! myQASR-style heuristic baseline (Fish et al. 2023).
//!
//! Label-free mixed-precision search: using a small calibration set, rank
//! layers by the magnitude of their activation statistics (myQASR uses the
//! median of activations; on this substrate we use the batch-mean absolute
//! activation the qat_step artifact already reports — same monotone role:
//! smaller statistic ⇒ more quantization-tolerant). Then repeatedly lower
//! by one power-of-2 step the bit-width of the *most tolerant layer among
//! those at the current maximum bit-width*, until the budget holds.
//! Finally the bit-widths are frozen and the network finetunes.
//!
//! Properties mirrored from the paper's discussion: layer granularity only,
//! at most two distinct bit-widths in flight during the descent, no
//! training signal in the search itself.

use anyhow::{bail, Result};

use crate::coordinator::Trainer;
use crate::cost::{model_bops, rbop_percent};
use crate::gates::Granularity;
use crate::quant::{gate_for_bits, transform_t};
use crate::tensor::Tensor;
use crate::BIT_LEVELS;

#[derive(Debug, Clone)]
pub struct MyQasrResult {
    pub test_acc: f64,
    pub rbop_percent: f64,
    pub satisfied: bool,
    /// (layer name, weight bits) after the descent.
    pub assignment: Vec<(String, u32)>,
}

fn next_lower(bits: u32) -> Option<u32> {
    let i = BIT_LEVELS.iter().position(|&b| b == bits)?;
    if i == 0 {
        None
    } else {
        Some(BIT_LEVELS[i - 1])
    }
}

/// Per-layer activation statistic from one calibration epoch (mean |act|).
fn activation_stats(trainer: &mut Trainer) -> Result<Vec<f64>> {
    // One no-update epoch purely to pull the act_mean outputs: we reuse the
    // calibrate artifact instead (cheaper: float forward, act maxes) — the
    // ranking only needs a monotone per-layer magnitude.
    let name = format!("{}_calibrate", trainer.arch.name);
    let batch = crate::data::Batcher::sequential(&trainer.train_data, trainer.arch.train_batch)
        .into_iter()
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty dataset"))?;
    let mut x_shape = vec![trainer.arch.train_batch];
    x_shape.extend_from_slice(&trainer.arch.input_shape);
    let x = Tensor::new(x_shape, batch.images.clone())?;
    let mut args: Vec<crate::runtime::Arg> =
        trainer.params.iter().map(crate::runtime::Arg::F32).collect();
    args.push(crate::runtime::Arg::F32(&x));
    let out = trainer.artifacts.get(&name)?.run(&args)?;
    Ok(out[1].data().iter().map(|&v| v as f64).collect())
}

/// Run the heuristic: descend bit-widths until the budget holds, then
/// finetune for `epochs`. Trainer must be pretrained + calibrated and use
/// layer granularity.
pub fn run(trainer: &mut Trainer, epochs: usize) -> Result<MyQasrResult> {
    if trainer.gates.granularity != Granularity::Layer {
        bail!("myqasr baseline requires layer granularity");
    }
    let stats = activation_stats(trainer)?;
    let n_act = stats.len(); // quantized-activation layers

    // Joint per-layer bit-width (weights + activations move together, as in
    // myQASR's per-layer setting). Output layer (no quantized activation)
    // keeps its weight bits at the running level of the *preceding* rank.
    let mut bits: Vec<u32> = vec![32; n_act];
    loop {
        let assigned: Vec<(usize, u32)> = bits.iter().cloned().enumerate().collect();
        apply_assignment(trainer, &assigned)?;
        let bops = model_bops(
            &trainer.arch,
            &trainer.gates.materialize_all_w(&trainer.arch),
            &trainer.gates.materialize_all_a(&trainer.arch),
        )?;
        if trainer.constraint.is_satisfied(&trainer.arch, bops) {
            break;
        }
        // candidate: among layers at the current max bit-width, the one
        // with the smallest activation statistic.
        let max_bits = *bits.iter().max().unwrap();
        let candidate = (0..n_act)
            .filter(|&i| bits[i] == max_bits)
            .min_by(|&a, &b| stats[a].partial_cmp(&stats[b]).unwrap())
            .unwrap();
        match next_lower(bits[candidate]) {
            Some(b) => bits[candidate] = b,
            None => bail!("myqasr: budget unreachable even at all-2-bit"),
        }
    }

    for _ in 0..epochs {
        trainer.qat_epoch(false)?;
    }
    let bops = model_bops(
        &trainer.arch,
        &trainer.gates.materialize_all_w(&trainer.arch),
        &trainer.gates.materialize_all_a(&trainer.arch),
    )?;
    let assignment = trainer
        .arch
        .layers
        .iter()
        .enumerate()
        .map(|(li, l)| (l.name.to_string(), transform_t(trainer.gates.gates_w[li].data()[0])))
        .collect();
    Ok(MyQasrResult {
        test_acc: trainer.evaluate()?,
        rbop_percent: rbop_percent(&trainer.arch, bops),
        satisfied: trainer.constraint.is_satisfied(&trainer.arch, bops),
        assignment,
    })
}

/// Write a per-quant-act-layer bit assignment into the gate set (weights of
/// the final, non-quant-act layer follow the last assigned level).
fn apply_assignment(trainer: &mut Trainer, bits: &[(usize, u32)]) -> Result<()> {
    let mut last = 32;
    let mut ai = 0;
    for (li, layer) in trainer.arch.layers.iter().enumerate() {
        if layer.quant_act {
            let (_, b) = bits[ai];
            trainer.gates.gates_w[li] = Tensor::scalar(gate_for_bits(b));
            trainer.gates.gates_a[ai] = Tensor::scalar(gate_for_bits(b));
            last = b;
            ai += 1;
        } else {
            trainer.gates.gates_w[li] = Tensor::scalar(gate_for_bits(last));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_lower_walks_the_ladder() {
        assert_eq!(next_lower(32), Some(16));
        assert_eq!(next_lower(16), Some(8));
        assert_eq!(next_lower(8), Some(4));
        assert_eq!(next_lower(4), Some(2));
        assert_eq!(next_lower(2), None);
    }
}
