//! Uniform fixed-bit-width QAT baseline.
//!
//! All weight and activation gates are pinned at one bit-width b; training
//! proceeds exactly like CGMQ's phase 4 but without gate updates. This is
//! the classical QAT recipe (Jacob et al. 2017 / Verhoef et al. 2019): the
//! practitioner picks b by hand and has no budget knob other than trying
//! different b values.

use anyhow::Result;

use crate::coordinator::Trainer;
use crate::cost::rbop_percent;
use crate::quant::gate_for_bits;
use crate::tensor::Tensor;

/// Result of one fixed-bit run.
#[derive(Debug, Clone)]
pub struct FixedQatResult {
    pub bits: u32,
    pub test_acc: f64,
    pub rbop_percent: f64,
}

/// Pin every gate to `bits` and finetune for `epochs`.
///
/// Assumes the trainer is already pretrained + calibrated (phases 1-3).
pub fn run(trainer: &mut Trainer, bits: u32, epochs: usize) -> Result<FixedQatResult> {
    let g = gate_for_bits(bits);
    for t in trainer.gates.gates_w.iter_mut().chain(trainer.gates.gates_a.iter_mut()) {
        *t = Tensor::full(&t.shape().to_vec(), g);
    }
    for _ in 0..epochs {
        trainer.qat_epoch(false)?;
    }
    let bops = crate::cost::model_bops(
        &trainer.arch,
        &trainer.gates.materialize_all_w(&trainer.arch),
        &trainer.gates.materialize_all_a(&trainer.arch),
    )?;
    Ok(FixedQatResult {
        bits,
        test_acc: trainer.evaluate()?,
        rbop_percent: rbop_percent(&trainer.arch, bops),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rbop_of_uniform_bits_is_square_ratio() {
        // (b*b)/(32*32) in percent — pure math, no artifacts needed.
        for bits in [2u32, 4, 8] {
            let expect = 100.0 * (bits * bits) as f64 / 1024.0;
            let arch = crate::model::lenet5();
            let g = gate_for_bits(bits);
            let gw: Vec<Tensor> =
                arch.layers.iter().map(|l| Tensor::full(&l.w_shape, g)).collect();
            let ga: Vec<Tensor> = arch
                .layers
                .iter()
                .filter(|l| l.quant_act)
                .map(|l| Tensor::full(&l.act_shape, g))
                .collect();
            let bops = crate::cost::model_bops(&arch, &gw, &ga).unwrap();
            assert!((rbop_percent(&arch, bops) - expect).abs() < 1e-9);
        }
    }
}
