//! Uniform fixed-bit-width QAT baseline.
//!
//! All weight and activation gates are pinned at one bit-width b; training
//! proceeds exactly like CGMQ's phase 4 but without gate updates. This is
//! the classical QAT recipe (Jacob et al. 2017 / Verhoef et al. 2019): the
//! practitioner picks b by hand and has no budget knob other than trying
//! different b values.
//!
//! On the staged API this baseline is not special-cased at all — it is the
//! stage sequence `[Pretrain, Calibrate, PinGates(b), Finetune]` (see
//! [`stages`] for the post-calibration tail). [`run`] drives that tail over
//! an existing context for function-style callers.

use anyhow::Result;

use crate::session::stage::Stage;
use crate::session::{Finetune, PinGates, TrainCtx};

/// Result of one fixed-bit run.
#[derive(Debug, Clone)]
pub struct FixedQatResult {
    pub bits: u32,
    pub test_acc: f64,
    pub rbop_percent: f64,
}

/// The baseline's stage tail (everything after pretrain+calibrate):
/// pin every gate to `bits`, then finetune for `epochs`.
pub fn stages(bits: u32, epochs: usize) -> Vec<Box<dyn Stage>> {
    vec![Box::new(PinGates::bits(bits)), Box::new(Finetune::epochs(epochs))]
}

/// Summarize a finished fixed-bit run from the context state.
pub fn result(ctx: &TrainCtx, bits: u32) -> Result<FixedQatResult> {
    let (rbop, _) = ctx.constraint_status()?;
    Ok(FixedQatResult { bits, test_acc: ctx.evaluate()?, rbop_percent: rbop })
}

/// Pin every gate to `bits` and finetune for `epochs`.
///
/// Assumes the context is already pretrained + calibrated (phases 1-2).
pub fn run(ctx: &mut TrainCtx, bits: u32, epochs: usize) -> Result<FixedQatResult> {
    PinGates::bits(bits).run(ctx)?;
    let report = Finetune::epochs(epochs).run(ctx)?;
    match report.test_acc {
        // The final finetune epoch already evaluated this exact state.
        Some(acc) => {
            let (rbop, _) = ctx.constraint_status()?;
            Ok(FixedQatResult { bits, test_acc: acc, rbop_percent: rbop })
        }
        None => result(ctx, bits),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::rbop_percent;
    use crate::quant::gate_for_bits;
    use crate::tensor::Tensor;

    #[test]
    fn rbop_of_uniform_bits_is_square_ratio() {
        // (b*b)/(32*32) in percent — pure math, no artifacts needed.
        for bits in [2u32, 4, 8] {
            let expect = 100.0 * (bits * bits) as f64 / 1024.0;
            let arch = crate::model::lenet5();
            let g = gate_for_bits(bits);
            let gw: Vec<Tensor> =
                arch.layers.iter().map(|l| Tensor::full(&l.w_shape, g)).collect();
            let ga: Vec<Tensor> = arch
                .layers
                .iter()
                .filter(|l| l.quant_act)
                .map(|l| Tensor::full(&l.act_shape, g))
                .collect();
            let bops = crate::cost::model_bops(&arch, &gw, &ga).unwrap();
            assert!((rbop_percent(&arch, bops) - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn stage_tail_is_pin_then_finetune() {
        let s = stages(8, 3);
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].name(), "pin-gates");
        assert_eq!(s[1].name(), "finetune");
    }
}
