//! DQ-style penalty-method baseline (Uhlich et al. 2020; paper §3, A2).
//!
//! The cost constraint is moved into the objective as a soft penalty
//! λ · max(0, cost - bound). Gates have no true gradient, so — exactly like
//! the surrogate DQ uses for its bit-width parametrization — the penalty's
//! "gradient" w.r.t. each gate is its (constant, positive) cost
//! sensitivity whenever the model is over budget, and zero otherwise:
//!
//! ```text
//! dir_penalty(g) = λ           if cost > bound   (push bit-widths down)
//!                = 0           otherwise         (no recovery force)
//! ```
//!
//! The crucial contrast with CGMQ: the *per-step* pressure is λ, a
//! hyperparameter. Too small and the budget is never reached within the
//! training horizon (constraint violated at the end — DQ's documented
//! failure mode); too large and every gate is crushed to 2 bits long before
//! the weights can adapt, wasting accuracy. CGMQ's Sat/Unsat dir needs no
//! such tuning. The A2 sweep in `bench_harness` exposes exactly this
//! trade-off.
//!
//! [`PenaltyStage`] packages one fixed-λ run as a pipeline stage.

use anyhow::Result;

use crate::metrics::{EpochRecord, Stopwatch};
use crate::session::stage::{Stage, StageReport};
use crate::session::{ConstraintEvent, GatePolicy, PolicyInputs, TrainCtx};
use crate::tensor::Tensor;

/// The penalty gate policy.
pub struct PenaltyPolicy {
    pub lambda: f32,
    /// Over-budget flag, refreshed at epoch ends by the driver.
    pub over_budget: std::cell::Cell<bool>,
}

impl GatePolicy for PenaltyPolicy {
    fn dirs(&self, t: &PolicyInputs) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let push = if self.over_budget.get() { self.lambda } else { 0.0 };
        let dirs_w =
            t.gates.gates_w.iter().map(|g| Tensor::full(&g.shape().to_vec(), push)).collect();
        let dirs_a =
            t.gates.gates_a.iter().map(|g| Tensor::full(&g.shape().to_vec(), push)).collect();
        Ok((dirs_w, dirs_a))
    }
}

/// One penalty run at a fixed λ.
#[derive(Debug, Clone)]
pub struct PenaltyResult {
    pub lambda: f32,
    pub test_acc: f64,
    pub rbop_percent: f64,
    pub satisfied: bool,
}

/// The penalty method as a pipeline stage (one fixed λ).
#[derive(Debug, Clone)]
pub struct PenaltyStage {
    pub lambda: f32,
    /// `None` -> `cfg.cgmq_epochs`.
    pub epochs: Option<usize>,
}

impl PenaltyStage {
    pub fn new(lambda: f32) -> Self {
        Self { lambda, epochs: None }
    }

    pub fn epochs(lambda: f32, epochs: usize) -> Self {
        Self { lambda, epochs: Some(epochs) }
    }
}

impl Stage for PenaltyStage {
    fn name(&self) -> &str {
        "penalty"
    }

    fn run(&mut self, ctx: &mut TrainCtx) -> Result<StageReport> {
        let total = Stopwatch::start();
        let epochs = self.epochs.unwrap_or(ctx.cfg.cgmq_epochs);
        let policy = PenaltyPolicy { lambda: self.lambda, over_budget: std::cell::Cell::new(true) };
        let mut report = StageReport::new(self.name());
        for epoch in 0..epochs {
            let sw = Stopwatch::start();
            let loss = ctx.qat_epoch_with(Some(&policy))?;
            // Deliberately NOT end_of_epoch_check: penalty epochs are not
            // CGMQ epochs, so the Sat/Unsat dir state and the G1 RBOP
            // trace must stay untouched; observers still see the check.
            let (rbop, sat_now) = ctx.constraint_status()?;
            ctx.bus.constraint_check(&ConstraintEvent {
                phase: "penalty".into(),
                epoch,
                rbop_percent: rbop,
                bound_percent: ctx.cfg.bound_rbop_percent,
                satisfied: sat_now,
            });
            policy.over_budget.set(!sat_now);
            let acc = ctx.evaluate()?;
            ctx.record_epoch(EpochRecord {
                phase: "penalty".into(),
                epoch,
                train_loss: loss,
                test_acc: acc,
                rbop_percent: rbop,
                sat: sat_now,
                mean_weight_bits: ctx.gates.mean_weight_bits(&ctx.arch),
                secs: sw.secs(),
            });
            report.epochs_run += 1;
            report.final_train_loss = Some(loss);
            report.test_acc = Some(acc);
            report.rbop_percent = Some(rbop);
        }
        report.secs = total.secs();
        Ok(report)
    }
}

/// Train with the penalty method for `epochs` at strength `lambda`.
///
/// Assumes the context is pretrained + calibrated. Unlike CGMQ there is no
/// best-Sat snapshotting: the penalty method has no notion of a guaranteed
/// feasible iterate, so the *final* iterate is what you get (that is the
/// point of the comparison).
pub fn run(ctx: &mut TrainCtx, lambda: f32, epochs: usize) -> Result<PenaltyResult> {
    let report = PenaltyStage::epochs(lambda, epochs).run(ctx)?;
    match report.test_acc {
        // The final epoch already evaluated this exact state.
        Some(acc) => summarize(ctx, lambda, acc),
        None => result(ctx, lambda),
    }
}

/// Summarize a finished penalty run from the context state.
pub fn result(ctx: &TrainCtx, lambda: f32) -> Result<PenaltyResult> {
    let acc = ctx.evaluate()?;
    summarize(ctx, lambda, acc)
}

fn summarize(ctx: &TrainCtx, lambda: f32, test_acc: f64) -> Result<PenaltyResult> {
    let (rbop, satisfied) = ctx.constraint_status()?;
    Ok(PenaltyResult { lambda, test_acc, rbop_percent: rbop, satisfied })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::direction::{DirConfig, DirKind, Sat};
    use crate::gates::{GateSet, Granularity};
    use crate::model::mlp;

    #[test]
    fn policy_pushes_down_only_when_over_budget() {
        let arch = mlp();
        let gates = GateSet::new(&arch, Granularity::Layer);
        let params = arch.init_params(0);
        let grads: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        let act: Vec<Tensor> = vec![Tensor::zeros(&[128]), Tensor::zeros(&[64])];
        let cfg = DirConfig::new(DirKind::Dir1);
        let inputs = PolicyInputs {
            arch: &arch,
            sat: Sat::Unsatisfied,
            grads: &grads,
            params: &params,
            act_grads: &act,
            act_means: &act,
            gates: &gates,
            dir_cfg: &cfg,
        };
        let p = PenaltyPolicy { lambda: 0.3, over_budget: std::cell::Cell::new(true) };
        let (dw, da) = p.dirs(&inputs).unwrap();
        assert!(dw.iter().chain(da.iter()).all(|t| t.data().iter().all(|&v| v == 0.3)));
        p.over_budget.set(false);
        let (dw, _) = p.dirs(&inputs).unwrap();
        assert!(dw.iter().all(|t| t.data().iter().all(|&v| v == 0.0)));
    }
}
