//! DQ-style penalty-method baseline (Uhlich et al. 2020; paper §3, A2).
//!
//! The cost constraint is moved into the objective as a soft penalty
//! λ · max(0, cost - bound). Gates have no true gradient, so — exactly like
//! the surrogate DQ uses for its bit-width parametrization — the penalty's
//! "gradient" w.r.t. each gate is its (constant, positive) cost
//! sensitivity whenever the model is over budget, and zero otherwise:
//!
//! ```text
//! dir_penalty(g) = λ           if cost > bound   (push bit-widths down)
//!                = 0           otherwise         (no recovery force)
//! ```
//!
//! The crucial contrast with CGMQ: the *per-step* pressure is λ, a
//! hyperparameter. Too small and the budget is never reached within the
//! training horizon (constraint violated at the end — DQ's documented
//! failure mode); too large and every gate is crushed to 2 bits long before
//! the weights can adapt, wasting accuracy. CGMQ's Sat/Unsat dir needs no
//! such tuning. `sweep` exposes exactly this trade-off for experiment A2.

use anyhow::Result;

use crate::coordinator::{GatePolicy, PolicyInputs, Trainer};
use crate::cost::{model_bops, rbop_percent};
use crate::tensor::Tensor;

/// The penalty gate policy.
pub struct PenaltyPolicy {
    pub lambda: f32,
    /// Over-budget flag, refreshed at epoch ends by the driver.
    pub over_budget: std::cell::Cell<bool>,
}

impl GatePolicy for PenaltyPolicy {
    fn dirs(&self, t: &PolicyInputs) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let push = if self.over_budget.get() { self.lambda } else { 0.0 };
        let dirs_w =
            t.gates.gates_w.iter().map(|g| Tensor::full(&g.shape().to_vec(), push)).collect();
        let dirs_a =
            t.gates.gates_a.iter().map(|g| Tensor::full(&g.shape().to_vec(), push)).collect();
        Ok((dirs_w, dirs_a))
    }
}

/// One penalty run at a fixed λ.
#[derive(Debug, Clone)]
pub struct PenaltyResult {
    pub lambda: f32,
    pub test_acc: f64,
    pub rbop_percent: f64,
    pub satisfied: bool,
}

/// Train with the penalty method for `epochs` at strength `lambda`.
///
/// Assumes the trainer is pretrained + calibrated. Unlike CGMQ there is no
/// best-Sat snapshotting: the penalty method has no notion of a guaranteed
/// feasible iterate, so the *final* iterate is what you get (that is the
/// point of the comparison).
pub fn run(trainer: &mut Trainer, lambda: f32, epochs: usize) -> Result<PenaltyResult> {
    let policy = PenaltyPolicy { lambda, over_budget: std::cell::Cell::new(true) };
    for _ in 0..epochs {
        trainer.qat_epoch_with(Some(&policy))?;
        let bops = model_bops(
            &trainer.arch,
            &trainer.gates.materialize_all_w(&trainer.arch),
            &trainer.gates.materialize_all_a(&trainer.arch),
        )?;
        policy.over_budget.set(!trainer.constraint.is_satisfied(&trainer.arch, bops));
    }
    let bops = model_bops(
        &trainer.arch,
        &trainer.gates.materialize_all_w(&trainer.arch),
        &trainer.gates.materialize_all_a(&trainer.arch),
    )?;
    let rbop = rbop_percent(&trainer.arch, bops);
    Ok(PenaltyResult {
        lambda,
        test_acc: trainer.evaluate()?,
        rbop_percent: rbop,
        satisfied: trainer.constraint.is_satisfied(&trainer.arch, bops),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::direction::{DirConfig, DirKind, Sat};
    use crate::gates::{GateSet, Granularity};
    use crate::model::mlp;

    #[test]
    fn policy_pushes_down_only_when_over_budget() {
        let arch = mlp();
        let gates = GateSet::new(&arch, Granularity::Layer);
        let params = arch.init_params(0);
        let grads: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        let act: Vec<Tensor> = vec![Tensor::zeros(&[128]), Tensor::zeros(&[64])];
        let cfg = DirConfig::new(DirKind::Dir1);
        let inputs = PolicyInputs {
            arch: &arch,
            sat: Sat::Unsatisfied,
            grads: &grads,
            params: &params,
            act_grads: &act,
            act_means: &act,
            gates: &gates,
            dir_cfg: &cfg,
        };
        let p = PenaltyPolicy { lambda: 0.3, over_budget: std::cell::Cell::new(true) };
        let (dw, da) = p.dirs(&inputs).unwrap();
        assert!(dw.iter().chain(da.iter()).all(|t| t.data().iter().all(|&v| v == 0.3)));
        p.over_budget.set(false);
        let (dw, _) = p.dirs(&inputs).unwrap();
        assert!(dw.iter().all(|t| t.data().iter().all(|&v| v == 0.0)));
    }
}
