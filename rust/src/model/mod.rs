//! Model metadata registry: layer specs, parameter/gate shapes, artifact
//! binding.
//!
//! The specs are the single Rust-side source of truth for tensor shapes and
//! orderings. They are hard-coded to mirror `python/compile/arch.py` and
//! *verified against* `artifacts/manifest.json` at load time
//! (`runtime::ArtifactSet::verify_arch`), so any drift between the compile
//! path and the run path fails fast at startup instead of silently feeding
//! tensors into the wrong executable slot.

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::rng::SplitMix64;

/// One layer of a feed-forward architecture.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSpec {
    pub name: &'static str,
    pub kind: LayerKind,
    /// OIHW for conv, (in, out) for dense.
    pub w_shape: Vec<usize>,
    pub b_shape: Vec<usize>,
    /// Feature dims of the (pre-pool) activation, no batch dim.
    pub act_shape: Vec<usize>,
    /// Square max-pool window/stride applied after the activation (0 = none).
    pub pool: usize,
    /// Whether this layer's activation is fake-quantized (last layer: false).
    pub quant_act: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Dense,
}

impl LayerSpec {
    /// Multiply-accumulates per sample (BOP building block, paper §2.5).
    pub fn macs(&self) -> u64 {
        match self.kind {
            LayerKind::Conv => {
                let (o, i, kh, kw) =
                    (self.w_shape[0], self.w_shape[1], self.w_shape[2], self.w_shape[3]);
                let (oh, ow) = (self.act_shape[1], self.act_shape[2]);
                (o * oh * ow * i * kh * kw) as u64
            }
            LayerKind::Dense => (self.w_shape[0] * self.w_shape[1]) as u64,
        }
    }

    /// Fan-in of one output unit (weights feeding one activation).
    pub fn fan_in(&self) -> usize {
        match self.kind {
            LayerKind::Conv => self.w_shape[1] * self.w_shape[2] * self.w_shape[3],
            LayerKind::Dense => self.w_shape[0],
        }
    }

    /// Number of output units (activations) of this layer.
    pub fn n_units(&self) -> usize {
        self.act_shape.iter().product()
    }

    pub fn w_len(&self) -> usize {
        self.w_shape.iter().product()
    }
}

/// A full architecture (mirror of python ArchSpec).
#[derive(Debug, Clone)]
pub struct ArchSpec {
    pub name: &'static str,
    pub input_shape: Vec<usize>,
    pub layers: Vec<LayerSpec>,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub input_bits: u32,
}

impl ArchSpec {
    pub fn quant_act_layers(&self) -> impl Iterator<Item = (usize, &LayerSpec)> {
        self.layers.iter().enumerate().filter(|(_, l)| l.quant_act)
    }

    pub fn n_quant_act(&self) -> usize {
        self.layers.iter().filter(|l| l.quant_act).count()
    }

    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.w_len() + l.b_shape.iter().product::<usize>()).sum()
    }

    /// Parameter tensor names in artifact order: w, b per layer.
    pub fn param_names(&self) -> Vec<String> {
        let mut out = Vec::new();
        for l in &self.layers {
            out.push(format!("{}.w", l.name));
            out.push(format!("{}.b", l.name));
        }
        out
    }

    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        for l in &self.layers {
            out.push(l.w_shape.clone());
            out.push(l.b_shape.clone());
        }
        out
    }

    /// He-normal initial parameters (weights) + zero biases, deterministic.
    pub fn init_params(&self, seed: u64) -> Vec<Tensor> {
        let mut rng = SplitMix64::new(seed);
        let mut out = Vec::new();
        for l in &self.layers {
            out.push(Tensor::he_normal(&l.w_shape, l.fan_in(), &mut rng));
            out.push(Tensor::zeros(&l.b_shape));
        }
        out
    }

    /// Per-sample input element count.
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// The paper's evaluation model: LeNet-5 (Caffe variant, as in Bayesian Bits).
pub fn lenet5() -> ArchSpec {
    ArchSpec {
        name: "lenet5",
        input_shape: vec![1, 28, 28],
        layers: vec![
            LayerSpec {
                name: "conv1",
                kind: LayerKind::Conv,
                w_shape: vec![20, 1, 5, 5],
                b_shape: vec![20],
                act_shape: vec![20, 24, 24],
                pool: 2,
                quant_act: true,
            },
            LayerSpec {
                name: "conv2",
                kind: LayerKind::Conv,
                w_shape: vec![50, 20, 5, 5],
                b_shape: vec![50],
                act_shape: vec![50, 8, 8],
                pool: 2,
                quant_act: true,
            },
            LayerSpec {
                name: "fc1",
                kind: LayerKind::Dense,
                w_shape: vec![800, 500],
                b_shape: vec![500],
                act_shape: vec![500],
                pool: 0,
                quant_act: true,
            },
            LayerSpec {
                name: "fc2",
                kind: LayerKind::Dense,
                w_shape: vec![500, 10],
                b_shape: vec![10],
                act_shape: vec![10],
                pool: 0,
                quant_act: false,
            },
        ],
        train_batch: 128,
        eval_batch: 256,
        input_bits: 8,
    }
}

/// CI-scale model for tests/examples: 784-128-64-10 MLP.
pub fn mlp() -> ArchSpec {
    ArchSpec {
        name: "mlp",
        input_shape: vec![784],
        layers: vec![
            LayerSpec {
                name: "fc1",
                kind: LayerKind::Dense,
                w_shape: vec![784, 128],
                b_shape: vec![128],
                act_shape: vec![128],
                pool: 0,
                quant_act: true,
            },
            LayerSpec {
                name: "fc2",
                kind: LayerKind::Dense,
                w_shape: vec![128, 64],
                b_shape: vec![64],
                act_shape: vec![64],
                pool: 0,
                quant_act: true,
            },
            LayerSpec {
                name: "fc3",
                kind: LayerKind::Dense,
                w_shape: vec![64, 10],
                b_shape: vec![10],
                act_shape: vec![10],
                pool: 0,
                quant_act: false,
            },
        ],
        train_batch: 128,
        eval_batch: 256,
        input_bits: 8,
    }
}

/// Look up an architecture by name.
pub fn arch_by_name(name: &str) -> Result<ArchSpec> {
    match name {
        "lenet5" => Ok(lenet5()),
        "mlp" => Ok(mlp()),
        other => bail!("unknown architecture '{other}' (known: lenet5, mlp)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet5_param_count_matches_paper_model() {
        assert_eq!(lenet5().n_params(), 431_080);
    }

    #[test]
    fn mlp_param_count() {
        assert_eq!(mlp().n_params(), 784 * 128 + 128 + 128 * 64 + 64 + 64 * 10 + 10);
    }

    #[test]
    fn lenet5_macs() {
        let a = lenet5();
        let macs: Vec<u64> = a.layers.iter().map(|l| l.macs()).collect();
        assert_eq!(macs, vec![288_000, 1_600_000, 400_000, 5_000]);
    }

    #[test]
    fn fan_in() {
        let a = lenet5();
        assert_eq!(a.layers[0].fan_in(), 25);
        assert_eq!(a.layers[1].fan_in(), 500);
        assert_eq!(a.layers[2].fan_in(), 800);
    }

    #[test]
    fn init_params_deterministic_and_shaped() {
        let a = mlp();
        let p1 = a.init_params(9);
        let p2 = a.init_params(9);
        assert_eq!(p1, p2);
        assert_eq!(p1.len(), 6);
        assert_eq!(p1[0].shape(), &[784, 128]);
        assert_eq!(p1[1].data().iter().map(|x| x.abs()).sum::<f32>(), 0.0); // zero bias
        let p3 = a.init_params(10);
        assert_ne!(p1, p3);
    }

    #[test]
    fn unknown_arch_rejected() {
        assert!(arch_by_name("resnet18").is_err());
    }
}
