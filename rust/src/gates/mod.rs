//! Gate-variable store (paper Section 2.1).
//!
//! A gate g controls the bit-width of a weight or activation via the
//! staircase T(g) (Eq. 4). Two granularities, as in the paper's
//! experiments:
//!
//! * `Individual` — one gate per weight and per activation unit (the
//!   *indiv.* rows of Tables 1/3);
//! * `Layer` — one gate for all weights of a layer plus one for all
//!   activations of a layer (the *layer* rows of Tables 1/2).
//!
//! Storage is shape-faithful: individual gates are full tensors, layer
//! gates are scalars. `materialize_*` broadcasts to the artifact-shaped
//! tensors the XLA step function expects, so the compiled graph is
//! identical for both granularities (the coordinator just feeds different
//! tensors).
//!
//! Pruning is future work in the paper, so gates are clamped to
//! `GATE_FLOOR` (= 0.5, bit-width 2) from below; the cap keeps Sat-phase
//! growth bounded (any g > 4 already means 32 bit).

use anyhow::{bail, Result};

use crate::model::ArchSpec;
use crate::quant::transform_t;
use crate::tensor::Tensor;
use crate::{BIT_LEVELS, GATE_FLOOR, GATE_INIT};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// One gate per layer for weights + one per layer for activations.
    Layer,
    /// One gate per individual weight / activation unit.
    Individual,
}

impl Granularity {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "layer" => Ok(Granularity::Layer),
            "individual" | "indiv" => Ok(Granularity::Individual),
            other => bail!("unknown granularity '{other}' (layer | individual)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Granularity::Layer => "layer",
            Granularity::Individual => "indiv",
        }
    }
}

/// All gate variables of a model.
#[derive(Debug, Clone)]
pub struct GateSet {
    pub granularity: Granularity,
    /// One entry per layer; scalar tensor for `Layer`, w-shaped for `Individual`.
    pub gates_w: Vec<Tensor>,
    /// One entry per quantized-activation layer.
    pub gates_a: Vec<Tensor>,
    /// Upper clamp for gate values (>= 4 keeps 32-bit reachable).
    pub cap: f32,
}

impl GateSet {
    /// Fresh gate set at the paper's init (5.5 -> everything 32 bit).
    pub fn new(arch: &ArchSpec, granularity: Granularity) -> Self {
        Self::with_init(arch, granularity, GATE_INIT)
    }

    pub fn with_init(arch: &ArchSpec, granularity: Granularity, init: f32) -> Self {
        let shape = |full: &[usize]| -> Vec<usize> {
            match granularity {
                Granularity::Layer => vec![],
                Granularity::Individual => full.to_vec(),
            }
        };
        let gates_w =
            arch.layers.iter().map(|l| Tensor::full(&shape(&l.w_shape), init)).collect();
        let gates_a = arch
            .layers
            .iter()
            .filter(|l| l.quant_act)
            .map(|l| Tensor::full(&shape(&l.act_shape), init))
            .collect();
        Self { granularity, gates_w, gates_a, cap: GATE_INIT }
    }

    /// Clamp every gate into [GATE_FLOOR, cap] (paper: g < 0.5 -> 0.5).
    pub fn clamp(&mut self) {
        let cap = self.cap;
        for t in self.gates_w.iter_mut().chain(self.gates_a.iter_mut()) {
            t.map_inplace(|g| g.max(GATE_FLOOR).min(cap));
        }
    }

    /// Broadcast the weight gate of layer `li` to the full weight shape.
    pub fn materialize_w(&self, arch: &ArchSpec, li: usize) -> Tensor {
        match self.granularity {
            Granularity::Individual => self.gates_w[li].clone(),
            Granularity::Layer => {
                Tensor::full(&arch.layers[li].w_shape, self.gates_w[li].data()[0])
            }
        }
    }

    /// Broadcast the activation gate of quant-act layer index `ai`.
    pub fn materialize_a(&self, arch: &ArchSpec, ai: usize) -> Tensor {
        match self.granularity {
            Granularity::Individual => self.gates_a[ai].clone(),
            Granularity::Layer => {
                let l = arch.layers.iter().filter(|l| l.quant_act).nth(ai).expect("act layer");
                Tensor::full(&l.act_shape, self.gates_a[ai].data()[0])
            }
        }
    }

    /// All materialized weight gates in layer order.
    pub fn materialize_all_w(&self, arch: &ArchSpec) -> Vec<Tensor> {
        (0..arch.layers.len()).map(|li| self.materialize_w(arch, li)).collect()
    }

    /// All materialized activation gates in quant-act-layer order.
    pub fn materialize_all_a(&self, arch: &ArchSpec) -> Vec<Tensor> {
        (0..self.gates_a.len()).map(|ai| self.materialize_a(arch, ai)).collect()
    }

    /// Histogram of weight bit-widths {2,4,8,16,32} -> count (reporting).
    pub fn weight_bit_histogram(&self, arch: &ArchSpec) -> Vec<(u32, u64)> {
        let mut counts = std::collections::BTreeMap::new();
        for b in BIT_LEVELS {
            counts.insert(b, 0u64);
        }
        for (li, g) in self.gates_w.iter().enumerate() {
            match self.granularity {
                Granularity::Individual => {
                    for &v in g.data() {
                        *counts.entry(transform_t(v)).or_insert(0) += 1;
                    }
                }
                Granularity::Layer => {
                    let n = arch.layers[li].w_len() as u64;
                    *counts.entry(transform_t(g.data()[0])).or_insert(0) += n;
                }
            }
        }
        counts.into_iter().collect()
    }

    /// Mean weight bit-width (reporting).
    pub fn mean_weight_bits(&self, arch: &ArchSpec) -> f64 {
        let hist = self.weight_bit_histogram(arch);
        let total: u64 = hist.iter().map(|(_, c)| c).sum();
        if total == 0 {
            return 0.0;
        }
        hist.iter().map(|&(b, c)| b as f64 * c as f64).sum::<f64>() / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{lenet5, mlp};

    #[test]
    fn init_all_32_bit() {
        let a = mlp();
        for gran in [Granularity::Layer, Granularity::Individual] {
            let gs = GateSet::new(&a, gran);
            assert_eq!(gs.gates_w.len(), 3);
            assert_eq!(gs.gates_a.len(), 2);
            let hist = gs.weight_bit_histogram(&a);
            let total: u64 = a.layers.iter().map(|l| l.w_len() as u64).sum();
            assert_eq!(hist, vec![(2, 0), (4, 0), (8, 0), (16, 0), (32, total)]);
        }
    }

    #[test]
    fn storage_shapes_by_granularity() {
        let a = lenet5();
        let layer = GateSet::new(&a, Granularity::Layer);
        assert_eq!(layer.gates_w[0].len(), 1);
        let indiv = GateSet::new(&a, Granularity::Individual);
        assert_eq!(indiv.gates_w[0].shape(), &[20, 1, 5, 5]);
        assert_eq!(indiv.gates_a[0].shape(), &[20, 24, 24]);
    }

    #[test]
    fn materialize_broadcasts() {
        let a = mlp();
        let mut gs = GateSet::new(&a, Granularity::Layer);
        gs.gates_w[1] = Tensor::scalar(1.5);
        let m = gs.materialize_w(&a, 1);
        assert_eq!(m.shape(), &[128, 64]);
        assert!(m.data().iter().all(|&v| v == 1.5));
    }

    #[test]
    fn clamp_applies_floor_and_cap() {
        let a = mlp();
        let mut gs = GateSet::new(&a, Granularity::Layer);
        gs.gates_w[0] = Tensor::scalar(-3.0);
        gs.gates_a[0] = Tensor::scalar(99.0);
        gs.clamp();
        assert_eq!(gs.gates_w[0].data()[0], GATE_FLOOR);
        assert_eq!(gs.gates_a[0].data()[0], gs.cap);
    }

    #[test]
    fn mean_bits_mixed() {
        let a = mlp();
        let mut gs = GateSet::new(&a, Granularity::Layer);
        // fc1 -> 2 bit, fc2 -> 8 bit, fc3 -> 32 bit
        gs.gates_w[0] = Tensor::scalar(0.5);
        gs.gates_w[1] = Tensor::scalar(2.5);
        gs.gates_w[2] = Tensor::scalar(5.5);
        let n1 = (784 * 128) as f64;
        let n2 = (128 * 64) as f64;
        let n3 = (64 * 10) as f64;
        let expect = (2.0 * n1 + 8.0 * n2 + 32.0 * n3) / (n1 + n2 + n3);
        assert!((gs.mean_weight_bits(&a) - expect).abs() < 1e-9);
    }
}
