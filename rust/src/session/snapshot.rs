//! Model-state snapshots (the constraint-satisfying candidates the trainer
//! keeps while the CGMQ loop explores).

use std::path::Path;

use anyhow::Result;

use crate::checkpoint::Checkpoint;
use crate::gates::GateSet;
use crate::tensor::Tensor;

/// A full model state captured at an epoch end.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub params: Vec<Tensor>,
    pub betas_w: Tensor,
    pub betas_a: Tensor,
    pub gates: GateSet,
    pub test_acc: f64,
    pub rbop_percent: f64,
}

impl Snapshot {
    /// Persist the snapshot (params + ranges + gates) as a checkpoint.
    pub fn save(&self, path: &Path, arch_name: &str) -> Result<()> {
        let mut c = Checkpoint::new();
        c.insert_all("params", &self.params);
        c.insert("betas_w", self.betas_w.clone());
        c.insert("betas_a", self.betas_a.clone());
        c.insert_all("gates_w", &self.gates.gates_w);
        c.insert_all("gates_a", &self.gates.gates_a);
        c.meta.insert("arch".into(), arch_name.to_string());
        c.meta.insert("granularity".into(), self.gates.granularity.label().to_string());
        c.meta.insert("test_acc".into(), format!("{:.6}", self.test_acc));
        c.meta.insert("rbop_percent".into(), format!("{:.6}", self.rbop_percent));
        c.save(path)
    }
}
