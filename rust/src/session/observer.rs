//! Observers — the event bus the training loop reports into.
//!
//! Metrics logging, checkpoint-on-best, the RBOP constraint trace and any
//! user instrumentation subscribe to the stream of training events instead
//! of being woven through the loop: a [`Stage`](super::Stage) drives
//! [`TrainCtx`](super::TrainCtx) primitives, and the context broadcasts
//!
//! * `on_stage_start` / `on_stage_end` — pipeline progress;
//! * `on_epoch_end` — one [`EpochRecord`] per trained epoch (any phase);
//! * `on_constraint_check` — the end-of-epoch BOP constraint verdict that
//!   drives the Sat/Unsat dir dispatch (paper §2.5);
//! * `on_snapshot` — a new best constraint-satisfying model was kept.
//!
//! Observer callbacks are infallible by design: an observer must not be
//! able to abort training. IO-backed observers (e.g.
//! [`JsonlMetricsObserver`]) report their own failures to stderr.

use std::io::Write as _;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::metrics::EpochRecord;
use crate::util::json::Json;

use super::stage::StageReport;
use super::Snapshot;

/// End-of-epoch constraint verdict (paper §2.5).
#[derive(Debug, Clone)]
pub struct ConstraintEvent {
    /// Stage phase label ("cgmq", "penalty", ...).
    pub phase: String,
    pub epoch: usize,
    pub rbop_percent: f64,
    pub bound_percent: f64,
    pub satisfied: bool,
}

/// A new best constraint-satisfying model was captured.
pub struct SnapshotEvent<'a> {
    pub arch: &'a str,
    pub epoch: usize,
    pub test_acc: f64,
    pub rbop_percent: f64,
    pub snapshot: &'a Snapshot,
}

/// Subscriber to training events. All methods default to no-ops so an
/// observer implements only what it cares about.
pub trait Observer {
    fn on_stage_start(&mut self, _stage: &str) {}
    fn on_stage_end(&mut self, _report: &StageReport) {}
    fn on_epoch_end(&mut self, _record: &EpochRecord) {}
    fn on_constraint_check(&mut self, _event: &ConstraintEvent) {}
    fn on_snapshot(&mut self, _event: &SnapshotEvent<'_>) {}
}

/// Fan-out bus: broadcasts each event to every attached observer in
/// attachment order.
#[derive(Default)]
pub struct ObserverBus {
    observers: Vec<Box<dyn Observer>>,
}

impl ObserverBus {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn attach(&mut self, observer: Box<dyn Observer>) {
        self.observers.push(observer);
    }

    pub fn len(&self) -> usize {
        self.observers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.observers.is_empty()
    }

    pub fn stage_start(&mut self, stage: &str) {
        for o in &mut self.observers {
            o.on_stage_start(stage);
        }
    }

    pub fn stage_end(&mut self, report: &StageReport) {
        for o in &mut self.observers {
            o.on_stage_end(report);
        }
    }

    pub fn epoch_end(&mut self, record: &EpochRecord) {
        for o in &mut self.observers {
            o.on_epoch_end(record);
        }
    }

    pub fn constraint_check(&mut self, event: &ConstraintEvent) {
        for o in &mut self.observers {
            o.on_constraint_check(event);
        }
    }

    pub fn snapshot(&mut self, event: &SnapshotEvent<'_>) {
        for o in &mut self.observers {
            o.on_snapshot(event);
        }
    }
}

// ---------------------------------------------------------------------------
// Built-in observers
// ---------------------------------------------------------------------------

/// Streams every event as one JSON object per line (JSONL), so per-epoch
/// trajectories can be scraped by tooling without parsing stdout.
///
/// Line shapes (discriminated by the `"event"` key):
///
/// ```text
/// {"event":"stage_start","stage":"cgmq"}
/// {"event":"epoch","phase":"cgmq","epoch":3,"train_loss":...,"test_acc":...}
/// {"event":"constraint_check","phase":"cgmq","epoch":3,"rbop_percent":...}
/// {"event":"snapshot","epoch":3,"test_acc":...,"rbop_percent":...}
/// {"event":"stage_end","stage":"cgmq","epochs_run":10,"secs":...}
/// ```
pub struct JsonlMetricsObserver {
    path: PathBuf,
    file: std::io::BufWriter<std::fs::File>,
}

impl JsonlMetricsObserver {
    /// Create (truncate) the JSONL file, creating parent directories.
    pub fn create(path: impl Into<PathBuf>) -> Result<Self> {
        let path = path.into();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let file = std::fs::File::create(&path)
            .with_context(|| format!("creating {}", path.display()))?;
        Ok(Self { path, file: std::io::BufWriter::new(file) })
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    fn write_line(&mut self, json: Json) {
        let ok = writeln!(self.file, "{}", json.to_string()).and_then(|_| self.file.flush());
        if ok.is_err() {
            eprintln!("warning: failed writing metrics line to {}", self.path.display());
        }
    }
}

fn tagged(event: &str, json: Json) -> Json {
    match json {
        Json::Obj(mut m) => {
            m.insert("event".into(), Json::str(event));
            Json::Obj(m)
        }
        other => other,
    }
}

impl Observer for JsonlMetricsObserver {
    fn on_stage_start(&mut self, stage: &str) {
        self.write_line(tagged(
            "stage_start",
            Json::obj(vec![("stage", Json::str(stage))]),
        ));
    }

    fn on_stage_end(&mut self, report: &StageReport) {
        self.write_line(tagged("stage_end", report.to_json()));
    }

    fn on_epoch_end(&mut self, record: &EpochRecord) {
        self.write_line(tagged("epoch", record.to_json()));
    }

    fn on_constraint_check(&mut self, ev: &ConstraintEvent) {
        self.write_line(tagged(
            "constraint_check",
            Json::obj(vec![
                ("phase", Json::str(ev.phase.clone())),
                ("epoch", Json::num(ev.epoch as f64)),
                ("rbop_percent", Json::num(ev.rbop_percent)),
                ("bound_percent", Json::num(ev.bound_percent)),
                ("satisfied", Json::Bool(ev.satisfied)),
            ]),
        ));
    }

    fn on_snapshot(&mut self, ev: &SnapshotEvent<'_>) {
        self.write_line(tagged(
            "snapshot",
            Json::obj(vec![
                ("arch", Json::str(ev.arch)),
                ("epoch", Json::num(ev.epoch as f64)),
                ("test_acc", Json::num(ev.test_acc)),
                ("rbop_percent", Json::num(ev.rbop_percent)),
            ]),
        ));
    }
}

/// Persists every new best constraint-satisfying model to a fixed path, so
/// a long CGMQ run always has its current deliverable on disk.
pub struct BestSnapshotSaver {
    pub path: PathBuf,
}

impl BestSnapshotSaver {
    pub fn new(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into() }
    }
}

impl Observer for BestSnapshotSaver {
    fn on_snapshot(&mut self, ev: &SnapshotEvent<'_>) {
        if let Err(e) = ev.snapshot.save(&self.path, ev.arch) {
            eprintln!("warning: failed saving best snapshot to {}: {e:#}", self.path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    /// Observer that journals every callback in order (shared handle).
    struct Recorder(Rc<RefCell<Vec<String>>>);

    impl Observer for Recorder {
        fn on_stage_start(&mut self, stage: &str) {
            self.0.borrow_mut().push(format!("start:{stage}"));
        }
        fn on_stage_end(&mut self, report: &StageReport) {
            self.0.borrow_mut().push(format!("end:{}", report.stage));
        }
        fn on_epoch_end(&mut self, r: &EpochRecord) {
            self.0.borrow_mut().push(format!("epoch:{}:{}", r.phase, r.epoch));
        }
        fn on_constraint_check(&mut self, ev: &ConstraintEvent) {
            self.0.borrow_mut().push(format!("check:{}:{}", ev.epoch, ev.satisfied));
        }
    }

    fn rec(epoch: usize) -> EpochRecord {
        EpochRecord {
            phase: "cgmq".into(),
            epoch,
            train_loss: 0.1,
            test_acc: 0.9,
            rbop_percent: 1.0,
            sat: true,
            mean_weight_bits: 8.0,
            secs: 0.0,
        }
    }

    #[test]
    fn bus_broadcasts_in_order() {
        let seen = Rc::new(RefCell::new(Vec::new()));
        let mut bus = ObserverBus::new();
        bus.attach(Box::new(Recorder(seen.clone())));
        bus.stage_start("cgmq");
        bus.epoch_end(&rec(0));
        bus.constraint_check(&ConstraintEvent {
            phase: "cgmq".into(),
            epoch: 0,
            rbop_percent: 1.0,
            bound_percent: 2.0,
            satisfied: true,
        });
        bus.epoch_end(&rec(1));
        bus.stage_end(&StageReport::new("cgmq"));
        assert_eq!(
            *seen.borrow(),
            vec!["start:cgmq", "epoch:cgmq:0", "check:0:true", "epoch:cgmq:1", "end:cgmq"]
        );
    }

    #[test]
    fn bus_fans_out_to_all_observers() {
        let a = Rc::new(RefCell::new(Vec::new()));
        let b = Rc::new(RefCell::new(Vec::new()));
        let mut bus = ObserverBus::new();
        bus.attach(Box::new(Recorder(a.clone())));
        bus.attach(Box::new(Recorder(b.clone())));
        assert_eq!(bus.len(), 2);
        bus.epoch_end(&rec(7));
        assert_eq!(*a.borrow(), vec!["epoch:cgmq:7"]);
        assert_eq!(*b.borrow(), vec!["epoch:cgmq:7"]);
    }

    #[test]
    fn jsonl_observer_writes_tagged_lines() {
        let dir = std::env::temp_dir().join("cgmq_observer_tests");
        let path = dir.join("metrics.jsonl");
        let mut o = JsonlMetricsObserver::create(&path).unwrap();
        o.on_stage_start("pretrain");
        o.on_epoch_end(&rec(0));
        o.on_constraint_check(&ConstraintEvent {
            phase: "cgmq".into(),
            epoch: 0,
            rbop_percent: 1.5,
            bound_percent: 0.4,
            satisfied: false,
        });
        drop(o);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let first = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(first.get("event").unwrap().as_str().unwrap(), "stage_start");
        let second = crate::util::json::parse(lines[1]).unwrap();
        assert_eq!(second.get("event").unwrap().as_str().unwrap(), "epoch");
        assert_eq!(second.get("epoch").unwrap().as_usize().unwrap(), 0);
        let third = crate::util::json::parse(lines[2]).unwrap();
        assert_eq!(third.get("event").unwrap().as_str().unwrap(), "constraint_check");
        assert!(!third.get("satisfied").unwrap().as_bool().unwrap());
    }
}
