//! `TrainCtx` — the shared training context every [`Stage`](super::Stage)
//! operates on.
//!
//! This owns what the old monolithic `Trainer` owned: config, arch,
//! compiled artifacts, model parameters, quantization ranges, gates,
//! optimizers, data, the constraint, and the run bookkeeping (metrics log,
//! RBOP trace, best constraint-satisfying snapshot). Stages are thin
//! orchestrators; every *primitive* training operation (one pretrain epoch,
//! one calibration pass, one QAT epoch, evaluation, checkpoint IO) lives
//! here so alternative stage sequences can recombine them freely.
//!
//! The context also carries the [`ObserverBus`]: stages report epoch ends,
//! constraint checks and snapshots through `record_epoch` /
//! `end_of_epoch_check` / `offer_snapshot`, and every attached
//! [`Observer`](super::Observer) sees the same event stream.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{Config, DataSource};
use crate::cost::{model_bops, rbop_percent, CostConstraint};
use crate::data::{Batch, Batcher, Dataset};
use crate::direction::{dir_tensor_a, dir_tensor_w, DirConfig, Sat};
use crate::gates::GateSet;
use crate::metrics::{accuracy, EpochRecord, MetricsLog};
use crate::model::{arch_by_name, ArchSpec};
use crate::optim::{Adam, GateGd};
use crate::runtime::{Arg, ArtifactSet};
use crate::tensor::{Tensor, TensorI32};

use super::observer::{ConstraintEvent, ObserverBus, SnapshotEvent};
use super::{RunResult, Snapshot};

/// Everything a stage needs to train one CGMQ run.
pub struct TrainCtx {
    pub cfg: Config,
    pub arch: ArchSpec,
    pub artifacts: ArtifactSet,
    // --- model state ---
    pub params: Vec<Tensor>,
    pub betas_w: Tensor,
    pub betas_a: Tensor,
    pub gates: GateSet,
    // --- optimization state ---
    adam: Adam,
    gate_gd: GateGd,
    pub dir_cfg: DirConfig,
    /// Constraint state decided at the previous epoch end (paper §2.5).
    pub sat: Sat,
    // --- data ---
    pub train_data: Dataset,
    pub test_data: Dataset,
    batcher: Batcher,
    // --- bookkeeping ---
    pub constraint: CostConstraint,
    pub log: MetricsLog,
    /// Float test accuracy, recorded by `Pretrain` / `LoadCheckpoint`.
    pub float_acc: Option<f64>,
    best: Option<Snapshot>,
    /// RBOP (%) at the end of every CGMQ epoch — the constraint trace (G1).
    pub rbop_trace: Vec<f64>,
    /// Event bus all observers are attached to.
    pub bus: ObserverBus,
}

impl TrainCtx {
    /// Build a context: load artifacts, verify the manifest, init state.
    pub fn new(cfg: Config) -> Result<Self> {
        cfg.validate()?;
        let arch = arch_by_name(&cfg.arch)?;
        let mut artifacts = ArtifactSet::open(Path::new(&cfg.artifacts_dir))?;
        artifacts.verify_arch(&arch)?;
        for kind in ["float_step", "qat_step", "eval", "eval_float", "calibrate"] {
            artifacts.load(&format!("{}_{kind}", arch.name))?;
        }

        let (train_data, test_data) = load_data(&cfg, &arch)?;
        let params = arch.init_params(cfg.seed);
        let n_layers = arch.layers.len();
        let n_act = arch.n_quant_act();
        let betas_w = Tensor::full(&[n_layers], 1.0);
        let betas_a = Tensor::full(&[n_act], 6.0);
        let gates = GateSet::with_init(&arch, cfg.granularity, cfg.gate_init);

        // One Adam instance over [params..., betas_w, betas_a] (paper §4.2:
        // weights and quantization ranges share Adam at lr 1e-3).
        let mut shapes = arch.param_shapes();
        shapes.push(vec![n_layers]);
        shapes.push(vec![n_act]);
        let adam = Adam::new(cfg.lr_weights, &shapes);

        let mut dir_cfg = DirConfig::new(cfg.direction);
        dir_cfg.clip_min = cfg.dir_clip_min;
        dir_cfg.clip_max = cfg.dir_clip_max;

        let batcher = Batcher::new(train_data.len(), arch.train_batch, cfg.seed ^ 0xBA7C4);
        let constraint = CostConstraint::new(cfg.bound_rbop_percent);

        Ok(Self {
            gate_gd: GateGd::new(cfg.lr_gates),
            cfg,
            arch,
            artifacts,
            params,
            betas_w,
            betas_a,
            gates,
            adam,
            dir_cfg,
            sat: Sat::Unsatisfied,
            train_data,
            test_data,
            batcher,
            constraint,
            log: MetricsLog::new(),
            float_acc: None,
            best: None,
            rbop_trace: Vec::new(),
            bus: ObserverBus::new(),
        })
    }

    // ------------------------------------------------------------------
    // Primitive training operations (one epoch / one pass each)
    // ------------------------------------------------------------------

    /// One epoch of float training; returns the mean batch loss.
    pub fn pretrain_epoch(&mut self) -> Result<f64> {
        let name = format!("{}_float_step", self.arch.name);
        let batches = self.batcher.epoch(&self.train_data);
        let mut loss_sum = 0.0;
        for batch in &batches {
            let (x, y) = self.batch_tensors(batch, self.arch.train_batch)?;
            let mut args: Vec<Arg> = self.params.iter().map(Arg::F32).collect();
            args.push(Arg::F32(&x));
            args.push(Arg::I32(&y));
            let out = self.artifacts.get(&name)?.run(&args)?;
            loss_sum += out[0].item()? as f64;
            let grads = &out[1..1 + self.params.len()];
            // Adam state covers params + betas; pad beta grads with zero.
            let mut full_grads: Vec<Tensor> = grads.to_vec();
            full_grads.push(Tensor::zeros(self.betas_w.shape()));
            full_grads.push(Tensor::zeros(self.betas_a.shape()));
            self.adam_step(&full_grads)?;
        }
        Ok(loss_sum / batches.len() as f64)
    }

    /// One range-calibration pass (paper §2.4): exact per-layer max |w| for
    /// weight ranges, running mean (momentum) of per-batch max |activation|
    /// for activation ranges.
    pub fn calibrate_pass(&mut self) -> Result<()> {
        let n_layers = self.arch.layers.len();
        for li in 0..n_layers {
            self.betas_w.data_mut()[li] = self.params[2 * li].abs_max().max(1e-3);
        }
        let name = format!("{}_calibrate", self.arch.name);
        let momentum = self.cfg.calib_momentum;
        let batches = self.batcher.epoch(&self.train_data);
        let mut running: Option<Vec<f32>> = None;
        for batch in &batches {
            let (x, _) = self.batch_tensors(batch, self.arch.train_batch)?;
            let mut args: Vec<Arg> = self.params.iter().map(Arg::F32).collect();
            args.push(Arg::F32(&x));
            let out = self.artifacts.get(&name)?.run(&args)?;
            let act_maxes = out[1].data();
            running = Some(match running {
                None => act_maxes.to_vec(),
                Some(prev) => prev
                    .iter()
                    .zip(act_maxes)
                    .map(|(&r, &m)| (1.0 - momentum) * r + momentum * m)
                    .collect(),
            });
        }
        let running = running.context("no calibration batches")?;
        for (i, r) in running.iter().enumerate() {
            self.betas_a.data_mut()[i] = r.max(1e-3);
        }
        Ok(())
    }

    /// One epoch of QAT steps with the paper's CGMQ gate policy (or none).
    pub fn qat_epoch(&mut self, update_gates: bool) -> Result<f64> {
        if update_gates {
            self.qat_epoch_with(Some(&CgmqPolicy))
        } else {
            self.qat_epoch_with(None)
        }
    }

    /// One epoch of QAT steps; weights+ranges always get Adam, gates are
    /// driven by the supplied policy (CGMQ's dirs, a baseline's penalty, or
    /// nothing).
    pub fn qat_epoch_with(&mut self, policy: Option<&dyn GatePolicy>) -> Result<f64> {
        let name = format!("{}_qat_step", self.arch.name);
        let batches = self.batcher.epoch(&self.train_data);
        let n_p = self.params.len();
        let n_a = self.arch.n_quant_act();
        let mut loss_sum = 0.0;
        for batch in &batches {
            let (x, y) = self.batch_tensors(batch, self.arch.train_batch)?;
            let gw = self.gates.materialize_all_w(&self.arch);
            let ga = self.gates.materialize_all_a(&self.arch);
            let mut args: Vec<Arg> = self.params.iter().map(Arg::F32).collect();
            args.push(Arg::F32(&self.betas_w));
            args.push(Arg::F32(&self.betas_a));
            args.extend(gw.iter().map(Arg::F32));
            args.extend(ga.iter().map(Arg::F32));
            args.push(Arg::F32(&x));
            args.push(Arg::I32(&y));
            let out = self.artifacts.get(&name)?.run(&args)?;
            // outputs: loss, param grads, grad betas_w, grad betas_a,
            //          act_grads (n_a), act_means (n_a)
            loss_sum += out[0].item()? as f64;
            let mut full_grads: Vec<Tensor> = out[1..1 + n_p].to_vec();
            full_grads.push(out[1 + n_p].clone());
            full_grads.push(out[2 + n_p].clone());

            if let Some(policy) = policy {
                let inputs = PolicyInputs {
                    arch: &self.arch,
                    sat: self.sat,
                    grads: &full_grads[..n_p],
                    params: &self.params,
                    act_grads: &out[3 + n_p..3 + n_p + n_a],
                    act_means: &out[3 + n_p + n_a..3 + n_p + 2 * n_a],
                    gates: &self.gates,
                    dir_cfg: &self.dir_cfg,
                };
                let (dirs_w, dirs_a) = policy.dirs(&inputs)?;
                self.gate_gd.step(&mut self.gates.gates_w, &dirs_w)?;
                self.gate_gd.step(&mut self.gates.gates_a, &dirs_a)?;
                self.gates.clamp();
            }
            self.adam_step(&full_grads)?;
        }
        Ok(loss_sum / batches.len() as f64)
    }

    // ------------------------------------------------------------------
    // Evaluation
    // ------------------------------------------------------------------

    /// Quantized test accuracy (the paper's Acc column).
    pub fn evaluate(&self) -> Result<f64> {
        self.eval_with(&self.gates, &self.params, &self.betas_w, &self.betas_a)
    }

    /// Quantized accuracy for an explicit state (snapshots, baselines).
    pub fn eval_with(
        &self,
        gates: &GateSet,
        params: &[Tensor],
        betas_w: &Tensor,
        betas_a: &Tensor,
    ) -> Result<f64> {
        let name = format!("{}_eval", self.arch.name);
        let exe = self.artifacts.get(&name)?;
        let batch_size = self.arch.eval_batch;
        let gw = gates.materialize_all_w(&self.arch);
        let ga = gates.materialize_all_a(&self.arch);
        let (mut correct, mut total) = (0u64, 0u64);
        for batch in Batcher::sequential(&self.test_data, batch_size) {
            let (x, _) = self.batch_tensors(&batch, batch_size)?;
            let mut args: Vec<Arg> = params.iter().map(Arg::F32).collect();
            args.push(Arg::F32(betas_w));
            args.push(Arg::F32(betas_a));
            args.extend(gw.iter().map(Arg::F32));
            args.extend(ga.iter().map(Arg::F32));
            args.push(Arg::F32(&x));
            let out = exe.run(&args)?;
            let preds = out[0].argmax_rows()?;
            let (c, t) = accuracy(&preds, &batch.labels, batch.valid);
            correct += c;
            total += t;
        }
        Ok(correct as f64 / total as f64)
    }

    /// Float test accuracy (the paper's FP32 row).
    pub fn evaluate_float(&self) -> Result<f64> {
        let name = format!("{}_eval_float", self.arch.name);
        let exe = self.artifacts.get(&name)?;
        let batch_size = self.arch.eval_batch;
        let (mut correct, mut total) = (0u64, 0u64);
        for batch in Batcher::sequential(&self.test_data, batch_size) {
            let (x, _) = self.batch_tensors(&batch, batch_size)?;
            let mut args: Vec<Arg> = self.params.iter().map(Arg::F32).collect();
            args.push(Arg::F32(&x));
            let out = exe.run(&args)?;
            let preds = out[0].argmax_rows()?;
            let (c, t) = accuracy(&preds, &batch.labels, batch.valid);
            correct += c;
            total += t;
        }
        Ok(correct as f64 / total as f64)
    }

    // ------------------------------------------------------------------
    // Constraint + bookkeeping (event-emitting)
    // ------------------------------------------------------------------

    pub fn current_rbop(&self) -> Result<f64> {
        let bops = model_bops(
            &self.arch,
            &self.gates.materialize_all_w(&self.arch),
            &self.gates.materialize_all_a(&self.arch),
        )?;
        Ok(rbop_percent(&self.arch, bops))
    }

    pub fn check_constraint(&self) -> Result<Sat> {
        let bops = model_bops(
            &self.arch,
            &self.gates.materialize_all_w(&self.arch),
            &self.gates.materialize_all_a(&self.arch),
        )?;
        Ok(if self.constraint.is_satisfied(&self.arch, bops) {
            Sat::Satisfied
        } else {
            Sat::Unsatisfied
        })
    }

    /// Current `(rbop, satisfied)` of the live gate state, *without*
    /// touching the Sat/Unsat dir state or the G1 trace — for stages
    /// (baselines) whose epochs are not CGMQ epochs.
    pub fn constraint_status(&self) -> Result<(f64, bool)> {
        let bops = model_bops(
            &self.arch,
            &self.gates.materialize_all_w(&self.arch),
            &self.gates.materialize_all_a(&self.arch),
        )?;
        Ok((rbop_percent(&self.arch, bops), self.constraint.is_satisfied(&self.arch, bops)))
    }

    /// The end-of-epoch BOP constraint check (paper §2.5): updates the
    /// Sat/Unsat state that selects the next epoch's dir case, appends to
    /// the RBOP trace, and notifies observers. Returns `(rbop, satisfied)`.
    pub fn end_of_epoch_check(&mut self, phase: &str, epoch: usize) -> Result<(f64, bool)> {
        let bops = model_bops(
            &self.arch,
            &self.gates.materialize_all_w(&self.arch),
            &self.gates.materialize_all_a(&self.arch),
        )?;
        let rbop = rbop_percent(&self.arch, bops);
        let satisfied = self.constraint.is_satisfied(&self.arch, bops);
        self.sat = if satisfied { Sat::Satisfied } else { Sat::Unsatisfied };
        self.rbop_trace.push(rbop);
        self.bus.constraint_check(&ConstraintEvent {
            phase: phase.to_string(),
            epoch,
            rbop_percent: rbop,
            bound_percent: self.cfg.bound_rbop_percent,
            satisfied,
        });
        Ok((rbop, satisfied))
    }

    /// Record one epoch: observers first, then the context's own log.
    pub fn record_epoch(&mut self, rec: EpochRecord) {
        self.bus.epoch_end(&rec);
        self.log.push(rec);
    }

    /// Offer a constraint-satisfying epoch-end state as the delivered
    /// model; kept (and announced to observers) if it beats the incumbent.
    pub fn offer_snapshot(&mut self, test_acc: f64, rbop: f64, epoch: usize) {
        let better = match &self.best {
            None => true,
            Some(b) => test_acc > b.test_acc,
        };
        if better {
            let snap = self.snapshot(test_acc, rbop);
            self.bus.snapshot(&SnapshotEvent {
                arch: self.arch.name,
                epoch,
                test_acc,
                rbop_percent: rbop,
                snapshot: &snap,
            });
            self.best = Some(snap);
        }
    }

    /// The delivered model: best accuracy among constraint-satisfying
    /// epoch-end snapshots (the paper's guarantee as an API property).
    pub fn final_model(&self) -> Result<Snapshot> {
        match &self.best {
            Some(s) => Ok(s.clone()),
            None => bail!(
                "no constraint-satisfying model found after {} CGMQ epochs \
                 (bound {}%, last RBOP {:?}%) — increase cgmq_epochs",
                self.rbop_trace.len(),
                self.cfg.bound_rbop_percent,
                self.rbop_trace.last()
            ),
        }
    }

    pub fn snapshot(&self, test_acc: f64, rbop: f64) -> Snapshot {
        Snapshot {
            params: self.params.clone(),
            betas_w: self.betas_w.clone(),
            betas_a: self.betas_a.clone(),
            gates: self.gates.clone(),
            test_acc,
            rbop_percent: rbop,
        }
    }

    // ------------------------------------------------------------------
    // Results
    // ------------------------------------------------------------------

    /// Summary of the finished run, using the float accuracy recorded by
    /// the `Pretrain` / `LoadCheckpoint` stage.
    pub fn result(&self) -> Result<RunResult> {
        let float_acc = self.float_acc.context(
            "no float accuracy recorded — run a Pretrain or LoadCheckpoint stage first",
        )?;
        self.result_with_float_acc(float_acc)
    }

    /// Public result builder for drivers that measured float accuracy
    /// themselves.
    pub fn result_with_float_acc(&self, float_acc: f64) -> Result<RunResult> {
        let final_model = self.final_model()?;
        Ok(RunResult {
            run_id: self.cfg.run_id(),
            float_acc,
            quant_acc: final_model.test_acc,
            rbop_percent: final_model.rbop_percent,
            bound_rbop_percent: self.cfg.bound_rbop_percent,
            satisfied: final_model.rbop_percent <= self.cfg.bound_rbop_percent + 1e-9,
            mean_weight_bits: final_model.gates.mean_weight_bits(&self.arch),
            rbop_trace: self.rbop_trace.clone(),
        })
    }

    // ------------------------------------------------------------------
    // Helpers
    // ------------------------------------------------------------------

    fn adam_step(&mut self, full_grads: &[Tensor]) -> Result<()> {
        // One parameter list: params..., betas_w, betas_a.
        let mut all: Vec<Tensor> = std::mem::take(&mut self.params);
        all.push(std::mem::replace(&mut self.betas_w, Tensor::zeros(&[0])));
        all.push(std::mem::replace(&mut self.betas_a, Tensor::zeros(&[0])));
        let r = self.adam.step(&mut all, full_grads);
        self.betas_a = all.pop().unwrap();
        self.betas_w = all.pop().unwrap();
        self.params = all;
        // Ranges must stay positive (alpha = -beta convention).
        self.betas_w.map_inplace(|b| b.max(1e-4));
        self.betas_a.map_inplace(|b| b.max(1e-4));
        r
    }

    /// Batch -> (x tensor shaped for the arch, y labels).
    pub(crate) fn batch_tensors(
        &self,
        batch: &Batch,
        batch_size: usize,
    ) -> Result<(Tensor, TensorI32)> {
        let mut x_shape = vec![batch_size];
        x_shape.extend_from_slice(&self.arch.input_shape);
        let x = Tensor::new(x_shape, batch.images.clone())?;
        let y = TensorI32::new(vec![batch_size], batch.labels.clone())?;
        Ok((x, y))
    }

    // ------------------------------------------------------------------
    // Checkpointing
    // ------------------------------------------------------------------

    pub fn save_params(&self, path: &Path) -> Result<()> {
        let mut c = crate::checkpoint::Checkpoint::new();
        c.insert_all("params", &self.params);
        c.insert("betas_w", self.betas_w.clone());
        c.insert("betas_a", self.betas_a.clone());
        c.meta.insert("arch".into(), self.arch.name.to_string());
        c.save(path)
    }

    pub fn load_params(&mut self, path: &Path) -> Result<()> {
        let c = crate::checkpoint::Checkpoint::load(path)?;
        if let Some(a) = c.meta.get("arch") {
            if a != self.arch.name {
                bail!("checkpoint is for arch '{a}', session is '{}'", self.arch.name);
            }
        }
        let params = c.get_all("params")?;
        let shapes = self.arch.param_shapes();
        if params.len() != shapes.len() {
            bail!("checkpoint has {} param tensors, arch wants {}", params.len(), shapes.len());
        }
        for (p, s) in params.iter().zip(&shapes) {
            if p.shape() != s.as_slice() {
                bail!("checkpoint param shape {:?} != arch {:?}", p.shape(), s);
            }
        }
        self.params = params;
        if let Ok(bw) = c.get("betas_w") {
            self.betas_w = bw.clone();
        }
        if let Ok(ba) = c.get("betas_a") {
            self.betas_a = ba.clone();
        }
        Ok(())
    }
}

/// Per-step inputs a gate policy may use to construct its update.
pub struct PolicyInputs<'a> {
    pub arch: &'a ArchSpec,
    /// Constraint state from the *previous* epoch end (paper §2.5).
    pub sat: Sat,
    /// Parameter gradients in (w, b) layer order (batch-mean loss).
    pub grads: &'a [Tensor],
    pub params: &'a [Tensor],
    /// Batch-mean loss gradient per quantized activation (probe outputs).
    pub act_grads: &'a [Tensor],
    /// Batch-mean activation values.
    pub act_means: &'a [Tensor],
    pub gates: &'a GateSet,
    pub dir_cfg: &'a DirConfig,
}

/// A per-step gate update rule: returns (dirs_w, dirs_a) shaped like the
/// gate *stores* (scalars for layer granularity, tensors for individual).
pub trait GatePolicy {
    fn dirs(&self, inputs: &PolicyInputs) -> Result<(Vec<Tensor>, Vec<Tensor>)>;
}

/// The paper's CGMQ policy: dir1/dir2/dir3 dispatched on Sat/Unsat.
pub struct CgmqPolicy;

impl GatePolicy for CgmqPolicy {
    fn dirs(&self, t: &PolicyInputs) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let n_l = t.arch.layers.len();
        let mut dirs_w = Vec::with_capacity(n_l);
        for li in 0..n_l {
            dirs_w.push(dir_tensor_w(
                t.dir_cfg,
                t.gates.granularity,
                t.sat,
                &t.grads[2 * li],
                &t.params[2 * li],
                &t.gates.gates_w[li],
            )?);
        }
        let mut dirs_a = Vec::with_capacity(t.act_grads.len());
        for ai in 0..t.act_grads.len() {
            dirs_a.push(dir_tensor_a(
                t.dir_cfg,
                t.gates.granularity,
                t.sat,
                &t.act_grads[ai],
                &t.act_means[ai],
                &t.gates.gates_a[ai],
            )?);
        }
        Ok((dirs_w, dirs_a))
    }
}

fn load_data(cfg: &Config, arch: &ArchSpec) -> Result<(Dataset, Dataset)> {
    match &cfg.data {
        DataSource::Synth => {
            // Independent seeds for train/test streams; the generator is
            // balanced by construction.
            let train = Dataset::synth(cfg.seed, cfg.train_size);
            let test = Dataset::synth(cfg.seed ^ 0x5EED_7E57, cfg.test_size);
            check_sample_len(arch, train.sample_len)?;
            Ok((train, test))
        }
        DataSource::Mnist(dir) => {
            let d = Path::new(dir);
            let train = crate::data::idx::load_pair(
                &d.join("train-images-idx3-ubyte"),
                &d.join("train-labels-idx1-ubyte"),
            )?;
            let test = crate::data::idx::load_pair(
                &d.join("t10k-images-idx3-ubyte"),
                &d.join("t10k-labels-idx1-ubyte"),
            )?;
            let sample_len = train.rows * train.cols;
            check_sample_len(arch, sample_len)?;
            Ok((
                Dataset::new(train.images, train.labels, sample_len)?,
                Dataset::new(test.images, test.labels, sample_len)?,
            ))
        }
    }
}

fn check_sample_len(arch: &ArchSpec, sample_len: usize) -> Result<()> {
    if sample_len != arch.input_len() {
        bail!("dataset sample length {} != arch input {}", sample_len, arch.input_len());
    }
    Ok(())
}
