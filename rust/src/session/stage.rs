//! Pipeline stages — the paper's four phases (and baseline building
//! blocks) as first-class, recomposable values.
//!
//! A [`Stage`] is one self-contained segment of a training pipeline. The
//! paper's CGMQ recipe is the sequence
//! `[Pretrain, Calibrate, RangeLearn, CgmqLoop]`
//! (what [`SessionBuilder::paper_pipeline`](super::SessionBuilder) installs),
//! but the whole point of the staged API is that other methods are just
//! other sequences over the same [`TrainCtx`]:
//!
//! * fixed-bit QAT     — `[Pretrain, Calibrate, PinGates(b), Finetune]`
//! * resume-from-ckpt  — `[LoadCheckpoint, Calibrate, RangeLearn, CgmqLoop]`
//! * myQASR heuristic  — `[Pretrain, Calibrate, RangeLearn, MyQasrStage]`
//!   (see `baselines::myqasr`)
//!
//! Epoch-count fields default (`None`) to the corresponding `Config`
//! schedule value, so a stage list works across configs.

use std::path::PathBuf;

use anyhow::Result;

use crate::metrics::{EpochRecord, Stopwatch};
use crate::quant::gate_for_bits;
use crate::tensor::Tensor;
use crate::util::json::Json;

use super::ctx::{CgmqPolicy, TrainCtx};

/// One pipeline segment, run to completion over the shared context.
pub trait Stage {
    /// Stable name used for observer events and reports.
    fn name(&self) -> &str;

    fn run(&mut self, ctx: &mut TrainCtx) -> Result<StageReport>;
}

/// What one stage did (returned by every [`Stage::run`]).
#[derive(Debug, Clone)]
pub struct StageReport {
    pub stage: String,
    pub epochs_run: usize,
    pub final_train_loss: Option<f64>,
    pub test_acc: Option<f64>,
    pub rbop_percent: Option<f64>,
    pub secs: f64,
}

impl StageReport {
    pub fn new(stage: impl Into<String>) -> Self {
        Self {
            stage: stage.into(),
            epochs_run: 0,
            final_train_loss: None,
            test_acc: None,
            rbop_percent: None,
            secs: 0.0,
        }
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
        Json::obj(vec![
            ("stage", Json::str(self.stage.clone())),
            ("epochs_run", Json::num(self.epochs_run as f64)),
            ("final_train_loss", opt(self.final_train_loss)),
            ("test_acc", opt(self.test_acc)),
            ("rbop_percent", opt(self.rbop_percent)),
            ("secs", Json::num(self.secs)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Phase 1: float pretraining
// ---------------------------------------------------------------------------

/// Paper phase 1 — float training with Adam (`*_float_step` artifact).
/// Records the float test accuracy in `ctx.float_acc` when done.
#[derive(Debug, Clone, Default)]
pub struct Pretrain {
    /// `None` -> `cfg.pretrain_epochs`.
    pub epochs: Option<usize>,
}

impl Pretrain {
    pub fn epochs(epochs: usize) -> Self {
        Self { epochs: Some(epochs) }
    }
}

impl Stage for Pretrain {
    fn name(&self) -> &str {
        "pretrain"
    }

    fn run(&mut self, ctx: &mut TrainCtx) -> Result<StageReport> {
        let total = Stopwatch::start();
        let epochs = self.epochs.unwrap_or(ctx.cfg.pretrain_epochs);
        let mut report = StageReport::new(self.name());
        for epoch in 0..epochs {
            let sw = Stopwatch::start();
            let loss = ctx.pretrain_epoch()?;
            let acc = ctx.evaluate_float()?;
            ctx.record_epoch(EpochRecord {
                phase: "pretrain".into(),
                epoch,
                train_loss: loss,
                test_acc: acc,
                rbop_percent: 100.0,
                sat: true,
                mean_weight_bits: 32.0,
                secs: sw.secs(),
            });
            report.epochs_run += 1;
            report.final_train_loss = Some(loss);
            report.test_acc = Some(acc);
        }
        // The last epoch's eval already measured the final parameters;
        // only a zero-epoch stage still needs one.
        let float_acc = match report.test_acc {
            Some(acc) => acc,
            None => ctx.evaluate_float()?,
        };
        ctx.float_acc = Some(float_acc);
        report.test_acc = Some(float_acc);
        report.secs = total.secs();
        Ok(report)
    }
}

// ---------------------------------------------------------------------------
// Phase 2: range calibration (paper §2.4)
// ---------------------------------------------------------------------------

/// Paper phase 2 — quantization-range initialization.
#[derive(Debug, Clone, Default)]
pub struct Calibrate;

impl Stage for Calibrate {
    fn name(&self) -> &str {
        "calibrate"
    }

    fn run(&mut self, ctx: &mut TrainCtx) -> Result<StageReport> {
        let total = Stopwatch::start();
        ctx.calibrate_pass()?;
        let mut report = StageReport::new(self.name());
        report.secs = total.secs();
        Ok(report)
    }
}

// ---------------------------------------------------------------------------
// Phase 3: range learning (QAT at 32-bit gates, no gate updates)
// ---------------------------------------------------------------------------

/// Paper phase 3 — QAT over weights *and* ranges with gates frozen.
#[derive(Debug, Clone, Default)]
pub struct RangeLearn {
    /// `None` -> `cfg.range_epochs`.
    pub epochs: Option<usize>,
}

impl RangeLearn {
    pub fn epochs(epochs: usize) -> Self {
        Self { epochs: Some(epochs) }
    }
}

impl Stage for RangeLearn {
    fn name(&self) -> &str {
        "ranges"
    }

    fn run(&mut self, ctx: &mut TrainCtx) -> Result<StageReport> {
        let total = Stopwatch::start();
        let epochs = self.epochs.unwrap_or(ctx.cfg.range_epochs);
        let mut report = StageReport::new(self.name());
        for epoch in 0..epochs {
            let sw = Stopwatch::start();
            let loss = ctx.qat_epoch(false)?;
            let acc = ctx.evaluate()?;
            let rbop = ctx.current_rbop()?;
            ctx.record_epoch(EpochRecord {
                phase: "ranges".into(),
                epoch,
                train_loss: loss,
                test_acc: acc,
                rbop_percent: rbop,
                sat: true,
                mean_weight_bits: ctx.gates.mean_weight_bits(&ctx.arch),
                secs: sw.secs(),
            });
            report.epochs_run += 1;
            report.final_train_loss = Some(loss);
            report.test_acc = Some(acc);
            report.rbop_percent = Some(rbop);
        }
        report.secs = total.secs();
        Ok(report)
    }
}

// ---------------------------------------------------------------------------
// Phase 4: the CGMQ constraint-guided loop (paper §2.2-2.5)
// ---------------------------------------------------------------------------

/// Paper phase 4 — every step updates weights + ranges with Adam and gates
/// with plain GD along the dir rules; the BOP constraint is checked only at
/// the end of each epoch, and that Sat/Unsat outcome selects the dir case
/// for the whole next epoch. Constraint-satisfying epoch ends are offered
/// as the delivered model.
#[derive(Debug, Clone, Default)]
pub struct CgmqLoop {
    /// `None` -> `cfg.cgmq_epochs`.
    pub epochs: Option<usize>,
}

impl CgmqLoop {
    pub fn epochs(epochs: usize) -> Self {
        Self { epochs: Some(epochs) }
    }
}

impl Stage for CgmqLoop {
    fn name(&self) -> &str {
        "cgmq"
    }

    fn run(&mut self, ctx: &mut TrainCtx) -> Result<StageReport> {
        let total = Stopwatch::start();
        let epochs = self.epochs.unwrap_or(ctx.cfg.cgmq_epochs);
        let mut report = StageReport::new(self.name());
        // Initial Sat/Unsat from the current gate state (everything 32-bit
        // -> Unsat for any bound < 100%).
        ctx.sat = ctx.check_constraint()?;
        for epoch in 0..epochs {
            let sw = Stopwatch::start();
            let loss = ctx.qat_epoch_with(Some(&CgmqPolicy))?;
            let (rbop, sat_now) = ctx.end_of_epoch_check("cgmq", epoch)?;
            let acc = ctx.evaluate()?;
            if sat_now {
                ctx.offer_snapshot(acc, rbop, epoch);
            }
            ctx.record_epoch(EpochRecord {
                phase: "cgmq".into(),
                epoch,
                train_loss: loss,
                test_acc: acc,
                rbop_percent: rbop,
                sat: sat_now,
                mean_weight_bits: ctx.gates.mean_weight_bits(&ctx.arch),
                secs: sw.secs(),
            });
            report.epochs_run += 1;
            report.final_train_loss = Some(loss);
            report.test_acc = Some(acc);
            report.rbop_percent = Some(rbop);
        }
        report.secs = total.secs();
        Ok(report)
    }
}

// ---------------------------------------------------------------------------
// Baseline / composition building blocks
// ---------------------------------------------------------------------------

/// Pin every weight and activation gate to one bit-width (classical
/// uniform QAT setup; combine with [`Finetune`]).
#[derive(Debug, Clone)]
pub struct PinGates {
    pub bits: u32,
}

impl PinGates {
    pub fn bits(bits: u32) -> Self {
        Self { bits }
    }
}

impl Stage for PinGates {
    fn name(&self) -> &str {
        "pin-gates"
    }

    fn run(&mut self, ctx: &mut TrainCtx) -> Result<StageReport> {
        if !crate::BIT_LEVELS.contains(&self.bits) {
            anyhow::bail!("bits must be one of {:?}, got {}", crate::BIT_LEVELS, self.bits);
        }
        let g = gate_for_bits(self.bits);
        for t in ctx.gates.gates_w.iter_mut().chain(ctx.gates.gates_a.iter_mut()) {
            *t = Tensor::full(&t.shape().to_vec(), g);
        }
        let mut report = StageReport::new(self.name());
        report.rbop_percent = Some(ctx.current_rbop()?);
        Ok(report)
    }
}

/// QAT finetuning at frozen gates (whatever the gate state currently is).
/// Same mechanics as [`RangeLearn`] but logged under its own phase label
/// and with the honest end-of-epoch sat flag.
#[derive(Debug, Clone, Default)]
pub struct Finetune {
    /// `None` -> `cfg.cgmq_epochs` (the schedule slot baselines reuse).
    pub epochs: Option<usize>,
}

impl Finetune {
    pub fn epochs(epochs: usize) -> Self {
        Self { epochs: Some(epochs) }
    }
}

impl Stage for Finetune {
    fn name(&self) -> &str {
        "finetune"
    }

    fn run(&mut self, ctx: &mut TrainCtx) -> Result<StageReport> {
        let total = Stopwatch::start();
        let epochs = self.epochs.unwrap_or(ctx.cfg.cgmq_epochs);
        let mut report = StageReport::new(self.name());
        for epoch in 0..epochs {
            let sw = Stopwatch::start();
            let loss = ctx.qat_epoch(false)?;
            let acc = ctx.evaluate()?;
            let (rbop, sat) = ctx.constraint_status()?;
            ctx.record_epoch(EpochRecord {
                phase: "finetune".into(),
                epoch,
                train_loss: loss,
                test_acc: acc,
                rbop_percent: rbop,
                sat,
                mean_weight_bits: ctx.gates.mean_weight_bits(&ctx.arch),
                secs: sw.secs(),
            });
            report.epochs_run += 1;
            report.final_train_loss = Some(loss);
            report.test_acc = Some(acc);
            report.rbop_percent = Some(rbop);
        }
        report.secs = total.secs();
        Ok(report)
    }
}

/// Load float parameters (and ranges, if present) from a checkpoint instead
/// of pretraining; records the float accuracy like [`Pretrain`] does, so a
/// `[LoadCheckpoint, Calibrate, RangeLearn, CgmqLoop]` sequence is a drop-in
/// resume pipeline.
#[derive(Debug, Clone)]
pub struct LoadCheckpoint {
    pub path: PathBuf,
    /// Record `ctx.float_acc` after loading (one full float test-set
    /// pass). On by default — `result()` needs it; pipelines that never
    /// build a `RunResult` can opt out.
    pub eval_float: bool,
}

impl LoadCheckpoint {
    pub fn new(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into(), eval_float: true }
    }

    pub fn skip_float_eval(mut self) -> Self {
        self.eval_float = false;
        self
    }
}

impl Stage for LoadCheckpoint {
    fn name(&self) -> &str {
        "load-checkpoint"
    }

    fn run(&mut self, ctx: &mut TrainCtx) -> Result<StageReport> {
        let total = Stopwatch::start();
        ctx.load_params(&self.path)?;
        let mut report = StageReport::new(self.name());
        if self.eval_float {
            let float_acc = ctx.evaluate_float()?;
            ctx.float_acc = Some(float_acc);
            report.test_acc = Some(float_acc);
        }
        report.secs = total.secs();
        Ok(report)
    }
}
