//! The staged training API — the crate's public entry point.
//!
//! A training run is a [`Session`]: a [`TrainCtx`] (model + optimizer +
//! data + artifact state) driven through an ordered list of [`Stage`]s,
//! with [`Observer`]s subscribed to the event bus. [`SessionBuilder`]
//! assembles and validates all three:
//!
//! ```no_run
//! use cgmq::config::Config;
//! use cgmq::session::SessionBuilder;
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut session = SessionBuilder::new(Config::default())
//!     .paper_pipeline() // Pretrain -> Calibrate -> RangeLearn -> CgmqLoop
//!     .build()?;
//! session.run()?;
//! let result = session.result()?; // guaranteed to satisfy the bound
//! println!("acc {:.2}% @ RBOP {:.3}%", 100.0 * result.quant_acc, result.rbop_percent);
//! # Ok(())
//! # }
//! ```
//!
//! Alternative methods are alternative stage sequences — uniform QAT is
//! `[Pretrain, Calibrate, PinGates(b), Finetune]`, resuming from a float
//! checkpoint swaps `Pretrain` for `LoadCheckpoint` — and custom stages
//! (anything implementing [`Stage`]) compose with the built-ins.

mod ctx;
pub mod observer;
mod snapshot;
pub mod stage;

pub use ctx::{CgmqPolicy, GatePolicy, PolicyInputs, TrainCtx};
pub use snapshot::Snapshot;
pub use observer::{
    BestSnapshotSaver, ConstraintEvent, JsonlMetricsObserver, Observer, ObserverBus,
    SnapshotEvent,
};
pub use stage::{
    Calibrate, CgmqLoop, Finetune, LoadCheckpoint, PinGates, Pretrain, RangeLearn, Stage,
    StageReport,
};

use std::collections::VecDeque;
use std::path::Path;

use anyhow::Result;

use crate::config::Config;
use crate::metrics::MetricsLog;

/// Builder for a [`Session`]: config + stage sequence + observers.
///
/// `build()` is where all up-front validation happens — config values,
/// architecture name, artifact directory and manifest/arch agreement —
/// so a mis-assembled session fails before any training starts.
#[derive(Default)]
pub struct SessionBuilder {
    cfg: Config,
    stages: Vec<Box<dyn Stage>>,
    observers: Vec<Box<dyn Observer>>,
}

impl SessionBuilder {
    pub fn new(cfg: Config) -> Self {
        Self { cfg, stages: Vec::new(), observers: Vec::new() }
    }

    /// Start from a TOML config file (same schema as `--config`).
    pub fn from_toml(path: &Path) -> Result<Self> {
        Ok(Self::new(Config::from_file(path)?))
    }

    /// Append the paper's four-phase pipeline:
    /// `Pretrain -> Calibrate -> RangeLearn -> CgmqLoop`, all epoch counts
    /// taken from the config schedule.
    pub fn paper_pipeline(self) -> Self {
        self.stage(Pretrain::default())
            .stage(Calibrate)
            .stage(RangeLearn::default())
            .stage(CgmqLoop::default())
    }

    /// Append one stage.
    pub fn stage<S: Stage + 'static>(mut self, stage: S) -> Self {
        self.stages.push(Box::new(stage));
        self
    }

    /// Append a pre-boxed stage list (e.g. from a baseline helper).
    pub fn boxed_stages(mut self, stages: Vec<Box<dyn Stage>>) -> Self {
        self.stages.extend(stages);
        self
    }

    /// Subscribe an observer to the session's event bus.
    pub fn observer<O: Observer + 'static>(mut self, observer: O) -> Self {
        self.observers.push(Box::new(observer));
        self
    }

    /// Validate everything and construct the session. Fails (without
    /// training) on invalid config values, an unknown architecture, a
    /// missing artifacts directory, or manifest/arch drift — all via
    /// `TrainCtx::new`, the single validation site.
    pub fn build(self) -> Result<Session> {
        let mut ctx = TrainCtx::new(self.cfg)?;
        for o in self.observers {
            ctx.bus.attach(o);
        }
        Ok(Session { ctx, queue: self.stages.into(), reports: Vec::new() })
    }
}

/// A training run in progress: context + remaining stages + reports.
pub struct Session {
    /// The shared training state; freely inspectable between stages.
    pub ctx: TrainCtx,
    queue: VecDeque<Box<dyn Stage>>,
    reports: Vec<StageReport>,
}

impl Session {
    /// Run every queued stage, in order. Returns the reports of the stages
    /// run by *this* call.
    pub fn run(&mut self) -> Result<&[StageReport]> {
        let first = self.reports.len();
        while let Some(mut stage) = self.queue.pop_front() {
            self.exec(stage.as_mut())?;
        }
        Ok(&self.reports[first..])
    }

    /// Run one ad-hoc stage immediately (ahead of any queued stages) —
    /// e.g. extending a run with extra `CgmqLoop` epochs until the
    /// constraint is met.
    pub fn run_stage<S: Stage>(&mut self, mut stage: S) -> Result<&StageReport> {
        self.exec(&mut stage)?;
        Ok(self.reports.last().expect("exec pushed a report"))
    }

    fn exec(&mut self, stage: &mut dyn Stage) -> Result<()> {
        self.ctx.bus.stage_start(stage.name());
        let report = stage.run(&mut self.ctx)?;
        self.ctx.bus.stage_end(&report);
        self.reports.push(report);
        Ok(())
    }

    /// Reports of every stage run so far.
    pub fn reports(&self) -> &[StageReport] {
        &self.reports
    }

    /// Number of stages still queued.
    pub fn pending_stages(&self) -> usize {
        self.queue.len()
    }

    /// The accumulated per-epoch metrics log.
    pub fn metrics(&self) -> &MetricsLog {
        &self.ctx.log
    }

    /// The delivered model: best accuracy among constraint-satisfying
    /// epoch-end snapshots (the paper's guarantee as an API property).
    pub fn final_model(&self) -> Result<Snapshot> {
        self.ctx.final_model()
    }

    /// Summary of the finished run (one table row).
    pub fn result(&self) -> Result<RunResult> {
        self.ctx.result()
    }

    /// Dissolve the session into its context (for function-style drivers
    /// like the outer bb_proxy tuning loop).
    pub fn into_ctx(self) -> TrainCtx {
        self.ctx
    }
}

/// Summary of one finished run (one table row).
#[derive(Debug, Clone)]
pub struct RunResult {
    pub run_id: String,
    pub float_acc: f64,
    pub quant_acc: f64,
    pub rbop_percent: f64,
    pub bound_rbop_percent: f64,
    pub satisfied: bool,
    pub mean_weight_bits: f64,
    pub rbop_trace: Vec<f64>,
}

impl RunResult {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("run_id", Json::str(self.run_id.clone())),
            ("float_acc", Json::num(self.float_acc)),
            ("quant_acc", Json::num(self.quant_acc)),
            ("rbop_percent", Json::num(self.rbop_percent)),
            ("bound_rbop_percent", Json::num(self.bound_rbop_percent)),
            ("satisfied", Json::Bool(self.satisfied)),
            ("mean_weight_bits", Json::num(self.mean_weight_bits)),
            ("rbop_trace", Json::arr_f64(&self.rbop_trace)),
        ])
    }
}
