//! Metrics: epoch records, accuracy computation, CSV/JSON logging.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One training-epoch record (any phase).
#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub phase: String,
    pub epoch: usize,
    pub train_loss: f64,
    pub test_acc: f64,
    /// Relative BOPs in percent (0 for float phases).
    pub rbop_percent: f64,
    /// Constraint satisfied at epoch end (float phases: true).
    pub sat: bool,
    pub mean_weight_bits: f64,
    pub secs: f64,
}

impl EpochRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("phase", Json::str(self.phase.clone())),
            ("epoch", Json::num(self.epoch as f64)),
            ("train_loss", Json::num(self.train_loss)),
            ("test_acc", Json::num(self.test_acc)),
            ("rbop_percent", Json::num(self.rbop_percent)),
            ("sat", Json::Bool(self.sat)),
            ("mean_weight_bits", Json::num(self.mean_weight_bits)),
            ("secs", Json::num(self.secs)),
        ])
    }
}

/// Collects epoch records; writes CSV and JSON.
#[derive(Debug, Default)]
pub struct MetricsLog {
    pub records: Vec<EpochRecord>,
}

impl MetricsLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: EpochRecord) {
        self.records.push(r);
    }

    pub fn last(&self) -> Option<&EpochRecord> {
        self.records.last()
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "phase,epoch,train_loss,test_acc,rbop_percent,sat,mean_weight_bits,secs\n",
        );
        for r in &self.records {
            s.push_str(&format!(
                "{},{},{:.6},{:.4},{:.6},{},{:.3},{:.3}\n",
                r.phase, r.epoch, r.train_loss, r.test_acc, r.rbop_percent, r.sat,
                r.mean_weight_bits, r.secs
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.records.iter().map(|r| r.to_json()).collect())
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }
}

/// Classification accuracy from logits rows vs labels, counting only the
/// first `valid` rows (epoch-wrap padding excluded).
pub fn accuracy(preds: &[usize], labels: &[i32], valid: usize) -> (u64, u64) {
    let n = valid.min(preds.len()).min(labels.len());
    let correct =
        preds[..n].iter().zip(&labels[..n]).filter(|&(&p, &l)| p as i32 == l).count() as u64;
    (correct, n as u64)
}

/// Simple wall-clock stopwatch for phase timing.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(epoch: usize) -> EpochRecord {
        EpochRecord {
            phase: "cgmq".into(),
            epoch,
            train_loss: 0.5,
            test_acc: 0.9,
            rbop_percent: 1.5,
            sat: true,
            mean_weight_bits: 8.0,
            secs: 1.0,
        }
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut log = MetricsLog::new();
        log.push(rec(0));
        log.push(rec(1));
        let csv = log.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("phase,epoch"));
        assert!(csv.contains("cgmq,1,"));
    }

    #[test]
    fn json_roundtrips() {
        let mut log = MetricsLog::new();
        log.push(rec(3));
        let j = crate::util::json::parse(&log.to_json().to_string()).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("epoch").unwrap().as_usize().unwrap(), 3);
        assert!(arr[0].get("sat").unwrap().as_bool().unwrap());
    }

    #[test]
    fn accuracy_respects_valid() {
        let preds = vec![1, 2, 3, 0];
        let labels = vec![1, 2, 9, 0];
        let (c, n) = accuracy(&preds, &labels, 4);
        assert_eq!((c, n), (3, 4));
        // last sample is padding
        let (c, n) = accuracy(&preds, &labels, 2);
        assert_eq!((c, n), (2, 2));
    }
}
