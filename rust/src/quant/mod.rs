//! Rust mirror of the paper's quantization math (Eq. 1, 3, 4).
//!
//! The *training-path* quantization happens inside the AOT-compiled XLA
//! artifacts (L1 Pallas kernel); this module re-implements the same math on
//! the host for (a) BOP cost accounting, (b) the export path (deployable
//! integer weights), (c) the penalty/myQASR baselines, and (d) the
//! cross-language golden tests against `python/compile/kernels/ref.py`.
//!
//! Every numerical convention matches ref.py bit-for-bit: f32 arithmetic,
//! identity-clip at >= 24 bits, step-size floor 1e-12, and the saturated
//! integer grid (signed: [-(2^(b-1)-1), 2^(b-1)-1]; unsigned: [0, 2^b-1]).

use crate::tensor::Tensor;

/// Step-size floor (mirror of ref.EPS_SCALE).
pub const EPS_SCALE: f32 = 1e-12;

/// Bit-widths at/above which fake quantization degenerates to clip.
pub const IDENTITY_BITS: u32 = 24;

/// clip_{[alpha, beta]} from the paper.
#[inline]
pub fn clip(x: f32, alpha: f32, beta: f32) -> f32 {
    x.max(alpha).min(beta)
}

/// Step size of the `bits`-bit grid over the range implied by `beta`
/// (alpha = -beta if signed else 0). Shared by [`quantize`],
/// [`integer_code`] and [`decode_code`] so the deploy path dequantizes
/// with *exactly* the arithmetic the fake quantizer used (bit-for-bit).
#[inline]
pub fn step_size(bits: u32, beta: f32, signed: bool) -> f32 {
    let alpha = if signed { -beta } else { 0.0 };
    let levels = ((1u64 << bits) - 1) as f32;
    ((beta - alpha) / levels).max(EPS_SCALE)
}

/// Eq. 1: fake-quantize one value to `bits` bits on the range implied by
/// `beta` (alpha = -beta if signed else 0), saturated integer grid.
#[inline]
pub fn quantize(x: f32, bits: u32, beta: f32, signed: bool) -> f32 {
    let alpha = if signed { -beta } else { 0.0 };
    let v = clip(x, alpha, beta);
    if bits >= IDENTITY_BITS {
        return v;
    }
    let levels = ((1u64 << bits) - 1) as f32;
    let scale = step_size(bits, beta, signed);
    let n_max = if signed { ((1u64 << (bits - 1)) - 1) as f32 } else { levels };
    let n_min = if signed { -n_max } else { 0.0 };
    let n = (v / scale).round_ties_even().max(n_min).min(n_max);
    // `+ 0.0` normalizes -0.0 (tiny negative x rounds to n = -0.0) to +0.0:
    // the integer grid index cannot carry a zero sign, so this keeps
    // decode_code(integer_code(x)) == quantize(x) bit-for-bit on the deploy
    // path. Exact identity for every nonzero value; the cross-language
    // goldens compare within tolerance and are unaffected.
    scale * n + 0.0
}

/// Eq. 4: staircase transform gate value -> bit-width (0 = pruned).
#[inline]
pub fn transform_t(g: f32) -> u32 {
    if g <= 0.0 {
        0
    } else if g <= 1.0 {
        2
    } else if g <= 2.0 {
        4
    } else if g <= 3.0 {
        8
    } else if g <= 4.0 {
        16
    } else {
        32
    }
}

/// Inverse-ish of T: the smallest gate value whose T() equals `bits`
/// (midpoint of the step, so small perturbations don't change the level).
pub fn gate_for_bits(bits: u32) -> f32 {
    match bits {
        0 => -0.5,
        2 => 0.5,
        4 => 1.5,
        8 => 2.5,
        16 => 3.5,
        _ => 5.5,
    }
}

/// Eq. 3: gated residual-decomposition quantizer for one element.
///
/// Uses the telescoping identity of the nested residual sum: with masks
/// G_b = [T(g) >= b], Eq. 3 collapses exactly to Q(x, T(g), ...) (0 when
/// T(g) = 0). `gated_quantize_reference` keeps the literal five-level form;
/// the unit tests assert both agree on the full gate range (§Perf L3
/// iteration 1: 5 quantizations -> 1, ~5x on the export/BOP path).
#[inline]
pub fn gated_quantize(x: f32, g: f32, beta: f32, signed: bool) -> f32 {
    match transform_t(g) {
        0 => 0.0,
        bits => quantize(x, bits, beta, signed),
    }
}

/// Literal Eq. 3 (all five residual levels), kept as the structural
/// reference the Pallas kernel mirrors; used by tests to pin the telescoped
/// fast path above.
#[inline]
pub fn gated_quantize_reference(x: f32, g: f32, beta: f32, signed: bool) -> f32 {
    let t = transform_t(g);
    let m = |b: u32| -> f32 {
        if t >= b {
            1.0
        } else {
            0.0
        }
    };
    let q2 = quantize(x, 2, beta, signed);
    let q4 = quantize(x, 4, beta, signed);
    let q8 = quantize(x, 8, beta, signed);
    let q16 = quantize(x, 16, beta, signed);
    let q32 = quantize(x, 32, beta, signed);
    m(2) * (q2
        + m(4) * ((q4 - q2) + m(8) * ((q8 - q4) + m(16) * ((q16 - q8) + m(32) * (q32 - q16)))))
}

/// Tensor version of Eq. 3 (same-shape gate tensor).
pub fn gated_quantize_tensor(x: &Tensor, g: &Tensor, beta: f32, signed: bool) -> Tensor {
    debug_assert_eq!(x.shape(), g.shape());
    let data: Vec<f32> = x
        .data()
        .iter()
        .zip(g.data().iter())
        .map(|(&xv, &gv)| gated_quantize(xv, gv, beta, signed))
        .collect();
    Tensor::new(x.shape().to_vec(), data).expect("same shape")
}

/// Materialize per-element bit-widths T(g) for a gate tensor.
pub fn bitwidths(g: &Tensor) -> Vec<u32> {
    g.data().iter().map(|&v| transform_t(v)).collect()
}

/// Integer code of a quantized value (export path): the grid index n such
/// that q = scale * n. Returns (n, scale).
pub fn integer_code(x: f32, bits: u32, beta: f32, signed: bool) -> (i64, f32) {
    assert!(bits < IDENTITY_BITS, "integer export only for real bit-widths");
    let alpha = if signed { -beta } else { 0.0 };
    let v = clip(x, alpha, beta);
    let levels = ((1u64 << bits) - 1) as f32;
    let scale = step_size(bits, beta, signed);
    let n_max = if signed { ((1i64 << (bits - 1)) - 1) as f32 } else { levels };
    let n_min = if signed { -n_max } else { 0.0 };
    let n = (v / scale).round_ties_even().max(n_min).min(n_max);
    (n as i64, scale)
}

/// Inverse of [`integer_code`]: grid index -> fake-quantized value.
///
/// Computes `step_size * n` with the same f32 arithmetic as [`quantize`],
/// so for every code produced by `integer_code` the decoded value equals
/// the fake-quantized value *bit-for-bit* — the invariant the packed
/// deployment format ([`crate::deploy::format`]) is built on.
#[inline]
pub fn decode_code(n: i64, bits: u32, beta: f32, signed: bool) -> f32 {
    debug_assert!(bits < IDENTITY_BITS, "integer decode only for real bit-widths");
    step_size(bits, beta, signed) * n as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::BIT_LEVELS;

    #[test]
    fn staircase_matches_paper_table() {
        // Eq. 4 boundary semantics: intervals are left-open.
        let cases = [
            (-1.0, 0),
            (0.0, 0),
            (0.25, 2),
            (0.5, 2),
            (1.0, 2),
            (1.5, 4),
            (2.0, 4),
            (2.5, 8),
            (3.0, 8),
            (3.5, 16),
            (4.0, 16),
            (4.5, 32),
            (5.5, 32),
        ];
        for (g, b) in cases {
            assert_eq!(transform_t(g), b, "T({g})");
        }
    }

    #[test]
    fn gate_for_bits_roundtrips() {
        for b in BIT_LEVELS {
            assert_eq!(transform_t(gate_for_bits(b)), b);
        }
        assert_eq!(transform_t(gate_for_bits(0)), 0);
    }

    #[test]
    fn quantize_respects_range_and_levels() {
        let mut rng = crate::util::rng::SplitMix64::new(0);
        for bits in [2u32, 4, 8] {
            let mut values = std::collections::BTreeSet::new();
            for _ in 0..4000 {
                let x = rng.uniform(-3.0, 3.0) as f32;
                let q = quantize(x, bits, 1.0, true);
                assert!(q.abs() <= 1.0 + 1e-6);
                values.insert((q * 1e6).round() as i64);
            }
            assert!(values.len() <= (1usize << bits), "bits={bits}");
            assert!(values.contains(&0), "grid contains zero");
        }
    }

    #[test]
    fn quantize_32_is_clip() {
        for x in [-5.0f32, -0.3, 0.0, 0.7, 9.0] {
            assert_eq!(quantize(x, 32, 1.5, true), clip(x, -1.5, 1.5));
        }
    }

    #[test]
    fn unsigned_grid_nonnegative() {
        let mut rng = crate::util::rng::SplitMix64::new(1);
        for _ in 0..1000 {
            let x = rng.uniform(-2.0, 2.0) as f32;
            let q = quantize(x, 4, 1.0, false);
            assert!((0.0..=1.0 + 1e-6).contains(&q));
        }
    }

    #[test]
    fn gated_telescopes_to_direct() {
        // With a uniform gate, Eq. 3 == Eq. 1 at T(g) bits.
        let mut rng = crate::util::rng::SplitMix64::new(2);
        for (g, bits) in [(0.7f32, 2u32), (1.5, 4), (2.5, 8), (3.5, 16), (5.0, 32)] {
            for _ in 0..500 {
                let x = rng.uniform(-2.0, 2.0) as f32;
                let a = gated_quantize(x, g, 1.0, true);
                let b = quantize(x, bits, 1.0, true);
                assert!((a - b).abs() < 1e-6, "g={g} x={x}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn fast_path_matches_reference_decomposition() {
        // The telescoped gated_quantize must equal the literal Eq. 3 for
        // every gate level, both signednesses, clipped and interior values.
        let mut rng = crate::util::rng::SplitMix64::new(9);
        for _ in 0..5000 {
            let x = rng.uniform(-3.0, 3.0) as f32;
            let g = rng.uniform(-1.0, 6.0) as f32;
            for signed in [true, false] {
                let fast = gated_quantize(x, g, 1.1, signed);
                let slow = gated_quantize_reference(x, g, 1.1, signed);
                assert!((fast - slow).abs() < 1e-7, "x={x} g={g} signed={signed}");
            }
        }
    }

    #[test]
    fn gated_zero_gate_prunes() {
        assert_eq!(gated_quantize(0.8, -0.1, 1.0, true), 0.0);
        assert_eq!(gated_quantize(-0.8, 0.0, 1.0, true), 0.0);
    }

    #[test]
    fn error_decreases_with_bits() {
        let mut rng = crate::util::rng::SplitMix64::new(3);
        let xs: Vec<f32> = (0..8192).map(|_| rng.uniform(-1.5, 1.5) as f32).collect();
        let mut last = f64::INFINITY;
        for bits in BIT_LEVELS {
            let mse: f64 = xs
                .iter()
                .map(|&x| {
                    let e = (quantize(x, bits, 1.5, true) - x) as f64;
                    e * e
                })
                .sum::<f64>()
                / xs.len() as f64;
            assert!(mse <= last + 1e-12, "bits={bits}");
            last = mse;
        }
        assert!(last < 1e-10);
    }

    #[test]
    fn integer_code_consistent() {
        for x in [-0.9f32, -0.2, 0.0, 0.4, 1.3] {
            let (n, scale) = integer_code(x, 4, 1.0, true);
            let q = quantize(x, 4, 1.0, true);
            assert!(((n as f32) * scale - q).abs() < 1e-7);
            assert!(n.abs() <= 7);
        }
    }

    #[test]
    fn decode_code_is_bitwise_inverse_of_integer_code() {
        // The deploy format depends on decode(encode(x)) == quantize(x)
        // exactly (f32 bit equality), for every bit-width, signedness,
        // range and value — including clipped values and the pruned grid
        // extremes.
        let mut rng = crate::util::rng::SplitMix64::new(11);
        for _ in 0..5000 {
            let x = rng.uniform(-4.0, 4.0) as f32;
            let beta = rng.uniform(0.05, 3.0) as f32;
            for bits in [2u32, 4, 8, 16] {
                for signed in [true, false] {
                    let (n, _) = integer_code(x, bits, beta, signed);
                    let decoded = decode_code(n, bits, beta, signed);
                    let q = quantize(x, bits, beta, signed);
                    assert_eq!(decoded.to_bits(), q.to_bits(), "x={x} bits={bits} beta={beta}");
                }
            }
        }
    }

    #[test]
    fn tensor_version_matches_scalar() {
        let mut rng = crate::util::rng::SplitMix64::new(4);
        let x: Vec<f32> = (0..257).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let g: Vec<f32> = (0..257).map(|_| rng.uniform(-0.5, 5.5) as f32).collect();
        let xt = Tensor::new(vec![257], x.clone()).unwrap();
        let gt = Tensor::new(vec![257], g.clone()).unwrap();
        let out = gated_quantize_tensor(&xt, &gt, 1.0, true);
        for i in 0..257 {
            assert_eq!(out.data()[i], gated_quantize(x[i], g[i], 1.0, true));
        }
    }
}
