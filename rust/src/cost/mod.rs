//! BOP (Bit-Operations) cost accounting — paper Section 2.5.
//!
//! For a layer, the BOP count is the sum over output activations of
//! (bit-width of the activation) x (sum of bit-widths of the weights that
//! determine it):
//!
//!   dense (in, out):  BOP = sum_j b_a(j) * sum_i b_W(i, j)
//!   conv  (OIHW):     BOP = sum_{c,h,w} b_a(c,h,w) * sum_{i in filter c} b_W(i)
//!
//! Conventions (DESIGN.md §7, anchored on the paper's quoted 0.392% floor):
//! biases are excluded (the paper quantizes activations instead of biases),
//! and the *output layer* is excluded from both the quantized count and the
//! fp32 reference (its activation is kept float and "cannot be altered",
//! Section 4.2). With those rules the all-2-bit floor is exactly
//! (2*2)/(32*32) = 0.390625% for every architecture, matching the paper's
//! 0.392% for LeNet-5 up to their rounding.

use anyhow::{bail, Result};

use crate::model::{ArchSpec, LayerKind, LayerSpec};
use crate::quant::transform_t;
use crate::tensor::Tensor;

/// BOPs of one layer given per-weight and per-activation bit-width tensors.
///
/// `w_bits` is laid out like the weight tensor (row-major); `a_bits` like
/// the activation feature dims. Lengths are checked against the spec.
pub fn layer_bops(layer: &LayerSpec, w_bits: &[u32], a_bits: &[u32]) -> Result<u64> {
    if w_bits.len() != layer.w_len() {
        bail!("{}: w_bits len {} != {}", layer.name, w_bits.len(), layer.w_len());
    }
    if a_bits.len() != layer.n_units() {
        bail!("{}: a_bits len {} != {}", layer.name, a_bits.len(), layer.n_units());
    }
    match layer.kind {
        LayerKind::Dense => {
            // w is (in, out) row-major: index i*out + j. Per-column sums.
            let (n_in, n_out) = (layer.w_shape[0], layer.w_shape[1]);
            let mut col_sums = vec![0u64; n_out];
            for i in 0..n_in {
                let row = &w_bits[i * n_out..(i + 1) * n_out];
                for (j, &b) in row.iter().enumerate() {
                    col_sums[j] += b as u64;
                }
            }
            Ok(col_sums.iter().zip(a_bits.iter()).map(|(&ws, &ab)| ws * ab as u64).sum())
        }
        LayerKind::Conv => {
            // OIHW: filter c = w_bits[c*f..(c+1)*f]; every spatial position
            // (h, w) of channel c reuses the same filter.
            let o = layer.w_shape[0];
            let f = layer.fan_in();
            let spatial = layer.act_shape[1] * layer.act_shape[2];
            let mut total = 0u64;
            for c in 0..o {
                let wsum: u64 = w_bits[c * f..(c + 1) * f].iter().map(|&b| b as u64).sum();
                let asum: u64 =
                    a_bits[c * spatial..(c + 1) * spatial].iter().map(|&b| b as u64).sum();
                total += wsum * asum;
            }
            Ok(total)
        }
    }
}

/// Total model BOPs from gate tensors (T applied here), output layer excluded.
pub fn model_bops(arch: &ArchSpec, gates_w: &[Tensor], gates_a: &[Tensor]) -> Result<u64> {
    if gates_w.len() != arch.layers.len() {
        bail!("gates_w: {} tensors for {} layers", gates_w.len(), arch.layers.len());
    }
    if gates_a.len() != arch.n_quant_act() {
        bail!("gates_a: {} tensors for {} act layers", gates_a.len(), arch.n_quant_act());
    }
    let mut total = 0u64;
    let mut ai = 0;
    for (li, layer) in arch.layers.iter().enumerate() {
        if !layer.quant_act {
            continue; // output layer: excluded from the BOP count
        }
        let w_bits: Vec<u32> = gates_w[li].data().iter().map(|&g| transform_t(g)).collect();
        let a_bits: Vec<u32> = gates_a[ai].data().iter().map(|&g| transform_t(g)).collect();
        total += layer_bops(layer, &w_bits, &a_bits)?;
        ai += 1;
    }
    Ok(total)
}

/// fp32 reference BOPs (everything at 32 bit, same exclusions).
pub fn fp32_bops(arch: &ArchSpec) -> u64 {
    arch.layers.iter().filter(|l| l.quant_act).map(|l| l.macs() * 32 * 32).sum()
}

/// All-2-bit floor (the theoretical minimum without pruning).
pub fn floor_bops(arch: &ArchSpec) -> u64 {
    arch.layers.iter().filter(|l| l.quant_act).map(|l| l.macs() * 2 * 2).sum()
}

/// Relative BOPs in percent of the fp32 reference (the paper's RBOP).
pub fn rbop_percent(arch: &ArchSpec, bops: u64) -> f64 {
    100.0 * bops as f64 / fp32_bops(arch) as f64
}

/// Weight memory of the quantized model in bits (for reporting; all layers).
pub fn weight_memory_bits(gates_w: &[Tensor]) -> u64 {
    gates_w
        .iter()
        .flat_map(|g| g.data().iter())
        .map(|&g| transform_t(g) as u64)
        .sum()
}

/// The cost constraint: an upper bound expressed as RBOP percent
/// (paper's BGBOP column). Checked only at the end of each epoch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostConstraint {
    /// Bound as a percentage of fp32 BOPs, e.g. 0.40.
    pub bound_rbop_percent: f64,
}

impl CostConstraint {
    pub fn new(bound_rbop_percent: f64) -> Self {
        Self { bound_rbop_percent }
    }

    /// Absolute BOP bound for an architecture.
    pub fn bound_bops(&self, arch: &ArchSpec) -> u64 {
        (self.bound_rbop_percent / 100.0 * fp32_bops(arch) as f64).floor() as u64
    }

    pub fn is_satisfied(&self, arch: &ArchSpec, bops: u64) -> bool {
        bops <= self.bound_bops(arch)
    }

    /// Whether a non-pruned model can satisfy this bound at all.
    pub fn is_feasible(&self, arch: &ArchSpec) -> bool {
        floor_bops(arch) <= self.bound_bops(arch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{lenet5, mlp};
    use crate::quant::gate_for_bits;

    fn uniform_gates(arch: &ArchSpec, bits: u32) -> (Vec<Tensor>, Vec<Tensor>) {
        let g = gate_for_bits(bits);
        let gw = arch.layers.iter().map(|l| Tensor::full(&l.w_shape, g)).collect();
        let ga = arch
            .layers
            .iter()
            .filter(|l| l.quant_act)
            .map(|l| Tensor::full(&l.act_shape, g))
            .collect();
        (gw, ga)
    }

    #[test]
    fn fp32_reference_is_macs_1024() {
        let a = lenet5();
        // counted layers: conv1, conv2, fc1 (fc2 excluded)
        let macs = 288_000u64 + 1_600_000 + 400_000;
        assert_eq!(fp32_bops(&a), macs * 1024);
    }

    #[test]
    fn uniform_bits_equal_macs_product() {
        let a = lenet5();
        for bits in [2u32, 4, 8, 16, 32] {
            let (gw, ga) = uniform_gates(&a, bits);
            let bops = model_bops(&a, &gw, &ga).unwrap();
            let macs = 288_000u64 + 1_600_000 + 400_000;
            assert_eq!(bops, macs * (bits as u64) * (bits as u64), "bits={bits}");
        }
    }

    #[test]
    fn floor_rbop_matches_paper_0392() {
        // Paper Section 4.2: "the RBOP for LeNet-5 is 0.392%"; our model
        // gives exactly (2*2)/(32*32) = 0.390625%.
        for arch in [lenet5(), mlp()] {
            let r = rbop_percent(&arch, floor_bops(&arch));
            assert!((r - 0.390625).abs() < 1e-12, "{}: {r}", arch.name);
        }
    }

    #[test]
    fn mixed_precision_dense_by_hand() {
        // 2x3 dense layer: w_bits = [[2,4,8],[2,2,32]], a_bits = [4,2,8]
        let layer = LayerSpec {
            name: "t",
            kind: LayerKind::Dense,
            w_shape: vec![2, 3],
            b_shape: vec![3],
            act_shape: vec![3],
            pool: 0,
            quant_act: true,
        };
        let w_bits = vec![2, 4, 8, 2, 2, 32];
        let a_bits = vec![4, 2, 8];
        // column sums: [4, 6, 40]; dot with a_bits: 16 + 12 + 320 = 348
        assert_eq!(layer_bops(&layer, &w_bits, &a_bits).unwrap(), 348);
    }

    #[test]
    fn mixed_precision_conv_by_hand() {
        // 2 filters of fan-in 2, act 2x1x2 (c,h,w): per-channel wsum x asum.
        let layer = LayerSpec {
            name: "t",
            kind: LayerKind::Conv,
            w_shape: vec![2, 2, 1, 1],
            b_shape: vec![2],
            act_shape: vec![2, 1, 2],
            pool: 0,
            quant_act: true,
        };
        let w_bits = vec![2, 4, 8, 8]; // filter0 sum 6, filter1 sum 16
        let a_bits = vec![2, 4, 32, 2]; // ch0 sum 6, ch1 sum 34
        assert_eq!(layer_bops(&layer, &w_bits, &a_bits).unwrap(), 6 * 6 + 16 * 34);
    }

    #[test]
    fn constraint_bound_and_feasibility() {
        let a = lenet5();
        let c = CostConstraint::new(0.40);
        assert!(c.is_feasible(&a)); // floor 0.3906 <= 0.40
        let (gw, ga) = uniform_gates(&a, 2);
        assert!(c.is_satisfied(&a, model_bops(&a, &gw, &ga).unwrap()));
        let (gw32, ga32) = uniform_gates(&a, 32);
        assert!(!c.is_satisfied(&a, model_bops(&a, &gw32, &ga32).unwrap()));
        assert!(!CostConstraint::new(0.38).is_feasible(&a)); // below floor
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = mlp();
        let (mut gw, ga) = uniform_gates(&a, 8);
        gw[0] = Tensor::zeros(&[3, 3]);
        assert!(model_bops(&a, &gw, &ga).is_err());
    }

    #[test]
    fn weight_memory_counts_all_layers() {
        let a = mlp();
        let (gw, _) = uniform_gates(&a, 8);
        let n_w: u64 = a.layers.iter().map(|l| l.w_len() as u64).sum();
        assert_eq!(weight_memory_bits(&gw), n_w * 8);
    }
}
