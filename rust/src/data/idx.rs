//! IDX (real MNIST) file loader.
//!
//! When the paper's actual dataset is available on disk (the four standard
//! `train-images-idx3-ubyte` / `t10k-…` files, optionally gzipped is NOT
//! supported — decompress first), this loader replaces SynthMNIST with the
//! genuine article; the rest of the pipeline is unchanged. Format per
//! Yann LeCun's spec: big-endian magic (0x801 labels / 0x803 images),
//! dimension sizes, then raw u8 payload.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Images normalised to [-1, 1] (mean 0.5 / std 0.5, paper §4.1), flattened
/// row-major, plus labels.
pub struct IdxDataset {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    pub rows: usize,
    pub cols: usize,
}

fn read_u32_be(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_be_bytes(b))
}

/// Load an images file (magic 0x00000803).
pub fn load_images(path: &Path) -> Result<(Vec<u8>, usize, usize, usize)> {
    let mut f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let magic = read_u32_be(&mut f)?;
    if magic != 0x0000_0803 {
        bail!("{}: bad image magic {magic:#x}", path.display());
    }
    let n = read_u32_be(&mut f)? as usize;
    let rows = read_u32_be(&mut f)? as usize;
    let cols = read_u32_be(&mut f)? as usize;
    let mut data = vec![0u8; n * rows * cols];
    f.read_exact(&mut data).context("truncated image payload")?;
    Ok((data, n, rows, cols))
}

/// Load a labels file (magic 0x00000801).
pub fn load_labels(path: &Path) -> Result<Vec<u8>> {
    let mut f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let magic = read_u32_be(&mut f)?;
    if magic != 0x0000_0801 {
        bail!("{}: bad label magic {magic:#x}", path.display());
    }
    let n = read_u32_be(&mut f)? as usize;
    let mut data = vec![0u8; n];
    f.read_exact(&mut data).context("truncated label payload")?;
    Ok(data)
}

/// Paper preprocessing for one raw pixel: [0, 255] -> [-1, 1]
/// (mean 0.5 / std 0.5, §4.1). Single definition shared by every IDX
/// consumer so the normalization cannot drift between paths.
#[inline]
pub fn normalize_pixel(p: u8) -> f32 {
    ((p as f32 / 255.0) - 0.5) / 0.5
}

/// Load an (images, labels) pair and normalise like the paper.
pub fn load_pair(images_path: &Path, labels_path: &Path) -> Result<IdxDataset> {
    let (raw, n, rows, cols) = load_images(images_path)?;
    let labels_u8 = load_labels(labels_path)?;
    if labels_u8.len() != n {
        bail!("{} images but {} labels", n, labels_u8.len());
    }
    let images = raw.iter().map(|&p| normalize_pixel(p)).collect();
    let labels = labels_u8.iter().map(|&l| l as i32).collect();
    Ok(IdxDataset { images, labels, n, rows, cols })
}

/// Look for the standard MNIST file names under `dir`.
pub fn mnist_available(dir: &Path) -> bool {
    dir.join("train-images-idx3-ubyte").exists()
        && dir.join("train-labels-idx1-ubyte").exists()
        && dir.join("t10k-images-idx3-ubyte").exists()
        && dir.join("t10k-labels-idx1-ubyte").exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_images(path: &Path, n: u32, rows: u32, cols: u32, payload: &[u8]) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(&0x0000_0803u32.to_be_bytes()).unwrap();
        f.write_all(&n.to_be_bytes()).unwrap();
        f.write_all(&rows.to_be_bytes()).unwrap();
        f.write_all(&cols.to_be_bytes()).unwrap();
        f.write_all(payload).unwrap();
    }

    fn write_labels(path: &Path, n: u32, payload: &[u8]) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(&0x0000_0801u32.to_be_bytes()).unwrap();
        f.write_all(&n.to_be_bytes()).unwrap();
        f.write_all(payload).unwrap();
    }

    #[test]
    fn roundtrip_synthetic_idx() {
        let dir = std::env::temp_dir().join("cgmq_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let ip = dir.join("imgs");
        let lp = dir.join("labs");
        // 2 images of 2x2: [0, 255, 128, 0] and [255; 4]
        write_images(&ip, 2, 2, 2, &[0, 255, 128, 0, 255, 255, 255, 255]);
        write_labels(&lp, 2, &[7, 3]);
        let ds = load_pair(&ip, &lp).unwrap();
        assert_eq!((ds.n, ds.rows, ds.cols), (2, 2, 2));
        assert_eq!(ds.labels, vec![7, 3]);
        assert!((ds.images[0] + 1.0).abs() < 1e-6); // 0 -> -1
        assert!((ds.images[1] - 1.0).abs() < 1e-6); // 255 -> 1
        assert!(ds.images[2].abs() < 0.01); // 128 -> ~0
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("cgmq_idx_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad");
        std::fs::write(&p, [0u8; 16]).unwrap();
        assert!(load_images(&p).is_err());
        assert!(load_labels(&p).is_err());
    }

    #[test]
    fn truncated_payload_rejected() {
        let dir = std::env::temp_dir().join("cgmq_idx_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let ip = dir.join("trunc");
        write_images(&ip, 10, 28, 28, &[0u8; 100]); // far too short
        assert!(load_images(&ip).is_err());
    }

    #[test]
    fn label_count_mismatch_rejected() {
        let dir = std::env::temp_dir().join("cgmq_idx_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let ip = dir.join("i");
        let lp = dir.join("l");
        write_images(&ip, 1, 2, 2, &[0; 4]);
        write_labels(&lp, 2, &[1, 2]);
        assert!(load_pair(&ip, &lp).is_err());
    }
}
