//! Data pipeline: SynthMNIST generation, real-MNIST IDX loading, batching.
//!
//! SynthMNIST (`synth`) is the repo's substitution for MNIST in the
//! offline build environment (DESIGN.md §2); `idx` loads the real MNIST
//! IDX files when they are present so the paper's exact dataset drops in
//! unchanged; `batcher` shuffles and serves fixed-size normalised batches
//! matching the compiled artifact shapes.

pub mod batcher;
pub mod idx;
pub mod synth;

pub use batcher::{Batch, Batcher, Dataset};
