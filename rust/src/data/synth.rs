//! SynthMNIST renderer — bit-for-bit mirror of `python/compile/data_synth.py`.
//!
//! Deterministic procedural 28x28 digits: per-class stroke skeletons warped
//! by a random affine map, rendered as a soft distance field, plus Gaussian
//! noise. Identical constants, RNG (SplitMix64) and call order as the
//! Python side; `artifacts/goldens.json` pins a handful of samples and the
//! integration tests compare against them with 1e-4 tolerance (libm ulp).

use crate::util::rng::{sample_seed, SplitMix64};

pub const GRID: usize = 28;
const NOISE_SIGMA: f64 = 0.04;
const SOFTNESS: f64 = 0.35;

type Point = (f64, f64);

fn circle(cx: f64, cy: f64, rx: f64, ry: f64, n: usize) -> Vec<Point> {
    (0..=n)
        .map(|k| {
            let t = 2.0 * std::f64::consts::PI * k as f64 / n as f64;
            (cx + rx * t.cos(), cy + ry * t.sin())
        })
        .collect()
}

/// Stroke skeletons per digit class (unit square, y down) — mirror of
/// `data_synth.SKELETONS`.
fn skeleton(label: usize) -> Vec<Vec<Point>> {
    match label {
        0 => vec![circle(0.5, 0.5, 0.24, 0.34, 12)],
        1 => vec![vec![(0.36, 0.28), (0.52, 0.14)], vec![(0.52, 0.14), (0.52, 0.86)]],
        2 => vec![
            vec![
                (0.28, 0.30),
                (0.32, 0.17),
                (0.50, 0.12),
                (0.68, 0.18),
                (0.72, 0.33),
                (0.58, 0.52),
                (0.30, 0.84),
            ],
            vec![(0.30, 0.84), (0.74, 0.84)],
        ],
        3 => vec![
            vec![(0.30, 0.16), (0.55, 0.12), (0.70, 0.28), (0.52, 0.46)],
            vec![(0.52, 0.46), (0.72, 0.62), (0.58, 0.84), (0.30, 0.80)],
        ],
        4 => vec![
            vec![(0.62, 0.12), (0.28, 0.62)],
            vec![(0.28, 0.62), (0.76, 0.62)],
            vec![(0.62, 0.30), (0.62, 0.88)],
        ],
        5 => vec![
            vec![(0.70, 0.13), (0.33, 0.13)],
            vec![(0.33, 0.13), (0.31, 0.45)],
            vec![
                (0.31, 0.45),
                (0.55, 0.41),
                (0.71, 0.56),
                (0.66, 0.78),
                (0.44, 0.87),
                (0.28, 0.79),
            ],
        ],
        6 => vec![
            vec![(0.64, 0.13), (0.42, 0.33), (0.32, 0.58)],
            circle(0.48, 0.67, 0.19, 0.20, 12),
        ],
        7 => vec![vec![(0.26, 0.15), (0.74, 0.15)], vec![(0.74, 0.15), (0.44, 0.86)]],
        8 => vec![circle(0.5, 0.31, 0.17, 0.17, 12), circle(0.5, 0.67, 0.21, 0.20, 12)],
        9 => vec![
            circle(0.5, 0.33, 0.19, 0.20, 12),
            vec![(0.69, 0.37), (0.64, 0.62), (0.54, 0.86)],
        ],
        _ => unreachable!("label must be 0..9"),
    }
}

/// Random affine warp around the glyph centre — mirror of `data_synth._affine`
/// (same RNG draw order: theta, sx, sy, shear, tx, ty).
fn affine(rng: &mut SplitMix64) -> (f64, f64, f64, f64, f64, f64) {
    let theta = rng.uniform(-0.25, 0.25);
    let sx = rng.uniform(0.85, 1.15);
    let sy = rng.uniform(0.85, 1.15);
    let shear = rng.uniform(-0.15, 0.15);
    let tx = rng.uniform(-0.08, 0.08);
    let ty = rng.uniform(-0.08, 0.08);
    let (ct, st) = (theta.cos(), theta.sin());
    let a00 = ct * sx;
    let a01 = ct * (shear * sy) - st * sy;
    let a10 = st * sx;
    let a11 = st * (shear * sy) + ct * sy;
    (a00, a01, a10, a11, tx, ty)
}

fn warp(pts: &[Point], aff: (f64, f64, f64, f64, f64, f64)) -> Vec<Point> {
    let (a00, a01, a10, a11, tx, ty) = aff;
    pts.iter()
        .map(|&(x, y)| {
            let (dx, dy) = (x - 0.5, y - 0.5);
            (0.5 + a00 * dx + a01 * dy + tx, 0.5 + a10 * dx + a11 * dy + ty)
        })
        .collect()
}

#[inline]
fn seg_dist(px: f64, py: f64, a: Point, b: Point) -> f64 {
    let (vx, vy) = (b.0 - a.0, b.1 - a.1);
    let (wx, wy) = (px - a.0, py - a.1);
    let vv = vx * vx + vy * vy;
    let t = if vv <= 1e-18 { 0.0 } else { ((wx * vx + wy * vy) / vv).clamp(0.0, 1.0) };
    let (dx, dy) = (px - (a.0 + t * vx), py - (a.1 + t * vy));
    (dx * dx + dy * dy).sqrt()
}

/// Render sample `index` -> (28x28 image in [0,1] row-major, label).
pub fn render_digit(seed: u64, index: u64) -> ([f32; GRID * GRID], usize) {
    let label = (index % 10) as usize;
    let mut rng = SplitMix64::new(sample_seed(seed, index));
    let aff = affine(&mut rng);
    let tau = rng.uniform(0.035, 0.060);
    let strokes: Vec<Vec<Point>> =
        skeleton(label).iter().map(|poly| warp(poly, aff)).collect();

    let mut img = [0f64; GRID * GRID];
    for r in 0..GRID {
        let py = (r as f64 + 0.5) / GRID as f64;
        for c in 0..GRID {
            let px = (c as f64 + 0.5) / GRID as f64;
            let mut d = f64::INFINITY;
            for poly in &strokes {
                for k in 0..poly.len() - 1 {
                    d = d.min(seg_dist(px, py, poly[k], poly[k + 1]));
                }
            }
            let v = (tau - d) / (SOFTNESS * tau);
            img[r * GRID + c] = v.clamp(0.0, 1.0);
        }
    }
    // Noise pass in the same raster order as Python.
    let mut out = [0f32; GRID * GRID];
    for (i, v) in img.iter().enumerate() {
        out[i] = (v + NOISE_SIGMA * rng.gauss()).clamp(0.0, 1.0) as f32;
    }
    (out, label)
}

/// Generate a normalised dataset: images in [-1, 1] (paper preprocessing:
/// mean 0.5 / std 0.5), labels balanced by `index % 10`.
pub fn dataset(seed: u64, n: usize) -> (Vec<f32>, Vec<i32>) {
    let mut xs = Vec::with_capacity(n * GRID * GRID);
    let mut ys = Vec::with_capacity(n);
    for i in 0..n {
        let (img, label) = render_digit(seed, i as u64);
        xs.extend(img.iter().map(|&v| (v - 0.5) / 0.5));
        ys.push(label as i32);
    }
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let (a, la) = render_digit(7, 3);
        let (b, lb) = render_digit(7, 3);
        assert_eq!(a[..], b[..]);
        assert_eq!(la, lb);
    }

    #[test]
    fn labels_balanced() {
        let (_, ys) = dataset(0, 100);
        let mut counts = [0u32; 10];
        for &y in &ys {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn normalised_range() {
        let (xs, _) = dataset(3, 10);
        assert!(xs.iter().all(|&v| (-1.0..=1.0).contains(&v)));
        assert_eq!(xs.len(), 10 * GRID * GRID);
    }

    #[test]
    fn digits_have_ink() {
        for i in 0..20 {
            let (img, _) = render_digit(5, i);
            let max = img.iter().cloned().fold(0.0f32, f32::max);
            assert!(max > 0.8, "sample {i} has no stroke");
            let ink = img.iter().filter(|&&v| v > 0.5).count();
            assert!((10..350).contains(&ink), "sample {i} ink mass {ink}");
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (a, _) = render_digit(1, 3);
        let (b, _) = render_digit(2, 3);
        let max_diff =
            a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(max_diff > 0.05);
    }

    /// Nearest-class-mean classifier beats chance by a wide margin —
    /// mirrors python test_data.py::test_classes_are_distinguishable.
    #[test]
    fn classes_distinguishable() {
        let (xs, ys) = dataset(11, 400);
        let (xt, yt) = dataset(12, 200);
        let d = GRID * GRID;
        let mut means = vec![[0f64; GRID * GRID]; 10];
        let mut counts = [0usize; 10];
        for i in 0..400 {
            let c = ys[i] as usize;
            counts[c] += 1;
            for j in 0..d {
                means[c][j] += xs[i * d + j] as f64;
            }
        }
        for c in 0..10 {
            for j in 0..d {
                means[c][j] /= counts[c] as f64;
            }
        }
        let mut correct = 0;
        for i in 0..200 {
            let mut best = (f64::INFINITY, 0);
            for c in 0..10 {
                let dist: f64 = (0..d)
                    .map(|j| {
                        let e = xt[i * d + j] as f64 - means[c][j];
                        e * e
                    })
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == yt[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / 200.0;
        assert!(acc > 0.6, "nearest-mean acc {acc}");
    }
}
