//! Dataset container + seeded shuffling batcher.
//!
//! The compiled artifacts have static batch shapes (train 128 / eval 256),
//! so the batcher always emits full batches: the tail of an epoch is padded
//! by wrapping around to the epoch's start (standard practice; the wrap
//! samples are counted once for accuracy by `Batch::valid`).

use anyhow::{bail, Result};

use crate::util::rng::SplitMix64;

/// In-memory dataset: flattened images + labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    /// Elements per sample (e.g. 784).
    pub sample_len: usize,
}

impl Dataset {
    pub fn new(images: Vec<f32>, labels: Vec<i32>, sample_len: usize) -> Result<Self> {
        if images.len() != labels.len() * sample_len {
            bail!(
                "images len {} != {} labels x {} sample_len",
                images.len(),
                labels.len(),
                sample_len
            );
        }
        Ok(Self { images, labels, sample_len })
    }

    /// SynthMNIST dataset of n samples (DESIGN.md §2 substitution).
    pub fn synth(seed: u64, n: usize) -> Self {
        let (images, labels) = super::synth::dataset(seed, n);
        Self { images, labels, sample_len: super::synth::GRID * super::synth::GRID }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Split off the last `n` samples as a held-out set.
    pub fn split_tail(mut self, n: usize) -> Result<(Dataset, Dataset)> {
        if n >= self.len() {
            bail!("cannot split {} from {}", n, self.len());
        }
        let keep = self.len() - n;
        let tail_images = self.images.split_off(keep * self.sample_len);
        let tail_labels = self.labels.split_off(keep);
        let tail = Dataset::new(tail_images, tail_labels, self.sample_len)?;
        Ok((self, tail))
    }
}

/// One fixed-size batch. `valid` <= batch size: number of non-wrapped
/// samples (the rest are epoch-wrap padding, excluded from metrics).
#[derive(Debug, Clone)]
pub struct Batch {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub valid: usize,
}

/// Seeded shuffling batcher producing fixed-size batches.
#[derive(Debug)]
pub struct Batcher {
    order: Vec<usize>,
    batch: usize,
    rng: SplitMix64,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, seed: u64) -> Self {
        Self { order: (0..n).collect(), batch, rng: SplitMix64::new(seed) }
    }

    /// Shuffle and yield every batch of one epoch.
    pub fn epoch<'d>(&mut self, data: &'d Dataset) -> Vec<Batch> {
        self.rng.shuffle(&mut self.order);
        let n = data.len();
        let nb = n.div_ceil(self.batch);
        let mut out = Vec::with_capacity(nb);
        for b in 0..nb {
            let start = b * self.batch;
            let valid = self.batch.min(n - start);
            let mut images = Vec::with_capacity(self.batch * data.sample_len);
            let mut labels = Vec::with_capacity(self.batch);
            for k in 0..self.batch {
                // wrap into the already-shuffled order for the tail padding
                let idx = self.order[(start + k) % n];
                let s = idx * data.sample_len;
                images.extend_from_slice(&data.images[s..s + data.sample_len]);
                labels.push(data.labels[idx]);
            }
            out.push(Batch { images, labels, valid });
        }
        out
    }

    /// Sequential (unshuffled) batches — evaluation order.
    pub fn sequential(data: &Dataset, batch: usize) -> Vec<Batch> {
        let n = data.len();
        let nb = n.div_ceil(batch);
        let mut out = Vec::with_capacity(nb);
        for b in 0..nb {
            let start = b * batch;
            let valid = batch.min(n - start);
            let mut images = Vec::with_capacity(batch * data.sample_len);
            let mut labels = Vec::with_capacity(batch);
            for k in 0..batch {
                let idx = (start + k) % n;
                let s = idx * data.sample_len;
                images.extend_from_slice(&data.images[s..s + data.sample_len]);
                labels.push(data.labels[idx]);
            }
            out.push(Batch { images, labels, valid });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(n: usize) -> Dataset {
        let images = (0..n * 4).map(|i| i as f32).collect();
        let labels = (0..n as i32).collect();
        Dataset::new(images, labels, 4).unwrap()
    }

    #[test]
    fn batches_cover_dataset_once() {
        let data = tiny(10);
        let mut b = Batcher::new(10, 4, 1);
        let batches = b.epoch(&data);
        assert_eq!(batches.len(), 3);
        let valid_total: usize = batches.iter().map(|b| b.valid).sum();
        assert_eq!(valid_total, 10);
        // every batch is full-size
        assert!(batches.iter().all(|b| b.labels.len() == 4 && b.images.len() == 16));
        // all 10 samples appear among the valid slots exactly once
        let mut seen: Vec<i32> =
            batches.iter().flat_map(|b| b.labels[..b.valid].to_vec()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn shuffling_changes_order_but_is_seeded() {
        let data = tiny(32);
        let mut b1 = Batcher::new(32, 8, 7);
        let mut b2 = Batcher::new(32, 8, 7);
        let e1 = b1.epoch(&data);
        let e2 = b2.epoch(&data);
        assert_eq!(e1[0].labels, e2[0].labels); // same seed, same order
        let mut b3 = Batcher::new(32, 8, 8);
        let e3 = b3.epoch(&data);
        assert_ne!(e1[0].labels, e3[0].labels); // different seed
        assert_ne!(e1[0].labels, (0..8).collect::<Vec<i32>>()); // actually shuffled
    }

    #[test]
    fn epochs_reshuffle() {
        let data = tiny(64);
        let mut b = Batcher::new(64, 16, 3);
        let e1 = b.epoch(&data);
        let e2 = b.epoch(&data);
        assert_ne!(e1[0].labels, e2[0].labels);
    }

    #[test]
    fn sequential_is_ordered() {
        let data = tiny(9);
        let batches = Batcher::sequential(&data, 4);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].labels, vec![0, 1, 2, 3]);
        assert_eq!(batches[2].valid, 1);
        assert_eq!(batches[2].labels[0], 8);
    }

    #[test]
    fn split_tail() {
        let data = tiny(10);
        let (train, test) = data.split_tail(3).unwrap();
        assert_eq!(train.len(), 7);
        assert_eq!(test.len(), 3);
        assert_eq!(test.labels, vec![7, 8, 9]);
        assert!(tiny(5).split_tail(5).is_err());
    }

    #[test]
    fn dataset_shape_checked() {
        assert!(Dataset::new(vec![0.0; 7], vec![0, 1], 4).is_err());
    }
}
