//! `cgmq` — CLI entrypoint for the CGMQ reproduction.
//!
//! Commands:
//!   train      full pipeline (pretrain -> calibrate -> ranges -> CGMQ)
//!   pretrain   float pretraining only; caches a checkpoint
//!   eval       evaluate a snapshot checkpoint
//!   export     export a snapshot: JSON memory report or packed .cgmqm
//!   infer      run a packed .cgmqm model on IDX / synthetic inputs
//!   serve-bench  throughput/latency of the batched serve path
//!   route-bench  multi-model router: routing, bounded queues + shed, hot swap
//!   serve      HTTP/1.1 network front over the router (429 on overload)
//!   load-bench loopback load generator against a running `serve`
//!   watch      live per-model table polled from a running `serve`'s /stats
//!   analyze    static-analysis gate over the crate's own source
//!   table1/2/3 regenerate the paper's tables
//!   table-deploy packed-model size + engine throughput table
//!   a2         penalty-method (DQ-style) tuning comparison
//!   info       show artifact manifest + runtime info
//!
//! Every command assembles a `session::SessionBuilder` pipeline: `train` is
//! the paper's four stages, `fixed-qat` swaps the CGMQ loop for
//! `PinGates + Finetune`, `--from-pretrained` swaps `Pretrain` for
//! `LoadCheckpoint`. Training commands stream per-epoch metrics as JSONL
//! (`<run_id>.epochs.jsonl` in `--out-dir`) via the metrics observer.
//!
//! Every command takes `--config <toml>` plus targeted overrides; run with
//! no command for usage.

use std::path::Path;

use anyhow::{bail, Result};

use cgmq::baselines::{fixed_qat, myqasr};
use cgmq::bench_harness;
use cgmq::cli::Args;
use cgmq::config::Config;
use cgmq::direction::DirKind;
use cgmq::gates::Granularity;
use cgmq::session::{
    Calibrate, CgmqLoop, JsonlMetricsObserver, LoadCheckpoint, Pretrain, RangeLearn, Session,
    SessionBuilder,
};

const USAGE: &str = "\
cgmq — Constraint Guided Model Quantization (paper reproduction)

USAGE: cgmq <command> [--flag value]...

COMMANDS
  train      --config <toml> | overrides: --arch --direction --granularity
             --bound --cgmq-epochs --pretrain-epochs --train-size --seed
             [--save <ckpt>] [--from-pretrained <ckpt>]
  pretrain   same config flags; --save <ckpt> (default runs/pretrained.ckpt)
  eval       --ckpt <snapshot> [--config <toml>]
  export     (--ckpt <snapshot> | --synth) [--config <toml>]
             [--format json|packed] [--out <path>]   (json: memory report
             incl. packed sizes; packed: bit-packed .cgmqm artifact for
             `infer`/`serve-bench`; --synth packs a deterministic
             synthetic mixed-precision state — no checkpoint/artifacts
             needed, the CI serve-smoke path)
  infer      --model <m.cgmqm> (--input <idx-images> | --synth <n>)
             [--index <i>] [--labels <idx-labels>] [--batch <b>]
             [--mode unpack|streaming] [--seed <s>]
  serve-bench --model <m.cgmqm> [--requests <n>] [--batch <b>]
             [--deadline-us <d>] [--workers <n>] [--seed <s>]
             (prints JSON: single vs batched vs pooled 1-vs-N-worker
             throughput + latency percentiles)
  route-bench --models <key=m.cgmqm,key2=m2.cgmqm,...> [--requests <n>]
             [--batch <b>] [--deadline-us <d>] [--workers <n>]
             [--queue-cap <c>] [--swap] [--seed <s>]
             (drives a multi-model router: requests routed round-robin
             across keys through bounded per-shard queues — overload is
             shed, not queued; --swap hot-swaps every model mid-traffic;
             prints per-model throughput/shed/swap stats as JSON)
  serve      --models <key=m.cgmqm,...> [--addr <host:port>] [--workers <n>]
             [--batch <b>] [--deadline-us <d>] [--queue-cap <c>]
             [--max-body-kib <k>] [--addr-file <path>]
             [--livez-shed-rate <r>] [--livez-p99-us <us>]
             (HTTP/1.1 front over the router: POST /v1/models/{key}/infer,
             GET /healthz, GET /livez, GET /stats, GET /metrics
             (Prometheus text), POST /admin/shutdown; overload is answered
             429 + Retry-After; every infer response carries X-Request-Id;
             --addr 127.0.0.1:0 picks an ephemeral port, written to
             --addr-file; /livez answers 503 when the trailing-window shed
             rate reaches --livez-shed-rate (default 0.5; > 1.0 disables)
             or the windowed p99 latency bound exceeds --livez-p99-us
             (default 0 = disabled); on shutdown the server drains, prints
             final stats JSON and exits non-zero if any accepted request
             was lost)
  load-bench --addr <host:port> [--key <k>] [--requests <n>] [--clients <n>]
             [--rate <rps>] [--seed <s>] [--verify-model <m.cgmqm>]
             [--min-shed <n>] [--require-stages] [--require-window]
             [--shutdown]
             (loopback load generator: open-loop client threads, 429s are
             counted and retried until accepted; --verify-model pins every
             HTTP response bit-identical to the direct engine output;
             --min-shed asserts the burst saturated admission; scrapes
             /metrics and exits non-zero unless the server-side accept/shed
             counters match the client tallies bit-exactly;
             --require-stages additionally asserts every stage histogram
             recorded samples; --require-window additionally asserts the
             windowed signal plane is live (positive arrival rate, margin
             samples recorded, /livez answering 200); --shutdown drains
             the server afterwards; prints throughput/shed/latency
             percentiles as JSON)
  watch      --addr <host:port> [--interval <s>] [--once]
             (polls a running serve's GET /stats every --interval seconds
             — default 2 — and renders the windowed signal plane as a
             per-model table: arrival rate, shed %, queue depth, in-flight,
             p50/p99 latency bounds, margin p10; empty windowed histograms
             render as \"—\"; --once prints a single frame and exits)
  analyze    [--root <repo>] [--json]
             (static-analysis gate over the crate's own source: panic
             hygiene in deploy/ hot paths, atomic-ordering justifications,
             SeqCst-on-hot-path, lock scopes containing blocking calls or
             nested locks, stats-counter choke points, README status
             taxonomy sync, /metrics metric-name sync; exits non-zero on
             any finding; allowlist a site with
             `// analyze-allow: <rule> <reason>`)
  fixed-qat  --bits <b> + config flags (uniform-bit QAT baseline)
  myqasr     config flags (heuristic baseline; layer granularity)
  table1     --config <toml>   (method comparison @ bound 0.40%)
  table2     --config <toml>   (bound sweep, layer gates)
  table3     --config <toml>   (bound sweep, individual gates)
  table-deploy [--requests <n>] [--batch <b>] [--workers <n>]
             (deploy engine bench rows incl. the 1-vs-N-worker pool
              and the per-op compute split: MatMul / Im2col / Elem %)
  a2         --config <toml> [--lambdas 0.001,0.01,...]
  info       [--config <toml>]

Training commands write <run_id>.epochs.csv and <run_id>.epochs.jsonl
(one JSON event per line: epoch, constraint_check, snapshot, stage_*)
into --out-dir for machine scraping.

Library users: the same pipelines are cgmq::session::SessionBuilder stage
sequences — see the crate docs (`cargo doc --open`) for the API and the
migration note from the old coordinator::Trainer.
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    match run(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "pretrain" => cmd_pretrain(&args),
        "eval" => cmd_eval(&args),
        "export" => cmd_export(&args),
        "infer" => cmd_infer(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "route-bench" => cmd_route_bench(&args),
        "serve" => cmd_serve(&args),
        "load-bench" => cmd_load_bench(&args),
        "watch" => cmd_watch(&args),
        "analyze" => cmd_analyze(&args),
        "fixed-qat" => cmd_fixed_qat(&args),
        "myqasr" => cmd_myqasr(&args),
        "table1" => cmd_table(&args, 1),
        "table2" => cmd_table(&args, 2),
        "table3" => cmd_table(&args, 3),
        "table-deploy" => cmd_table_deploy(&args),
        "a2" => cmd_a2(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

/// Build a Config from --config plus CLI overrides.
fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(Path::new(path))?,
        None => Config::default(),
    };
    if let Some(v) = args.get("arch") {
        cfg.arch = v.to_string();
    }
    if let Some(v) = args.get("direction") {
        cfg.direction = DirKind::parse(v)?;
        cfg.lr_gates = Config::paper_gate_lr(cfg.direction);
    }
    if let Some(v) = args.get("granularity") {
        cfg.granularity = Granularity::parse(v)?;
    }
    if let Some(v) = args.get_f64("bound")? {
        cfg.bound_rbop_percent = v;
    }
    if let Some(v) = args.get_usize("cgmq-epochs")? {
        cfg.cgmq_epochs = v;
    }
    if let Some(v) = args.get_usize("pretrain-epochs")? {
        cfg.pretrain_epochs = v;
    }
    if let Some(v) = args.get_usize("train-size")? {
        cfg.train_size = v;
    }
    if let Some(v) = args.get_usize("test-size")? {
        cfg.test_size = v;
    }
    if let Some(v) = args.get_usize("seed")? {
        cfg.seed = v as u64;
    }
    if let Some(v) = args.get("out-dir") {
        cfg.out_dir = v.to_string();
    }
    if let Some(v) = args.get("artifacts") {
        cfg.artifacts_dir = v.to_string();
    }
    cfg.validate()?;
    Ok(cfg)
}

/// The paper pipeline (or its resume-from-checkpoint variant) with the
/// JSONL metrics observer attached.
fn train_session(cfg: &Config, from_pretrained: Option<&str>) -> Result<Session> {
    let jsonl = Path::new(&cfg.out_dir).join(format!("{}.epochs.jsonl", cfg.run_id()));
    let builder = SessionBuilder::new(cfg.clone()).observer(JsonlMetricsObserver::create(jsonl)?);
    let builder = match from_pretrained {
        Some(ckpt) => builder
            .stage(LoadCheckpoint::new(ckpt))
            .stage(Calibrate)
            .stage(RangeLearn::default())
            .stage(CgmqLoop::default()),
        None => builder.paper_pipeline(),
    };
    builder.build()
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let save = args.get("save").map(str::to_string);
    let from = args.get("from-pretrained").map(str::to_string);
    args.finish()?;
    let out_dir = cfg.out_dir.clone();
    let run_id = cfg.run_id();
    let mut session = train_session(&cfg, from.as_deref())?;
    session.run()?;
    let result = session.result()?;
    println!(
        "{}: float acc {:.2}% | quantized acc {:.2}% @ RBOP {:.3}% (bound {:.2}%) sat={} mean bits {:.2}",
        result.run_id,
        100.0 * result.float_acc,
        100.0 * result.quant_acc,
        result.rbop_percent,
        result.bound_rbop_percent,
        result.satisfied,
        result.mean_weight_bits
    );
    let dir = Path::new(&out_dir);
    session.metrics().write_csv(&dir.join(format!("{run_id}.epochs.csv")))?;
    std::fs::write(dir.join(format!("{run_id}.result.json")), result.to_json().to_string())?;
    if let Some(save) = save {
        session.final_model()?.save(Path::new(&save), session.ctx.arch.name)?;
        println!("saved best constraint-satisfying snapshot to {save}");
    }
    println!("epoch log: {}", dir.join(format!("{run_id}.epochs.csv")).display());
    println!("epoch jsonl: {}", dir.join(format!("{run_id}.epochs.jsonl")).display());
    Ok(())
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let save = args.get("save").unwrap_or("runs/pretrained.ckpt").to_string();
    args.finish()?;
    let epochs = cfg.pretrain_epochs;
    let mut session = SessionBuilder::new(cfg).stage(Pretrain::default()).build()?;
    session.run()?;
    let acc = session.ctx.float_acc.expect("Pretrain records float accuracy");
    session.ctx.save_params(Path::new(&save))?;
    println!("pretrained {} epochs, float acc {:.2}%, saved {}", epochs, 100.0 * acc, save);
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let ckpt = args.get("ckpt").map(str::to_string);
    args.finish()?;
    let Some(ckpt) = ckpt else { bail!("eval needs --ckpt <snapshot>") };
    let c = cgmq::checkpoint::Checkpoint::load(Path::new(&ckpt))?;
    let mut session = SessionBuilder::new(cfg).build()?;
    let ctx = &mut session.ctx;
    ctx.params = c.get_all("params")?;
    ctx.betas_w = c.get("betas_w")?.clone();
    ctx.betas_a = c.get("betas_a")?.clone();
    if let Ok(gw) = c.get_all("gates_w") {
        ctx.gates.gates_w = gw;
        ctx.gates.gates_a = c.get_all("gates_a")?;
        let acc = ctx.evaluate()?;
        let rbop = ctx.current_rbop()?;
        println!("quantized acc {:.2}% @ RBOP {:.3}%", 100.0 * acc, rbop);
    } else {
        let acc = ctx.evaluate_float()?;
        println!("float acc {:.2}%", 100.0 * acc);
    }
    Ok(())
}

fn cmd_export(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let ckpt = args.get("ckpt").map(str::to_string);
    let synth = args.get_bool("synth");
    let format = args.get("format").unwrap_or(if synth { "packed" } else { "json" }).to_string();
    let out = args.get("out").map(str::to_string);
    args.finish()?;
    if synth {
        // No checkpoint (and no compiled artifacts) needed: pack the
        // deterministic synthetic mixed-precision state the deploy bench
        // rows use. Exercises the identical pack → save → load → serve
        // path, so CI can smoke the serving stack without a pjrt build.
        if ckpt.is_some() {
            bail!("--ckpt and --synth are mutually exclusive");
        }
        if format != "packed" {
            bail!("export --synth only supports --format packed");
        }
        let out = out.unwrap_or_else(|| "synth.cgmqm".into());
        let arch = cgmq::model::arch_by_name(&cfg.arch)?;
        let s =
            bench_harness::synthetic_deploy_state(&arch, &bench_harness::DEPLOY_LEVELS, cfg.seed);
        let model = cgmq::deploy::PackedModel::from_state(
            &arch,
            &s.params,
            &s.betas_w,
            &s.betas_a,
            &s.gates,
        )?;
        let bytes = model.save(Path::new(&out))?;
        println!(
            "wrote synthetic packed model to {out} ({} bytes, {} weight payload bytes, arch {})",
            bytes,
            model.total_payload_bytes(),
            arch.name
        );
        return Ok(());
    }
    let Some(ckpt) = ckpt else { bail!("export needs --ckpt <snapshot> (or --synth)") };
    match format.as_str() {
        "json" => {
            let out = out.unwrap_or_else(|| "export.json".into());
            let report = cgmq::baselines::export_report(&cfg, Path::new(&ckpt))?;
            std::fs::write(&out, report.to_string())?;
            println!("wrote deployment report to {out}");
        }
        "packed" => {
            let out = out.unwrap_or_else(|| "export.cgmqm".into());
            let (model, arch, _) =
                cgmq::baselines::load_packable_snapshot(&cfg, Path::new(&ckpt))?;
            let bytes = model.save(Path::new(&out))?;
            println!(
                "wrote packed model to {out} ({} bytes, {} weight payload bytes, arch {})",
                bytes,
                model.total_payload_bytes(),
                arch.name
            );
        }
        other => bail!("unknown --format '{other}' (json | packed)"),
    }
    Ok(())
}

/// Load sample images for `infer`: an IDX images file (normalised like the
/// paper, mean 0.5 / std 0.5) or `--synth n` SynthMNIST samples.
fn infer_inputs(args: &Args) -> Result<(Vec<f32>, Option<Vec<i32>>, usize, usize)> {
    // Consumed up front so `--seed` is accepted (and ignored) with --input
    // too, instead of erroring as an unknown flag on that path only.
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    match (args.get("input").map(str::to_string), args.get_usize("synth")?) {
        (Some(path), None) => match args.get("labels") {
            // With labels: the shared loader enforces image/label count
            // agreement and applies the paper normalization.
            Some(lp) => {
                let ds = cgmq::data::idx::load_pair(Path::new(&path), Path::new(lp))?;
                Ok((ds.images, Some(ds.labels), ds.n, ds.rows * ds.cols))
            }
            None => {
                let (raw, n, rows, cols) = cgmq::data::idx::load_images(Path::new(&path))?;
                let images: Vec<f32> =
                    raw.iter().map(|&p| cgmq::data::idx::normalize_pixel(p)).collect();
                Ok((images, None, n, rows * cols))
            }
        },
        (None, Some(0)) => bail!("--synth needs at least one sample"),
        (None, Some(n)) => {
            let ds = cgmq::data::Dataset::synth(seed, n);
            let sample_len = ds.sample_len;
            Ok((ds.images, Some(ds.labels), n, sample_len))
        }
        (Some(_), Some(_)) => bail!("--input and --synth are mutually exclusive"),
        (None, None) => bail!("infer needs --input <idx-images> or --synth <n>"),
    }
}

fn cmd_infer(args: &Args) -> Result<()> {
    use cgmq::deploy::{DecodeMode, Engine};
    use cgmq::util::json::Json;
    let Some(model_path) = args.get("model").map(str::to_string) else {
        bail!("infer needs --model <m.cgmqm>")
    };
    let mode = match args.get("mode").unwrap_or("unpack") {
        "unpack" => DecodeMode::UnpackOnce,
        "streaming" => DecodeMode::Streaming,
        other => bail!("unknown --mode '{other}' (unpack | streaming)"),
    };
    let index = args.get_usize("index")?;
    let batch = args.get_usize("batch")?.unwrap_or(64).max(1);
    let (images, labels, n, sample_len) = infer_inputs(args)?;
    args.finish()?;
    let engine = Engine::load(Path::new(&model_path))?.with_mode(mode);
    if sample_len != engine.input_len() {
        bail!("inputs have {} values/sample, model wants {}", sample_len, engine.input_len());
    }
    if let Some(i) = index {
        if i >= n {
            bail!("--index {i} out of range ({n} samples)");
        }
        let x = &images[i * sample_len..(i + 1) * sample_len];
        let logits = engine.infer(x)?;
        let pred = cgmq::deploy::kernels::argmax(&logits);
        let mut fields = vec![
            ("model", Json::str(model_path)),
            ("index", Json::num(i as f64)),
            ("predicted", Json::num(pred as f64)),
            ("logits", Json::arr_f32(&logits)),
        ];
        if let Some(labels) = &labels {
            fields.push(("label", Json::num(labels[i] as f64)));
        }
        println!("{}", Json::obj(fields));
        return Ok(());
    }
    // Full-set prediction in engine batches.
    let mut preds = Vec::with_capacity(n);
    let mut start = 0;
    while start < n {
        let take = batch.min(n - start);
        let xs = &images[start * sample_len..(start + take) * sample_len];
        preds.extend(engine.predict_batch(xs, take)?);
        start += take;
    }
    let mut hist = vec![0u64; engine.num_classes()];
    for &p in &preds {
        hist[p] += 1;
    }
    let mut fields = vec![
        ("model", Json::str(model_path)),
        ("samples", Json::num(n as f64)),
        (
            "prediction_histogram",
            Json::Arr(hist.iter().map(|&c| Json::num(c as f64)).collect()),
        ),
    ];
    if let Some(labels) = &labels {
        let correct = preds.iter().zip(labels).filter(|&(&p, &l)| p as i32 == l).count();
        fields.push(("accuracy", Json::num(correct as f64 / n as f64)));
    }
    println!("{}", Json::obj(fields));
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    let Some(model_path) = args.get("model").map(str::to_string) else {
        bail!("serve-bench needs --model <m.cgmqm>")
    };
    let requests = args.get_usize("requests")?.unwrap_or(256).max(1);
    let batch = args.get_usize("batch")?.unwrap_or(32).max(1);
    let deadline_us = args.get_usize("deadline-us")?.unwrap_or(200) as u64;
    let workers = args.get_usize("workers")?.unwrap_or_else(cgmq::deploy::default_workers).max(1);
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    args.finish()?;
    let report = cgmq::bench_harness::serve_bench(
        Path::new(&model_path),
        requests,
        batch,
        std::time::Duration::from_micros(deadline_us),
        workers,
        seed,
    )?;
    println!("{report}");
    Ok(())
}

/// Parse a `--models key=a.cgmqm,key2=b.cgmqm,...` list (route-bench and
/// serve share the grammar).
fn parse_model_list(spec: &str) -> Result<Vec<(String, std::path::PathBuf)>> {
    let mut models: Vec<(String, std::path::PathBuf)> = Vec::new();
    for part in spec.split(',') {
        let Some((key, path)) = part.split_once('=') else {
            bail!("--models entry '{part}' is not key=path");
        };
        let (key, path) = (key.trim(), path.trim());
        if key.is_empty() || path.is_empty() {
            bail!("--models entry '{part}' has an empty key or path");
        }
        if models.iter().any(|(k, _)| k == key) {
            bail!("--models lists key '{key}' twice");
        }
        models.push((key.to_string(), std::path::PathBuf::from(path)));
    }
    Ok(models)
}

fn cmd_route_bench(args: &Args) -> Result<()> {
    let Some(spec) = args.get("models").map(str::to_string) else {
        bail!("route-bench needs --models <key=m.cgmqm,key2=m2.cgmqm,...>")
    };
    let models = parse_model_list(&spec)?;
    let requests = args.get_usize("requests")?.unwrap_or(256).max(1);
    let batch = args.get_usize("batch")?.unwrap_or(16).max(1);
    let deadline_us = args.get_usize("deadline-us")?.unwrap_or(200) as u64;
    let workers = args.get_usize("workers")?.unwrap_or_else(cgmq::deploy::default_workers).max(1);
    // Per-shard in-flight cap; 0 = unbounded (no shedding).
    let queue_cap = args.get_usize("queue-cap")?.unwrap_or(32);
    let swap = args.get_bool("swap");
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    args.finish()?;
    let pool = cgmq::deploy::PoolConfig {
        workers,
        batch: cgmq::deploy::BatchConfig {
            max_batch: batch,
            max_delay: std::time::Duration::from_micros(deadline_us),
        },
        queue_cap,
    };
    let report = bench_harness::router_bench_files(&models, swap, requests, pool, seed)?;
    println!("{report}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use cgmq::deploy::net::{Server, ServerConfig};
    let Some(spec) = args.get("models").map(str::to_string) else {
        bail!("serve needs --models <key=m.cgmqm,key2=m2.cgmqm,...>")
    };
    let models = parse_model_list(&spec)?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:8080").to_string();
    let workers = args.get_usize("workers")?.unwrap_or_else(cgmq::deploy::default_workers).max(1);
    let batch = args.get_usize("batch")?.unwrap_or(32).max(1);
    let deadline_us = args.get_usize("deadline-us")?.unwrap_or(200) as u64;
    // Per-shard in-flight cap; 0 = unbounded (no 429s).
    let queue_cap = args.get_usize("queue-cap")?.unwrap_or(32);
    let max_body_kib = args.get_usize("max-body-kib")?.unwrap_or(1024).max(1);
    let addr_file = args.get("addr-file").map(str::to_string);
    // /livez degradation thresholds over the trailing window; the shed-rate
    // default (0.5) trips when half the windowed traffic is 429s, and the
    // p99 bound is disabled (0) unless asked for.
    let livez_shed_rate = args.get_f64("livez-shed-rate")?.unwrap_or(0.5);
    let livez_p99_us = args.get_usize("livez-p99-us")?.unwrap_or(0) as u64;
    args.finish()?;
    let mut engines = Vec::with_capacity(models.len());
    for (key, path) in models {
        engines.push((key, std::sync::Arc::new(cgmq::deploy::Engine::load(&path)?)));
    }
    let cfg = ServerConfig {
        pool: cgmq::deploy::PoolConfig {
            workers,
            batch: cgmq::deploy::BatchConfig {
                max_batch: batch,
                max_delay: std::time::Duration::from_micros(deadline_us),
            },
            queue_cap,
        },
        max_body: max_body_kib << 10,
        livez_shed_rate,
        livez_p99_us,
        ..ServerConfig::default()
    };
    let keys: Vec<String> = engines.iter().map(|(k, _)| k.clone()).collect();
    let server = Server::bind(&addr, engines, cfg)?;
    let bound = server.local_addr();
    eprintln!(
        "listening on {bound} (models: {}; POST /v1/models/{{key}}/infer, GET /healthz, \
         GET /stats, GET /metrics, POST /admin/shutdown)",
        keys.join(", ")
    );
    if let Some(path) = addr_file {
        // Written after bind so a watcher reading it can connect at once.
        std::fs::write(&path, bound.to_string())?;
    }
    // Serve until /admin/shutdown, then drain; exit non-zero if the drain
    // lost an accepted request.
    let report = server.run()?;
    println!("{}", report.to_json());
    report.verify_drained()?;
    eprintln!("drained cleanly: every accepted request completed");
    Ok(())
}

fn cmd_load_bench(args: &Args) -> Result<()> {
    let Some(addr) = args.get("addr").map(str::to_string) else {
        bail!("load-bench needs --addr <host:port> (from `cgmq serve`)")
    };
    let key = args.get("key").unwrap_or("m").to_string();
    let requests = args.get_usize("requests")?.unwrap_or(256).max(1);
    let clients = args.get_usize("clients")?.unwrap_or(4).max(1);
    let rate_rps = args.get_f64("rate")?.unwrap_or(0.0).max(0.0);
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    let verify_model = args.get("verify-model").map(std::path::PathBuf::from);
    let min_shed = args.get_usize("min-shed")?.unwrap_or(0) as u64;
    let require_stages = args.get_bool("require-stages");
    let require_window = args.get_bool("require-window");
    let shutdown = args.get_bool("shutdown");
    args.finish()?;
    let spec = bench_harness::LoadBenchSpec {
        addr,
        key,
        requests,
        clients,
        rate_rps,
        seed,
        verify_model,
        require_stages,
        require_window,
        shutdown,
    };
    let report = bench_harness::load_bench(&spec)?;
    println!("{report}");
    let shed = report.get("shed")?.as_f64()? as u64;
    if shed < min_shed {
        bail!(
            "saturation check failed: observed {shed} shed (429) responses, --min-shed {min_shed}"
        );
    }
    Ok(())
}

fn cmd_watch(args: &Args) -> Result<()> {
    let Some(addr) = args.get("addr").map(str::to_string) else {
        bail!("watch needs --addr <host:port> (from `cgmq serve`)")
    };
    let interval_s = args.get_f64("interval")?.unwrap_or(2.0);
    let once = args.get_bool("once");
    args.finish()?;
    if !once && !(interval_s > 0.0) {
        bail!("--interval must be positive (got {interval_s})");
    }
    loop {
        // Each frame is one /stats poll rendered as a per-model table;
        // errors (server restarting, connection refused) end the watch
        // rather than spinning on a dead endpoint.
        println!("{}", bench_harness::watch_once(&addr)?);
        if once {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(interval_s));
    }
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let root = args.get("root").unwrap_or(".").to_string();
    let json = args.get_bool("json");
    args.finish()?;
    let report = cgmq::analyze::analyze_crate(Path::new(&root))?;
    if json {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.render());
    }
    if !report.clean() {
        bail!("analyze: {} finding(s)", report.findings.len());
    }
    Ok(())
}

fn cmd_table_deploy(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let requests = args.get_usize("requests")?.unwrap_or(64).max(1);
    let batch = args.get_usize("batch")?.unwrap_or(16).max(1);
    let workers = args.get_usize("workers")?.unwrap_or_else(cgmq::deploy::default_workers).max(1);
    args.finish()?;
    let out = bench_harness::deploy_table(&cfg, requests, batch, workers)?;
    println!("{out}");
    Ok(())
}

fn cmd_fixed_qat(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let bits = args.get_usize("bits")?.unwrap_or(8) as u32;
    args.finish()?;
    if !cgmq::BIT_LEVELS.contains(&bits) {
        bail!("--bits must be one of {:?}", cgmq::BIT_LEVELS);
    }
    let epochs = cfg.cgmq_epochs;
    let mut session = SessionBuilder::new(cfg)
        .stage(Pretrain::default())
        .stage(Calibrate)
        .boxed_stages(fixed_qat::stages(bits, epochs))
        .build()?;
    session.run()?;
    let r = fixed_qat::result(&session.ctx, bits)?;
    println!(
        "fixed {} bit QAT: acc {:.2}% @ RBOP {:.3}%",
        r.bits,
        100.0 * r.test_acc,
        r.rbop_percent
    );
    Ok(())
}

fn cmd_myqasr(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    args.finish()?;
    cfg.granularity = Granularity::Layer;
    let mut session = SessionBuilder::new(cfg)
        .stage(Pretrain::default())
        .stage(Calibrate)
        .stage(RangeLearn::default())
        .stage(myqasr::MyQasrStage::default())
        .build()?;
    session.run()?;
    let r = myqasr::result(&session.ctx)?;
    println!(
        "myQASR: acc {:.2}% @ RBOP {:.3}% sat={} assignment {:?}",
        100.0 * r.test_acc,
        r.rbop_percent,
        r.satisfied,
        r.assignment
    );
    Ok(())
}

fn cmd_table(args: &Args, which: usize) -> Result<()> {
    let cfg = load_config(args)?;
    args.finish()?;
    let out = match which {
        1 => bench_harness::table1(&cfg)?,
        2 => bench_harness::table_sweep(&cfg, Granularity::Layer)?,
        3 => bench_harness::table_sweep(&cfg, Granularity::Individual)?,
        _ => unreachable!(),
    };
    println!("{out}");
    Ok(())
}

fn cmd_a2(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let lambdas: Vec<f32> = match args.get("lambdas") {
        Some(s) => s
            .split(',')
            .map(|p| p.trim().parse::<f32>().map_err(|_| anyhow::anyhow!("bad lambda '{p}'")))
            .collect::<Result<_>>()?,
        None => vec![1e-3, 1e-2, 1e-1, 1.0],
    };
    args.finish()?;
    let out = bench_harness::penalty_comparison(&cfg, &lambdas)?;
    println!("{out}");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    args.finish()?;
    let artifacts = cgmq::runtime::ArtifactSet::open(Path::new(&cfg.artifacts_dir))?;
    let m = artifacts.manifest();
    println!("artifact dir: {}", cfg.artifacts_dir);
    for (name, entry) in m.get("artifacts")?.as_obj()? {
        let n_in = entry.get("inputs")?.as_arr()?.len();
        let n_out = entry.get("outputs")?.as_arr()?.len();
        println!("  {name}: {n_in} inputs -> {n_out} outputs ({})",
            entry.get("file")?.as_str()?);
    }
    for arch_name in ["lenet5", "mlp"] {
        let arch = cgmq::model::arch_by_name(arch_name)?;
        println!(
            "{arch_name}: {} params, fp32 {} GBOPs, floor RBOP {:.4}%",
            arch.n_params(),
            cgmq::cost::fp32_bops(&arch) as f64 / 1e9,
            cgmq::cost::rbop_percent(&arch, cgmq::cost::floor_bops(&arch)),
        );
    }
    Ok(())
}
