//! Coordinator invariants — randomized property tests over real training
//! runs (hand-rolled harness; the environment vendors no proptest).
//!
//! G1 (paper §3): "CGMQ guarantees that some model is found that satisfies
//! the cost constraint as long as such a model exists" — checked here for
//! random (direction, granularity, bound, seed) draws on the MLP arch.

mod common;

use cgmq::coordinator::Trainer;
use cgmq::direction::DirKind;
use cgmq::gates::Granularity;
use cgmq::util::rng::SplitMix64;
use cgmq::{GATE_FLOOR, GATE_INIT};

#[test]
fn constraint_satisfied_for_random_configs() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut rng = SplitMix64::new(0xC0FFEE);
    // 4 random property draws (each is a full small training run).
    for case in 0..4 {
        let mut cfg = common::quick_cfg();
        cfg.direction = match rng.below(3) {
            0 => DirKind::Dir1,
            1 => DirKind::Dir2,
            _ => DirKind::Dir3,
        };
        // CI-fast gate lr (see Config::gate_lr_scale doc): the guarantee
        // under test is lr-independent.
        cfg.lr_gates = 0.05;
        cfg.granularity =
            if rng.below(2) == 0 { Granularity::Layer } else { Granularity::Individual };
        cfg.bound_rbop_percent = [0.40, 0.90, 2.00, 5.00][rng.below(4)];
        cfg.seed = rng.next_u64() % 1000;
        cfg.cgmq_epochs = 10;
        let label = format!(
            "case {case}: {} {} bound {}",
            cfg.direction.label(),
            cfg.granularity.label(),
            cfg.bound_rbop_percent
        );

        let mut t = Trainer::new(cfg.clone()).unwrap();
        t.pretrain(cfg.pretrain_epochs).unwrap();
        t.calibrate().unwrap();
        t.learn_ranges(cfg.range_epochs).unwrap();
        // dir2/dir3's Unsat magnitude is ~1/(|grad|+|w|), so the descent
        // from 32-bit needs a horizon proportional to 1/(lr_g * batches)
        // (the paper runs 250 epochs x 469 batches; this CI set has 6
        // batches/epoch). Train in chunks until the guarantee kicks in.
        let mut epochs = 0;
        while t.final_model().is_err() && epochs < 60 {
            t.cgmq(10).unwrap();
            epochs += 10;
        }
        let float_acc = t.evaluate_float().unwrap();
        let r = t
            .final_model()
            .map(|m| cgmq::coordinator::RunResult {
                run_id: cfg.run_id(),
                float_acc,
                quant_acc: m.test_acc,
                rbop_percent: m.rbop_percent,
                bound_rbop_percent: cfg.bound_rbop_percent,
                satisfied: m.rbop_percent <= cfg.bound_rbop_percent + 1e-9,
                mean_weight_bits: 0.0,
                rbop_trace: t.rbop_trace.clone(),
            })
            .unwrap_or_else(|e| panic!("{label}: {e}"));
        // The delivered model satisfies the bound — the paper's guarantee.
        assert!(r.satisfied, "{label}: final model violates bound (rbop {})", r.rbop_percent);
        assert!(
            r.rbop_percent <= cfg.bound_rbop_percent + 1e-9,
            "{label}: rbop {} > bound",
            r.rbop_percent
        );
        // Gates stayed inside [floor, cap] the whole time (checked at end).
        for g in t.gates.gates_w.iter().chain(t.gates.gates_a.iter()) {
            for &v in g.data() {
                assert!(
                    (GATE_FLOOR..=GATE_INIT + 1e-6).contains(&v),
                    "{label}: gate {v} escaped [{GATE_FLOOR}, {GATE_INIT}]"
                );
            }
        }
        // The trace reaches the bound region from above (starts at 100%).
        assert!(!r.rbop_trace.is_empty());
        assert!(r.rbop_trace[0] <= 100.0);
        let min_trace = r.rbop_trace.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            min_trace <= cfg.bound_rbop_percent + 1e-9,
            "{label}: trace never reached the bound: {:?}",
            r.rbop_trace
        );
    }
}

#[test]
fn rbop_decreases_monotonically_while_unsat() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = common::quick_cfg();
    cfg.cgmq_epochs = 5;
    cfg.bound_rbop_percent = 0.40;
    let mut t = Trainer::new(cfg).unwrap();
    t.pretrain(1).unwrap();
    t.calibrate().unwrap();
    t.cgmq(5).unwrap();
    // While the constraint was unsatisfied, every epoch must reduce RBOP
    // (dirs are strictly positive in Unsat — paper property (i)).
    let trace = &t.rbop_trace;
    for w in trace.windows(2) {
        let was_unsat = w[0] > 0.40;
        if was_unsat {
            assert!(w[1] < w[0] + 1e-9, "RBOP went up while Unsat: {trace:?}");
        }
    }
}

#[test]
fn accuracy_survives_quantization_on_mlp() {
    // CGMQ at a loose bound should not destroy accuracy relative to float.
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = common::quick_cfg();
    cfg.bound_rbop_percent = 5.0;
    cfg.cgmq_epochs = 5;
    let mut t = Trainer::new(cfg).unwrap();
    let r = t.run_full().unwrap();
    assert!(r.float_acc > 0.5, "float model failed to learn: {}", r.float_acc);
    assert!(
        r.quant_acc > r.float_acc - 0.15,
        "quantization destroyed accuracy: float {} vs quant {}",
        r.float_acc,
        r.quant_acc
    );
}

#[test]
fn epoch_log_is_complete_and_serializable() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = common::quick_cfg();
    cfg.cgmq_epochs = 2;
    let mut t = Trainer::new(cfg.clone()).unwrap();
    t.run_full().unwrap();
    let expected = cfg.pretrain_epochs + cfg.range_epochs + cfg.cgmq_epochs;
    assert_eq!(t.log.records.len(), expected);
    let csv = t.log.to_csv();
    assert_eq!(csv.lines().count(), expected + 1);
    // JSON parses back
    let j = cgmq::util::json::parse(&t.log.to_json().to_string()).unwrap();
    assert_eq!(j.as_arr().unwrap().len(), expected);
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = common::quick_cfg();
    cfg.pretrain_epochs = 1;
    let mut t = Trainer::new(cfg.clone()).unwrap();
    t.pretrain(1).unwrap();
    let acc1 = t.evaluate_float().unwrap();
    let path = std::env::temp_dir().join("cgmq_itest_trainer.ckpt");
    t.save_params(&path).unwrap();

    let mut t2 = Trainer::new(cfg).unwrap();
    t2.load_params(&path).unwrap();
    let acc2 = t2.evaluate_float().unwrap();
    assert!((acc1 - acc2).abs() < 1e-9, "checkpoint changed accuracy: {acc1} vs {acc2}");
}

#[test]
fn wrong_arch_checkpoint_rejected() {
    let Some(_) = common::artifacts_dir() else { return };
    let cfg = common::quick_cfg();
    let mut t = Trainer::new(cfg.clone()).unwrap();
    let path = std::env::temp_dir().join("cgmq_itest_wrongarch.ckpt");
    t.save_params(&path).unwrap();
    // rewrite meta to claim a different arch
    let meta = std::env::temp_dir().join("cgmq_itest_wrongarch.ckpt.meta.json");
    std::fs::write(&meta, "{\"arch\": \"lenet5\"}").unwrap();
    let mut t2 = Trainer::new(cfg).unwrap();
    assert!(t2.load_params(&path).is_err());
}
