//! Training-loop invariants — randomized property tests over real training
//! runs (hand-rolled harness; the environment vendors no proptest).
//!
//! G1 (paper §3): "CGMQ guarantees that some model is found that satisfies
//! the cost constraint as long as such a model exists" — checked here for
//! random (direction, granularity, bound, seed) draws on the MLP arch,
//! driven through the staged `session` API.

mod common;

use cgmq::coordinator::Trainer;
use cgmq::direction::DirKind;
use cgmq::gates::Granularity;
use cgmq::session::{Calibrate, CgmqLoop, Pretrain, RangeLearn, SessionBuilder};
use cgmq::util::rng::SplitMix64;
use cgmq::{GATE_FLOOR, GATE_INIT};

#[test]
fn constraint_satisfied_for_random_configs() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut rng = SplitMix64::new(0xC0FFEE);
    // 4 random property draws (each is a full small training run).
    for case in 0..4 {
        let mut cfg = common::quick_cfg();
        cfg.direction = match rng.below(3) {
            0 => DirKind::Dir1,
            1 => DirKind::Dir2,
            _ => DirKind::Dir3,
        };
        // CI-fast gate lr (see Config::gate_lr_scale doc): the guarantee
        // under test is lr-independent.
        cfg.lr_gates = 0.05;
        cfg.granularity =
            if rng.below(2) == 0 { Granularity::Layer } else { Granularity::Individual };
        cfg.bound_rbop_percent = [0.40, 0.90, 2.00, 5.00][rng.below(4)];
        cfg.seed = rng.next_u64() % 1000;
        cfg.cgmq_epochs = 10;
        let label = format!(
            "case {case}: {} {} bound {}",
            cfg.direction.label(),
            cfg.granularity.label(),
            cfg.bound_rbop_percent
        );

        let mut session = SessionBuilder::new(cfg.clone())
            .stage(Pretrain::default())
            .stage(Calibrate)
            .stage(RangeLearn::default())
            .build()
            .unwrap();
        session.run().unwrap();
        // dir2/dir3's Unsat magnitude is ~1/(|grad|+|w|), so the descent
        // from 32-bit needs a horizon proportional to 1/(lr_g * batches)
        // (the paper runs 250 epochs x 469 batches; this CI set has 6
        // batches/epoch). Train in chunks until the guarantee kicks in.
        let mut epochs = 0;
        while session.final_model().is_err() && epochs < 60 {
            session.run_stage(CgmqLoop::epochs(10)).unwrap();
            epochs += 10;
        }
        let r = session.result().unwrap_or_else(|e| panic!("{label}: {e}"));
        // The delivered model satisfies the bound — the paper's guarantee.
        assert!(r.satisfied, "{label}: final model violates bound (rbop {})", r.rbop_percent);
        assert!(
            r.rbop_percent <= cfg.bound_rbop_percent + 1e-9,
            "{label}: rbop {} > bound",
            r.rbop_percent
        );
        // Gates stayed inside [floor, cap] the whole time (checked at end).
        let gates = &session.ctx.gates;
        for g in gates.gates_w.iter().chain(gates.gates_a.iter()) {
            for &v in g.data() {
                assert!(
                    (GATE_FLOOR..=GATE_INIT + 1e-6).contains(&v),
                    "{label}: gate {v} escaped [{GATE_FLOOR}, {GATE_INIT}]"
                );
            }
        }
        // The trace reaches the bound region from above (starts at 100%).
        assert!(!r.rbop_trace.is_empty());
        assert!(r.rbop_trace[0] <= 100.0);
        let min_trace = r.rbop_trace.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            min_trace <= cfg.bound_rbop_percent + 1e-9,
            "{label}: trace never reached the bound: {:?}",
            r.rbop_trace
        );
    }
}

#[test]
fn rbop_decreases_monotonically_while_unsat() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = common::quick_cfg();
    cfg.cgmq_epochs = 5;
    cfg.bound_rbop_percent = 0.40;
    let mut session = SessionBuilder::new(cfg)
        .stage(Pretrain::epochs(1))
        .stage(Calibrate)
        .stage(CgmqLoop::epochs(5))
        .build()
        .unwrap();
    session.run().unwrap();
    // While the constraint was unsatisfied, every epoch must reduce RBOP
    // (dirs are strictly positive in Unsat — paper property (i)).
    let trace = &session.ctx.rbop_trace;
    for w in trace.windows(2) {
        let was_unsat = w[0] > 0.40;
        if was_unsat {
            assert!(w[1] < w[0] + 1e-9, "RBOP went up while Unsat: {trace:?}");
        }
    }
}

#[test]
fn accuracy_survives_quantization_on_mlp() {
    // CGMQ at a loose bound should not destroy accuracy relative to float.
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = common::quick_cfg();
    cfg.bound_rbop_percent = 5.0;
    cfg.cgmq_epochs = 5;
    let mut session = SessionBuilder::new(cfg).paper_pipeline().build().unwrap();
    session.run().unwrap();
    let r = session.result().unwrap();
    assert!(r.float_acc > 0.5, "float model failed to learn: {}", r.float_acc);
    assert!(
        r.quant_acc > r.float_acc - 0.15,
        "quantization destroyed accuracy: float {} vs quant {}",
        r.float_acc,
        r.quant_acc
    );
}

#[test]
fn epoch_log_is_complete_and_serializable() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = common::quick_cfg();
    cfg.cgmq_epochs = 2;
    let mut session = SessionBuilder::new(cfg.clone()).paper_pipeline().build().unwrap();
    session.run().unwrap();
    let expected = cfg.pretrain_epochs + cfg.range_epochs + cfg.cgmq_epochs;
    assert_eq!(session.metrics().records.len(), expected);
    let csv = session.metrics().to_csv();
    assert_eq!(csv.lines().count(), expected + 1);
    // JSON parses back
    let j = cgmq::util::json::parse(&session.metrics().to_json().to_string()).unwrap();
    assert_eq!(j.as_arr().unwrap().len(), expected);
    // One report per stage, in pipeline order.
    let stages: Vec<&str> = session.reports().iter().map(|r| r.stage.as_str()).collect();
    assert_eq!(stages, ["pretrain", "calibrate", "ranges", "cgmq"]);
}

/// The old `Trainer` facade still drives the same pipeline (shim coverage:
/// it must keep compiling *and* producing identical results while external
/// drivers migrate to `SessionBuilder`).
#[test]
fn trainer_shim_checkpoint_roundtrip() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = common::quick_cfg();
    cfg.pretrain_epochs = 1;
    let mut t = Trainer::new(cfg.clone()).unwrap();
    t.pretrain(1).unwrap();
    let acc1 = t.evaluate_float().unwrap();
    let path = std::env::temp_dir().join("cgmq_itest_trainer.ckpt");
    t.save_params(&path).unwrap();

    let mut t2 = Trainer::new(cfg).unwrap();
    t2.load_params(&path).unwrap();
    let acc2 = t2.evaluate_float().unwrap();
    assert!((acc1 - acc2).abs() < 1e-9, "checkpoint changed accuracy: {acc1} vs {acc2}");
}

#[test]
fn wrong_arch_checkpoint_rejected() {
    let Some(_) = common::artifacts_dir() else { return };
    let cfg = common::quick_cfg();
    let session = SessionBuilder::new(cfg.clone()).build().unwrap();
    let path = std::env::temp_dir().join("cgmq_itest_wrongarch.ckpt");
    session.ctx.save_params(&path).unwrap();
    // rewrite meta to claim a different arch
    let meta = std::env::temp_dir().join("cgmq_itest_wrongarch.ckpt.meta.json");
    std::fs::write(&meta, "{\"arch\": \"lenet5\"}").unwrap();
    let mut session2 = SessionBuilder::new(cfg).build().unwrap();
    assert!(session2.ctx.load_params(&path).is_err());
}
