//! Fixture-driven tests for the `cgmq::analyze` rule engine, plus the
//! self-check asserting the shipped crate is clean under the full
//! ruleset.
//!
//! Each rule family gets at least one positive fixture (the rule fires,
//! with the right rule id and line) and one negative fixture (the
//! compliant shapes, allowlist syntax and multi-line-guard edge cases
//! stay silent). Fixtures live in `fixtures/analyze/` and are embedded
//! with `include_str!`, so the tests run from any working directory.

use std::path::Path;

use cgmq::analyze::{analyze_crate, analyze_source, rules, Finding};

/// Virtual path inside the deploy hot-path scope.
const DEPLOY: &str = "rust/src/deploy/net/fixture.rs";
/// Virtual path outside deploy (crate-wide rules still apply here).
const ELSEWHERE: &str = "rust/src/metrics.rs";

fn rule_ids(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------- panic

#[test]
fn panic_hygiene_flags_unwrap_in_deploy() {
    let findings = analyze_source(DEPLOY, include_str!("fixtures/analyze/panic_bad.rs"));
    assert_eq!(rule_ids(&findings), vec![rules::RULE_PANIC], "{findings:#?}");
    assert_eq!(findings[0].line, 3);
    assert_eq!(findings[0].file, DEPLOY);
    assert!(findings[0].message.contains(".unwrap()"), "{}", findings[0].message);
}

#[test]
fn panic_hygiene_covers_the_kernel_and_plan_layer() {
    // The compiled-plan + shared-kernel files are serving hot path: a
    // planted unwrap at those paths must be caught exactly like one in
    // the network front.
    let src = include_str!("fixtures/analyze/panic_bad.rs");
    for path in [
        "rust/src/deploy/plan.rs",
        "rust/src/deploy/kernels/gemm.rs",
        "rust/src/deploy/kernels/im2col.rs",
        "rust/src/deploy/kernels/elementwise.rs",
    ] {
        let findings = analyze_source(path, src);
        assert_eq!(rule_ids(&findings), vec![rules::RULE_PANIC], "{path}: {findings:#?}");
        assert_eq!(findings[0].file, path);
        assert_eq!(findings[0].line, 3);
    }
}

#[test]
fn panic_hygiene_is_scoped_to_deploy() {
    // The same source outside deploy/ (and in the load-time/oracle files)
    // is out of scope.
    let src = include_str!("fixtures/analyze/panic_bad.rs");
    assert!(analyze_source(ELSEWHERE, src).is_empty());
    assert!(analyze_source("rust/src/deploy/format.rs", src).is_empty());
    assert!(analyze_source("rust/src/deploy/reference.rs", src).is_empty());
}

#[test]
fn panic_hygiene_negative_fixture_is_clean() {
    // Typed fallback, allowlisted expect, panic tokens inside a string
    // literal, and #[cfg(test)]-gated unwraps: all silent.
    let findings = analyze_source(DEPLOY, include_str!("fixtures/analyze/panic_ok.rs"));
    assert!(findings.is_empty(), "{findings:#?}");
}

#[test]
fn same_line_allow_suppresses() {
    let src = "pub fn admit(x: Option<u32>) -> u32 {\n    \
               x.unwrap() // analyze-allow: panic-hygiene recovered at the caller\n}\n";
    assert!(analyze_source(DEPLOY, src).is_empty());
}

// ------------------------------------------------------------- ordering

#[test]
fn atomic_ordering_flags_unjustified_use_crate_wide() {
    // Applies outside deploy/ too.
    let findings = analyze_source(ELSEWHERE, include_str!("fixtures/analyze/ordering_bad.rs"));
    assert_eq!(rule_ids(&findings), vec![rules::RULE_ORDERING], "{findings:#?}");
    assert_eq!(findings[0].line, 6);
}

#[test]
fn atomic_ordering_negative_fixture_is_clean() {
    // Same-line marker, marker directly above, marker at the top of a
    // multi-line comment run.
    let findings = analyze_source(ELSEWHERE, include_str!("fixtures/analyze/ordering_ok.rs"));
    assert!(findings.is_empty(), "{findings:#?}");
}

// --------------------------------------------------------------- seqcst

#[test]
fn seqcst_flagged_in_hot_functions() {
    let findings = analyze_source(DEPLOY, include_str!("fixtures/analyze/seqcst_bad.rs"));
    assert_eq!(rule_ids(&findings), vec![rules::RULE_SEQCST], "{findings:#?}");
    assert!(findings[0].message.contains("admit"), "{}", findings[0].message);
}

#[test]
fn seqcst_rule_is_scoped_to_deploy_hot_paths() {
    // Outside deploy/ the SeqCst rule does not apply (the ordering rule
    // is satisfied by the fixture's marker).
    let src = include_str!("fixtures/analyze/seqcst_bad.rs");
    assert!(analyze_source(ELSEWHERE, src).is_empty());
}

#[test]
fn seqcst_negative_fixture_is_clean() {
    // Cold-function SeqCst, hot-function Relaxed, allowlisted hot SeqCst.
    let findings = analyze_source(DEPLOY, include_str!("fixtures/analyze/seqcst_ok.rs"));
    assert!(findings.is_empty(), "{findings:#?}");
}

// ----------------------------------------------------------------- lock

#[test]
fn lock_scope_flags_blocking_call_and_second_lock() {
    let findings = analyze_source(DEPLOY, include_str!("fixtures/analyze/lock_bad.rs"));
    assert_eq!(
        rule_ids(&findings),
        vec![rules::RULE_LOCK, rules::RULE_LOCK],
        "{findings:#?}"
    );
    assert!(findings[0].message.contains("blocking"), "{}", findings[0].message);
    assert!(findings[1].message.contains("second lock"), "{}", findings[1].message);
    // Each finding names the guard it saw and where it was taken.
    assert!(findings[0].message.contains("guard 'guard'"), "{}", findings[0].message);
    assert!(findings[1].message.contains("guard 'first'"), "{}", findings[1].message);
}

#[test]
fn lock_scope_negative_fixture_is_clean() {
    // drop() before the blocking call, a guard whose multi-line block
    // scope closes before the blocking call, and an allowlisted
    // documented double-lock: all silent.
    let findings = analyze_source(DEPLOY, include_str!("fixtures/analyze/lock_ok.rs"));
    assert!(findings.is_empty(), "{findings:#?}");
}

// -------------------------------------------------------------- counter

#[test]
fn counter_choke_flags_mutation_outside_choke_points() {
    let findings = analyze_source(DEPLOY, include_str!("fixtures/analyze/counter_bad.rs"));
    assert_eq!(rule_ids(&findings), vec![rules::RULE_COUNTER], "{findings:#?}");
    assert!(findings[0].message.contains("outstanding"), "{}", findings[0].message);
    assert!(findings[0].message.contains("sweep"), "{}", findings[0].message);
}

#[test]
fn counter_choke_negative_fixture_is_clean() {
    let findings = analyze_source(DEPLOY, include_str!("fixtures/analyze/counter_ok.rs"));
    assert!(findings.is_empty(), "{findings:#?}");
}

// ------------------------------------------------------------ bad-allow

#[test]
fn bad_allow_vets_the_annotations_themselves() {
    let findings = analyze_source(DEPLOY, include_str!("fixtures/analyze/allow_bad.rs"));
    assert_eq!(
        rule_ids(&findings),
        vec![rules::RULE_BAD_ALLOW, rules::RULE_BAD_ALLOW],
        "{findings:#?}"
    );
    assert!(findings[0].message.contains("panick-hygiene"), "{}", findings[0].message);
    assert!(findings[1].message.contains("no reason"), "{}", findings[1].message);
}

// ------------------------------------------------------------- taxonomy

#[test]
fn taxonomy_in_sync_is_clean() {
    let findings = rules::check_taxonomy(
        "http.rs",
        include_str!("fixtures/analyze/taxonomy_http.rs"),
        "README.md",
        include_str!("fixtures/analyze/taxonomy_readme_ok.md"),
    );
    assert!(findings.is_empty(), "{findings:#?}");
}

#[test]
fn taxonomy_drift_is_flagged_both_directions() {
    let findings = rules::check_taxonomy(
        "http.rs",
        include_str!("fixtures/analyze/taxonomy_http.rs"),
        "README.md",
        include_str!("fixtures/analyze/taxonomy_readme_bad.md"),
    );
    assert_eq!(
        rule_ids(&findings),
        vec![rules::RULE_TAXONOMY, rules::RULE_TAXONOMY],
        "{findings:#?}"
    );
    // Emitted but undocumented: 429 (reported against http.rs).
    assert!(findings[0].message.contains("429"), "{}", findings[0].message);
    assert_eq!(findings[0].file, "http.rs");
    // Documented but never emitted: 503 (reported against the README).
    assert!(findings[1].message.contains("503"), "{}", findings[1].message);
    assert_eq!(findings[1].file, "README.md");
}

#[test]
fn taxonomy_missing_markers_is_flagged() {
    let findings = rules::check_taxonomy(
        "http.rs",
        include_str!("fixtures/analyze/taxonomy_http.rs"),
        "README.md",
        "# README without the analyze markers\n",
    );
    assert_eq!(rule_ids(&findings), vec![rules::RULE_TAXONOMY], "{findings:#?}");
    assert!(findings[0].message.contains("analyze:taxonomy"), "{}", findings[0].message);
}

// -------------------------------------------------------------- metrics

#[test]
fn metrics_in_sync_is_clean() {
    let findings = rules::check_metrics(
        "telemetry.rs",
        include_str!("fixtures/analyze/metrics_src.rs"),
        "README.md",
        include_str!("fixtures/analyze/metrics_readme_ok.md"),
    );
    assert!(findings.is_empty(), "{findings:#?}");
}

#[test]
fn metrics_drift_is_flagged_both_directions() {
    let findings = rules::check_metrics(
        "telemetry.rs",
        include_str!("fixtures/analyze/metrics_src.rs"),
        "README.md",
        include_str!("fixtures/analyze/metrics_readme_bad.md"),
    );
    assert_eq!(
        rule_ids(&findings),
        vec![rules::RULE_METRICS, rules::RULE_METRICS],
        "{findings:#?}"
    );
    // Emitted but undocumented: reported against the source, at the line
    // defining the name (comment-stripped, so the retired name in the
    // fixture's prose comment does not also fire).
    assert!(findings[0].message.contains("cgmq_requests_total"), "{}", findings[0].message);
    assert_eq!(findings[0].file, "telemetry.rs");
    assert_eq!(findings[0].line, 4);
    // Documented but never emitted: reported against the README table.
    assert!(findings[1].message.contains("cgmq_latency_seconds"), "{}", findings[1].message);
    assert_eq!(findings[1].file, "README.md");
}

#[test]
fn metrics_missing_markers_is_flagged() {
    let findings = rules::check_metrics(
        "telemetry.rs",
        include_str!("fixtures/analyze/metrics_src.rs"),
        "README.md",
        "# README without the analyze markers\n",
    );
    assert_eq!(rule_ids(&findings), vec![rules::RULE_METRICS], "{findings:#?}");
    assert!(findings[0].message.contains("analyze:metrics"), "{}", findings[0].message);
}

#[test]
fn metrics_scope_spans_the_window_submodule_when_sources_are_concatenated() {
    // `analyze_crate` feeds `check_metrics` the concatenation of
    // `telemetry.rs` and `telemetry/window.rs` (joined with '\n'), so a
    // metric name defined only in the window submodule is in scope.
    // Mirror that exact composition here.
    let window_src = "//! Window submodule fixture.\n\
                      pub const M_REQUESTS_WINDOW: &str = \"cgmq_requests_window\";\n";
    let combined =
        format!("{}\n{}", include_str!("fixtures/analyze/metrics_src.rs"), window_src);
    let readme = "# Fixture README\n\n\
                  <!-- analyze:metrics:begin -->\n\
                  | metric | type |\n\
                  |---|---|\n\
                  | `cgmq_connections_total` | counter |\n\
                  | `cgmq_requests_total` | counter |\n\
                  | `cgmq_stage_duration_seconds` | histogram |\n\
                  | `cgmq_requests_window` | windowed counter |\n\
                  <!-- analyze:metrics:end -->\n";
    assert!(rules::check_metrics("telemetry.rs", &combined, "README.md", readme).is_empty());

    // Dropping the window row flags the window-defined name — proof that
    // the concatenated scope is what the rule checks in both directions.
    let stale = readme.replace("| `cgmq_requests_window` | windowed counter |\n", "");
    let findings = rules::check_metrics("telemetry.rs", &combined, "README.md", &stale);
    assert_eq!(rule_ids(&findings), vec![rules::RULE_METRICS], "{findings:#?}");
    assert!(findings[0].message.contains("cgmq_requests_window"), "{}", findings[0].message);
    assert_eq!(findings[0].file, "telemetry.rs");
}

// ----------------------------------------------------------- self-check

#[test]
fn shipped_crate_is_clean_under_the_full_ruleset() {
    // The repo root is the directory holding Cargo.toml.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = analyze_crate(root).expect("analyze_crate runs on the shipped tree");
    assert!(report.files_scanned > 30, "walked only {} files", report.files_scanned);
    assert!(
        report.clean(),
        "shipped crate has analyze findings:\n{}",
        report.render()
    );
}
