//! Property tests of the shared kernel layer (`cgmq::deploy::kernels`)
//! against naive oracles, on seeded deterministic inputs.
//!
//! The contract under test is *stronger* than numerical closeness: the
//! blocked GEMM must equal the naive triple loop **bit-for-bit** on every
//! shape, because the engine ↔ reference cross-path goldens (and the HTTP
//! bit-identity check in `load-bench --verify-model`) ride on the kernels
//! producing exactly the seed implementation's float sums. That holds by
//! construction — one accumulator per output element, k swept ascending
//! and never split — and these tests pin it across awkward tile
//! remainders: dims of 1, the register tile edges (MR±1, NR±1), primes
//! past the cache block, and everything in between.

use cgmq::deploy::kernels::{
    add_bias_cols, add_bias_rows, conv2d, dense, gemm, gemm_naive, im2col, MR, NR,
};

/// Deterministic xorshift64* so the matrices are seeded, not random.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform-ish f32 in [-0.5, 0.5) — exercises cancellation without
    /// overflow, like normalized activations/weights.
    fn f32(&mut self) -> f32 {
        (self.next() >> 40) as f32 / 16_777_216.0 - 0.5
    }

    fn vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32()).collect()
    }
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: element {i}: got {g}, want {w}");
    }
}

// ------------------------------------------------------------- gemm

/// Awkward dims around every blocking boundary: 1, the MR=4 / NR=8
/// register tile edges, primes, and primes past the NC=256 cache block.
const DIMS: [usize; 8] = [1, 2, MR - 1, MR + 1, NR - 1, NR + 1, 13, 37];

#[test]
fn blocked_gemm_is_bitwise_equal_to_the_naive_oracle() {
    let mut rng = Rng(0x9E37_79B9_7F4A_7C15);
    for &m in &DIMS {
        for &k in &DIMS {
            for &n in &DIMS {
                let a = rng.vec(m * k);
                let b = rng.vec(k * n);
                let mut c = vec![f32::NAN; m * n]; // stale garbage must be overwritten
                let mut c_ref = vec![0.0f32; m * n];
                gemm(&a, &b, &mut c, m, k, n);
                gemm_naive(&a, &b, &mut c_ref, m, k, n);
                assert_bits_eq(&c, &c_ref, &format!("gemm {m}x{k}x{n}"));
            }
        }
    }
}

#[test]
fn blocked_gemm_crosses_the_cache_column_block() {
    // n = 257 and 263 straddle the NC = 256 column block; k = 131 is a
    // prime that leaves every register-tile remainder shape live at once.
    let mut rng = Rng(7);
    for (m, k, n) in [(5, 131, 257), (MR, 64, 263), (17, 3, 256)] {
        let a = rng.vec(m * k);
        let b = rng.vec(k * n);
        let mut c = vec![f32::NAN; m * n];
        let mut c_ref = vec![0.0f32; m * n];
        gemm(&a, &b, &mut c, m, k, n);
        gemm_naive(&a, &b, &mut c_ref, m, k, n);
        assert_bits_eq(&c, &c_ref, &format!("gemm {m}x{k}x{n}"));
    }
}

#[test]
fn gemm_zero_k_writes_zeros_over_stale_output() {
    // k = 0: an empty reduction must still overwrite the whole output.
    let mut c = vec![f32::NAN; 6];
    gemm(&[], &[], &mut c, 2, 0, 3);
    assert!(c.iter().all(|v| v.to_bits() == 0.0f32.to_bits()), "{c:?}");
}

#[test]
fn gemm_is_deterministic_across_repeated_calls() {
    let mut rng = Rng(42);
    let (m, k, n) = (NR + 1, 37, NC_PROBE);
    let a = rng.vec(m * k);
    let b = rng.vec(k * n);
    let mut first = vec![0.0f32; m * n];
    gemm(&a, &b, &mut first, m, k, n);
    for _ in 0..3 {
        let mut again = vec![f32::NAN; m * n];
        gemm(&a, &b, &mut again, m, k, n);
        assert_bits_eq(&again, &first, "repeated gemm");
    }
}

/// A column count that exercises one full cache block plus a remainder.
const NC_PROBE: usize = 300;

// ------------------------------------------------------------ dense

#[test]
fn dense_single_rows_equal_the_batched_result_bitwise() {
    // The accumulation order is batch-size-independent: running each
    // sample alone must reproduce the batched rows bit-for-bit. This is
    // what makes serve-path batching invisible to the HTTP bit-identity
    // check.
    let mut rng = Rng(0xDEAD_BEEF);
    let (n_samples, d_in, d_out) = (7, 29, NR + 3);
    let h = rng.vec(n_samples * d_in);
    let w = rng.vec(d_in * d_out);
    let bias = rng.vec(d_out);
    let batched = dense(&h, &w, &bias, n_samples, d_in, d_out);
    for s in 0..n_samples {
        let one = dense(&h[s * d_in..(s + 1) * d_in], &w, &bias, 1, d_in, d_out);
        assert_bits_eq(&one, &batched[s * d_out..(s + 1) * d_out], &format!("sample {s}"));
    }
}

#[test]
fn bias_epilogues_match_hand_expansion() {
    // 2x3: cols broadcast per output column, rows per output row.
    let mut c = vec![0.0f32; 6];
    add_bias_cols(&mut c, &[1.0, 2.0, 3.0], 2, 3);
    assert_eq!(c, [1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    let mut c = vec![0.0f32; 6];
    add_bias_rows(&mut c, &[1.0, 2.0], 2, 3);
    assert_eq!(c, [1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
}

// ------------------------------------------------------------- conv

/// Naive 6-loop valid conv oracle (NCHW / OIHW), accumulation ascending
/// (ic, ky, kx) — the seed engine's exact summation order.
#[allow(clippy::too_many_arguments)]
fn conv_oracle(
    h: &[f32],
    w: &[f32],
    bias: &[f32],
    n: usize,
    ci: usize,
    hi: usize,
    wi: usize,
    o: usize,
    kh: usize,
    kw: usize,
) -> Vec<f32> {
    let (ho, wo) = (hi - kh + 1, wi - kw + 1);
    let mut out = vec![0.0f32; n * o * ho * wo];
    for s in 0..n {
        for oc in 0..o {
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = 0.0f32;
                    for ic in 0..ci {
                        for ky in 0..kh {
                            for kx in 0..kw {
                                let iv = h[((s * ci + ic) * hi + oy + ky) * wi + ox + kx];
                                let wv = w[((oc * ci + ic) * kh + ky) * kw + kx];
                                acc += iv * wv;
                            }
                        }
                    }
                    out[((s * o + oc) * ho + oy) * wo + ox] = acc + bias[oc];
                }
            }
        }
    }
    out
}

#[test]
fn im2col_gemm_conv_is_bitwise_equal_to_the_six_loop_oracle() {
    let mut rng = Rng(0x5EED);
    // (ci, hi, wi, o, kh, kw): 1x1 kernels, full-image kernels, tall
    // kernels, multi-channel, multi-output — every im2col edge.
    let shapes = [
        (1, 1, 1, 1, 1, 1),
        (1, 5, 5, 3, 3, 3),
        (2, 4, 6, 5, 3, 2),
        (3, 7, 7, 4, 7, 7),
        (4, 6, 5, NR + 1, 2, 3),
        (5, 9, 8, 2, 1, 5),
    ];
    for (ci, hi, wi, o, kh, kw) in shapes {
        for n in [1, 3] {
            let h = rng.vec(n * ci * hi * wi);
            let w = rng.vec(o * ci * kh * kw);
            let bias = rng.vec(o);
            let got = conv2d(&h, &w, &bias, n, ci, hi, wi, o, kh, kw);
            let want = conv_oracle(&h, &w, &bias, n, ci, hi, wi, o, kh, kw);
            assert_bits_eq(&got, &want, &format!("conv {ci}x{hi}x{wi} o={o} k={kh}x{kw} n={n}"));
        }
    }
}

#[test]
fn im2col_fills_only_the_declared_prefix() {
    // A scratch buffer longer than ci·kh·kw × ho·wo keeps its tail.
    let img: Vec<f32> = (0..9).map(|v| v as f32).collect();
    let mut col = vec![f32::NAN; 4 * 4 + 5];
    im2col(&img, 1, 3, 3, 2, 2, &mut col);
    assert!(col[..16].iter().all(|v| !v.is_nan()));
    assert!(col[16..].iter().all(|v| v.is_nan()));
}

// ------------------------------------------------------------- swar

use cgmq::deploy::kernels::{
    decide, encode_scalar_rows, pack_conv_weights, pack_dense_weights, pack_lane_cols, swar_gemm,
    ActGrid,
};
use cgmq::deploy::plan::{Kernel, KernelSelector};
use cgmq::deploy::reference::fake_quant_logits;
use cgmq::deploy::PackedModel;
use cgmq::gates::{GateSet, Granularity};
use cgmq::model::{ArchSpec, LayerKind, LayerSpec};
use cgmq::quant::{gate_for_bits, quantize};
use cgmq::tensor::Tensor;

/// Uniform random integer in `[lo, hi]` from the test rng.
fn code_in(rng: &mut Rng, lo: i64, hi: i64) -> i64 {
    lo + (rng.next() % (hi - lo + 1) as u64) as i64
}

/// One-layer arch around an arbitrary lowered matmul shape — `verify()`
/// would reject it (unregistered), but `from_state` and the fake-quant
/// reference take the spec directly, which is exactly what kernel-level
/// property tests need to reach awkward reduction depths.
fn one_layer_arch(spec: LayerSpec, input_shape: Vec<usize>, input_bits: u32) -> ArchSpec {
    ArchSpec {
        name: "swar-prop",
        input_shape,
        layers: vec![spec],
        train_batch: 8,
        eval_batch: 8,
        input_bits,
    }
}

/// Uniform-width state: every gate at `gate_for_bits(w_bits)`, weight
/// ranges from the data, seeded non-zero biases so the epilogue is live.
fn uniform_state(
    arch: &ArchSpec,
    granularity: Granularity,
    w_bits: u32,
    seed: u64,
) -> (Vec<Tensor>, Tensor, Tensor, GateSet) {
    let mut params = arch.init_params(seed);
    let mut rng = Rng(seed | 1);
    let n_layers = arch.layers.len();
    let mut betas_w = Tensor::zeros(&[n_layers]);
    for li in 0..n_layers {
        betas_w.data_mut()[li] = params[2 * li].abs_max().max(1e-3);
        for b in params[2 * li + 1].data_mut() {
            *b = rng.f32();
        }
    }
    let betas_a = Tensor::full(&[arch.n_quant_act()], 4.0);
    let mut gates = GateSet::new(arch, granularity);
    for t in gates.gates_w.iter_mut().chain(gates.gates_a.iter_mut()) {
        for g in t.data_mut().iter_mut() {
            *g = gate_for_bits(w_bits);
        }
    }
    (params, betas_w, betas_a, gates)
}

/// The dense SWAR lowering — packed stream through `pack_dense_weights`
/// + `encode_scalar_rows` + `swar_gemm` + bias, exactly as the engine
/// dispatches it — must be bit-equal to `reference.rs` logits for every
/// width at reduction depths straddling the u64-lane flush cadence and
/// the quad-stripe remainder.
#[test]
fn swar_dense_path_is_bitwise_equal_to_the_reference_logits() {
    let mut rng = Rng(0xC0DE_5EED);
    for &w_bits in &[2u32, 4, 8] {
        for &d_in in &[1usize, 63, 64, 65, 129] {
            // d_out = 13: 16-bit lanes give nb=4 (pure quad-stripe with a
            // j < n tail guard); 32-bit lanes give nb=7 (quad + 3 single).
            let d_out = 13;
            let arch = one_layer_arch(
                LayerSpec {
                    name: "out",
                    kind: LayerKind::Dense,
                    w_shape: vec![d_in, d_out],
                    b_shape: vec![d_out],
                    act_shape: vec![d_out],
                    pool: 0,
                    quant_act: false,
                },
                vec![d_in],
                8,
            );
            let (params, betas_w, betas_a, gates) =
                uniform_state(&arch, Granularity::Layer, w_bits, 0x11 + d_in as u64);
            let model =
                PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
            let n = 5;
            let xs: Vec<f32> = (0..n * d_in).map(|_| rng.f32() * 2.2).collect();
            let want =
                fake_quant_logits(&arch, &params, &betas_w, &betas_a, &gates, &xs, n).unwrap();

            let grid = ActGrid { bits: 8, signed: true, beta: 1.0 };
            let beta_w = betas_w.data()[0];
            let (kernel, prm) =
                KernelSelector::default().select(w_bits, Some(w_bits), beta_w, Some(grid), d_in);
            let expect = match w_bits {
                2 => Kernel::Swar2,
                4 => Kernel::Swar4,
                _ => Kernel::Swar8,
            };
            assert_eq!(kernel, expect, "selector must pick the SWAR kernel for w={w_bits}");
            let prm = prm.unwrap();

            let h: Vec<f32> = xs.iter().map(|&v| quantize(v, 8, 1.0, true)).collect();
            let (mut words, mut wsums) = (Vec::new(), Vec::new());
            pack_dense_weights(&model.layers[0], d_in, d_out, &prm, &mut words, &mut wsums)
                .unwrap();
            let (mut codes, mut asums) = (Vec::new(), Vec::new());
            encode_scalar_rows(&h, n, d_in, &prm, &mut codes, &mut asums);
            let mut out = vec![f32::NAN; n * d_out];
            swar_gemm(
                &codes,
                &asums,
                &words,
                &wsums,
                &mut out,
                n,
                d_in,
                d_out,
                &prm,
                prm.a_off,
                prm.w_off,
                prm.combined_scale,
            );
            add_bias_cols(&mut out, &model.layers[0].bias, n, d_out);
            assert_bits_eq(&out, &want, &format!("swar dense w={w_bits} k={d_in}"));
        }
    }
}

/// The conv SWAR lowering — `pack_conv_weights` + per-sample im2col +
/// `pack_lane_cols` + `swar_gemm` + row bias — against the reference's
/// seven-loop integer oracle, at kdim values hitting every u64-lane
/// remainder class.
#[test]
fn swar_conv_path_is_bitwise_equal_to_the_reference_logits() {
    let mut rng = Rng(0xCAFE_F00D);
    // (ci, kh, kw) with kdim = 63, 64, 65.
    for &(ci, kh, kw) in &[(7usize, 3usize, 3usize), (1, 8, 8), (5, 13, 1)] {
        for &w_bits in &[2u32, 4, 8] {
            let (hi, wi, o) = (14, 9, 6);
            let (ho, wo) = (hi - kh + 1, wi - kw + 1);
            let kdim = ci * kh * kw;
            let p = ho * wo;
            let arch = one_layer_arch(
                LayerSpec {
                    name: "conv",
                    kind: LayerKind::Conv,
                    w_shape: vec![o, ci, kh, kw],
                    b_shape: vec![o],
                    act_shape: vec![o, ho, wo],
                    pool: 0,
                    quant_act: false,
                },
                vec![ci, hi, wi],
                8,
            );
            let (params, betas_w, betas_a, gates) =
                uniform_state(&arch, Granularity::Layer, w_bits, 0x31 + kdim as u64);
            let model =
                PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
            let n = 3;
            let xs: Vec<f32> = (0..n * ci * hi * wi).map(|_| rng.f32() * 2.2).collect();
            let want =
                fake_quant_logits(&arch, &params, &betas_w, &betas_a, &gates, &xs, n).unwrap();

            let grid = ActGrid { bits: 8, signed: true, beta: 1.0 };
            let prm = decide(Some(w_bits), betas_w.data()[0], Some(grid), kdim).unwrap();
            let h: Vec<f32> = xs.iter().map(|&v| quantize(v, 8, 1.0, true)).collect();
            let (mut wcodes, mut wsums) = (Vec::new(), Vec::new());
            pack_conv_weights(&model.layers[0], o, kdim, &prm, &mut wcodes, &mut wsums).unwrap();
            let mut out = vec![f32::NAN; n * o * p];
            let mut col = vec![0.0f32; kdim * p];
            let (mut lanes, mut lsums) = (Vec::new(), Vec::new());
            for s in 0..n {
                im2col(&h[s * ci * hi * wi..(s + 1) * ci * hi * wi], ci, hi, wi, kh, kw, &mut col);
                pack_lane_cols(&col, kdim, p, &prm, &mut lanes, &mut lsums);
                let planes = &mut out[s * o * p..(s + 1) * o * p];
                swar_gemm(
                    &wcodes,
                    &wsums,
                    &lanes,
                    &lsums,
                    planes,
                    o,
                    kdim,
                    p,
                    &prm,
                    prm.w_off,
                    prm.a_off,
                    prm.combined_scale,
                );
                add_bias_rows(planes, &model.layers[0].bias, o, p);
            }
            assert_bits_eq(&out, &want, &format!("swar conv w={w_bits} kdim={kdim}"));
        }
    }
}

/// Per-element (Individual) granularity with pruned weights sprinkled
/// in: the stream is still uniform in its nonzero widths, so the layer
/// stays SWAR-eligible and the pruned elements ride along as offset
/// (zero) codes — bit-equal to the reference, which zeroes their codes.
#[test]
fn swar_tolerates_pruned_elements_under_individual_granularity() {
    let mut rng = Rng(0x0DD5);
    for &w_bits in &[2u32, 4, 8] {
        let (d_in, d_out) = (65, 9);
        let arch = one_layer_arch(
            LayerSpec {
                name: "out",
                kind: LayerKind::Dense,
                w_shape: vec![d_in, d_out],
                b_shape: vec![d_out],
                act_shape: vec![d_out],
                pool: 0,
                quant_act: false,
            },
            vec![d_in],
            8,
        );
        let (params, betas_w, betas_a, mut gates) =
            uniform_state(&arch, Granularity::Individual, w_bits, 0x51);
        // Prune every fifth weight; the rest keep the uniform width.
        for (i, g) in gates.gates_w[0].data_mut().iter_mut().enumerate() {
            if i % 5 == 0 {
                *g = gate_for_bits(0);
            }
        }
        let model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
        let n = 4;
        let xs: Vec<f32> = (0..n * d_in).map(|_| rng.f32() * 2.2).collect();
        let want = fake_quant_logits(&arch, &params, &betas_w, &betas_a, &gates, &xs, n).unwrap();

        let grid = ActGrid { bits: 8, signed: true, beta: 1.0 };
        let prm = decide(Some(w_bits), betas_w.data()[0], Some(grid), d_in)
            .expect("pruned holes must not break SWAR eligibility");
        let h: Vec<f32> = xs.iter().map(|&v| quantize(v, 8, 1.0, true)).collect();
        let (mut words, mut wsums) = (Vec::new(), Vec::new());
        pack_dense_weights(&model.layers[0], d_in, d_out, &prm, &mut words, &mut wsums).unwrap();
        let (mut codes, mut asums) = (Vec::new(), Vec::new());
        encode_scalar_rows(&h, n, d_in, &prm, &mut codes, &mut asums);
        let mut out = vec![f32::NAN; n * d_out];
        swar_gemm(
            &codes,
            &asums,
            &words,
            &wsums,
            &mut out,
            n,
            d_in,
            d_out,
            &prm,
            prm.a_off,
            prm.w_off,
            prm.combined_scale,
        );
        add_bias_cols(&mut out, &model.layers[0].bias, n, d_out);
        assert_bits_eq(&out, &want, &format!("swar pruned-holes w={w_bits}"));
    }
}

/// Unsigned activation grids (what hidden layers feed after activation
/// quantization) across widths and lane-remainder depths: the packed
/// lanes must reproduce a naive i64 dot exactly, through the same
/// public packers the engine uses for the conv orientation.
#[test]
fn swar_gemm_matches_the_integer_oracle_on_every_grid() {
    let mut rng = Rng(0xFEED_FACE);
    for &(a_bits, signed) in &[(2u32, false), (4, false), (8, false), (8, true)] {
        for &w_bits in &[2u32, 4, 8] {
            for &k in &[1usize, 17, 63, 64, 65, 129] {
                let (m, n) = (3, 11);
                let grid = ActGrid { bits: a_bits, signed, beta: 3.7 };
                let prm = decide(Some(w_bits), 1.9, Some(grid), k).unwrap();
                let qw_hi = (1i64 << (w_bits - 1)) - 1;
                let qa_hi = if signed { (1i64 << (a_bits - 1)) - 1 } else { (1i64 << a_bits) - 1 };
                let qa_lo = if signed { -qa_hi } else { 0 };
                let qw: Vec<i64> = (0..m * k).map(|_| code_in(&mut rng, -qw_hi, qw_hi)).collect();
                let qa: Vec<i64> = (0..k * n).map(|_| code_in(&mut rng, qa_lo, qa_hi)).collect();

                // Scalar side: offset weight codes (the conv orientation).
                let mut scodes = vec![0u16; m * k];
                let mut ssums = vec![0i64; m];
                for r in 0..m {
                    for i in 0..k {
                        let u = qw[r * k + i] + prm.w_off;
                        scodes[r * k + i] = u as u16;
                        ssums[r] += u;
                    }
                }
                // Lane side: on-grid f32 activations through the packer.
                let col: Vec<f32> = qa.iter().map(|&q| prm.a_scale * q as f32).collect();
                let (mut lanes, mut lsums) = (Vec::new(), Vec::new());
                pack_lane_cols(&col, k, n, &prm, &mut lanes, &mut lsums);

                let mut out = vec![f32::NAN; m * n];
                swar_gemm(
                    &scodes,
                    &ssums,
                    &lanes,
                    &lsums,
                    &mut out,
                    m,
                    k,
                    n,
                    &prm,
                    prm.w_off,
                    prm.a_off,
                    prm.combined_scale,
                );
                let mut want = vec![0.0f32; m * n];
                for r in 0..m {
                    for j in 0..n {
                        let mut dot = 0i64;
                        for i in 0..k {
                            dot += qw[r * k + i] * qa[i * n + j];
                        }
                        want[r * n + j] = dot as f32 * prm.combined_scale;
                    }
                }
                assert_bits_eq(
                    &out,
                    &want,
                    &format!("swar oracle w={w_bits} a={a_bits}{} k={k}", if signed { "s" } else { "u" }),
                );
            }
        }
    }
}

/// The plan's declared eligibility bound is exactly the i32 accumulator
/// bound: `decide` accepts `k_max = floor(i32::MAX / (w_max * a_max))`
/// and rejects `k_max + 1`; a fully saturated GEMM inside the bound
/// (every accumulator at its ceiling) stays exact.
#[test]
fn swar_accumulators_never_overflow_inside_the_declared_bound() {
    for &(w_bits, a_bits, signed) in &[(2u32, 8u32, true), (4, 8, false), (8, 8, true)] {
        let grid = ActGrid { bits: a_bits, signed, beta: 1.0 };
        let w_max = (1i64 << w_bits) - 2;
        let a_max =
            if signed { 2 * ((1i64 << (a_bits - 1)) - 1) } else { (1i64 << a_bits) - 1 };
        let k_max = (i32::MAX as i64 / (w_max * a_max)) as usize;
        assert!(decide(Some(w_bits), 1.0, Some(grid), k_max).is_some(), "k_max must be eligible");
        assert!(decide(Some(w_bits), 1.0, Some(grid), k_max + 1).is_none(), "k_max+1 must not");
        let sel = KernelSelector::default();
        let (kernel, prm) = sel.select(w_bits, Some(w_bits), 1.0, Some(grid), k_max + 1);
        assert_eq!(kernel, Kernel::F32Gemm, "over-bound layers fall back to f32");
        assert!(prm.is_none());
    }
    // Worst-case magnitude run: 8-bit x 8-bit signed, k = 4096, every
    // code saturated, so each i32 accumulator reaches k * 254 * 254
    // (~264M) — inside i32, and the dot must still be exact.
    let grid = ActGrid { bits: 8, signed: true, beta: 1.0 };
    let k = 4096;
    let prm = decide(Some(8), 1.0, Some(grid), k).unwrap();
    let (m, n) = (1, 5);
    let mut scodes = vec![0u16; m * k];
    let mut ssums = vec![0i64; m];
    for i in 0..k {
        scodes[i] = (127 + prm.a_off) as u16;
        ssums[0] += 127 + prm.a_off;
    }
    // Lane side: on-grid values that all decode to the saturated code.
    let wcol: Vec<f32> = vec![prm.a_scale * 127.0; k * n];
    let (mut lanes, mut lsums) = (Vec::new(), Vec::new());
    pack_lane_cols(&wcol, k, n, &prm, &mut lanes, &mut lsums);
    let mut out = vec![f32::NAN; m * n];
    swar_gemm(
        &scodes,
        &ssums,
        &lanes,
        &lsums,
        &mut out,
        m,
        k,
        n,
        &prm,
        prm.a_off,
        prm.a_off,
        prm.combined_scale,
    );
    let want = (k as i64 * 127 * 127) as f32 * prm.combined_scale;
    for (j, &v) in out.iter().enumerate() {
        assert_eq!(v.to_bits(), want.to_bits(), "saturated dot {j}: {v} != {want}");
    }
}

/// Selection precedence: pruned beats everything (including the forced
/// f32 baseline switch), force_f32 beats SWAR, and ineligible shapes —
/// mixed widths, gridless inputs, identity widths — fall back to f32.
#[test]
fn kernel_selector_precedence_and_fallbacks() {
    let grid = Some(ActGrid { bits: 8, signed: true, beta: 1.0 });
    let sel = KernelSelector::default();
    let forced = KernelSelector { force_f32: true };
    assert_eq!(sel.select(0, None, 1.0, grid, 64).0, Kernel::Pruned);
    assert_eq!(forced.select(0, None, 1.0, grid, 64).0, Kernel::Pruned);
    assert_eq!(forced.select(4, Some(4), 1.0, grid, 64).0, Kernel::F32Gemm);
    assert_eq!(sel.select(2, Some(2), 1.0, grid, 64).0, Kernel::Swar2);
    assert_eq!(sel.select(4, Some(4), 1.0, grid, 64).0, Kernel::Swar4);
    assert_eq!(sel.select(8, Some(8), 1.0, grid, 64).0, Kernel::Swar8);
    assert_eq!(sel.select(8, None, 1.0, grid, 64).0, Kernel::F32Gemm, "mixed widths");
    assert_eq!(sel.select(4, Some(4), 1.0, None, 64).0, Kernel::F32Gemm, "gridless input");
    assert_eq!(sel.select(32, Some(32), 1.0, grid, 64).0, Kernel::F32Gemm, "identity width");
}
