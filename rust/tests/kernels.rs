//! Property tests of the shared kernel layer (`cgmq::deploy::kernels`)
//! against naive oracles, on seeded deterministic inputs.
//!
//! The contract under test is *stronger* than numerical closeness: the
//! blocked GEMM must equal the naive triple loop **bit-for-bit** on every
//! shape, because the engine ↔ reference cross-path goldens (and the HTTP
//! bit-identity check in `load-bench --verify-model`) ride on the kernels
//! producing exactly the seed implementation's float sums. That holds by
//! construction — one accumulator per output element, k swept ascending
//! and never split — and these tests pin it across awkward tile
//! remainders: dims of 1, the register tile edges (MR±1, NR±1), primes
//! past the cache block, and everything in between.

use cgmq::deploy::kernels::{
    add_bias_cols, add_bias_rows, conv2d, dense, gemm, gemm_naive, im2col, MR, NR,
};

/// Deterministic xorshift64* so the matrices are seeded, not random.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform-ish f32 in [-0.5, 0.5) — exercises cancellation without
    /// overflow, like normalized activations/weights.
    fn f32(&mut self) -> f32 {
        (self.next() >> 40) as f32 / 16_777_216.0 - 0.5
    }

    fn vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32()).collect()
    }
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: element {i}: got {g}, want {w}");
    }
}

// ------------------------------------------------------------- gemm

/// Awkward dims around every blocking boundary: 1, the MR=4 / NR=8
/// register tile edges, primes, and primes past the NC=256 cache block.
const DIMS: [usize; 8] = [1, 2, MR - 1, MR + 1, NR - 1, NR + 1, 13, 37];

#[test]
fn blocked_gemm_is_bitwise_equal_to_the_naive_oracle() {
    let mut rng = Rng(0x9E37_79B9_7F4A_7C15);
    for &m in &DIMS {
        for &k in &DIMS {
            for &n in &DIMS {
                let a = rng.vec(m * k);
                let b = rng.vec(k * n);
                let mut c = vec![f32::NAN; m * n]; // stale garbage must be overwritten
                let mut c_ref = vec![0.0f32; m * n];
                gemm(&a, &b, &mut c, m, k, n);
                gemm_naive(&a, &b, &mut c_ref, m, k, n);
                assert_bits_eq(&c, &c_ref, &format!("gemm {m}x{k}x{n}"));
            }
        }
    }
}

#[test]
fn blocked_gemm_crosses_the_cache_column_block() {
    // n = 257 and 263 straddle the NC = 256 column block; k = 131 is a
    // prime that leaves every register-tile remainder shape live at once.
    let mut rng = Rng(7);
    for (m, k, n) in [(5, 131, 257), (MR, 64, 263), (17, 3, 256)] {
        let a = rng.vec(m * k);
        let b = rng.vec(k * n);
        let mut c = vec![f32::NAN; m * n];
        let mut c_ref = vec![0.0f32; m * n];
        gemm(&a, &b, &mut c, m, k, n);
        gemm_naive(&a, &b, &mut c_ref, m, k, n);
        assert_bits_eq(&c, &c_ref, &format!("gemm {m}x{k}x{n}"));
    }
}

#[test]
fn gemm_zero_k_writes_zeros_over_stale_output() {
    // k = 0: an empty reduction must still overwrite the whole output.
    let mut c = vec![f32::NAN; 6];
    gemm(&[], &[], &mut c, 2, 0, 3);
    assert!(c.iter().all(|v| v.to_bits() == 0.0f32.to_bits()), "{c:?}");
}

#[test]
fn gemm_is_deterministic_across_repeated_calls() {
    let mut rng = Rng(42);
    let (m, k, n) = (NR + 1, 37, NC_PROBE);
    let a = rng.vec(m * k);
    let b = rng.vec(k * n);
    let mut first = vec![0.0f32; m * n];
    gemm(&a, &b, &mut first, m, k, n);
    for _ in 0..3 {
        let mut again = vec![f32::NAN; m * n];
        gemm(&a, &b, &mut again, m, k, n);
        assert_bits_eq(&again, &first, "repeated gemm");
    }
}

/// A column count that exercises one full cache block plus a remainder.
const NC_PROBE: usize = 300;

// ------------------------------------------------------------ dense

#[test]
fn dense_single_rows_equal_the_batched_result_bitwise() {
    // The accumulation order is batch-size-independent: running each
    // sample alone must reproduce the batched rows bit-for-bit. This is
    // what makes serve-path batching invisible to the HTTP bit-identity
    // check.
    let mut rng = Rng(0xDEAD_BEEF);
    let (n_samples, d_in, d_out) = (7, 29, NR + 3);
    let h = rng.vec(n_samples * d_in);
    let w = rng.vec(d_in * d_out);
    let bias = rng.vec(d_out);
    let batched = dense(&h, &w, &bias, n_samples, d_in, d_out);
    for s in 0..n_samples {
        let one = dense(&h[s * d_in..(s + 1) * d_in], &w, &bias, 1, d_in, d_out);
        assert_bits_eq(&one, &batched[s * d_out..(s + 1) * d_out], &format!("sample {s}"));
    }
}

#[test]
fn bias_epilogues_match_hand_expansion() {
    // 2x3: cols broadcast per output column, rows per output row.
    let mut c = vec![0.0f32; 6];
    add_bias_cols(&mut c, &[1.0, 2.0, 3.0], 2, 3);
    assert_eq!(c, [1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    let mut c = vec![0.0f32; 6];
    add_bias_rows(&mut c, &[1.0, 2.0], 2, 3);
    assert_eq!(c, [1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
}

// ------------------------------------------------------------- conv

/// Naive 6-loop valid conv oracle (NCHW / OIHW), accumulation ascending
/// (ic, ky, kx) — the seed engine's exact summation order.
#[allow(clippy::too_many_arguments)]
fn conv_oracle(
    h: &[f32],
    w: &[f32],
    bias: &[f32],
    n: usize,
    ci: usize,
    hi: usize,
    wi: usize,
    o: usize,
    kh: usize,
    kw: usize,
) -> Vec<f32> {
    let (ho, wo) = (hi - kh + 1, wi - kw + 1);
    let mut out = vec![0.0f32; n * o * ho * wo];
    for s in 0..n {
        for oc in 0..o {
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = 0.0f32;
                    for ic in 0..ci {
                        for ky in 0..kh {
                            for kx in 0..kw {
                                let iv = h[((s * ci + ic) * hi + oy + ky) * wi + ox + kx];
                                let wv = w[((oc * ci + ic) * kh + ky) * kw + kx];
                                acc += iv * wv;
                            }
                        }
                    }
                    out[((s * o + oc) * ho + oy) * wo + ox] = acc + bias[oc];
                }
            }
        }
    }
    out
}

#[test]
fn im2col_gemm_conv_is_bitwise_equal_to_the_six_loop_oracle() {
    let mut rng = Rng(0x5EED);
    // (ci, hi, wi, o, kh, kw): 1x1 kernels, full-image kernels, tall
    // kernels, multi-channel, multi-output — every im2col edge.
    let shapes = [
        (1, 1, 1, 1, 1, 1),
        (1, 5, 5, 3, 3, 3),
        (2, 4, 6, 5, 3, 2),
        (3, 7, 7, 4, 7, 7),
        (4, 6, 5, NR + 1, 2, 3),
        (5, 9, 8, 2, 1, 5),
    ];
    for (ci, hi, wi, o, kh, kw) in shapes {
        for n in [1, 3] {
            let h = rng.vec(n * ci * hi * wi);
            let w = rng.vec(o * ci * kh * kw);
            let bias = rng.vec(o);
            let got = conv2d(&h, &w, &bias, n, ci, hi, wi, o, kh, kw);
            let want = conv_oracle(&h, &w, &bias, n, ci, hi, wi, o, kh, kw);
            assert_bits_eq(&got, &want, &format!("conv {ci}x{hi}x{wi} o={o} k={kh}x{kw} n={n}"));
        }
    }
}

#[test]
fn im2col_fills_only_the_declared_prefix() {
    // A scratch buffer longer than ci·kh·kw × ho·wo keeps its tail.
    let img: Vec<f32> = (0..9).map(|v| v as f32).collect();
    let mut col = vec![f32::NAN; 4 * 4 + 5];
    im2col(&img, 1, 3, 3, 2, 2, &mut col);
    assert!(col[..16].iter().all(|v| !v.is_nan()));
    assert!(col[16..].iter().all(|v| v.is_nan()));
}
