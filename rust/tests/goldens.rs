//! Cross-language golden tests: the Rust quantizer, staircase, BOP model
//! and SynthMNIST renderer must agree with the Python compile path
//! (artifacts/goldens.json, emitted by `make artifacts`).

mod common;

use cgmq::quant;
use cgmq::util::json;

fn goldens() -> Option<json::Json> {
    let dir = common::artifacts_dir()?;
    Some(json::parse_file(&dir.join("goldens.json")).expect("parse goldens.json"))
}

#[test]
fn quantizer_matches_python_oracle() {
    let Some(g) = goldens() else { return };
    let q = g.get("quantizer").unwrap();
    let x = q.get("x").unwrap().as_f32_vec().unwrap();
    let beta = q.get("beta").unwrap().as_f64().unwrap() as f32;
    let cases = q.get("cases").unwrap();
    for bits in [2u32, 4, 8, 16, 32] {
        for (signed, tag) in [(true, 's'), (false, 'u')] {
            let expect = cases.get(&format!("q_b{bits}_{tag}")).unwrap().as_f32_vec().unwrap();
            for (i, (&xv, &ev)) in x.iter().zip(&expect).enumerate() {
                let got = quant::quantize(xv, bits, beta, signed);
                assert!(
                    (got - ev).abs() <= 1e-6,
                    "b{bits} {tag} x[{i}]={xv}: rust {got} vs python {ev}"
                );
            }
        }
    }
}

#[test]
fn staircase_matches_python_oracle() {
    let Some(g) = goldens() else { return };
    let q = g.get("quantizer").unwrap();
    let gates = q.get("g").unwrap().as_f32_vec().unwrap();
    let t = q.get("T").unwrap().as_f32_vec().unwrap();
    for (&gv, &tv) in gates.iter().zip(&t) {
        assert_eq!(quant::transform_t(gv) as f32, tv, "T({gv})");
    }
}

#[test]
fn gated_quantizer_matches_python_oracle() {
    let Some(g) = goldens() else { return };
    let q = g.get("quantizer").unwrap();
    let x = q.get("x").unwrap().as_f32_vec().unwrap();
    let gates = q.get("g").unwrap().as_f32_vec().unwrap();
    let beta = q.get("beta").unwrap().as_f64().unwrap() as f32;
    for (key, signed) in [("gated_signed", true), ("gated_unsigned", false)] {
        let expect = q.get(key).unwrap().as_f32_vec().unwrap();
        for i in 0..x.len() {
            let got = quant::gated_quantize(x[i], gates[i], beta, signed);
            assert!(
                (got - expect[i]).abs() <= 1e-6,
                "{key}[{i}]: x={} g={} rust {got} vs python {}",
                x[i],
                gates[i],
                expect[i]
            );
        }
    }
}

#[test]
fn synth_renderer_matches_python() {
    let Some(g) = goldens() else { return };
    let s = g.get("synth").unwrap();
    let seed = s.get("seed").unwrap().as_usize().unwrap() as u64;
    for sample in s.get("samples").unwrap().as_arr().unwrap() {
        let index = sample.get("index").unwrap().as_usize().unwrap() as u64;
        let label = sample.get("label").unwrap().as_usize().unwrap();
        let sum = sample.get("sum").unwrap().as_f64().unwrap();
        let pixels = sample.get("pixels").unwrap().as_f32_vec().unwrap();
        let (img, got_label) = cgmq::data::synth::render_digit(seed, index);
        assert_eq!(got_label, label, "sample {index} label");
        let got_sum: f64 = img.iter().map(|&v| v as f64).sum();
        assert!(
            (got_sum - sum).abs() < 1e-2,
            "sample {index}: pixel sum rust {got_sum} vs python {sum}"
        );
        for (i, &pv) in pixels.iter().enumerate() {
            assert!(
                (img[i] - pv).abs() < 1e-4,
                "sample {index} pixel {i}: rust {} vs python {pv}",
                img[i]
            );
        }
    }
}

#[test]
fn bop_model_matches_python() {
    let Some(g) = goldens() else { return };
    let b = g.get("bop").unwrap();
    for arch_name in ["lenet5", "mlp"] {
        let arch = cgmq::model::arch_by_name(arch_name).unwrap();
        let rec = b.get(arch_name).unwrap();
        assert_eq!(
            rec.get("fp32_bops").unwrap().as_usize().unwrap() as u64,
            cgmq::cost::fp32_bops(&arch),
            "{arch_name} fp32 bops"
        );
        assert_eq!(
            rec.get("floor_bops").unwrap().as_usize().unwrap() as u64,
            cgmq::cost::floor_bops(&arch),
            "{arch_name} floor bops"
        );
        let layers = rec.get("layers").unwrap().as_arr().unwrap();
        for (l, lr) in arch.layers.iter().zip(layers) {
            assert_eq!(
                lr.get("macs").unwrap().as_usize().unwrap() as u64,
                l.macs(),
                "{arch_name}.{} macs",
                l.name
            );
        }
    }
}
