//! Concurrent-serving tests: one shared `Engine` across threads must be
//! bit-identical to the single-threaded path (no loom needed — the only
//! shared mutable state is the `OnceLock` weight cache, and these tests
//! hammer it cold), and the sharded `WorkerPool` must complete every
//! submitted request exactly once, in submission order per shard.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cgmq::bench_harness::{synthetic_deploy_state, DEPLOY_LEVELS};
use cgmq::deploy::{BatchConfig, Engine, PackedModel, PoolConfig, WorkerPool};
use cgmq::model::{lenet5, mlp, ArchSpec};

fn packed(arch: &ArchSpec, seed: u64) -> PackedModel {
    let s = synthetic_deploy_state(arch, &DEPLOY_LEVELS, seed);
    PackedModel::from_state(arch, &s.params, &s.betas_w, &s.betas_a, &s.gates).unwrap()
}

// ---------------------------------------------------------------------------
// Shared-engine determinism
// ---------------------------------------------------------------------------

#[test]
fn shared_engine_is_bit_identical_across_threads() {
    for arch in [mlp(), lenet5()] {
        let n = if arch.name == "mlp" { 16 } else { 4 };
        let model = packed(&arch, 7);
        let in_len = arch.input_len();
        let data = cgmq::data::Dataset::synth(13, n);
        assert_eq!(data.sample_len, in_len);

        // Single-threaded reference on a private engine.
        let reference = Engine::new(model.clone()).unwrap().infer_batch(&data.images, n).unwrap();

        // One *cold* shared engine (no preload — the threads race to fill
        // the OnceLock weight cache), hit concurrently from 4 threads,
        // each mixing whole-set and per-sample calls.
        let shared = Arc::new(Engine::new(model).unwrap());
        std::thread::scope(|s| {
            for t in 0..4 {
                let shared = &shared;
                let reference = &reference;
                let images = &data.images;
                s.spawn(move || {
                    let all = shared.infer_batch(images, n).unwrap();
                    for (i, (&a, &b)) in all.iter().zip(reference).enumerate() {
                        assert_eq!(a.to_bits(), b.to_bits(), "thread {t} batched logit {i}");
                    }
                    let c = shared.num_classes();
                    for sample in (t % 4..n).step_by(4) {
                        let one =
                            shared.infer(&images[sample * in_len..(sample + 1) * in_len]).unwrap();
                        for (j, &v) in one.iter().enumerate() {
                            assert_eq!(
                                v.to_bits(),
                                reference[sample * c + j].to_bits(),
                                "thread {t} sample {sample} logit {j}"
                            );
                        }
                    }
                });
            }
        });
    }
}

// ---------------------------------------------------------------------------
// Worker pool
// ---------------------------------------------------------------------------

#[test]
fn pool_completes_every_request_exactly_once_in_shard_order() {
    let arch = mlp();
    let model = packed(&arch, 7);
    let in_len = arch.input_len();
    let workers = 3;
    let requests = 50;
    let data = cgmq::data::Dataset::synth(29, requests);
    let reference =
        Engine::new(model.clone()).unwrap().infer_batch(&data.images, requests).unwrap();
    let c = reference.len() / requests;

    let mut pool = WorkerPool::new(
        Arc::new(Engine::new(model).unwrap()),
        PoolConfig {
            workers,
            batch: BatchConfig { max_batch: 8, max_delay: Duration::from_millis(1) },
            queue_cap: 0,
        },
    )
    .unwrap();
    assert_eq!(pool.workers(), workers);
    let mut completions = Vec::new();
    for i in 0..requests {
        let id = pool.submit(data.images[i * in_len..(i + 1) * in_len].to_vec()).unwrap();
        assert_eq!(id, i as u64, "global ids are monotone from 0");
        completions.extend(pool.try_completions());
    }
    let (rest, shard_stats) = pool.shutdown().unwrap();
    completions.extend(rest);

    // Exactly once: every id appears once, with the round-robin shard.
    assert_eq!(completions.len(), requests);
    let mut seen = vec![false; requests];
    for comp in &completions {
        let id = comp.id as usize;
        assert!(!seen[id], "request {id} completed twice");
        seen[id] = true;
        assert_eq!(comp.shard, id % workers, "round-robin routing");
        // Pool logits are the single-threaded engine's bits.
        for (j, &v) in comp.logits.iter().enumerate() {
            assert_eq!(v.to_bits(), reference[id * c + j].to_bits(), "req {id} logit {j}");
        }
    }
    assert!(seen.iter().all(|&s| s), "every request completed");

    // Submission order per shard: within one shard, ids strictly increase.
    let mut last: Vec<Option<u64>> = vec![None; workers];
    for comp in &completions {
        if let Some(prev) = last[comp.shard] {
            assert!(prev < comp.id, "shard {} completed {} after {}", comp.shard, comp.id, prev);
        }
        last[comp.shard] = Some(comp.id);
    }

    // Per-shard stats: the flush-counter invariant holds, and the shards
    // together account for every request exactly once.
    assert_eq!(shard_stats.len(), workers);
    for (shard, s) in shard_stats.iter().enumerate() {
        assert!(s.consistent(), "shard {shard}: {s:?}");
    }
    assert_eq!(shard_stats.iter().map(|s| s.submitted).sum::<u64>(), requests as u64);
    assert_eq!(shard_stats.iter().map(|s| s.completed).sum::<u64>(), requests as u64);
}

#[test]
fn pool_deadline_flush_completes_without_shutdown() {
    // Fewer requests than max_batch: only the deadline (fired inside the
    // worker's channel sleep) can complete them — no drain involved.
    let arch = mlp();
    let model = packed(&arch, 7);
    let in_len = arch.input_len();
    let mut pool = WorkerPool::new(
        Arc::new(Engine::new(model).unwrap()),
        PoolConfig {
            workers: 2,
            batch: BatchConfig { max_batch: 1000, max_delay: Duration::from_millis(2) },
            queue_cap: 0,
        },
    )
    .unwrap();
    for i in 0..3 {
        pool.submit(vec![0.25 * (i as f32); in_len]).unwrap();
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut got = Vec::new();
    while got.len() < 3 && Instant::now() < deadline {
        got.extend(pool.try_completions());
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(got.len(), 3, "deadline flush must complete pending requests");
    let (rest, shard_stats) = pool.shutdown().unwrap();
    assert!(rest.is_empty());
    assert!(shard_stats.iter().map(|s| s.deadline_flushes).sum::<u64>() > 0);
    assert_eq!(shard_stats.iter().map(|s| s.drain_flushes).sum::<u64>(), 0);
}

#[test]
fn pool_validates_input_and_worker_count() {
    let arch = mlp();
    let model = packed(&arch, 7);
    let engine = Arc::new(Engine::new(model).unwrap());
    assert!(WorkerPool::new(
        Arc::clone(&engine),
        PoolConfig { workers: 0, batch: BatchConfig::default(), queue_cap: 0 }
    )
    .is_err());
    let mut pool = WorkerPool::new(
        engine,
        PoolConfig { workers: 1, batch: BatchConfig::default(), queue_cap: 0 },
    )
    .unwrap();
    assert!(pool.submit(vec![0.0; 3]).is_err(), "wrong-length input rejected at the front");
    let (rest, _) = pool.shutdown().unwrap();
    assert!(rest.is_empty());
}
