//! Deploy subsystem integration tests: bit-packer round-trips, the `.cgmqm`
//! format contract (checksum, version, arch drift), the cross-path golden
//! (packed engine vs host fake-quant eval logits, bit-for-bit), the request
//! batcher's flush triggers, and the export-report / file size cross-check.
//!
//! None of these need compiled artifacts — the whole deploy path is host
//! code — so they run in the default (stub-runtime) build.

use std::time::{Duration, Instant};

use cgmq::baselines::{export_report, load_packable_snapshot};
use cgmq::config::Config;
use cgmq::deploy::format::{sign_extend, BitReader, BitWriter, PackedAct, PackedLayer};
use cgmq::deploy::reference::fake_quant_logits;
use cgmq::deploy::{
    BatchConfig, BatcherStats, DecodeMode, Engine, Kernel, PackedModel, RequestBatcher, Scratch,
    WidthStream,
};
use cgmq::gates::{GateSet, Granularity};
use cgmq::model::{lenet5, mlp, ArchSpec, LayerKind};
use cgmq::quant::{gate_for_bits, gated_quantize_tensor};
use cgmq::session::Snapshot;
use cgmq::tensor::Tensor;
use cgmq::util::rng::SplitMix64;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("cgmq_deploy_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Deterministic mixed-precision state covering every T(g) level,
/// including pruned (0-bit) gates — which training never produces (the
/// gate floor is 2 bits) but the format must support. Intentionally
/// independent of `bench_harness::synthetic_deploy_state`: the golden
/// fixture must not share code with the library it pins.
fn mixed_state(
    arch: &ArchSpec,
    granularity: Granularity,
    seed: u64,
) -> (Vec<Tensor>, Tensor, Tensor, GateSet) {
    let params = arch.init_params(seed);
    let n_layers = arch.layers.len();
    let mut betas_w = Tensor::zeros(&[n_layers]);
    for li in 0..n_layers {
        betas_w.data_mut()[li] = params[2 * li].abs_max().max(1e-3);
    }
    let betas_a = Tensor::full(&[arch.n_quant_act()], 4.0);
    let mut gates = GateSet::new(arch, granularity);
    // 0 must appear (pruned weights); cycle the full level set.
    let levels = [2u32, 0, 4, 8, 16, 32, 8, 2];
    let mut k = seed as usize;
    for t in gates.gates_w.iter_mut().chain(gates.gates_a.iter_mut()) {
        for g in t.data_mut().iter_mut() {
            *g = gate_for_bits(levels[k % levels.len()]);
            k += 1;
        }
    }
    (params, betas_w, betas_a, gates)
}

// ---------------------------------------------------------------------------
// Pack -> unpack identity
// ---------------------------------------------------------------------------

#[test]
fn packed_weights_decode_to_fake_quantized_values_exactly() {
    for arch in [mlp(), lenet5()] {
        for gran in [Granularity::Layer, Granularity::Individual] {
            let (params, betas_w, betas_a, gates) = mixed_state(&arch, gran, 3);
            let model =
                PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
            for li in 0..arch.layers.len() {
                let decoded = model.decode_weights(li).unwrap();
                let expect = gated_quantize_tensor(
                    &params[2 * li],
                    &gates.materialize_w(&arch, li),
                    betas_w.data()[li],
                    true,
                );
                assert_eq!(decoded.len(), expect.len());
                for (i, (&d, &e)) in decoded.iter().zip(expect.data()).enumerate() {
                    assert_eq!(
                        d.to_bits(),
                        e.to_bits(),
                        "{} {:?} layer {li} weight {i}: {d} != {e}",
                        arch.name,
                        gran
                    );
                }
            }
        }
    }
}

#[test]
fn format_file_roundtrip_preserves_everything() {
    let arch = mlp();
    let (params, betas_w, betas_a, gates) = mixed_state(&arch, Granularity::Individual, 5);
    let model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
    let path = tmp("roundtrip.cgmqm");
    model.save(&path).unwrap();
    let (loaded, loaded_arch) = PackedModel::load(&path).unwrap();
    assert_eq!(loaded_arch.name, "mlp");
    assert_eq!(loaded.arch_name, model.arch_name);
    assert_eq!(loaded.granularity, model.granularity);
    assert_eq!(loaded.input_bits, model.input_bits);
    assert_eq!(loaded.layers.len(), model.layers.len());
    for (a, b) in loaded.layers.iter().zip(&model.layers) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.beta_w.to_bits(), b.beta_w.to_bits());
        assert_eq!(a.codes, b.codes);
        assert_eq!(a.code_bits, b.code_bits);
        assert_eq!(a.w_bits, b.w_bits);
        assert_eq!(a.bias, b.bias);
        assert_eq!(a.decode_weights().unwrap(), b.decode_weights().unwrap());
    }
}

#[test]
fn uniform_width_models_roundtrip_at_every_level() {
    // Whole-file round-trip at each uniform width, 2 through 32 bit.
    // (Ragged, non-byte-aligned code tails are pinned by the bit-level
    // property tests in deploy::format — random widths at odd lengths.)
    let arch = mlp();
    for bits in [2u32, 4, 8, 16, 32] {
        let params = arch.init_params(9);
        let n_layers = arch.layers.len();
        let mut betas_w = Tensor::zeros(&[n_layers]);
        for li in 0..n_layers {
            betas_w.data_mut()[li] = params[2 * li].abs_max().max(1e-3);
        }
        let betas_a = Tensor::full(&[arch.n_quant_act()], 4.0);
        let mut gates = GateSet::new(&arch, Granularity::Layer);
        for t in gates.gates_w.iter_mut().chain(gates.gates_a.iter_mut()) {
            t.data_mut()[0] = gate_for_bits(bits);
        }
        let model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
        let path = tmp(&format!("uniform{bits}.cgmqm"));
        model.save(&path).unwrap();
        let (loaded, _) = PackedModel::load(&path).unwrap();
        for li in 0..n_layers {
            let expect = gated_quantize_tensor(
                &params[2 * li],
                &gates.materialize_w(&arch, li),
                betas_w.data()[li],
                true,
            );
            let decoded = loaded.decode_weights(li).unwrap();
            for (&d, &e) in decoded.iter().zip(expect.data()) {
                assert_eq!(d.to_bits(), e.to_bits(), "bits={bits} layer={li}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Cross-path golden: packed engine == host fake-quant eval, bit-for-bit
// ---------------------------------------------------------------------------

fn golden_for(arch: ArchSpec, n: usize) {
    let mut rng = SplitMix64::new(17);
    let in_len = arch.input_len();
    let xs: Vec<f32> = (0..n * in_len).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    for gran in [Granularity::Layer, Granularity::Individual] {
        let (params, betas_w, betas_a, gates) = mixed_state(&arch, gran, 11);
        let reference =
            fake_quant_logits(&arch, &params, &betas_w, &betas_a, &gates, &xs, n).unwrap();
        let model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
        for mode in [DecodeMode::Streaming, DecodeMode::UnpackOnce] {
            let engine = Engine::new(model.clone()).unwrap().with_mode(mode);
            let logits = engine.infer_batch(&xs, n).unwrap();
            assert_eq!(logits.len(), reference.len());
            for (i, (&a, &b)) in logits.iter().zip(&reference).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{} {:?} {:?} logit {i}: {a} != {b}",
                    arch.name,
                    gran,
                    mode
                );
            }
            // Single-sample calls must agree with the batched call.
            let single = Engine::new(model.clone()).unwrap().with_mode(mode);
            for s in 0..n {
                let one = single.infer(&xs[s * in_len..(s + 1) * in_len]).unwrap();
                for (j, &v) in one.iter().enumerate() {
                    let b = reference[s * one.len() + j];
                    assert_eq!(v.to_bits(), b.to_bits(), "sample {s} logit {j}");
                }
            }
        }
    }
}

#[test]
fn cross_path_golden_mlp() {
    golden_for(mlp(), 4);
}

#[test]
fn cross_path_golden_lenet5() {
    golden_for(lenet5(), 2);
}

// ---------------------------------------------------------------------------
// Mode switches and the decoded-weight cache
// ---------------------------------------------------------------------------

#[test]
fn with_mode_resets_the_decoded_weight_cache() {
    let arch = mlp();
    let (params, betas_w, betas_a, gates) = mixed_state(&arch, Granularity::Layer, 9);
    let model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
    let n_layers = arch.layers.len();
    let mut rng = SplitMix64::new(23);
    let in_len = arch.input_len();
    let xs: Vec<f32> = (0..2 * in_len).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let want = fake_quant_logits(&arch, &params, &betas_w, &betas_a, &gates, &xs, 2).unwrap();

    let engine = Engine::new(model).unwrap();
    engine.preload().unwrap();
    assert_eq!(engine.decoded_layers(), n_layers);

    // A preloaded engine switched to Streaming must not keep the stale
    // decoded layers observable — and streaming inference must not
    // repopulate the cache.
    let streaming = engine.with_mode(DecodeMode::Streaming);
    assert_eq!(streaming.decoded_layers(), 0);
    let got = streaming.infer_batch(&xs, 2).unwrap();
    for (i, (&a, &b)) in got.iter().zip(&want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "streaming logit {i}");
    }
    assert_eq!(streaming.decoded_layers(), 0);

    // Switching back starts cold too (no resurrected fills), then warms
    // lazily through inference — bit-identical throughout.
    let back = streaming.with_mode(DecodeMode::UnpackOnce);
    assert_eq!(back.decoded_layers(), 0);
    let got = back.infer_batch(&xs, 2).unwrap();
    for (i, (&a, &b)) in got.iter().zip(&want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "unpack-once logit {i}");
    }
    assert_eq!(back.decoded_layers(), n_layers);
}

// ---------------------------------------------------------------------------
// Scratch reuse: the warm forward pass allocates nothing
// ---------------------------------------------------------------------------

#[test]
fn warm_infer_batch_into_reuses_every_buffer_in_place() {
    // lenet5 so the im2col buffer participates; both modes so the
    // streaming decode buffer does too. After the first full-size batch,
    // repeated calls (same n, then smaller n) must leave every scratch
    // buffer's base address and capacity — and the output buffer's —
    // untouched: the engine's warm path performs zero heap allocations.
    let arch = lenet5();
    let (params, betas_w, betas_a, gates) = mixed_state(&arch, Granularity::Individual, 5);
    let model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
    let in_len = arch.input_len();
    let n = 3;
    let mut rng = SplitMix64::new(31);
    let xs: Vec<f32> = (0..n * in_len).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    for mode in [DecodeMode::Streaming, DecodeMode::UnpackOnce] {
        let engine = Engine::new(model.clone()).unwrap().with_mode(mode);
        let want = engine.infer_batch(&xs, n).unwrap();
        let classes = engine.num_classes();

        let mut scratch = Scratch::new();
        let mut out = Vec::new();
        engine.infer_batch_into(&xs, n, &mut scratch, &mut out).unwrap();
        for (i, (&a, &b)) in out.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{mode:?} warmup logit {i}");
        }
        let caps = scratch.capacities();
        let ptrs = scratch.base_ptrs();
        let out_ptr = out.as_ptr() as usize;
        let out_cap = out.capacity();

        for round in 0..3 {
            engine.infer_batch_into(&xs, n, &mut scratch, &mut out).unwrap();
            for (i, (&a, &b)) in out.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{mode:?} round {round} logit {i}");
            }
            // A smaller batch rides the same buffers.
            engine.infer_batch_into(&xs[..in_len], 1, &mut scratch, &mut out).unwrap();
            assert_eq!(out.len(), classes);
            for (i, (&a, &b)) in out.iter().zip(&want[..classes]).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{mode:?} round {round} single logit {i}");
            }
            assert_eq!(scratch.capacities(), caps, "{mode:?} round {round}: scratch regrew");
            assert_eq!(scratch.base_ptrs(), ptrs, "{mode:?} round {round}: scratch reallocated");
            assert_eq!(out.capacity(), out_cap, "{mode:?} round {round}: output regrew");
            assert_eq!(out.as_ptr() as usize, out_ptr, "{mode:?} round {round}: output moved");
        }
    }
}

// ---------------------------------------------------------------------------
// Fail-fast loading
// ---------------------------------------------------------------------------

#[test]
fn corrupt_payload_fails_checksum() {
    let arch = mlp();
    let (params, betas_w, betas_a, gates) = mixed_state(&arch, Granularity::Layer, 2);
    let model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
    let path = tmp("corrupt.cgmqm");
    model.save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    let err = format!("{:#}", PackedModel::load(&path).unwrap_err());
    assert!(err.contains("checksum"), "{err}");
}

#[test]
fn version_mismatch_rejected() {
    let arch = mlp();
    let (params, betas_w, betas_a, gates) = mixed_state(&arch, Granularity::Layer, 2);
    let model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
    let path = tmp("version.cgmqm");
    model.save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[8..12].copy_from_slice(&99u32.to_le_bytes()); // version field
    std::fs::write(&path, &bytes).unwrap();
    let err = format!("{:#}", PackedModel::load(&path).unwrap_err());
    assert!(err.contains("version 99"), "{err}");
}

// ---------------------------------------------------------------------------
// SWAR kernel selection and the pruned-layer fast path
// ---------------------------------------------------------------------------

/// Uniform-width state at `bits` everywhere, Layer granularity —
/// deliberately re-derived here rather than shared with
/// `bench_harness::uniform_deploy_state`, same as `mixed_state`.
fn uniform_state(arch: &ArchSpec, bits: u32, seed: u64) -> (Vec<Tensor>, Tensor, Tensor, GateSet) {
    let params = arch.init_params(seed);
    let n_layers = arch.layers.len();
    let mut betas_w = Tensor::zeros(&[n_layers]);
    for li in 0..n_layers {
        betas_w.data_mut()[li] = params[2 * li].abs_max().max(1e-3);
    }
    let betas_a = Tensor::full(&[arch.n_quant_act()], 4.0);
    let mut gates = GateSet::new(arch, Granularity::Layer);
    for t in gates.gates_w.iter_mut().chain(gates.gates_a.iter_mut()) {
        t.data_mut()[0] = gate_for_bits(bits);
    }
    (params, betas_w, betas_a, gates)
}

/// Uniform 2/4/8-bit models must select the matching SWAR kernel on
/// every layer (16-bit must not), and the cross-path golden — engine
/// vs fake-quant reference, bit-for-bit — must hold on the SWAR paths
/// in both decode modes, on both archs (dense and conv lowerings).
#[test]
fn uniform_low_width_models_select_swar_and_stay_golden() {
    for arch in [mlp(), lenet5()] {
        let n = if arch.name == "mlp" { 4 } else { 2 };
        let mut rng = SplitMix64::new(41);
        let in_len = arch.input_len();
        let xs: Vec<f32> = (0..n * in_len).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        for bits in [2u32, 4, 8, 16] {
            let (params, betas_w, betas_a, gates) = uniform_state(&arch, bits, 13);
            let reference =
                fake_quant_logits(&arch, &params, &betas_w, &betas_a, &gates, &xs, n).unwrap();
            let model =
                PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
            let expect = match bits {
                2 => Kernel::Swar2,
                4 => Kernel::Swar4,
                8 => Kernel::Swar8,
                _ => Kernel::F32Gemm,
            };
            for mode in [DecodeMode::Streaming, DecodeMode::UnpackOnce] {
                let engine = Engine::new(model.clone()).unwrap().with_mode(mode);
                for op in &engine.plan().ops {
                    assert_eq!(
                        op.kernel, expect,
                        "{} bits={bits} layer {} kernel",
                        arch.name, op.layer
                    );
                    assert_eq!(op.swar.is_some(), expect != Kernel::F32Gemm);
                }
                let logits = engine.infer_batch(&xs, n).unwrap();
                assert_eq!(logits.len(), reference.len());
                for (i, (&a, &b)) in logits.iter().zip(&reference).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{} bits={bits} {:?} logit {i}: {a} != {b}",
                        arch.name,
                        mode
                    );
                }
            }
        }
    }
}

/// A fully pruned layer must select [`Kernel::Pruned`] — no decode, no
/// matmul, just zero-fill + bias — while downstream uniform layers keep
/// their SWAR kernels, and the whole pipeline stays bit-identical to
/// the reference (whose f32 path sums all-zero products into `+0.0`).
#[test]
fn pruned_layer_skips_its_matmul_and_stays_golden() {
    let arch = mlp();
    let (params, betas_w, betas_a, mut gates) = uniform_state(&arch, 8, 29);
    gates.gates_w[0].data_mut()[0] = gate_for_bits(0); // prune fc1 entirely
    let model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
    let n = 3;
    let mut rng = SplitMix64::new(43);
    let in_len = arch.input_len();
    let xs: Vec<f32> = (0..n * in_len).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let want = fake_quant_logits(&arch, &params, &betas_w, &betas_a, &gates, &xs, n).unwrap();
    for mode in [DecodeMode::Streaming, DecodeMode::UnpackOnce] {
        let engine = Engine::new(model.clone()).unwrap().with_mode(mode);
        assert_eq!(engine.plan().ops[0].kernel, Kernel::Pruned, "fc1 must skip its matmul");
        assert_eq!(engine.plan().ops[1].kernel, Kernel::Swar8, "fc2 keeps SWAR after a prune");
        assert_eq!(engine.plan().ops[2].kernel, Kernel::Swar8, "fc3 keeps SWAR after a prune");
        let got = engine.infer_batch(&xs, n).unwrap();
        for (i, (&a, &b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{mode:?} pruned-mlp logit {i}: {a} != {b}");
        }
        // The pruned op needs no weight material: preload must still
        // account every layer (the cache invariant; no-op in Streaming),
        // and inference must agree after it.
        engine.preload().unwrap();
        if mode == DecodeMode::UnpackOnce {
            assert_eq!(engine.decoded_layers(), arch.layers.len());
        }
        let got = engine.infer_batch(&xs, n).unwrap();
        for (i, (&a, &b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{mode:?} preloaded pruned logit {i}");
        }
    }
}

#[test]
fn arch_drift_fails_fast() {
    let arch = mlp();
    let (params, betas_w, betas_a, gates) = mixed_state(&arch, Granularity::Layer, 2);
    let mut model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();

    // Unknown arch name.
    model.arch_name = "resnet18".into();
    let path = tmp("drift_name.cgmqm");
    model.save(&path).unwrap(); // save recomputes the checksum
    let err = format!("{:#}", PackedModel::load(&path).unwrap_err());
    assert!(err.contains("unknown arch") || err.contains("resnet18"), "{err}");

    // Right name, drifted layer shape (same element count, so the byte
    // layout stays coherent and only the arch check can object).
    model.arch_name = "mlp".into();
    model.layers[0].w_shape = vec![128, 784];
    let path = tmp("drift_shape.cgmqm");
    model.save(&path).unwrap();
    let err = format!("{:#}", PackedModel::load(&path).unwrap_err());
    assert!(err.contains("w_shape"), "{err}");
}

#[test]
fn non_divisible_maxpool_geometry_rejected() {
    // lenet5 conv1 yields a 24x24 activation map; a pool window of 5 does
    // not divide it. The engine's `maxpool` floor-divides, so without the
    // verify() geometry walk this would *silently drop* the edge rows and
    // columns instead of erroring.
    let arch = lenet5();
    let (params, betas_w, betas_a, gates) = mixed_state(&arch, Granularity::Layer, 6);
    let mut model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
    assert!(model.verify().is_ok());
    model.layers[0].pool = 5;
    let err = format!("{:#}", model.verify().unwrap_err());
    assert!(
        err.contains("not divisible by max-pool window") && err.contains("24x24"),
        "{err}"
    );
    // The engine refuses to wrap it, and a saved file refuses to load.
    let err = format!("{:#}", Engine::new(model.clone()).unwrap_err());
    assert!(err.contains("max-pool window"), "{err}");
    let path = tmp("bad_pool.cgmqm");
    model.save(&path).unwrap(); // save recomputes the checksum
    assert!(PackedModel::load(&path).is_err());

    // Pooling a dense (non-spatial) output is geometry nonsense too.
    let mut model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
    model.layers[2].pool = 2; // fc1
    let err = format!("{:#}", model.verify().unwrap_err());
    assert!(err.contains("non-spatial"), "{err}");
}

#[test]
fn garbage_rejected() {
    let path = tmp("garbage.cgmqm");
    std::fs::write(&path, b"definitely not a packed model").unwrap();
    assert!(PackedModel::load(&path).is_err());
}

// ---------------------------------------------------------------------------
// Corruption matrix: every mutation is an Err, never a panic
// ---------------------------------------------------------------------------

/// A deliberately tiny hand-built model (one 4x3 dense layer, mixed
/// per-element widths including pruned and fp32) whose encoding is small
/// enough to corrupt *exhaustively*. `decode` does not resolve the arch,
/// so the record does not need to match a compiled-in spec.
fn tiny_packed_model() -> PackedModel {
    let w_bits = vec![2u32, 0, 4, 8, 16, 32, 2, 4, 8, 0, 16, 2];
    let mut bw = BitWriter::new();
    for (i, &b) in w_bits.iter().enumerate() {
        match b {
            0 => {}
            32 => bw.push((0.25f32 * i as f32).to_bits() as u64, 32),
            b => {
                let n_max = (1i64 << (b - 1)) - 1;
                let n = (i as i64 % (2 * n_max + 1)) - n_max;
                bw.push(n as u64 & ((1u64 << b) - 1), b);
            }
        }
    }
    let code_bits = bw.bit_len();
    let codes = bw.into_bytes();
    PackedModel {
        arch_name: "mlp".into(),
        granularity: Granularity::Individual,
        input_bits: 8,
        input_shape: vec![4],
        layers: vec![PackedLayer {
            name: "fc".into(),
            kind: LayerKind::Dense,
            w_shape: vec![4, 3],
            beta_w: 0.5,
            w_bits: WidthStream::PerElement(w_bits),
            codes,
            code_bits,
            bias: vec![0.0, 0.1, -0.1],
            pool: 0,
            act: Some(PackedAct {
                beta_a: 4.0,
                a_bits: WidthStream::PerElement(vec![2, 4, 8]),
            }),
        }],
    }
}

#[test]
fn corruption_matrix_every_byte_flip_and_truncation_errors() {
    // Exhaustive single-byte-flip / every-prefix-truncation matrix on the
    // tiny artifact: `decode` must return Err on every mutation and panic
    // on none. A single-byte flip always changes the FNV-1a checksum
    // (each absorption step is a bijection of the running state for a
    // fixed input byte), so no flip can slip through as valid.
    let model = tiny_packed_model();
    let bytes = model.encode().unwrap();
    assert!(PackedModel::decode(&bytes).is_ok(), "baseline must parse");

    for pos in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[pos] ^= 0xFF;
        assert!(
            PackedModel::decode(&bad).is_err(),
            "flipping byte {pos} of {} must be rejected",
            bytes.len()
        );
        // A milder flip (lowest bit) must be caught just the same.
        let mut bad = bytes.clone();
        bad[pos] ^= 0x01;
        assert!(PackedModel::decode(&bad).is_err(), "bit-flip at byte {pos}");
    }
    for len in 0..bytes.len() {
        assert!(
            PackedModel::decode(&bytes[..len]).is_err(),
            "truncation to {len} of {} bytes must be rejected",
            bytes.len()
        );
    }
    // Trailing junk after the payload is rejected too.
    let mut long = bytes.clone();
    long.push(0);
    assert!(PackedModel::decode(&long).is_err());
}

/// Mirror of the documented `.cgmqm` layout: the byte offset *after* each
/// section of `model`'s encoding (header fields, model preamble, every
/// per-layer field). The last offset must equal the file length — this
/// pins the layout described in `deploy::format`'s module docs.
fn section_boundaries(model: &PackedModel) -> Vec<usize> {
    fn width_stream_bytes(ws: &WidthStream) -> usize {
        match ws {
            WidthStream::Uniform(_) => 2,                                // flag + code
            WidthStream::PerElement(v) => 1 + 8 + (v.len() * 4).div_ceil(8), // flag + count + nibbles
        }
    }
    let mut offs = vec![8, 12, 20]; // magic | version | checksum
    let mut pos = 20;
    let section = |n: usize, offs: &mut Vec<usize>, pos: &mut usize| {
        *pos += n;
        offs.push(*pos);
    };
    section(2 + model.arch_name.len(), &mut offs, &mut pos); // arch_name
    section(1, &mut offs, &mut pos); // granularity
    section(4, &mut offs, &mut pos); // input_bits
    section(1 + 4 * model.input_shape.len(), &mut offs, &mut pos); // input_shape
    section(4, &mut offs, &mut pos); // n_layers
    for l in &model.layers {
        section(2 + l.name.len(), &mut offs, &mut pos); // name
        section(1, &mut offs, &mut pos); // kind
        section(1 + 4 * l.w_shape.len(), &mut offs, &mut pos); // w_shape
        section(4, &mut offs, &mut pos); // beta_w
        section(4 + 4 * l.bias.len(), &mut offs, &mut pos); // bias
        section(1, &mut offs, &mut pos); // pool
        section(width_stream_bytes(&l.w_bits), &mut offs, &mut pos); // weight widths
        section(8, &mut offs, &mut pos); // code_bits
        section(l.codes.len(), &mut offs, &mut pos); // codes
        section(1, &mut offs, &mut pos); // has_act
        if let Some(act) = &l.act {
            section(4, &mut offs, &mut pos); // beta_a
            section(width_stream_bytes(&act.a_bits), &mut offs, &mut pos); // act widths
        }
    }
    offs
}

#[test]
fn corruption_matrix_real_artifact_header_flips_and_boundary_truncations() {
    // The same matrix against a real exported artifact through the full
    // `load` path (file read + decode + arch verify): flip each header
    // byte, truncate at every section boundary.
    let arch = mlp();
    let (params, betas_w, betas_a, gates) = mixed_state(&arch, Granularity::Layer, 8);
    let model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
    let path = tmp("matrix.cgmqm");
    model.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert!(PackedModel::load(&path).is_ok(), "baseline must load");

    let boundaries = section_boundaries(&model);
    assert_eq!(
        *boundaries.last().unwrap(),
        bytes.len(),
        "layout walk must land exactly on the file end (format drifted from its docs?)"
    );

    let mutated = tmp("matrix_mut.cgmqm");
    for pos in 0..20 {
        let mut bad = bytes.clone();
        bad[pos] ^= 0xFF;
        std::fs::write(&mutated, &bad).unwrap();
        assert!(PackedModel::load(&mutated).is_err(), "header byte {pos} flip");
    }
    for &b in &boundaries {
        if b == bytes.len() {
            continue; // the full file is the valid baseline
        }
        std::fs::write(&mutated, &bytes[..b]).unwrap();
        assert!(PackedModel::load(&mutated).is_err(), "truncation at section boundary {b}");
        // One byte into the next section must fail too (unless that byte
        // is the last one, which would reconstruct the valid file).
        if b + 1 < bytes.len() {
            std::fs::write(&mutated, &bytes[..b + 1]).unwrap();
            assert!(PackedModel::load(&mutated).is_err(), "truncation at boundary {b} + 1");
        }
    }
}

// ---------------------------------------------------------------------------
// Bit-packer property: seeded-random round-trips per width
// ---------------------------------------------------------------------------

#[test]
fn bit_packer_roundtrips_random_codes_at_every_width_and_awkward_length() {
    // For each real integer width, pack seeded-random two's-complement
    // codes at lengths chosen to leave every possible partial tail byte,
    // and require bit-exact recovery plus exact storage accounting.
    let mut rng = SplitMix64::new(0xC0DE);
    for &bits in &[2u32, 4, 8, 16] {
        for &len in &[1usize, 2, 3, 5, 7, 9, 31, 63, 64, 65, 127, 255, 257] {
            let n_max = (1i64 << (bits - 1)) - 1;
            let mut codes: Vec<i64> = vec![n_max, -n_max]; // always hit the grid extremes
            codes.extend(
                (2..len.max(2)).map(|_| (rng.next_u64() % (2 * n_max as u64 + 1)) as i64 - n_max),
            );
            codes.truncate(len);
            let mut w = BitWriter::new();
            for &n in &codes {
                w.push(n as u64 & ((1u64 << bits) - 1), bits);
            }
            let total_bits = bits as u64 * len as u64;
            assert_eq!(w.bit_len(), total_bits, "bits={bits} len={len}");
            let bytes = w.into_bytes();
            assert_eq!(bytes.len() as u64, total_bits.div_ceil(8), "bits={bits} len={len}");
            let mut r = BitReader::new(&bytes);
            for (i, &n) in codes.iter().enumerate() {
                assert_eq!(
                    sign_extend(r.read(bits).unwrap(), bits),
                    n,
                    "bits={bits} len={len} i={i}"
                );
            }
            // The stream is exhausted at the tail: at most 7 spare bits
            // remain in the last byte, so a full-byte read must fail.
            assert!(r.read(8).is_err(), "bits={bits} len={len}");
        }
    }
}

// ---------------------------------------------------------------------------
// Export report <-> file size cross-check
// ---------------------------------------------------------------------------

#[test]
fn export_report_sizes_match_packed_file() {
    let arch = mlp();
    let (params, betas_w, betas_a, gates) = mixed_state(&arch, Granularity::Individual, 13);
    let snap = Snapshot {
        params,
        betas_w,
        betas_a,
        gates,
        test_acc: 0.9,
        rbop_percent: 1.0,
    };
    let ckpt = tmp("report.ckpt");
    snap.save(&ckpt, arch.name).unwrap();

    let cfg = Config { arch: "mlp".into(), ..Config::default() };
    let report = export_report(&cfg, &ckpt).unwrap();

    // The same packer writes the real artifact; sizes must agree exactly.
    let (model, _, _) = load_packable_snapshot(&cfg, &ckpt).unwrap();
    let path = tmp("report.cgmqm");
    model.save(&path).unwrap();
    let file_bytes = std::fs::metadata(&path).unwrap().len();
    assert_eq!(report.get("packed_file_bytes").unwrap().as_f64().unwrap(), file_bytes as f64);

    let payload = model.layer_payload_bytes();
    let layers = report.get("layers").unwrap().as_arr().unwrap();
    assert_eq!(layers.len(), payload.len());
    let mut total = 0.0;
    for (li, l) in layers.iter().enumerate() {
        let b = l.get("packed_weight_bytes").unwrap().as_f64().unwrap();
        assert_eq!(b, payload[li] as f64, "layer {li}");
        total += b;
        // The packed payload is the bit-exact ceil of the ideal memory
        // report (which counts fractional bytes).
        let ideal = l.get("weight_memory_bytes").unwrap().as_f64().unwrap();
        assert!(b >= ideal && b < ideal + 1.0, "layer {li}: packed {b} vs ideal {ideal}");
    }
    assert_eq!(
        report.get("packed_total_weight_bytes").unwrap().as_f64().unwrap(),
        total
    );
    // The file adds only headers/metadata on top of the weight payload.
    assert!(file_bytes as f64 >= total);
}

// ---------------------------------------------------------------------------
// Request batcher
// ---------------------------------------------------------------------------

fn small_engine() -> Engine {
    let arch = mlp();
    let (params, betas_w, betas_a, gates) = mixed_state(&arch, Granularity::Layer, 4);
    let model = PackedModel::from_state(&arch, &params, &betas_w, &betas_a, &gates).unwrap();
    Engine::new(model).unwrap()
}

#[test]
fn batcher_flushes_on_size() {
    let engine = small_engine();
    let in_len = engine.input_len();
    let cfg = BatchConfig { max_batch: 4, max_delay: Duration::from_secs(3600) };
    let mut b = RequestBatcher::new(engine, cfg).unwrap();
    let now = Instant::now();
    let x = vec![0.1f32; in_len];
    for i in 0..3 {
        assert!(b.submit_at(x.clone(), now).unwrap().is_empty(), "i={i}");
    }
    assert_eq!(b.pending(), 3);
    let done = b.submit_at(x.clone(), now).unwrap();
    assert_eq!(done.len(), 4);
    assert_eq!(b.pending(), 0);
    // FIFO ids, batch size recorded.
    assert_eq!(done.iter().map(|c| c.id).collect::<Vec<_>>(), [0, 1, 2, 3]);
    assert!(done.iter().all(|c| c.batch_size == 4));
    let stats = b.stats();
    assert_eq!(stats.flushes, 1);
    assert_eq!(stats.size_flushes, 1);
    assert_eq!(stats.deadline_flushes, 0);
    assert_eq!(stats.drain_flushes, 0);
    assert_eq!(stats.engine_calls, 1);
    assert_eq!(stats.completed, 4);
    assert!(stats.consistent(), "{stats:?}");
}

#[test]
fn batcher_flushes_on_deadline() {
    let engine = small_engine();
    let in_len = engine.input_len();
    let cfg = BatchConfig { max_batch: 1000, max_delay: Duration::from_millis(5) };
    let mut b = RequestBatcher::new(engine, cfg).unwrap();
    let t0 = Instant::now();
    let x = vec![0.1f32; in_len];
    assert!(b.submit_at(x.clone(), t0).unwrap().is_empty());
    assert!(b.submit_at(x.clone(), t0 + Duration::from_millis(1)).unwrap().is_empty());
    // Before the deadline: nothing.
    assert!(b.poll_at(t0 + Duration::from_millis(4)).unwrap().is_empty());
    assert_eq!(b.pending(), 2);
    // At/after the deadline of the *oldest* request: flush both.
    let done = b.poll_at(t0 + Duration::from_millis(5)).unwrap();
    assert_eq!(done.len(), 2);
    assert!(done[0].queue_delay >= Duration::from_millis(5));
    let stats = b.stats();
    assert_eq!(stats.deadline_flushes, 1);
    assert_eq!(stats.flushes, 1);
    assert!(stats.consistent(), "{stats:?}");
}

#[test]
fn batcher_stats_hold_flush_invariant_across_triggers() {
    // Exercise all three flush kinds and pin the invariant
    // `flushes == size_flushes + deadline_flushes + drain_flushes`,
    // with `engine_calls` counted separately (the drift the old counters
    // had: `flushes` bumped per engine call, triggers per event).
    let engine = small_engine();
    let in_len = engine.input_len();
    let cfg = BatchConfig { max_batch: 4, max_delay: Duration::from_millis(5) };
    let mut b = RequestBatcher::new(engine, cfg).unwrap();
    let t0 = Instant::now();
    let x = vec![0.1f32; in_len];

    // 8 submits -> two size flushes (at the 4th and 8th).
    let mut completed = 0;
    for i in 0..8 {
        completed += b.submit_at(x.clone(), t0).unwrap().len();
        assert!(b.pending() < 4, "i={i}");
    }
    assert_eq!(completed, 8);

    // 2 pending + an expired deadline -> one deadline flush.
    b.submit_at(x.clone(), t0).unwrap();
    b.submit_at(x.clone(), t0).unwrap();
    completed += b.poll_at(t0 + Duration::from_millis(5)).unwrap().len();
    assert_eq!(completed, 10);

    // 3 pending + an explicit drain -> one drain flush...
    for _ in 0..3 {
        b.submit_at(x.clone(), t0).unwrap();
    }
    completed += b.flush_at(t0).unwrap().len();
    assert_eq!(completed, 13);
    // ...and an empty drain is a no-op, not a flush event.
    assert!(b.flush_at(t0).unwrap().is_empty());

    let stats = b.stats();
    assert_eq!(stats.submitted, 13);
    assert_eq!(stats.completed, 13);
    assert_eq!(stats.size_flushes, 2);
    assert_eq!(stats.deadline_flushes, 1);
    assert_eq!(stats.drain_flushes, 1);
    assert_eq!(stats.flushes, 4, "one flush event per trigger");
    assert_eq!(stats.engine_calls, 4);
    assert!(stats.consistent(), "{stats:?}");
    assert!((stats.mean_batch() - 13.0 / 4.0).abs() < 1e-12);
}

#[test]
fn batcher_matches_direct_engine_and_validates_input() {
    let direct = small_engine();
    let in_len = direct.input_len();
    let data = cgmq::data::Dataset::synth(8, 6);
    assert_eq!(data.sample_len, in_len);
    let expect = direct.infer_batch(&data.images, 6).unwrap();
    let c = direct.num_classes();

    let cfg = BatchConfig { max_batch: 4, max_delay: Duration::from_secs(3600) };
    let mut b = RequestBatcher::new(small_engine(), cfg).unwrap();
    let now = Instant::now();
    let mut got: Vec<cgmq::deploy::Completion> = Vec::new();
    for i in 0..6 {
        got.extend(b.submit_at(data.images[i * in_len..(i + 1) * in_len].to_vec(), now).unwrap());
    }
    got.extend(b.flush_at(now).unwrap());
    assert_eq!(got.len(), 6);
    for comp in &got {
        let s = comp.id as usize;
        for (j, &v) in comp.logits.iter().enumerate() {
            assert_eq!(v.to_bits(), expect[s * c + j].to_bits(), "req {s} logit {j}");
        }
    }
    // Wrong-length input is rejected up front.
    assert!(b.submit_at(vec![0.0; in_len + 1], now).is_err());
}

#[test]
fn batcher_max_batch_one_degenerates_to_immediate_serving() {
    // max_batch == 1: every submit is its own size flush — the batcher
    // degenerates to direct per-request inference, never queueing.
    let engine = small_engine();
    let in_len = engine.input_len();
    let cfg = BatchConfig { max_batch: 1, max_delay: Duration::from_secs(3600) };
    let mut b = RequestBatcher::new(engine, cfg).unwrap();
    let now = Instant::now();
    for i in 0..5u64 {
        let done = b.submit_at(vec![0.1; in_len], now).unwrap();
        assert_eq!(done.len(), 1, "submit {i} must flush immediately");
        assert_eq!(done[0].id, i);
        assert_eq!(done[0].batch_size, 1);
        assert_eq!(b.pending(), 0);
    }
    let stats = b.stats();
    assert_eq!(stats.size_flushes, 5);
    assert_eq!(stats.flushes, 5);
    assert_eq!(stats.engine_calls, 5);
    assert_eq!((stats.submitted, stats.completed), (5, 5));
    assert!(stats.consistent(), "{stats:?}");
}

#[test]
fn batcher_zero_max_delay_flushes_on_every_poll() {
    // max_delay == 0: any pending request is instantly past its deadline,
    // so a poll at the very same instant already flushes.
    let engine = small_engine();
    let in_len = engine.input_len();
    let cfg = BatchConfig { max_batch: 1000, max_delay: Duration::ZERO };
    let mut b = RequestBatcher::new(engine, cfg).unwrap();
    let now = Instant::now();
    assert!(b.submit_at(vec![0.1; in_len], now).unwrap().is_empty());
    assert!(b.submit_at(vec![0.2; in_len], now).unwrap().is_empty());
    let done = b.poll_at(now).unwrap(); // zero elapsed time
    assert_eq!(done.len(), 2);
    assert!(done.iter().all(|c| c.queue_delay == Duration::ZERO));
    let stats = b.stats();
    assert_eq!(stats.deadline_flushes, 1);
    assert_eq!(stats.flushes, 1);
    assert!(stats.consistent(), "{stats:?}");
}

#[test]
fn batcher_empty_queue_poll_and_flush_are_noops() {
    let engine = small_engine();
    let cfg = BatchConfig { max_batch: 4, max_delay: Duration::ZERO };
    let mut b = RequestBatcher::new(engine, cfg).unwrap();
    let now = Instant::now();
    assert!(b.oldest_enqueued().is_none());
    assert!(b.poll_at(now).unwrap().is_empty());
    assert!(b.flush_at(now).unwrap().is_empty());
    assert!(b.poll_at(now + Duration::from_secs(1)).unwrap().is_empty());
    let stats = b.stats();
    // No flush event of any kind was counted.
    assert_eq!(stats.flushes, 0);
    assert_eq!(
        (stats.size_flushes, stats.deadline_flushes, stats.drain_flushes, stats.engine_calls),
        (0, 0, 0, 0)
    );
    assert_eq!((stats.submitted, stats.completed), (0, 0));
    assert!(stats.consistent(), "{stats:?}");
}

#[test]
fn batcher_stats_merge_preserves_consistency() {
    // Two batchers driven through different flush kinds, merged: the
    // counter invariant is linear, so consistent inputs merge into a
    // consistent total with component-wise sums.
    let in_len = small_engine().input_len();
    let now = Instant::now();

    let cfg = BatchConfig { max_batch: 2, max_delay: Duration::from_secs(3600) };
    let mut a = RequestBatcher::new(small_engine(), cfg).unwrap();
    for _ in 0..4 {
        a.submit_at(vec![0.1; in_len], now).unwrap(); // two size flushes
    }
    a.submit_at(vec![0.1; in_len], now).unwrap();
    a.flush_at(now).unwrap(); // one drain flush
    let sa = a.stats();
    assert!(sa.consistent(), "{sa:?}");

    let cfg = BatchConfig { max_batch: 1000, max_delay: Duration::ZERO };
    let mut b = RequestBatcher::new(small_engine(), cfg).unwrap();
    b.submit_at(vec![0.2; in_len], now).unwrap();
    b.poll_at(now).unwrap(); // one deadline flush
    let sb = b.stats();
    assert!(sb.consistent(), "{sb:?}");

    let mut merged = sa;
    merged.merge(&sb);
    assert!(merged.consistent(), "{merged:?}");
    assert_eq!(merged.submitted, sa.submitted + sb.submitted);
    assert_eq!(merged.completed, sa.completed + sb.completed);
    assert_eq!(merged.flushes, sa.flushes + sb.flushes);
    assert_eq!(merged.size_flushes, 2);
    assert_eq!(merged.drain_flushes, 1);
    assert_eq!(merged.deadline_flushes, 1);
    assert_eq!(merged.engine_calls, sa.engine_calls + sb.engine_calls);

    // merge_all over shards equals repeated merge, and merging the
    // default (all-zero) stats is the identity.
    let all = BatcherStats::merge_all([&sa, &sb, &BatcherStats::default()]);
    assert_eq!(format!("{all:?}"), format!("{merged:?}"));
    assert!(all.consistent());
}
