//! Shared helpers for the integration tests.
//!
//! All integration tests need the AOT artifacts (`make artifacts`); when
//! they are absent (plain `cargo test` on a fresh checkout) the tests skip
//! with a notice instead of failing — the Makefile's `test` target always
//! builds artifacts first.

use std::path::PathBuf;

pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = cgmq::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        None
    }
}

/// A fast CI-scale config on the MLP arch.
pub fn quick_cfg() -> cgmq::config::Config {
    cgmq::config::Config {
        arch: "mlp".into(),
        train_size: 768,
        test_size: 256,
        pretrain_epochs: 2,
        range_epochs: 1,
        cgmq_epochs: 4,
        out_dir: std::env::temp_dir().join("cgmq_itest").to_string_lossy().into_owned(),
        ..cgmq::config::Config::default()
    }
}
