//! Shared helpers for the integration tests.
//!
//! All integration tests need the AOT artifacts (`make artifacts`); when
//! they are absent (plain `cargo test` on a fresh checkout) the tests skip
//! with a notice instead of failing — the Makefile's `test` target always
//! builds artifacts first.

use std::path::PathBuf;

pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = cgmq::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        None
    }
}

/// A fast CI-scale config on the MLP arch.
pub fn quick_cfg() -> cgmq::config::Config {
    let mut cfg = cgmq::config::Config::default();
    cfg.arch = "mlp".into();
    cfg.train_size = 768;
    cfg.test_size = 256;
    cfg.pretrain_epochs = 2;
    cfg.range_epochs = 1;
    cfg.cgmq_epochs = 4;
    cfg.out_dir = std::env::temp_dir().join("cgmq_itest").to_string_lossy().into_owned();
    cfg
}
