//! Integration tests for the staged `session` API: builder validation,
//! stage-sequence composition (including the skip-pretrain resume
//! pipeline), and observer event ordering over a real training run.
//!
//! Builder-validation tests run everywhere; tests that train skip when the
//! AOT artifacts are absent (same convention as the other integration
//! tests).

mod common;

use std::cell::RefCell;
use std::rc::Rc;

use cgmq::metrics::EpochRecord;
use cgmq::session::stage::{Stage, StageReport};
use cgmq::session::{
    Calibrate, CgmqLoop, ConstraintEvent, JsonlMetricsObserver, LoadCheckpoint, Observer,
    Pretrain, RangeLearn, SessionBuilder, SnapshotEvent, TrainCtx,
};

// ---------------------------------------------------------------------------
// Builder validation (no artifacts needed)
// ---------------------------------------------------------------------------

#[test]
fn build_rejects_unknown_arch() {
    let mut cfg = common::quick_cfg();
    cfg.arch = "resnet18".into();
    let err = SessionBuilder::new(cfg).paper_pipeline().build().unwrap_err().to_string();
    assert!(err.contains("unknown architecture 'resnet18'"), "{err}");
}

#[test]
fn build_rejects_missing_artifacts_dir() {
    let mut cfg = common::quick_cfg();
    cfg.artifacts_dir = "/nonexistent/cgmq/artifacts".into();
    let err = format!("{:#}", SessionBuilder::new(cfg).paper_pipeline().build().unwrap_err());
    assert!(err.contains("manifest.json"), "{err}");
}

#[test]
fn build_rejects_invalid_config_values() {
    let mut cfg = common::quick_cfg();
    cfg.bound_rbop_percent = 0.0;
    assert!(SessionBuilder::new(cfg).build().is_err());
    let mut cfg = common::quick_cfg();
    cfg.lr_gates = -1.0;
    assert!(SessionBuilder::new(cfg).build().is_err());
}

// ---------------------------------------------------------------------------
// Event-recording observer used by the ordering tests
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Journal {
    events: Rc<RefCell<Vec<String>>>,
}

impl Journal {
    fn handle(&self) -> Rc<RefCell<Vec<String>>> {
        self.events.clone()
    }
}

impl Observer for Journal {
    fn on_stage_start(&mut self, stage: &str) {
        self.events.borrow_mut().push(format!("start:{stage}"));
    }
    fn on_stage_end(&mut self, report: &StageReport) {
        self.events.borrow_mut().push(format!("end:{}", report.stage));
    }
    fn on_epoch_end(&mut self, r: &EpochRecord) {
        self.events.borrow_mut().push(format!("epoch:{}:{}", r.phase, r.epoch));
    }
    fn on_constraint_check(&mut self, ev: &ConstraintEvent) {
        self.events.borrow_mut().push(format!("check:{}:{}", ev.phase, ev.epoch));
    }
    fn on_snapshot(&mut self, ev: &SnapshotEvent<'_>) {
        self.events.borrow_mut().push(format!("snapshot:{}", ev.epoch));
    }
}

// ---------------------------------------------------------------------------
// Composition + observers over real training (artifact-gated)
// ---------------------------------------------------------------------------

#[test]
fn observer_sees_epochs_in_order() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = common::quick_cfg();
    cfg.pretrain_epochs = 2;
    cfg.cgmq_epochs = 2;
    let journal = Journal::default();
    let events = journal.handle();
    let mut session = SessionBuilder::new(cfg)
        .stage(Pretrain::default())
        .stage(Calibrate)
        .stage(CgmqLoop::default())
        .observer(journal)
        .build()
        .unwrap();
    session.run().unwrap();
    let seen = events.borrow();
    // Stage brackets in pipeline order.
    let brackets: Vec<&String> =
        seen.iter().filter(|e| e.starts_with("start:") || e.starts_with("end:")).collect();
    assert_eq!(
        brackets,
        ["start:pretrain", "end:pretrain", "start:calibrate", "end:calibrate", "start:cgmq",
         "end:cgmq"]
    );
    // Epoch events arrive in order within each phase.
    let pretrain: Vec<&String> = seen.iter().filter(|e| e.starts_with("epoch:pretrain")).collect();
    assert_eq!(pretrain, ["epoch:pretrain:0", "epoch:pretrain:1"]);
    let cgmq: Vec<&String> = seen.iter().filter(|e| e.starts_with("epoch:cgmq")).collect();
    assert_eq!(cgmq, ["epoch:cgmq:0", "epoch:cgmq:1"]);
    // Every CGMQ epoch performs exactly one end-of-epoch constraint check,
    // delivered before that epoch's record.
    let cgmq_related: Vec<&String> = seen
        .iter()
        .filter(|e| e.starts_with("check:cgmq") || e.starts_with("epoch:cgmq"))
        .collect();
    assert_eq!(cgmq_related, ["check:cgmq:0", "epoch:cgmq:0", "check:cgmq:1", "epoch:cgmq:1"]);
}

#[test]
fn custom_sequence_skips_pretrain_from_checkpoint() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = common::quick_cfg();
    cfg.bound_rbop_percent = 5.0;
    cfg.cgmq_epochs = 4;
    cfg.lr_gates = 0.05;

    // First session: pretrain only, save the float checkpoint.
    let ckpt = std::env::temp_dir().join("cgmq_itest_session_resume.ckpt");
    let mut pre = SessionBuilder::new(cfg.clone()).stage(Pretrain::epochs(2)).build().unwrap();
    pre.run().unwrap();
    pre.ctx.save_params(&ckpt).unwrap();
    let float_acc = pre.ctx.float_acc.unwrap();

    // Second session: a custom stage sequence that skips pretraining.
    let mut session = SessionBuilder::new(cfg)
        .stage(LoadCheckpoint::new(&ckpt))
        .stage(Calibrate)
        .stage(RangeLearn::epochs(1))
        .stage(CgmqLoop::default())
        .build()
        .unwrap();
    session.run().unwrap();
    // No pretrain epochs were trained in the resumed session...
    assert!(session.metrics().records.iter().all(|r| r.phase != "pretrain"));
    // ...but the float accuracy carried over through the checkpoint.
    assert!((session.ctx.float_acc.unwrap() - float_acc).abs() < 1e-9);
    // The composed pipeline still delivers the guarantee at a loose bound.
    let r = session.result().unwrap();
    assert!(r.satisfied, "resumed pipeline violated the bound: {}", r.rbop_percent);
    let stages: Vec<&str> = session.reports().iter().map(|s| s.stage.as_str()).collect();
    assert_eq!(stages, ["load-checkpoint", "calibrate", "ranges", "cgmq"]);
}

#[test]
fn ad_hoc_stage_extends_a_finished_session() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = common::quick_cfg();
    cfg.bound_rbop_percent = 5.0;
    cfg.cgmq_epochs = 1;
    cfg.lr_gates = 0.05;
    let mut session = SessionBuilder::new(cfg).paper_pipeline().build().unwrap();
    session.run().unwrap();
    let before = session.ctx.rbop_trace.len();
    // Extend with two more CGMQ epochs through the public API.
    session.run_stage(CgmqLoop::epochs(2)).unwrap();
    assert_eq!(session.ctx.rbop_trace.len(), before + 2);
    assert_eq!(session.reports().last().unwrap().stage, "cgmq");
}

#[test]
fn jsonl_observer_streams_a_training_run() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = common::quick_cfg();
    cfg.pretrain_epochs = 1;
    cfg.cgmq_epochs = 1;
    let path = std::env::temp_dir().join("cgmq_itest_session.jsonl");
    let mut session = SessionBuilder::new(cfg)
        .paper_pipeline()
        .observer(JsonlMetricsObserver::create(&path).unwrap())
        .build()
        .unwrap();
    session.run().unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let mut epochs = 0;
    for line in text.lines() {
        let j = cgmq::util::json::parse(line).unwrap(); // every line is valid JSON
        let event = j.get("event").unwrap().as_str().unwrap().to_string();
        if event == "epoch" {
            epochs += 1;
        }
    }
    // pretrain 1 + ranges (quick_cfg: 1) + cgmq 1
    assert_eq!(epochs, session.metrics().records.len());
    assert!(text.contains("\"event\":\"stage_start\""), "stage events present");
    assert!(text.contains("\"event\":\"constraint_check\""), "constraint events present");
}

// ---------------------------------------------------------------------------
// Custom user-defined stage through the public trait
// ---------------------------------------------------------------------------

/// A user stage: deterministic gate nudge, no training. Verifies the Stage
/// trait is implementable outside the crate and composes with built-ins.
struct NudgeGates;

impl Stage for NudgeGates {
    fn name(&self) -> &str {
        "nudge-gates"
    }

    fn run(&mut self, ctx: &mut TrainCtx) -> anyhow::Result<StageReport> {
        for g in ctx.gates.gates_w.iter_mut().chain(ctx.gates.gates_a.iter_mut()) {
            g.map_inplace(|v| v - 0.1);
        }
        ctx.gates.clamp();
        let mut report = StageReport::new("nudge-gates");
        report.rbop_percent = Some(ctx.current_rbop()?);
        Ok(report)
    }
}

#[test]
fn external_stage_composes_with_builtins() {
    let Some(_) = common::artifacts_dir() else { return };
    let cfg = common::quick_cfg();
    let mut session = SessionBuilder::new(cfg).stage(NudgeGates).build().unwrap();
    let reports = session.run().unwrap();
    assert_eq!(reports.len(), 1);
    assert_eq!(reports[0].stage, "nudge-gates");
    let rbop = reports[0].rbop_percent.unwrap();
    assert!(rbop < 100.0, "nudged gates must cost less than fp32: {rbop}");
}
