//! Network serving front tests: the HTTP path is held **bit-identical** to
//! direct `Engine::infer_batch` output, the parser's negative matrix maps
//! to the documented status taxonomy without ever taking a connection
//! worker (or the server) down, a saturating burst sheds with 429 while
//! the `submitted == accepted + shed` accounting holds across the network
//! layer, a graceful shutdown drains every accepted request, and the
//! three telemetry surfaces (`/metrics`, `/stats`, the final
//! `ServerReport`) expose one bit-exact truth.
//!
//! The windowed signal plane gets the same deterministic treatment via an
//! injected `ManualClock` (`Server::bind_with_clock`): after traffic,
//! advancing the clock past the trailing window must decay **every**
//! windowed series to exactly zero while the cumulative counters keep the
//! history; an idle model carries the full zeros-included shape on
//! `/stats`, symmetric with `/metrics`; `GET /livez` flips 200 → 503 when
//! the windowed shed-rate or p99 threshold trips; and the `cgmq watch`
//! frame is pinned byte-exactly, including the `—` sentinel the
//! empty-histogram contract mandates for quantiles with zero samples.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use cgmq::bench_harness::{synthetic_deploy_state, DEPLOY_LEVELS};
use cgmq::deploy::net::{HttpClient, Server, ServerConfig};
use cgmq::deploy::{BatchConfig, Engine, PackedModel, PoolConfig};
use cgmq::model::{mlp, ArchSpec};
use cgmq::util::json::{self, Json};

fn engine(arch: &ArchSpec, seed: u64) -> Arc<Engine> {
    let s = synthetic_deploy_state(arch, &DEPLOY_LEVELS, seed);
    let model = PackedModel::from_state(arch, &s.params, &s.betas_w, &s.betas_a, &s.gates).unwrap();
    Arc::new(Engine::new(model).unwrap())
}

fn server_cfg(workers: usize, queue_cap: usize, max_batch: usize, delay: Duration) -> ServerConfig {
    ServerConfig {
        pool: PoolConfig {
            workers,
            batch: BatchConfig { max_batch, max_delay: delay },
            queue_cap,
        },
        // Bound how long a dangling keep-alive connection can delay join.
        read_timeout: Duration::from_secs(2),
        ..ServerConfig::default()
    }
}

fn infer_body(x: &[f32]) -> String {
    Json::obj(vec![("x", Json::arr_f32(x))]).to_string()
}

/// Assert an HTTP 200 infer response carries exactly `expect_row`'s bits.
fn assert_bit_identical(body: &str, expect_row: &[f32], ctx: &str) {
    let parsed = json::parse(body).unwrap();
    let logits = parsed.get("logits").unwrap().as_f32_vec().unwrap();
    assert_eq!(logits.len(), expect_row.len(), "{ctx}: logit count");
    for (j, (a, b)) in logits.iter().zip(expect_row).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: logit {j} drifted over HTTP");
    }
}

#[test]
fn http_path_is_bit_identical_to_direct_engine() {
    let arch = mlp();
    let in_len = arch.input_len();
    let requests = 24;
    let data = cgmq::data::Dataset::synth(11, requests);
    let eng = engine(&arch, 7);
    let expect = eng.infer_batch(&data.images, requests).unwrap();
    let c = expect.len() / requests;

    let server = Server::bind(
        "127.0.0.1:0",
        vec![("m".to_string(), Arc::clone(&eng))],
        server_cfg(2, 0, 4, Duration::from_millis(1)),
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let mut client = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();

    let (status, body) = client.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"ok\"") && body.contains("\"m\""), "{body}");

    for i in 0..requests {
        let body = infer_body(&data.images[i * in_len..(i + 1) * in_len]);
        let (status, text) = client.request("POST", "/v1/models/m/infer", Some(&body)).unwrap();
        assert_eq!(status, 200, "request {i}: {text}");
        let parsed = json::parse(&text).unwrap();
        assert_eq!(parsed.get("id").unwrap().as_usize().unwrap(), i, "sequential ids");
        assert_bit_identical(&text, &expect[i * c..(i + 1) * c], &format!("request {i}"));
        // predicted is the argmax the engine computed, not a re-derivation.
        let predicted = parsed.get("predicted").unwrap().as_usize().unwrap();
        assert!(predicted < c);
    }

    // Routing errors are clean statuses and do not count as submissions.
    let x = data.images[..in_len].to_vec();
    let (status, text) =
        client.request("POST", "/v1/models/nope/infer", Some(&infer_body(&x))).unwrap();
    assert_eq!(status, 404, "{text}");
    assert!(text.contains('m'), "404 should list the loaded keys: {text}");
    let (status, text) =
        client.request("POST", "/v1/models/m/infer", Some(&infer_body(&x[..3]))).unwrap();
    assert_eq!(status, 400, "wrong input length: {text}");

    let (status, text) = client.request("GET", "/stats", None).unwrap();
    assert_eq!(status, 200, "{text}");
    let stats = json::parse(&text).unwrap();
    assert_eq!(stats.get("served").unwrap().as_usize().unwrap(), requests);
    let m = stats.get("models").unwrap().get("m").unwrap().clone();
    assert_eq!(m.get("submitted").unwrap().as_usize().unwrap(), requests);
    assert_eq!(m.get("accepted").unwrap().as_usize().unwrap(), requests);
    assert_eq!(m.get("shed").unwrap().as_usize().unwrap(), 0);

    drop(client);
    let report = server.finish().unwrap();
    report.verify_drained().unwrap();
    assert_eq!(report.served, requests as u64);
    let s = report.models["m"].stats;
    assert_eq!(s.accepted, requests as u64);
    assert_eq!(s.completed, requests as u64);
}

/// Write raw bytes, close our write half, read whatever the server says
/// until it closes. Returns the raw response text ("" if the server just
/// closed).
fn raw_exchange(addr: &str, payload: &[u8]) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(payload).unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut out = String::new();
    let _ = stream.read_to_string(&mut out);
    out
}

#[test]
fn negative_matrix_maps_to_documented_statuses_and_keeps_serving() {
    let arch = mlp();
    let in_len = arch.input_len();
    let eng = engine(&arch, 7);
    let half = vec![0.5f32; in_len];
    let expect = eng.infer_batch(&half, 1).unwrap();
    let server = Server::bind(
        "127.0.0.1:0",
        vec![("m".to_string(), Arc::clone(&eng))],
        server_cfg(1, 0, 4, Duration::from_millis(1)),
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    let cases: &[(&str, &str)] = &[
        // malformed request line
        ("garbage\r\n\r\n", "HTTP/1.1 400 "),
        // truncated request line, then premature close
        ("GET /healthz", "HTTP/1.1 400 "),
        // header line without a colon
        ("GET /healthz HTTP/1.1\r\nno-colon\r\n\r\n", "HTTP/1.1 400 "),
        // body-bearing method without Content-Length
        ("POST /v1/models/m/infer HTTP/1.1\r\n\r\n", "HTTP/1.1 411 "),
        // declared body over the cap (default 1 MiB) — refused up front
        (
            "POST /v1/models/m/infer HTTP/1.1\r\ncontent-length: 99999999\r\n\r\n",
            "HTTP/1.1 413 ",
        ),
        // premature close mid-body
        (
            "POST /v1/models/m/infer HTTP/1.1\r\ncontent-length: 50\r\n\r\nabc",
            "HTTP/1.1 400 ",
        ),
        // unknown route / unknown model key
        ("GET /nope HTTP/1.1\r\n\r\n", "HTTP/1.1 404 "),
        (
            "POST /v1/models/nope/infer HTTP/1.1\r\ncontent-length: 9\r\n\r\n{\"x\":[1]}",
            "HTTP/1.1 404 ",
        ),
        // wrong method on known routes
        ("DELETE /healthz HTTP/1.1\r\n\r\n", "HTTP/1.1 405 "),
        ("GET /v1/models/m/infer HTTP/1.1\r\n\r\n", "HTTP/1.1 405 "),
        ("GET /admin/shutdown HTTP/1.1\r\n\r\n", "HTTP/1.1 405 "),
        // body that is not JSON / not the documented shape
        (
            "POST /v1/models/m/infer HTTP/1.1\r\ncontent-length: 3\r\n\r\nxyz",
            "HTTP/1.1 400 ",
        ),
        (
            "POST /v1/models/m/infer HTTP/1.1\r\ncontent-length: 8\r\n\r\n{\"y\":[]}",
            "HTTP/1.1 400 ",
        ),
    ];
    for (payload, want) in cases {
        let got = raw_exchange(&addr, payload.as_bytes());
        assert!(got.starts_with(want), "payload {payload:?}: expected {want:?}, got {got:?}");
    }

    // Pipelined garbage after a valid request: first answered 200, the
    // garbage 400, then the connection closes.
    let got = raw_exchange(&addr, b"GET /healthz HTTP/1.1\r\n\r\nXYZ\r\n\r\n");
    assert!(got.starts_with("HTTP/1.1 200 "), "{got:?}");
    assert!(got.contains("HTTP/1.1 400 "), "{got:?}");

    // A peer that connects and says nothing, then leaves.
    drop(TcpStream::connect(&addr).unwrap());

    // After the whole matrix the server still serves correct bits.
    let mut client = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();
    let (status, _) = client.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let (status, text) =
        client.request("POST", "/v1/models/m/infer", Some(&infer_body(&half))).unwrap();
    assert_eq!(status, 200, "{text}");
    assert_bit_identical(&text, &expect, "post-matrix request");

    drop(client);
    let report = server.finish().unwrap();
    report.verify_drained().unwrap();
    // Only the one well-formed infer request ever reached the router.
    assert_eq!(report.models["m"].stats.submitted, 1);
}

/// POST `body` until it is accepted, counting 429s along the way; any
/// other status panics. Every 429 must carry a `Retry-After` header
/// derived from the shedding pool's observed drain rate — a whole
/// number of seconds inside the policy clamp `[1, 30]`.
fn submit_until_accepted(client: &mut HttpClient, body: &str) -> (u64, String) {
    let mut sheds = 0u64;
    loop {
        let (status, headers, text) =
            client.request_with_headers("POST", "/v1/models/m/infer", Some(body)).unwrap();
        match status {
            200 => return (sheds, text),
            429 => {
                assert!(text.contains("shed"), "{text}");
                let retry = headers
                    .iter()
                    .find(|(n, _)| n == "retry-after")
                    .map(|(_, v)| v.as_str())
                    .expect("a 429 must carry Retry-After");
                let secs: u64 = retry.parse().expect("Retry-After must be whole seconds");
                assert!((1..=30).contains(&secs), "Retry-After {secs} outside [1, 30]");
                sheds += 1;
                std::thread::sleep(Duration::from_millis(2));
            }
            s => panic!("unexpected HTTP {s}: {text}"),
        }
    }
}

#[test]
fn saturating_burst_sheds_with_429_and_accounting_holds() {
    let arch = mlp();
    let in_len = arch.input_len();
    let requests = 8;
    let data = cgmq::data::Dataset::synth(13, requests);
    let eng = engine(&arch, 7);
    let expect = eng.infer_batch(&data.images, requests).unwrap();
    let c = expect.len() / requests;

    // One worker, in-flight cap 1, max_batch above the cap and a 100ms
    // deadline: whichever request is admitted holds the only slot until
    // its deadline flush, so two submissions overlapping in that window
    // cannot both be admitted first try — one of them MUST see a 429.
    let server = Server::bind(
        "127.0.0.1:0",
        vec![("m".to_string(), Arc::clone(&eng))],
        server_cfg(1, 1, 64, Duration::from_millis(100)),
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let images = Arc::new(data.images);

    // Two overlapping submissions into the single slot. The sleep makes
    // the overlap overwhelmingly likely but the assertion does not depend
    // on which side wins the slot — only that they overlapped.
    let primer = std::thread::spawn({
        let (addr, images) = (addr.clone(), Arc::clone(&images));
        move || {
            let mut client = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();
            submit_until_accepted(&mut client, &infer_body(&images[..in_len]))
        }
    });
    std::thread::sleep(Duration::from_millis(30));
    let mut main_client = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();
    let (main_sheds, text) =
        submit_until_accepted(&mut main_client, &infer_body(&images[in_len..2 * in_len]));
    assert_bit_identical(&text, &expect[c..2 * c], "sample 1");
    let (primer_sheds, text) = primer.join().unwrap();
    assert_bit_identical(&text, &expect[..c], "primer");
    assert!(
        main_sheds + primer_sheds >= 1,
        "two submissions overlapping one in-flight slot must shed at least once"
    );

    // Now complete the remaining samples with 429-retry from two
    // hammering clients.
    let mut handles = Vec::new();
    for t in 0..2 {
        handles.push(std::thread::spawn({
            let (addr, images) = (addr.clone(), Arc::clone(&images));
            move || -> Vec<(usize, String)> {
                let mut client = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();
                let mut out = Vec::new();
                let mut i = 2 + t; // samples 0 and 1 are already served
                while i < requests {
                    let body = infer_body(&images[i * in_len..(i + 1) * in_len]);
                    let (_, text) = submit_until_accepted(&mut client, &body);
                    out.push((i, text));
                    i += 2;
                }
                out
            }
        }));
    }
    let mut done = 2; // primer + main
    for handle in handles {
        for (i, text) in handle.join().unwrap() {
            assert_bit_identical(&text, &expect[i * c..(i + 1) * c], &format!("sample {i}"));
            done += 1;
        }
    }
    assert_eq!(done, requests);

    // The accounting invariant held across the network layer.
    let (status, text) = main_client.request("GET", "/stats", None).unwrap();
    assert_eq!(status, 200, "{text}");
    let stats = json::parse(&text).unwrap();
    let m = stats.get("models").unwrap().get("m").unwrap().clone();
    let submitted = m.get("submitted").unwrap().as_usize().unwrap();
    let accepted = m.get("accepted").unwrap().as_usize().unwrap();
    let shed = m.get("shed").unwrap().as_usize().unwrap();
    assert_eq!(submitted, accepted + shed, "submitted == accepted + shed over HTTP");
    assert_eq!(accepted, requests, "every request eventually admitted");
    assert!(shed >= 1, "the primed burst must have shed at least once");

    drop(main_client);
    let report = server.finish().unwrap();
    report.verify_drained().unwrap();
    let s = report.models["m"].stats;
    assert_eq!(s.accepted, requests as u64);
    assert_eq!(s.completed, requests as u64, "drain lost requests");
    assert!(s.shed >= 1);
}

#[test]
fn graceful_shutdown_drains_accepted_requests() {
    let arch = mlp();
    let in_len = arch.input_len();
    let clients = 4;
    let data = cgmq::data::Dataset::synth(17, clients);
    let eng = engine(&arch, 7);
    let expect = eng.infer_batch(&data.images, clients).unwrap();
    let c = expect.len() / clients;

    // A 150ms deadline and max_batch above the request count: every
    // request sits queued when the shutdown lands, so the drain guarantee
    // is actually exercised.
    let server = Server::bind(
        "127.0.0.1:0",
        vec![("m".to_string(), Arc::clone(&eng))],
        server_cfg(2, 0, 8, Duration::from_millis(150)),
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let images = Arc::new(data.images);

    let mut handles = Vec::new();
    for i in 0..clients {
        handles.push(std::thread::spawn({
            let (addr, images) = (addr.clone(), Arc::clone(&images));
            move || {
                let mut client = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();
                let body = infer_body(&images[i * in_len..(i + 1) * in_len]);
                client.request("POST", "/v1/models/m/infer", Some(&body)).unwrap()
            }
        }));
    }
    // Let the requests reach the queues, then ask for a graceful drain
    // the way an operator would: over HTTP.
    std::thread::sleep(Duration::from_millis(40));
    let mut admin = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();
    let (status, text) = admin.request("POST", "/admin/shutdown", Some("{}")).unwrap();
    assert_eq!(status, 200, "{text}");
    assert!(text.contains("draining"), "{text}");
    drop(admin);

    // run() observes the shutdown request and drains: every in-flight
    // request must still be answered 200 with the right bits.
    let report = server.run().unwrap();
    for (i, handle) in handles.into_iter().enumerate() {
        let (status, text) = handle.join().unwrap();
        assert_eq!(status, 200, "request {i} dropped by shutdown: {text}");
        assert_bit_identical(&text, &expect[i * c..(i + 1) * c], &format!("request {i}"));
    }
    report.verify_drained().unwrap();
    let s = report.models["m"].stats;
    assert_eq!(s.accepted, clients as u64);
    assert_eq!(s.completed, clients as u64, "graceful drain lost a request");
    assert_eq!(report.served, clients as u64);
}

#[test]
fn metrics_stats_and_report_expose_one_bit_exact_truth() {
    use cgmq::bench_harness::parse_prometheus;
    use cgmq::deploy::telemetry::{M_REQUESTS, M_SERVED, STATUS_CODES};

    let arch = mlp();
    let in_len = arch.input_len();
    let requests = 6;
    let data = cgmq::data::Dataset::synth(29, requests);
    let eng = engine(&arch, 7);

    // Same single-slot shape as the saturating test, so at least one 429
    // lands in the status counters.
    let server = Server::bind(
        "127.0.0.1:0",
        vec![("m".to_string(), Arc::clone(&eng))],
        server_cfg(1, 1, 64, Duration::from_millis(100)),
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let images = Arc::new(data.images);

    // The infer response carries the server-assigned trace id, so a
    // client can join its own latency numbers to the server-side trace.
    let body = infer_body(&images[..in_len]);
    let raw = raw_exchange(
        &addr,
        format!(
            "POST /v1/models/m/infer HTTP/1.1\r\ncontent-length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    );
    assert!(raw.starts_with("HTTP/1.1 200 "), "{raw:?}");
    assert!(raw.contains("\r\nx-request-id: "), "{raw:?}");

    // Two submissions overlapping the single in-flight slot force at
    // least one shed; then drain the remaining samples, plus one 400 and
    // one 404 so the non-200 rows are exercised too.
    let primer = std::thread::spawn({
        let (addr, images) = (addr.clone(), Arc::clone(&images));
        move || {
            let mut client = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();
            submit_until_accepted(&mut client, &infer_body(&images[in_len..2 * in_len])).0
        }
    });
    std::thread::sleep(Duration::from_millis(30));
    let mut client = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();
    let mut sheds =
        submit_until_accepted(&mut client, &infer_body(&images[2 * in_len..3 * in_len])).0;
    sheds += primer.join().unwrap();
    for i in 3..requests {
        let body = infer_body(&images[i * in_len..(i + 1) * in_len]);
        sheds += submit_until_accepted(&mut client, &body).0;
    }
    assert!(sheds >= 1, "the overlapping submissions must shed at least once");
    let (status, _) = client.request("POST", "/v1/models/m/infer", Some("{\"x\":[1]}")).unwrap();
    assert_eq!(status, 400);
    let (status, _) = client
        .request("POST", "/v1/models/nope/infer", Some(&infer_body(&images[..in_len])))
        .unwrap();
    assert_eq!(status, 404);

    // Every infer is answered (submit_until_accepted returns on its 200),
    // so the infer-route counters are quiescent: the scrape, the JSON
    // stats, and the post-drain report must agree bit-exactly.
    let (status, metrics_text) = client.request("GET", "/metrics", None).unwrap();
    assert_eq!(status, 200, "{metrics_text}");
    let series = parse_prometheus(&metrics_text);
    let (status, stats_text) = client.request("GET", "/stats", None).unwrap();
    assert_eq!(status, 200, "{stats_text}");
    let stats = json::parse(&stats_text).unwrap();
    let stat_statuses =
        stats.get("models").unwrap().get("m").unwrap().get("statuses").unwrap().clone();

    drop(client);
    let report = server.finish().unwrap();
    report.verify_drained().unwrap();
    let rep_m = &report.telemetry.models["m"];

    for &code in STATUS_CODES.iter() {
        let key = format!("{M_REQUESTS}{{model=\"m\",status=\"{code}\"}}");
        let prom = series[&key] as u64;
        let stat =
            stat_statuses.get(code.to_string().as_str()).unwrap().as_usize().unwrap() as u64;
        assert_eq!(prom, stat, "/metrics vs /stats drifted for status {code}");
        assert_eq!(prom, rep_m.status_count(code), "/metrics vs report drifted for {code}");
    }
    assert_eq!(rep_m.status_count(200), requests as u64);
    assert_eq!(rep_m.status_count(429), sheds, "every client-observed shed is counted");
    assert_eq!(rep_m.status_count(400), 1);
    assert_eq!(rep_m.status_count(404), 0, "unknown keys have no per-model slot");

    // `served` agrees across all three surfaces as well.
    assert_eq!(series[M_SERVED] as u64, requests as u64);
    assert_eq!(stats.get("served").unwrap().as_usize().unwrap(), requests);
    assert_eq!(report.served, requests as u64);
}

#[test]
fn windowed_series_decay_to_zero_while_cumulative_counters_persist() {
    use cgmq::bench_harness::parse_prometheus;
    use cgmq::deploy::telemetry::{
        M_ARRIVAL_RATE_WINDOW, M_MARGIN_WINDOW, M_REQUESTS, M_REQUESTS_WINDOW,
        M_REQUEST_WINDOW_SECONDS, STATUS_CODES,
    };
    use cgmq::deploy::ManualClock;

    let arch = mlp();
    let in_len = arch.input_len();
    let requests = 5;
    let data = cgmq::data::Dataset::synth(31, requests);
    let eng = engine(&arch, 7);

    // Inject a manual telemetry clock: all traffic lands in window
    // epoch 0, and "idle past the window" is an explicit `advance` —
    // no wall-clock sleeps, fully deterministic decay.
    let clock = Arc::new(ManualClock::default());
    let server = Server::bind_with_clock(
        "127.0.0.1:0",
        vec![("m".to_string(), Arc::clone(&eng))],
        server_cfg(2, 0, 4, Duration::from_millis(1)),
        clock.clone(),
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let mut client = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();
    for i in 0..requests {
        let body = infer_body(&data.images[i * in_len..(i + 1) * in_len]);
        let (status, text) = client.request("POST", "/v1/models/m/infer", Some(&body)).unwrap();
        assert_eq!(status, 200, "request {i}: {text}");
    }

    // While the window is live, the windowed series carry the traffic.
    let n = requests as f64;
    let (status, text) = client.request("GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    let live = parse_prometheus(&text);
    assert_eq!(live[&format!("{M_REQUESTS}{{model=\"m\",status=\"200\"}}")], n);
    assert_eq!(live[&format!("{M_REQUESTS_WINDOW}{{model=\"m\",status=\"200\"}}")], n);
    assert!(live[&format!("{M_ARRIVAL_RATE_WINDOW}{{model=\"m\"}}")] > 0.0);
    assert_eq!(live[&format!("{M_MARGIN_WINDOW}_count{{model=\"m\"}}")], n);
    assert_eq!(live[&format!("{M_REQUEST_WINDOW_SECONDS}_count{{model=\"m\"}}")], n);

    // Idle past the whole trailing window: every windowed series decays
    // to exactly zero while the cumulative counters keep the history.
    clock.advance(Duration::from_secs(60));
    let (status, text) = client.request("GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    let after = parse_prometheus(&text);
    for &code in STATUS_CODES.iter() {
        assert_eq!(
            after[&format!("{M_REQUESTS_WINDOW}{{model=\"m\",status=\"{code}\"}}")],
            0.0,
            "windowed status {code} must decay to zero"
        );
    }
    assert_eq!(after[&format!("{M_ARRIVAL_RATE_WINDOW}{{model=\"m\"}}")], 0.0);
    assert_eq!(after[&format!("{M_MARGIN_WINDOW}_count{{model=\"m\"}}")], 0.0);
    assert_eq!(after[&format!("{M_REQUEST_WINDOW_SECONDS}_count{{model=\"m\"}}")], 0.0);
    assert_eq!(
        after[&format!("{M_REQUESTS}{{model=\"m\",status=\"200\"}}")],
        n,
        "cumulative counters must survive the window"
    );

    // /stats agrees: an empty window section (with the null quantile
    // sentinel, not a fake zero bound) beside retained cumulative rows.
    let (status, text) = client.request("GET", "/stats", None).unwrap();
    assert_eq!(status, 200);
    let stats = json::parse(&text).unwrap();
    let m = stats.get("models").unwrap().get("m").unwrap().clone();
    assert_eq!(m.get("statuses").unwrap().get("200").unwrap().as_usize().unwrap(), requests);
    let w = m.get("window").unwrap();
    assert_eq!(w.get("arrivals").unwrap().as_usize().unwrap(), 0);
    assert_eq!(w.get("statuses").unwrap().get("200").unwrap().as_usize().unwrap(), 0);
    assert_eq!(w.get("total").unwrap().get("count").unwrap().as_usize().unwrap(), 0);
    assert!(matches!(w.get("total").unwrap().opt("p99_le"), Some(Json::Null)));
    assert_eq!(w.get("margin").unwrap().get("count").unwrap().as_usize().unwrap(), 0);
    assert!(matches!(w.get("margin").unwrap().opt("p10_le"), Some(Json::Null)));

    // An idle window is healthy by definition: /livez answers 200.
    let (status, text) = client.request("GET", "/livez", None).unwrap();
    assert_eq!(status, 200, "{text}");
    assert!(text.contains("\"live\""), "{text}");

    drop(client);
    server.finish().unwrap().verify_drained().unwrap();
}

#[test]
fn stats_and_metrics_include_zero_series_for_an_idle_model() {
    use cgmq::bench_harness::parse_prometheus;
    use cgmq::deploy::telemetry::{
        M_ARRIVAL_RATE_WINDOW, M_REQUESTS, M_REQUESTS_WINDOW, STATUS_CODES,
    };

    let arch = mlp();
    let in_len = arch.input_len();
    let server = Server::bind(
        "127.0.0.1:0",
        vec![("m".to_string(), engine(&arch, 7)), ("z".to_string(), engine(&arch, 9))],
        server_cfg(1, 0, 4, Duration::from_millis(1)),
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let mut client = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();

    // Traffic only to "m"; "z" never sees a request.
    let half = vec![0.5f32; in_len];
    let (status, _) =
        client.request("POST", "/v1/models/m/infer", Some(&infer_body(&half))).unwrap();
    assert_eq!(status, 200);

    // /stats: the idle model carries the full zeros-included shape —
    // every status over the whole taxonomy, the window section, the
    // gauges — symmetric with what /metrics emits for it.
    let (status, text) = client.request("GET", "/stats", None).unwrap();
    assert_eq!(status, 200);
    let stats = json::parse(&text).unwrap();
    let z = stats.get("models").unwrap().get("z").unwrap().clone();
    let zw = z.get("window").unwrap();
    for &code in STATUS_CODES.iter() {
        let key = code.to_string();
        assert_eq!(
            z.get("statuses").unwrap().get(&key).unwrap().as_usize().unwrap(),
            0,
            "idle model cumulative status {code}"
        );
        assert_eq!(
            zw.get("statuses").unwrap().get(&key).unwrap().as_usize().unwrap(),
            0,
            "idle model windowed status {code}"
        );
    }
    assert_eq!(zw.get("arrivals").unwrap().as_usize().unwrap(), 0);
    assert!(
        matches!(zw.get("margin").unwrap().opt("p10_le"), Some(Json::Null)),
        "an empty histogram must surface the null sentinel, never a (0, 0) bound"
    );
    assert_eq!(z.get("in_flight").unwrap().as_usize().unwrap(), 0);
    assert_eq!(z.get("queue_depth").unwrap().as_arr().unwrap().len(), 1, "one shard per worker");

    // /metrics honors the same contract: the idle model's series exist
    // at zero rather than being omitted.
    let (status, text) = client.request("GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    let series = parse_prometheus(&text);
    assert_eq!(series[&format!("{M_REQUESTS}{{model=\"z\",status=\"200\"}}")], 0.0);
    assert_eq!(series[&format!("{M_REQUESTS_WINDOW}{{model=\"z\",status=\"200\"}}")], 0.0);
    assert_eq!(series[&format!("{M_ARRIVAL_RATE_WINDOW}{{model=\"z\"}}")], 0.0);

    drop(client);
    server.finish().unwrap().verify_drained().unwrap();
}

#[test]
fn livez_degrades_on_windowed_shed_rate_and_p99_threshold() {
    let arch = mlp();
    let in_len = arch.input_len();
    let eng = engine(&arch, 7);
    let data = cgmq::data::Dataset::synth(37, 4);

    // Shed-rate trip: the single-slot shape from the saturating test
    // plus a hair-trigger threshold, so one 429 in the trailing window
    // is enough to degrade.
    let mut cfg = server_cfg(1, 1, 64, Duration::from_millis(100));
    cfg.livez_shed_rate = 0.01;
    let server =
        Server::bind("127.0.0.1:0", vec![("m".to_string(), Arc::clone(&eng))], cfg).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();

    // Idle: healthy.
    let (status, text) = client.request("GET", "/livez", None).unwrap();
    assert_eq!(status, 200, "{text}");
    assert!(text.contains("\"live\""), "{text}");

    // Two submissions overlapping the single in-flight slot force at
    // least one shed into the live window.
    let primer = std::thread::spawn({
        let (addr, images) = (addr.clone(), data.images.clone());
        move || {
            let mut client = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();
            submit_until_accepted(&mut client, &infer_body(&images[..in_len])).0
        }
    });
    std::thread::sleep(Duration::from_millis(30));
    let (sheds, _) =
        submit_until_accepted(&mut client, &infer_body(&data.images[in_len..2 * in_len]));
    let primer_sheds = primer.join().unwrap();
    assert!(sheds + primer_sheds >= 1, "overlapping submissions must shed");

    let (status, text) = client.request("GET", "/livez", None).unwrap();
    assert_eq!(status, 503, "{text}");
    assert!(text.contains("degraded") && text.contains("windowed shed rate"), "{text}");

    drop(client);
    server.finish().unwrap().verify_drained().unwrap();

    // p99 trip: a 1µs ceiling no real request can meet, with the shed
    // check disabled (threshold above any possible rate).
    let mut cfg = server_cfg(1, 0, 4, Duration::from_millis(1));
    cfg.livez_shed_rate = 2.0;
    cfg.livez_p99_us = 1;
    let server =
        Server::bind("127.0.0.1:0", vec![("m".to_string(), Arc::clone(&eng))], cfg).unwrap();
    let addr = server.local_addr().to_string();
    let mut client = HttpClient::connect(&addr, Duration::from_secs(5)).unwrap();
    let (status, _) = client
        .request("POST", "/v1/models/m/infer", Some(&infer_body(&data.images[..in_len])))
        .unwrap();
    assert_eq!(status, 200);
    let (status, text) = client.request("GET", "/livez", None).unwrap();
    assert_eq!(status, 503, "{text}");
    assert!(text.contains("degraded") && text.contains("windowed p99 bound"), "{text}");

    drop(client);
    server.finish().unwrap().verify_drained().unwrap();
}

#[test]
fn watch_frame_renders_idle_sentinels_and_known_numbers_exactly() {
    use cgmq::bench_harness::{render_watch_table, watch_once};

    // End to end against an idle server: the frame is fully
    // deterministic, with the em-dash sentinel for every quantile of an
    // empty windowed histogram.
    let arch = mlp();
    let server = Server::bind(
        "127.0.0.1:0",
        vec![("m".to_string(), engine(&arch, 7))],
        server_cfg(1, 0, 4, Duration::from_millis(1)),
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let frame = watch_once(&addr).unwrap();
    assert_eq!(
        frame,
        "window 10s · served 0\n\
         | model | req/s | shed % | queue | in-flight | p50 ms | p99 ms | margin p10 |\n\
         |-------|-------|--------|-------|-----------|--------|--------|------------|\n\
         | m | 0.0 | 0.0 | 0 | 0 | — | — | — |\n"
    );
    server.finish().unwrap().verify_drained().unwrap();

    // A fixture body with known numbers pins the renderer's unit
    // conversions (µs -> ms, milli-logits -> logits) and the shed %.
    let fixture = r#"{
        "served": 512,
        "models": {
            "m": {
                "in_flight": 2,
                "queue_depth": [1, 2],
                "window": {
                    "window_us": 10000000,
                    "arrivals": 35,
                    "arrival_rate_per_sec": 3.5,
                    "shed_rate": 0.25,
                    "total": {"count": 30, "sum": 60000, "max": 16000,
                              "p50_le": 2048, "p99_le": 16384},
                    "margin": {"count": 30, "sum": 30000, "max": 4096,
                               "p10_le": 512}
                }
            }
        }
    }"#;
    let table = render_watch_table(&json::parse(fixture).unwrap()).unwrap();
    assert_eq!(
        table,
        "window 10s · served 512\n\
         | model | req/s | shed % | queue | in-flight | p50 ms | p99 ms | margin p10 |\n\
         |-------|-------|--------|-------|-----------|--------|--------|------------|\n\
         | m | 3.5 | 25.0 | 3 | 2 | 2.05 | 16.38 | 0.512 |\n"
    );
}
