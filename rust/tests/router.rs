//! Multi-model router tests: the overload accounting invariant
//! (`submitted == accepted + shed`, `completed == accepted` after drain —
//! no request is ever lost), per-model bit-identity with the
//! single-threaded reference engine across a mid-traffic hot swap, and
//! clean errors for unknown model keys.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cgmq::bench_harness::{synthetic_deploy_state, DEPLOY_LEVELS};
use cgmq::deploy::{BatchConfig, Engine, PackedModel, PoolConfig, Router, Submission};
use cgmq::model::{mlp, ArchSpec};

fn engine(arch: &ArchSpec, seed: u64) -> Arc<Engine> {
    let s = synthetic_deploy_state(arch, &DEPLOY_LEVELS, seed);
    let model = PackedModel::from_state(arch, &s.params, &s.betas_w, &s.betas_a, &s.gates).unwrap();
    Arc::new(Engine::new(model).unwrap())
}

/// Single-threaded reference logits of `eng` over the whole request set.
fn reference(eng: &Engine, images: &[f32], n: usize) -> Vec<f32> {
    eng.infer_batch(images, n).unwrap()
}

#[test]
fn unknown_model_key_is_a_clean_error() {
    let arch = mlp();
    let mut router = Router::new(PoolConfig { workers: 1, ..PoolConfig::default() });
    router.add_model("tight", engine(&arch, 7)).unwrap();

    let x = vec![0.0f32; arch.input_len()];
    for err in [
        format!("{:#}", router.try_submit("loose", x.clone()).unwrap_err()),
        format!("{:#}", router.try_completions("loose").unwrap_err()),
        format!("{:#}", router.swap_model("loose", engine(&arch, 8)).unwrap_err()),
        format!("{:#}", router.stats("loose").unwrap_err()),
        format!("{:#}", router.remove_model("loose").unwrap_err()),
    ] {
        assert!(err.contains("no model behind key 'loose'"), "{err}");
        assert!(err.contains("tight"), "error should list the loaded keys: {err}");
    }

    // Duplicate and empty keys are rejected up front.
    let err = format!("{:#}", router.add_model("tight", engine(&arch, 8)).unwrap_err());
    assert!(err.contains("already loaded"), "{err}");
    assert!(router.add_model("", engine(&arch, 8)).is_err());

    // A removed key really is gone, and its drain loses nothing.
    let report = router.remove_model("tight").unwrap();
    assert!(report.completions.is_empty());
    assert!(report.stats.consistent(), "{:?}", report.stats);
    assert_eq!(router.keys(), Vec::<&str>::new());
    assert!(router.try_submit("tight", vec![0.0; arch.input_len()]).is_err());
}

#[test]
fn admission_bound_is_exact_when_no_flush_can_occur() {
    // With a deadline no request can reach and max_batch far above the
    // cap, workers can never flush mid-test — so a burst must admit
    // exactly workers * queue_cap requests and shed every other one,
    // deterministically. Shutdown then drains the admitted ones.
    let arch = mlp();
    let in_len = arch.input_len();
    let requests = 50;
    let data = cgmq::data::Dataset::synth(23, requests);
    let eng = engine(&arch, 7);
    let expect = reference(&eng, &data.images, requests);
    let c = expect.len() / requests;

    let (workers, cap) = (2, 2);
    let mut router = Router::new(PoolConfig {
        workers,
        batch: BatchConfig { max_batch: 64, max_delay: Duration::from_secs(3600) },
        queue_cap: cap,
    });
    router.add_model("m", eng).unwrap();
    for i in 0..requests {
        let x = data.images[i * in_len..(i + 1) * in_len].to_vec();
        match router.try_submit("m", x).unwrap() {
            Submission::Accepted { id, .. } => {
                assert!(i < workers * cap, "request {i} admitted past the bound");
                assert_eq!(id as usize, i);
            }
            Submission::Shed { queue_cap } => {
                assert!(i >= workers * cap, "request {i} shed below the bound");
                assert_eq!(queue_cap, cap);
            }
        }
    }
    let stats = router.stats("m").unwrap();
    assert_eq!(stats.accepted, (workers * cap) as u64);
    assert_eq!(stats.shed, (requests - workers * cap) as u64);
    assert!(stats.consistent(), "{stats:?}");

    let reports = router.shutdown().unwrap();
    let report = &reports["m"];
    assert_eq!(report.stats.completed, (workers * cap) as u64, "drain loses nothing");
    for comp in &report.completions {
        // The first workers * cap submissions were admitted in order.
        let sample = comp.id as usize;
        let row = &expect[sample * c..(sample + 1) * c];
        assert!(comp.logits.iter().zip(row).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}

#[test]
fn overload_sheds_but_never_loses_a_request() {
    let arch = mlp();
    let in_len = arch.input_len();
    let requests = 100;
    let data = cgmq::data::Dataset::synth(31, requests);
    let eng = engine(&arch, 7);
    let expect = reference(&eng, &data.images, requests);
    let c = expect.len() / requests;

    // Tiny per-shard cap, max_batch far above it: only deadline flushes
    // can drain a shard, so a fast burst must hit the admission bound.
    let mut router = Router::new(PoolConfig {
        workers: 2,
        batch: BatchConfig { max_batch: 64, max_delay: Duration::from_millis(2) },
        queue_cap: 2,
    });
    router.add_model("m", eng).unwrap();

    // Phase 1 — burst every request without draining: at most
    // workers * queue_cap can be admitted before the first deadline
    // flush, the rest are shed (typed, not an error, nothing enqueued).
    let mut accepted_sample: Vec<usize> = Vec::new(); // id -> sample index
    let mut pending: Vec<usize> = Vec::new();
    for i in 0..requests {
        let x = data.images[i * in_len..(i + 1) * in_len].to_vec();
        match router.try_submit("m", x).unwrap() {
            Submission::Accepted { id, .. } => {
                assert_eq!(id as usize, accepted_sample.len(), "per-key ids are contiguous");
                accepted_sample.push(i);
            }
            Submission::Shed { queue_cap } => {
                assert_eq!(queue_cap, 2);
                pending.push(i);
            }
        }
    }
    // On any realistic run the tight burst far outpaces the 2ms deadline
    // flushes and sheds most requests; a preempted CI machine could in
    // principle flush between submissions, so only the accounting — not a
    // minimum shed count — is asserted here (shed *semantics* are pinned
    // deterministically by admission_bound_is_exact_when_no_flush_can_occur).
    let burst = router.stats("m").unwrap();
    assert!(burst.consistent(), "{burst:?}");
    assert_eq!(burst.submitted, requests as u64);
    assert_eq!(burst.accepted + burst.shed, requests as u64);

    // Phase 2 — retry the shed requests with backoff while draining;
    // every one must eventually be admitted (shed is refusal, not loss of
    // anything accepted).
    let mut completions = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(60);
    while let Some(&i) = pending.last() {
        assert!(Instant::now() < deadline, "drain timed out with {} pending", pending.len());
        let x = data.images[i * in_len..(i + 1) * in_len].to_vec();
        match router.try_submit("m", x).unwrap() {
            Submission::Accepted { id, .. } => {
                assert_eq!(id as usize, accepted_sample.len());
                accepted_sample.push(i);
                pending.pop();
            }
            Submission::Shed { .. } => std::thread::sleep(Duration::from_micros(500)),
        }
        completions.extend(router.try_completions("m").unwrap());
    }
    let reports = router.shutdown().unwrap();
    let report = &reports["m"];
    completions.extend(report.completions.iter().cloned());
    let stats = report.stats;

    // The accounting invariant under overload: every routed request was
    // either admitted or shed, and every admitted request completed.
    assert!(stats.consistent(), "{stats:?}");
    assert_eq!(stats.submitted, stats.accepted + stats.shed);
    assert_eq!(stats.accepted, requests as u64, "every sample eventually admitted");
    assert_eq!(stats.completed, stats.accepted, "no admitted request lost");
    assert_eq!(completions.len(), requests);

    // Exactly-once, bit-identical to the single-threaded reference.
    let mut seen = vec![false; requests];
    for comp in &completions {
        let id = comp.id as usize;
        assert!(!seen[id], "request {id} completed twice");
        seen[id] = true;
        let sample = accepted_sample[id];
        let row = &expect[sample * c..(sample + 1) * c];
        for (j, (&a, &b)) in comp.logits.iter().zip(row).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "id {id} sample {sample} logit {j}");
        }
    }
    assert!(seen.iter().all(|&s| s));
}

#[test]
fn hot_swap_mid_traffic_keeps_per_model_bit_identity() {
    let arch = mlp();
    let in_len = arch.input_len();
    let requests = 60;
    let data = cgmq::data::Dataset::synth(37, requests);
    let eng_a = engine(&arch, 7);
    let eng_b = engine(&arch, 8);
    let ref_a = reference(&eng_a, &data.images, requests);
    let ref_b = reference(&eng_b, &data.images, requests);
    let c = ref_a.len() / requests;
    assert!(
        ref_a.iter().zip(&ref_b).any(|(a, b)| a.to_bits() != b.to_bits()),
        "the two variants must be distinguishable for this test to mean anything"
    );

    // Unbounded queues: with no shedding, id == sample index, and the swap
    // point cleanly partitions ids between the two engine versions.
    let mut router = Router::new(PoolConfig {
        workers: 2,
        batch: BatchConfig { max_batch: 8, max_delay: Duration::from_millis(1) },
        queue_cap: 0,
    });
    router.add_model("m", Arc::clone(&eng_a)).unwrap();

    let mut collected = Vec::new();
    let swap_at = requests / 2;
    for i in 0..requests {
        if i == swap_at {
            // Spawns + preloads the replacement, swaps it behind the key,
            // then drains the old pool; in-flight completions carry over.
            router.swap_model("m", Arc::clone(&eng_b)).unwrap();
        }
        let x = data.images[i * in_len..(i + 1) * in_len].to_vec();
        match router.try_submit("m", x).unwrap() {
            Submission::Accepted { id, .. } => assert_eq!(id as usize, i),
            Submission::Shed { .. } => panic!("unbounded queue must never shed"),
        }
        collected.extend(router.try_completions("m").unwrap());
    }
    let reports = router.shutdown().unwrap();
    let report = &reports["m"];
    collected.extend(report.completions.iter().cloned());
    let stats = report.stats;

    assert!(stats.consistent(), "{stats:?}");
    assert_eq!(stats.shed, 0);
    assert_eq!(stats.swaps, 1);
    assert_eq!(stats.accepted, requests as u64);
    assert_eq!(stats.completed, requests as u64, "the swap dropped requests");
    assert_eq!(collected.len(), requests);

    // Per-model bit-identity: ids accepted before the swap were served by
    // engine A (the swap fully drains the old pool before B takes the
    // key), ids after by engine B — each must match its version's
    // single-threaded reference exactly.
    let mut seen = vec![false; requests];
    for comp in &collected {
        let id = comp.id as usize;
        assert!(!seen[id], "request {id} completed twice");
        seen[id] = true;
        let expect = if id < swap_at { &ref_a } else { &ref_b };
        let row = &expect[id * c..(id + 1) * c];
        for (j, (&a, &b)) in comp.logits.iter().zip(row).enumerate() {
            let version = if id < swap_at { "A" } else { "B" };
            assert_eq!(a.to_bits(), b.to_bits(), "id {id} (engine {version}) logit {j}");
        }
    }
    assert!(seen.iter().all(|&s| s), "every request completed exactly once");
}

#[test]
fn routes_by_key_and_keeps_models_isolated() {
    let arch = mlp();
    let in_len = arch.input_len();
    let requests = 40;
    let data = cgmq::data::Dataset::synth(41, requests);
    let eng_a = engine(&arch, 7);
    let eng_b = engine(&arch, 8);
    let ref_a = reference(&eng_a, &data.images, requests);
    let ref_b = reference(&eng_b, &data.images, requests);
    let c = ref_a.len() / requests;

    let mut router = Router::new(PoolConfig {
        workers: 2,
        batch: BatchConfig { max_batch: 4, max_delay: Duration::from_millis(1) },
        queue_cap: 0,
    });
    router.add_model("a", eng_a).unwrap();
    router.add_model("b", eng_b).unwrap();
    assert_eq!(router.keys(), vec!["a", "b"]);

    // Alternate keys; per key, ids are contiguous so id maps back to the
    // sample index it was fed.
    let mut samples: std::collections::BTreeMap<&str, Vec<usize>> =
        [("a", Vec::new()), ("b", Vec::new())].into();
    for i in 0..requests {
        let key = if i % 2 == 0 { "a" } else { "b" };
        let x = data.images[i * in_len..(i + 1) * in_len].to_vec();
        let Submission::Accepted { id, .. } = router.try_submit(key, x).unwrap() else {
            panic!("unbounded queue must never shed");
        };
        let v = samples.get_mut(key).unwrap();
        assert_eq!(id as usize, v.len());
        v.push(i);
    }
    let reports = router.shutdown().unwrap();
    for (key, expect) in [("a", &ref_a), ("b", &ref_b)] {
        let report = &reports[key];
        assert_eq!(report.stats.completed, (requests / 2) as u64);
        assert!(report.stats.consistent(), "{key}: {:?}", report.stats);
        for comp in &report.completions {
            let sample = samples[key][comp.id as usize];
            let row = &expect[sample * c..(sample + 1) * c];
            assert!(
                comp.logits.iter().zip(row).all(|(a, b)| a.to_bits() == b.to_bits()),
                "model '{key}' id {} drifted from its own reference",
                comp.id
            );
        }
    }
}
